"""Render EXPERIMENTS.md tables from the dry-run / roofline JSON records.

    PYTHONPATH=src python experiments/render_tables.py [--which dryrun|roofline|all]
"""
import argparse
import glob
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))


def dryrun_table() -> str:
    rows = []
    for f in sorted(glob.glob(os.path.join(HERE, "dryrun", "*.json"))):
        rows.append(json.load(open(f)))
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    out = ["| arch | shape | mesh | compile(s) | HLO GFLOPs/dev | coll GB/dev "
           "| temp GB/dev | args GB/dev |",
           "|---|---|---|---:|---:|---:|---:|---:|"]
    for r in rows:
        temp = r.get("temp_size_in_bytes", 0) / 1e9
        args = r.get("argument_size_in_bytes", 0) / 1e9
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compile_seconds']:.0f} | {r['flops']/1e9:.1f} "
            f"| {r['collective_bytes']/1e9:.2f} | {temp:.1f} | {args:.2f} |")
    return "\n".join(out)


def roofline_table(subdir: str = "roofline") -> str:
    rows = []
    for f in sorted(glob.glob(os.path.join(HERE, subdir, "*.json"))):
        rows.append(json.load(open(f)))
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = ["| arch | shape | t_compute | t_memory | t_collective | dominant "
           "| useful | roofline frac |",
           "|---|---|---:|---:|---:|---|---:|---:|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {r['t_compute_s']*1e3:.2f} ms | {r['t_memory_s']*1e3:.2f} ms "
            f"| {r['t_collective_s']*1e3:.2f} ms | {r['dominant']} "
            f"| {r['useful_flop_ratio']:.3f} | {r['roofline_fraction']:.4f} |")
    return "\n".join(out)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--which", default="all")
    a = ap.parse_args()
    if a.which in ("dryrun", "all"):
        print("## Dry-run\n")
        print(dryrun_table())
    if a.which in ("roofline", "all"):
        print("\n## Roofline (optimized)\n")
        print(roofline_table("roofline"))
        print("\n## Roofline (baseline)\n")
        print(roofline_table("roofline_baseline"))
