"""Design-space exploration study (see EXPERIMENTS.md).

Sweeps a gap9-like accelerator family — vector lanes x L1 capacity x
M->L1 DMA bandwidth, 64 generated designs — scores every point on the
paper's Table-2 int8 GEMM grid *and* on qwen2-1.5b (smoke) decode
throughput at batch 8, takes the Pareto frontier over (tokens/s, SRAM,
area proxy), and then asks the serving simulator the deployment question
the frontier alone cannot answer: of the efficient designs, which is the
*cheapest* (lowest area proxy) that actually serves a fixed request
demand under a p99 <= 0.35 s end-to-end latency SLO?

The demand is fixed on purpose: the report-default traffic loads every
design at 0.6x *its own* peak, so a faster design is also asked to serve
more — the right question for capacity planning, the wrong one for
picking silicon to meet a known demand.  Here every design faces the
same Poisson stream (4 req/s, prompt 32, decode 16 — 192 tok/s of
demand) and the SLO separates the designs that ride it from the ones
queueing theory eats.

Prints the markdown section; EXPERIMENTS.md records the committed output.

  PYTHONPATH=src python experiments/design_space_study.py
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

SLO_P99_S = 0.35
BATCH = 8
DEMAND_RPS = 4.0


def run() -> list[str]:
    from repro.configs import get_config
    from repro.design import get_space, pareto, rerank_by_slo, score_designs
    from repro.simulate.traffic import PoissonTraffic

    # the named gap9-wide space: a gap9-like base with a 64-entry vector
    # register file (the stock 32 leaves no register-feasible micro-kernel
    # above 16 lanes), swept over the three axes that trade area for
    # decode latency.
    space = get_space("gap9-wide")
    cfg = get_config("qwen2-1.5b", smoke=True)
    points = list(space.points())
    scores = score_designs(points, cfg=cfg, batch=BATCH)
    front = pareto(scores, workload=f"table2+{cfg.name} decode@b{BATCH}")

    lines = [
        f"- space: `gap9-wide` — gap9-like template (64-entry register "
        f"file), lanes x L1 x DMA bandwidth = 4x4x4 = "
        f"{len(space)} generated designs "
        f"(`gen/*`), scored on the Table-2 int8 grid + `{cfg.name}` "
        f"decode at batch {BATCH}",
        f"- frontier over (tokens/s, SRAM bytes, area proxy): "
        f"**{len(front.frontier)} designs**, {len(front.dominated)} "
        f"dominated (each with a machine-readable dominance record), "
        f"{len(front.infeasible)} memory-infeasible",
        "",
        "| frontier design | lanes | L1 KiB | DMA MB/s | tok/s | area |",
        "|---|---|---|---|---|---|",
    ]
    for s in front.frontier[:8]:
        p = s.params
        lines.append(
            f"| `{s.name}` | {p['lanes']} | {p['l1_bytes'] // 1024} "
            f"| {p['dma_bw'] / 1e6:.1f} | {s.throughput:.1f} "
            f"| {s.area_proxy:.0f} |")
    if len(front.frontier) > 8:
        lines.append(f"| … {len(front.frontier) - 8} more … | | | | | |")

    traffic = PoissonTraffic(rate=DEMAND_RPS, prompt_len=32, decode_len=16)
    ranked = rerank_by_slo(front, points, cfg,
                           slo={"p99_latency_s": SLO_P99_S}, batch=BATCH,
                           requests=200, traffic=traffic)
    attaining = [r for r in ranked if r["attained"]]
    lines += [
        "",
        f"- SLO re-rank at a fixed demand of {DEMAND_RPS:g} req/s "
        f"(Poisson, prompt 32, decode 16; 200 simulated requests, "
        f"p99 <= {SLO_P99_S:g} s): {len(attaining)}/{len(ranked)} "
        f"frontier designs attain",
    ]
    if attaining:
        cheapest = min(attaining, key=lambda r: (r["area_proxy"],
                                                 r["design"]))
        p = cheapest["params"]
        lines += [
            f"- cheapest attaining design: **`{cheapest['design']}`** "
            f"(lanes {p['lanes']}, L1 {p['l1_bytes'] // 1024} KiB, DMA "
            f"{p['dma_bw'] / 1e6:.1f} MB/s) — area proxy "
            f"{cheapest['area_proxy']:.0f}, simulated goodput "
            f"{cheapest['goodput_tps']:.1f} tok/s at p99 "
            f"{cheapest['p99_latency_s'] * 1e3:.0f} ms",
            f"- decode is DMA-bound in this family: above 16 lanes the "
            f"step time barely moves with the MAC array, so the SLO is "
            f"bought with M->L1 bandwidth, not compute — exactly the "
            f"paper's memory-hierarchy story replayed at design time",
        ]
    else:
        lines.append("- no frontier design attains (widen the space or "
                     "relax the SLO)")
    lines += [
        "",
        f"- reproduce: `PYTHONPATH=src python "
        f"experiments/design_space_study.py`; the CLI equivalent of the "
        f"pipeline: `python -m repro.design frontier --space gap9-wide "
        f"--arch qwen2-1.5b --smoke --batch {BATCH} --slo-p99 "
        f"{SLO_P99_S:g} --rps {DEMAND_RPS:g}`",
    ]
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
