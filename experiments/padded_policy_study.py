"""Batched `padded`-policy Table-2 study (see EXPERIMENTS.md).

The paper's analytic accounting charges partial (edge) tiles their exact
byte ratios; a real blocked implementation pays full-tile cost on edges.
The simulator exposes both as policies ("analytic" vs "padded"), and the
bulk sweep makes the sensitivity cheap to chart across the whole
MobileNetV1 workload: one `repro.gemm.sweep` call crosses all 19 Table-2
layers x 3 variants x both policies (114 planned grid points).

Prints the per-layer sensitivity table as markdown; EXPERIMENTS.md records
the committed output.

  PYTHONPATH=src python experiments/padded_policy_study.py
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import gemm
from repro.core.mobilenet import TABLE2
from repro.core.variants import Variant


def run() -> list[str]:
    probs = [row.problem for row in TABLE2]
    res = gemm.sweep(probs, backends=["analytic-gap8"],
                     variants=list(Variant),
                     policies=["analytic", "padded"], cache=False)

    lines = [
        "| layer | variant | analytic mk | padded mk | analytic s "
        "| padded s | overhead |",
        "|---|---|---|---|---|---|---|",
    ]
    worst = (0.0, None)
    flips = 0
    tot = {"analytic": 0.0, "padded": 0.0}
    for row in TABLE2:
        for v in Variant:
            a = res.filter(variant=v.value, policy="analytic")
            p = res.filter(variant=v.value, policy="padded")
            ra = next(r for r in a if r.problem.m == row.m
                      and r.problem.n == row.n and r.problem.k == row.k)
            rp = next(r for r in p if r.problem.m == row.m
                      and r.problem.n == row.n and r.problem.k == row.k)
            over = rp.seconds / ra.seconds - 1.0
            tot["analytic"] += ra.seconds
            tot["padded"] += rp.seconds
            mka = str(ra.plan.estimate().micro_kernel)
            mkp = str(rp.plan.estimate().micro_kernel)
            flip = " *" if mka != mkp else ""
            flips += mka != mkp
            if over > worst[0]:
                worst = (over, (row.layer, v.value))
            lines.append(
                f"| {row.layer} | {v.value} | {mka} | {mkp}{flip} "
                f"| {ra.seconds:.4f} | {rp.seconds:.4f} | {over * 100:+.2f}% |")
    lines += [
        "",
        f"- grid: {res.stats['grid_points']} planned points "
        f"({res.stats['problems']} problems x 3 variants x 2 policies), "
        f"one bulk `sweep` call",
        f"- whole-workload overhead of padded accounting: "
        f"{(tot['padded'] / tot['analytic'] - 1) * 100:+.2f}% "
        f"({tot['analytic']:.2f}s -> {tot['padded']:.2f}s summed over the "
        f"grid)",
        f"- worst single cell: {worst[0] * 100:+.2f}% "
        f"(layer {worst[1][0]}, {worst[1][1]})",
        f"- micro-kernel selection flips between policies: {flips}/57 "
        f"(flipped cells marked `*`)",
    ]
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
