"""Fair-weather vs perturbation-robust SLO autoconfiguration (EXPERIMENTS.md).

The sim-backed SLO pick (`experiments/sim_slo_study.py`) chooses the cell
with the best *nominal* tail — which on a compute-bound edge machine means
the smallest batch that keeps up, i.e. the cell with the least headroom.
This study injects a duty-cycled thermal throttle
(`repro.simulate.faults.throttle_scenario`) into the same gap9-fc
acceptance scenario and measures what that missing headroom costs:

* per batch, the simulated p99 latency in fair weather and under the
  throttle — the dilation is far from uniform across batches;
* the `evaluate_deployment` pick without faults (fair) and with faults
  (robust), and the p99 each achieves *under* the throttle — the gap
  between them is the price of autoconfiguring for fair weather.

Prints markdown; EXPERIMENTS.md records the committed output.

  PYTHONPATH=src python experiments/robust_autoconf_study.py
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.serving.report import plan_deployment
from repro.simulate import (
    SLO,
    PoissonTraffic,
    ServiceModel,
    evaluate_deployment,
    simulate_serving,
    throttle_scenario,
)
from repro.simulate.autoconf import FAULT_REJECT_PREFIX

MACHINE = "gap9-fc"
BATCHES = (1, 2, 4, 8, 16)
RATE = 5.0
SLO_P99 = 0.45
REQUESTS = 150
FAULTS = throttle_scenario(factor=1.3, duty=0.2, period_s=10.0)


def _traffic() -> PoissonTraffic:
    return PoissonTraffic(rate=RATE, prompt_len=16, decode_len=16, seed=0)


def run() -> list[str]:
    cfg = get_config("qwen2-1.5b", smoke=True)
    report = plan_deployment(cfg, machines=(MACHINE,), batches=BATCHES,
                             dtypes=("bf16",))
    options = {o.batch: o for o in report.options}
    batches = sorted(options)
    services = {
        b: ServiceModel.from_plans(cfg, batch=b, machine=MACHINE,
                                   decode_step_s=o.seconds_per_step)
        for b, o in options.items()}

    lines = [
        f"simulated p99 latency (s) on {MACHINE}, {RATE:g} req/s Poisson "
        f"(prompt 16, decode 16, {REQUESTS} requests), fair weather vs "
        f"{FAULTS.name} ({FAULTS.throttles[0].factor}x throttle, "
        f"{FAULTS.throttles[0].duration_s:g}s of every "
        f"{FAULTS.period_s:g}s):",
        "",
        "| batch | " + " | ".join(map(str, batches)) + " |",
        "|---|" + "---|" * len(batches)]
    p99 = {}
    for label, faults in (("fair", None), (FAULTS.name, FAULTS)):
        cells = []
        for b in batches:
            rep = simulate_serving(services[b], _traffic(), max_batch=b,
                                   requests=REQUESTS, faults=faults)
            p99[(label, b)] = rep.latency["p99"]
            cells.append(f"{rep.latency['p99']:.3f}"
                         if rep.finite else "unstable")
        lines.append(f"| {label} | " + " | ".join(cells) + " |")
    lines.append("")

    slo = SLO(p99_latency_s=SLO_P99)
    fair = evaluate_deployment(cfg, report, slo=slo, traffic=_traffic(),
                               requests=REQUESTS, attach=False)
    robust = evaluate_deployment(cfg, report, slo=slo, traffic=_traffic(),
                                 requests=REQUESTS, faults=FAULTS,
                                 attach=False)
    fb, rb = fair.option.batch, robust.option.batch
    fair_under = p99[(FAULTS.name, fb)]
    robust_under = p99[(FAULTS.name, rb)]
    coded = sorted({r.reason for r in robust.rejections
                    if r.reason.startswith(FAULT_REJECT_PREFIX)})
    lines += [
        f"fair pick (SLO p99<={SLO_P99}s, no faults): batch **{fb}** "
        f"(nominal p99 {p99[('fair', fb)]:.3f}s) — under {FAULTS.name} it "
        f"degrades to **{fair_under:.3f}s**, violating the SLO",
        "",
        f"robust pick (same SLO, faults={FAULTS.name}): batch **{rb}** "
        f"(p99 {robust_under:.3f}s under the throttle, "
        f"{len(robust.rejections)} cell(s) rejected, reasons {coded})",
        "",
        f"p99 gap under the throttle, fair pick vs robust pick: "
        f"{fair_under:.3f}s vs {robust_under:.3f}s "
        f"({fair_under / robust_under:.2f}x of the robust tail, "
        f"{1000 * (fair_under - robust_under):+.0f}ms)",
        ""]
    return lines


def main() -> None:
    for line in run():
        print(line)


if __name__ == "__main__":
    main()
