"""Online prediction-drift study: a throttle flips the monitor mid-run
(EXPERIMENTS.md).

The offline calibration gate (`repro.measure.fit_from_store`) refuses to
refit when the median measured/predicted ratio drifts beyond 0.2 — but it
only looks when someone re-measures.  `repro.obs.DriftMonitor` watches the
same statistic *online*: every serving/simulation step feeds one
(predicted, measured) pair into a rolling window keyed by machine.  This
study drives the gap9-fc acceptance cell twice through the serving
simulator:

* **control** — no faults; the simulator's analytic costs match the
  model's predictions exactly, so the ratio pins at 1.0 and the verdict
  stays `ok` for the whole run;
* **throttle50** — a 2x thermal throttle with 50% duty (5s of every
  10s).  Probes sampled twice a second show the verdict flipping
  `ok -> stale` inside each throttle window and *recovering* once the
  window passes — the rolling window ages the fault out, which a
  cumulative statistic would not.

The same monitor runs inside the real `ServingEngine` (see
`perf_report()["drift"]`); the simulator variant is used here because its
un-faulted ratio is exactly 1.0, isolating the injected effect.

Prints markdown; EXPERIMENTS.md records the committed output.

  PYTHONPATH=src python experiments/drift_study.py
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.simulate import PoissonTraffic, ServiceModel
from repro.simulate.engine import Simulator
from repro.simulate.faults import SCENARIOS, FaultScenario
from repro.simulate.server import SlotServer

MACHINE = "gap9-fc"
DTYPE = "int8"
BATCH = 4
RATE = 5.0
REQUESTS = 100
DECODE_LEN = 8
PROBE_EVERY_S = 0.5
FAULTS = SCENARIOS["throttle50"]  # 2x throttle, 5s of every 10s


def _run(service: ServiceModel,
         faults: FaultScenario | None) -> tuple[list[dict], dict]:
    """One simulated run with drift probes; returns (probes, report)."""
    traffic = PoissonTraffic(rate=RATE, prompt_len=16, decode_len=DECODE_LEN,
                             seed=0)
    sim = Simulator(seed=0)
    server = SlotServer(sim, service, max_batch=BATCH, faults=faults,
                        drift_key=MACHINE)
    server.drive(traffic.requests(REQUESTS))
    probes: list[dict] = []

    def probe():
        probes.append({
            "t": sim.now,
            "throttled": (faults.service_scale(sim.now) > 1.0
                          if faults else False),
            "status": server.drift.status(MACHINE),
            "median_ratio": server.drift.median_ratio(MACHINE),
        })
        if sim.pending():
            sim.schedule(PROBE_EVERY_S, probe)

    sim.schedule(PROBE_EVERY_S, probe)
    sim.run()
    return probes, server.drift.report(MACHINE)


def _timeline(probes: list[dict]) -> str:
    """One char per probe: . ok, w warn, S stale (upper = throttling)."""
    sym = {"ok": ".", "warn": "w", "stale": "S"}
    return "".join(sym[p["status"]] for p in probes)


def run() -> list[str]:
    cfg = get_config("qwen2-1.5b", smoke=True)
    service = ServiceModel.from_plans(cfg, batch=BATCH, machine=MACHINE,
                                      dtype=DTYPE)
    control_probes, control = _run(service, None)
    fault_probes, faulted = _run(service, FAULTS)

    assert all(p["status"] == "ok" for p in control_probes), \
        "un-faulted control must stay ok at every probe"
    assert control["keys"][MACHINE]["median_ratio"] == 1.0
    stale = [p for p in fault_probes if p["status"] == "stale"]
    assert stale, "the throttle must flip the monitor stale mid-run"
    recovered = any(p["status"] == "ok" and p["t"] > stale[0]["t"]
                    for p in fault_probes)
    assert recovered, "the rolling window must recover between windows"

    w = FAULTS.throttles[0]
    lines = [
        f"`{MACHINE}` dtype={DTYPE} batch={BATCH}, {RATE:g} req/s Poisson "
        f"(prompt 16, decode {DECODE_LEN}, {REQUESTS} requests); "
        f"`DriftMonitor` probed every {PROBE_EVERY_S:g}s.  Fault: "
        f"`{FAULTS.name}` — {w.factor:g}x throttle for {w.duration_s:g}s "
        f"of every {FAULTS.period_s:g}s.",
        "",
        "| run | verdict timeline (1 char / probe: `.` ok, `w` warn, "
        "`S` stale) | final | final median ratio |",
        "|---|---|---|---|",
        f"| control | `{_timeline(control_probes)}` | {control['status']} "
        f"| {control['keys'][MACHINE]['median_ratio']:.3f} |",
        f"| {FAULTS.name} | `{_timeline(fault_probes)}` | "
        f"{faulted['status']} "
        f"| {faulted['keys'][MACHINE]['median_ratio']:.3f} |",
        "",
        f"The control pins at ratio 1.000 (analytic service times equal "
        f"the model's predictions) and never leaves `ok`.  Under "
        f"`{FAULTS.name}` the verdict flips to `stale` "
        f"{sum(1 for p in stale)} of {len(fault_probes)} probes — first at "
        f"t={stale[0]['t']:.1f}s, inside the first throttle window — and "
        f"recovers to `ok` between windows as the {faulted['window']}-"
        f"sample rolling window ages the throttled steps out.",
    ]
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
