"""Mixed-precision decode-GEMM trade-off study (see EXPERIMENTS.md).

Prices the qwen2-1.5b (smoke) decode step under the full precision zoo —
uniform f32/bf16/int8 paths plus the sequel paper's mixed configs
(int4xint8 widening dots, dequantize-on-the-fly fp weights, int8 KV
cache) — on an edge part (`gap9-fc`) and a datacenter part (`tpu-v5e`),
and reports the (tokens/s, accuracy proxy, deployment footprint)
frontier each machine actually offers.

Each machine is swept over the configs *it can plan*: gap9-fc has no
fp MAC path (`arith_rate` covers int8/int4 only), so its fp entries are
the dequantizing `*xint8->int32` configs priced via `rates_mixed`; the
TPU plans every uniform dtype natively and adds the `bf16xint8->f32`
weight-dequant config.  Quantize/dequantize traffic of wider-than-
compute operands is part of every mixed cell's cost (the `quant_*`
terms — docs/COST_MODELS.md, mixed-precision section).

Prints the markdown section; EXPERIMENTS.md records the committed output.

  PYTHONPATH=src python experiments/precision_tradeoff_study.py
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

BATCH = 8
MAX_LEN = 256

#: per-machine precision menus: every config the machine can price —
#: uniform paths where arith_rate covers the dtype, rates_mixed /
#: compute-dtype fallbacks otherwise.
MENUS = {
    "gap9-fc": ["int8xint8", "int4xint8->int32", "int4xint4->int32",
                "bf16xint8->int32", "f32xint8->int32"],
    "tpu-v5e": ["f32xf32", "bf16xbf16", "int8xint8",
                "bf16xint8->f32", "bf16xbf16->f32@kv=int8"],
}
BACKENDS = {"gap9-fc": "analytic-gap8", "tpu-v5e": "analytic-tpu"}
BASE_DTYPE = {"gap9-fc": "int8", "tpu-v5e": "bf16"}


def _frontier(options):
    """Pareto-efficient options over (tokens/s up, accuracy up, bytes
    down); deterministic order by descending throughput."""
    opts = sorted(options, key=lambda o: (-o.tokens_per_second,
                                          o.dtype))
    keep = []
    for o in opts:
        dominated = any(
            p.tokens_per_second >= o.tokens_per_second
            and p.accuracy_proxy >= o.accuracy_proxy
            and p.footprint.total_bytes <= o.footprint.total_bytes
            and (p.tokens_per_second > o.tokens_per_second
                 or p.accuracy_proxy > o.accuracy_proxy
                 or p.footprint.total_bytes < o.footprint.total_bytes)
            for p in opts if p is not o)
        if not dominated:
            keep.append(o)
    return keep


def run() -> list[str]:
    from repro.configs import get_config
    from repro.core.precision import PrecisionConfig
    from repro.serving.report import plan_deployment

    cfg = get_config("qwen2-1.5b", smoke=True)
    lines = [
        f"- workload: `{cfg.name}` (smoke) decode step at batch {BATCH}, "
        f"max_len {MAX_LEN}; every cell is the analytically planned "
        f"per-layer GEMM sum under one `PrecisionConfig`, footprinted "
        f"with weights in the B-operand dtype and the cache in the "
        f"config's KV dtype",
    ]
    for machine in ("gap9-fc", "tpu-v5e"):
        menu = MENUS[machine]
        report = plan_deployment(
            cfg, machines=machine, dtypes=(BASE_DTYPE[machine],),
            batches=(BATCH,), max_len=MAX_LEN,
            backend=BACKENDS[machine],
            precisions=tuple(menu))
        # keep one row per precision config: the dtype-axis base cell
        # duplicates its uniform config (bit-identically), so drop it.
        # A config's key() drops the @kv tag, so re-attach it from the
        # footprint for display (the cache dtype is the only difference).
        opts = [o for o in report.options if o.precision is not None]
        assert len(opts) == len(menu), (machine, [o.dtype for o in opts])

        def show(o):
            pc = PrecisionConfig.parse(o.precision)
            if o.footprint.kv_dtype == "int8" and pc.b_dtype != "int8":
                return f"{o.precision}@kv=int8"
            return o.precision

        front = {id(o) for o in _frontier(opts)}
        base = next(o for o in report.options if o.precision is None)
        uniform_twin = next(
            o for o in opts
            if PrecisionConfig.parse(o.precision).is_uniform
            and PrecisionConfig.parse(o.precision).a_dtype
            == BASE_DTYPE[machine])
        assert uniform_twin.seconds_per_step == base.seconds_per_step, \
            "uniform config must tie the plain dtype path bit-identically"
        lines += [
            "",
            f"### {machine} ({BACKENDS[machine]})",
            "",
            "| precision | tok/s | acc proxy | footprint MiB | frontier |",
            "|---|---|---|---|---|",
        ]
        for o in sorted(opts, key=lambda o: (-o.tokens_per_second,
                                             show(o))):
            lines.append(
                f"| `{show(o)}` | {o.tokens_per_second:.3g} "
                f"| {o.accuracy_proxy:.2f} "
                f"| {o.footprint.total_bytes / 2**20:.2f} "
                f"| {'**yes**' if id(o) in front else 'no'} |")
    lines += [
        "",
        "- reproduce: `PYTHONPATH=src python "
        "experiments/precision_tradeoff_study.py`; CLI equivalent per "
        "cell: `python -m repro.serving plan --arch qwen2-1.5b --smoke "
        "--machine gap9-fc --batches 8 --precision int4xint8->int32 ...`",
    ]
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
