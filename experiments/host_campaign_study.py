"""Host measure→fit→validate campaign study (see EXPERIMENTS.md).

Runs the paper's calibration methodology end to end on whatever host
executes this script: measure the Table-2 MobileNetV1 GEMMs (f32, so the
blocked replay hits the host BLAS) plus the smoke grid with the host-numpy
harness, fit the host-cpu template's rates by relative-error least squares,
and validate predicted vs measured — the accuracy claim as an artifact.

Prints the markdown section; EXPERIMENTS.md records the committed output
together with the fitted rates and the MAPE.

  PYTHONPATH=src python experiments/host_campaign_study.py [store_dir]
"""
from __future__ import annotations

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import measure


def run(store_dir: str | None = None) -> list[str]:
    store_dir = store_dir or tempfile.mkdtemp(prefix="host-campaign-")
    store = measure.SampleStore(os.path.join(store_dir, "host.jsonl"))

    camps = [
        measure.run_campaign("table2", machine="host-cpu", dtype="f32",
                             harness="host-numpy", store=store,
                             timing={"warmup": 1, "rounds": 2}),
        measure.run_campaign("smoke", machine="host-cpu",
                             harness="host-numpy", store=store),
    ]
    spec, fit = measure.fit_from_store(store, "host-cpu",
                                       name="host-cpu-measured", date=None,
                                       on_nonpositive="free",
                                       manifest_dir=store_dir)
    val = measure.validate_spec(spec, store)
    baseline = measure.validate_spec("host-cpu", store)

    lines = [
        f"- campaigns: "
        + " + ".join(f"`{c.grid}` ({len(c.samples)} samples)"
                     for c in camps)
        + f", host-numpy blocked-loop-nest replay, f32",
        f"- fit: relative-error least squares over "
        f"{fit.samples} samples, residual RMS {fit.residual_rms_s:.3e}s"
        + (f"; columns fitted as free (the host overlaps that traffic "
           f"with compute): {fit.dropped}" if fit.dropped else ""),
        "",
        "| rate column | template (placeholder) | fitted |",
        "|---|---|---|",
    ]
    from repro.machines import get
    template = get("host-cpu")
    for col, x in zip(fit.columns, fit.inverse_rates):
        if col in fit.dropped:
            continue
        if col.startswith("rate:"):
            o, _, d = col[len("rate:"):].partition("->")
            lines.append(f"| `{col}` | {template.transfer_rates[(o, d)]:.3g} "
                         f"B/s | {1.0 / x:.4g} B/s |")
        else:
            dt = col[len("arith:"):]
            lines.append(f"| `{col}` | {template.arith_rate[dt]:.3g} ops/s "
                         f"| {1.0 / x:.4g} ops/s |")
    # same samples, one extra design-matrix column: a fixed cost per
    # micro-kernel dispatch.  insample MAPE is the honest comparison (the
    # overhead term is not a spec rate, so validate_spec cannot see it).
    _, fit_oh = measure.fit_from_store(store, "host-cpu",
                                       name="host-cpu-measured-oh",
                                       date=None, on_nonpositive="free",
                                       overhead_per_block=True)

    w = val.worst
    lines += [
        "",
        f"- fitted-model accuracy: **MAPE {val.mape:.1f}%** over "
        f"{len(val.rows)} cells (median {val.median_ape:.1f}%, worst "
        f"{100 * w.ape:.1f}% on `{w.sample.cell}`)",
        f"- placeholder-template accuracy on the same samples: "
        f"MAPE {baseline.mape:.1f}% — the fit buys "
        f"{baseline.mape / max(val.mape, 1e-9):.1f}x",
        "- per-micro-kernel error profile (shared arithmetic rate):",
    ]
    for mk, g in val.per_micro_kernel().items():
        lines.append(f"  - `{mk}`: {g['cells']} cells, "
                     f"MAPE {g['mape_pct']:.1f}%, bias {g['bias_pct']:+.1f}%")
    oh = fit_oh.overhead_per_block_s
    what = (f"{oh * 1e6:.3g} µs/dispatch" if oh is not None
            else "column fit nonpositive and was dropped — the host-numpy "
                 "replay prices the same loop nest the model does, so "
                 "there is no real dispatch cost to find")
    lines += [
        f"- `overhead_per_block` refit on the same samples: {what}; "
        f"in-sample MAPE {fit.insample_mape_pct:.1f}% -> "
        f"{fit_oh.insample_mape_pct:.1f}% "
        f"({fit.insample_mape_pct - fit_oh.insample_mape_pct:+.1f} pts)",
    ]
    lines += [
        "",
        f"- store + fitted manifest under `{store_dir}` "
        f"(samples keyed by geometry fingerprint "
        f"`{spec.geometry_fingerprint()}`)",
    ]
    return lines


if __name__ == "__main__":
    print("\n".join(run(*sys.argv[1:2])))
