"""Arrival-rate x batch x policy serving-simulation study (EXPERIMENTS.md).

Crosses two zoo machines through the discrete-event simulator
(`repro.simulate`) at several Poisson arrival rates: the smoke-size
qwen2-1.5b served on

* ``gap9-fc`` — compute-bound at decode, so the step time *grows* with the
  slot pool and the simulated p99 latency is U-shaped in the batch
  (queueing kills small batches, step-time dilation kills big ones);
* ``cortex-m7`` — memory-bound at these batches, step time ~flat, so a
  bigger batch never hurts the tail and the SLO pick equals the
  throughput pick.

The headline is the gap9-fc acceptance scenario: the peak-throughput
configuration (batch 16) violates a 0.35s p99 SLO that the sim-backed
``evaluate_deployment`` avoids by picking batch 4 — the exact divergence
``ServingEngine.autoconfigure(slo=...)`` acts on.

Prints markdown; EXPERIMENTS.md records the committed output.

  PYTHONPATH=src python experiments/sim_slo_study.py
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.serving.report import plan_deployment
from repro.simulate import (
    SLO,
    PoissonTraffic,
    ServiceModel,
    evaluate_deployment,
    simulate_serving,
)

BATCHES = (1, 2, 4, 8, 16)
RATES = {"gap9-fc": (1.0, 2.0, 5.0), "cortex-m7": (20.0, 40.0, 60.0)}
SLO_P99 = {"gap9-fc": 0.35, "cortex-m7": 0.35}
REQUESTS = 150


def _traffic(rate: float) -> PoissonTraffic:
    return PoissonTraffic(rate=rate, prompt_len=16, decode_len=16, seed=0)


def run() -> list[str]:
    cfg = get_config("qwen2-1.5b", smoke=True)
    lines: list[str] = []
    for machine, rates in RATES.items():
        report = plan_deployment(cfg, machines=(machine,), batches=BATCHES,
                                 dtypes=("bf16",))
        options = {o.batch: o for o in report.options}
        batches = sorted(options)  # memory-infeasible cells already pruned
        services = {
            b: ServiceModel.from_plans(cfg, batch=b, machine=machine,
                                       decode_step_s=o.seconds_per_step)
            for b, o in options.items()}

        lines += [f"### {machine}", "",
                  "simulated p99 latency (s), greedy admission "
                  f"({REQUESTS} Poisson requests, prompt 16, decode 16):",
                  "",
                  "| rate \\ batch | " + " | ".join(map(str, batches))
                  + " |",
                  "|---|" + "---|" * len(batches)]
        for rate in rates:
            cells = []
            for b in batches:
                rep = simulate_serving(services[b], _traffic(rate),
                                       max_batch=b, requests=REQUESTS)
                cells.append(f"{rep.latency['p99']:.3f}"
                             if rep.finite else "unstable")
            lines.append(f"| {rate:g} req/s | " + " | ".join(cells) + " |")
        lines.append("")

        # admission-policy sensitivity at the machine's heaviest rate
        rate = rates[-1]
        b = max(batches)
        pol = {}
        for policy in ("greedy", "drain-first"):
            rep = simulate_serving(services[b], _traffic(rate),
                                   max_batch=b, policy=policy,
                                   requests=REQUESTS)
            pol[policy] = rep
        lines += [
            f"policy sensitivity at batch {b}, {rate:g} req/s: greedy p99 "
            f"{pol['greedy'].latency['p99']:.3f}s vs drain-first "
            f"{pol['drain-first'].latency['p99']:.3f}s "
            f"(batch-synchronous draining "
            f"{pol['drain-first'].latency['p99'] / pol['greedy'].latency['p99']:.2f}x)",
            ""]

        # the SLO-vs-throughput divergence
        base = report.select()
        traffic = _traffic(rates[-1])
        try:
            sel = evaluate_deployment(cfg, report, slo=SLO(
                p99_latency_s=SLO_P99[machine]), traffic=traffic,
                requests=REQUESTS)
            picked = sel.option.batch
            p99 = sel.sim.latency["p99"]
            n_rej = len(sel.rejections)
            lines += [
                f"throughput pick: batch {base.batch} "
                f"({base.tokens_per_second:.0f} peak tok/s); "
                f"SLO(p99<={SLO_P99[machine]}s) pick under {traffic.name}: "
                f"batch **{picked}** (sim p99 {p99:.3f}s, {n_rej} cell(s) "
                f"rejected with machine-readable slo_* reasons)",
                ""]
        except ValueError as e:
            lines += [f"SLO infeasible: {e}", ""]
    return lines


def main() -> None:
    for line in run():
        print(line)


if __name__ == "__main__":
    main()
