"""Quickstart: the paper's simulator, its TPU twin, and the framework in
five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# 1. The paper: plan blocked-GEMM variants on the GAP8 edge processor
# ---------------------------------------------------------------------------
from repro import gemm
from repro.core import Variant

print("=== 1. Paper simulator: MobileNetV1 layer #10 GEMM on GAP8 ===")
print(f"  backends: {gemm.backends()}")
layer10 = (256, 784, 2304)                       # im2col of conv layer 10
for v in Variant:
    cb = gemm.plan(layer10, backend="analytic-gap8", variant=v).estimate()
    print(f"  {v.value}: best micro-kernel {cb.micro_kernel}, "
          f"estimated {cb.total:.3f}s "
          f"(arith {cb.arith:.3f}s, transfers {cb.transfer:.3f}s)")

# ---------------------------------------------------------------------------
# 2. The TPU adaptation: the same plan() call picks Pallas block shapes
# ---------------------------------------------------------------------------
print("\n=== 2. TileTuner: a transformer MLP GEMM on TPU v5e ===")
d = gemm.plan((4096, 18944, 3584), backend="analytic-tpu")  # qwen2-7b w_up
print(f"  tile {d.selection} -> predicted {d.predicted_seconds*1e6:.0f}us, "
      f"{d.cost.roofline_fraction():.1%} of roofline "
      f"(paper-mode/no-overlap would be {d.cost.total_no_overlap*1e6:.0f}us)")

# ---------------------------------------------------------------------------
# 2b. Close the loop: execute a plan with the Pallas kernel (interpret mode)
# ---------------------------------------------------------------------------
print("\n=== 2b. plan -> execute on the pallas backend ===")
p = gemm.plan((256, 256, 256), backend="pallas", dtype="f32")
a = jnp.ones((256, 256), jnp.float32)
b = jnp.full((256, 256), 0.5, jnp.float32)
c = p.execute(a, b, interpret=True)
print(f"  {p.describe()}")
print(f"  execute(ones, halves)[0,0] = {float(c[0, 0])} (expect 128.0)")

# ---------------------------------------------------------------------------
# 3. The framework: train a small LM for a few steps on CPU
# ---------------------------------------------------------------------------
from repro.launch.train import train

print("\n=== 3. Train a smoke-scale qwen2 for 30 steps ===")
out = train("qwen2-1.5b", smoke=True, steps=30, batch=8, seq=64, lr=3e-3,
            log_every=10)

# ---------------------------------------------------------------------------
# 4. Serve it with the continuous-batching engine
# ---------------------------------------------------------------------------
from repro.configs import get_config
from repro.models.common import HOST_MESH
from repro.models.model import LM
from repro.serving.engine import Request, ServingEngine

print("\n=== 4. Serve a few batched requests ===")
cfg = get_config("qwen2-1.5b", smoke=True)
lm = LM(cfg, HOST_MESH)
eng = ServingEngine(lm, out["params"], max_batch=2, max_len=64)
for i in range(3):
    eng.submit(Request(rid=i, prompt=[1 + i, 2 + i, 3 + i],
                       max_new_tokens=5))
for r in sorted(eng.run_until_drained(), key=lambda r: r.rid):
    print(f"  request {r.rid}: prompt {r.prompt} -> generated {r.generated}")
print("\nquickstart done.")
