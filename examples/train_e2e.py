"""End-to-end training driver with checkpoint/restart fault tolerance.

Default: a ~2M-param smoke model for 300 steps on CPU (fast, loss visibly
drops).  ``--arch xlstm-125m --full`` trains the real 106M-parameter xLSTM
if you have the patience (or a TPU).

    PYTHONPATH=src python examples/train_e2e.py [--steps 300]

Kill it mid-run (Ctrl+C is fine, SIGTERM triggers the emergency
checkpoint) and re-run: it resumes bit-exactly from the latest checkpoint.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true",
                    help="train the full (non-smoke) config")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_e2e")
    a = ap.parse_args()

    out = train(a.arch, smoke=not a.full, steps=a.steps, batch=16, seq=128,
                lr=3e-3, ckpt_dir=a.ckpt_dir, ckpt_every=50,
                microbatches=2)
    losses = out["losses"]
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"\nloss first10={first:.3f} -> last10={last:.3f} "
          f"({(1 - last / first):.0%} reduction)")
    print(f"checkpoints in {a.ckpt_dir}: re-run this script to resume.")


if __name__ == "__main__":
    main()
