"""Batched serving demo: continuous batching over mixed-length requests.

    PYTHONPATH=src python examples/serve_batch.py --arch zamba2-1.2b
    PYTHONPATH=src python examples/serve_batch.py --autoconfigure \\
        --machine 'tpu-v5e*'    # sweep-driven max_batch/plan selection
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import serve_demo


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--autoconfigure", action="store_true")
    ap.add_argument("--machine", default=None)
    a = ap.parse_args()
    serve_demo(a.arch, n_requests=a.requests, max_new=a.max_new,
               max_batch=a.max_batch, autoconfigure=a.autoconfigure,
               machine=a.machine)


if __name__ == "__main__":
    main()
