"""Batched serving demo: continuous batching over mixed-length requests.

    PYTHONPATH=src python examples/serve_batch.py --arch zamba2-1.2b
    PYTHONPATH=src python examples/serve_batch.py --autoconfigure \\
        --machine 'zoo/*'       # memory-aware zoo-wide machine/batch pick
    PYTHONPATH=src python examples/serve_batch.py --autoconfigure \\
        --machine gap9-fc --slo-p99 0.35 --rate 5 \\
        --trace /tmp/trace.json # simulation-backed SLO pick + event trace
    PYTHONPATH=src python examples/serve_batch.py --requests 24 \\
        --deadline 2.0 --queue-limit 8   # overload: shed + backpressure

With ``--autoconfigure`` the engine comes from the ranked deployment grid
(``repro.serving.plan_deployment``): cells whose modelled footprint
(weights + KV cache + workspace) exceeds a machine's deployment-memory
budget are pruned before the GEMM sweep, and the surviving cell with the
best predicted decode throughput is frozen into the engine.  Adding
``--slo-p99`` instead picks the cell by *simulated* SLO attainment under
Poisson traffic (``repro.simulate``) — usually a smaller batch than the
peak-throughput winner; ``--faults throttle20`` on top makes the pick
perturbation-robust (SLO attainment *under* a duty-cycled thermal
throttle).  ``--deadline`` / ``--queue-limit`` arm the overload path —
expired or unmeetable requests are shed at admission, a full queue
pushes back on the submitter, and the shed/expired/degraded counters
land in ``perf_report()`` (see docs/RESILIENCE.md).  ``--trace`` writes
the engine's event trace for ``python -m repro.simulate replay``
sim-vs-real validation; ``--trace-out`` writes a Chrome-trace/Perfetto
JSON of the run's spans + events (``repro.obs``, see
docs/OBSERVABILITY.md).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import serve_demo


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--autoconfigure", action="store_true")
    ap.add_argument("--machine", default=None)
    ap.add_argument("--no-memory", action="store_true")
    ap.add_argument("--slo-p99", type=float, default=None)
    ap.add_argument("--rate", type=float, default=None)
    ap.add_argument("--deadline", type=float, default=None)
    ap.add_argument("--queue-limit", type=int, default=None)
    ap.add_argument("--faults", default=None)
    ap.add_argument("--on-truncate", choices=["raise", "report"],
                    default="raise")
    ap.add_argument("--trace", default=None)
    ap.add_argument("--trace-out", default=None)
    a = ap.parse_args()
    slo = traffic = None
    if a.slo_p99 is not None:
        from repro.simulate import SLO, PoissonTraffic
        slo = SLO(p99_latency_s=a.slo_p99)
        if a.rate is not None:
            traffic = PoissonTraffic(rate=a.rate, prompt_len=16,
                                     decode_len=a.max_new)
    serve_demo(a.arch, n_requests=a.requests, max_new=a.max_new,
               max_batch=a.max_batch, autoconfigure=a.autoconfigure,
               machine=a.machine, memory=not a.no_memory, slo=slo,
               traffic=traffic, deadline_s=a.deadline,
               queue_limit=a.queue_limit, faults=a.faults,
               on_truncate=a.on_truncate, trace_path=a.trace,
               trace_out=a.trace_out)


if __name__ == "__main__":
    main()
