"""Batched serving demo: continuous batching over mixed-length requests.

    PYTHONPATH=src python examples/serve_batch.py --arch zamba2-1.2b
    PYTHONPATH=src python examples/serve_batch.py --autoconfigure \\
        --machine 'zoo/*'       # memory-aware zoo-wide machine/batch pick

With ``--autoconfigure`` the engine comes from the ranked deployment grid
(``repro.serving.plan_deployment``): cells whose modelled footprint
(weights + KV cache + workspace) exceeds a machine's deployment-memory
budget are pruned before the GEMM sweep, and the surviving cell with the
best predicted decode throughput is frozen into the engine.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import serve_demo


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--autoconfigure", action="store_true")
    ap.add_argument("--machine", default=None)
    ap.add_argument("--no-memory", action="store_true")
    a = ap.parse_args()
    serve_demo(a.arch, n_requests=a.requests, max_new=a.max_new,
               max_batch=a.max_batch, autoconfigure=a.autoconfigure,
               machine=a.machine, memory=not a.no_memory)


if __name__ == "__main__":
    main()
