"""The paper's workflow, end to end, through the unified ``repro.gemm`` API:
explore GEMM algorithm alternatives *before* implementing them — first on the
paper's GAP8 target, then on TPU via the analytic tile search, then validate
the chosen plan against the Pallas kernel in interpret mode.

    PYTHONPATH=src python examples/autotune_explore.py --m 512 --n 2048 --k 1024
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro import gemm, machines
from repro.core import GemmShape, Variant
from repro.core.autotune import candidate_tiles
from repro.core.tpu_model import estimate
from repro.kernels.ref import gemm_ref


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=512)
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--k", type=int, default=1024)
    a = ap.parse_args()

    print(f"GEMM {a.m} x {a.n} x {a.k}")
    print("\n--- GAP8 (the paper's target): bulk sweep over the variant "
          "axis ---")
    res = gemm.sweep([(a.m, a.n, a.k)], backends=["analytic-gap8"],
                     variants=list(Variant), policies=["analytic", "padded"])
    for r in res.filter(policy="analytic"):
        cb = r.plan.estimate()
        g = cb.grouped()
        print(f"  {r.variant}: mk={cb.micro_kernel} total={cb.total:.3f}s  "
              f"[pack {g['packing']:.2f} | copy {g['copy']:.2f} | "
              f"streams {g['stream_M'] + g['stream_L1'] + g['stream_L2']:.2f} "
              f"| arith {g['arith']:.2f}]")
    win = res.best((a.m, a.n, a.k))
    print(f"  sweep winner across {len(res)} grid points: {win.variant} "
          f"{win.selection} ({win.policy} policy, {win.seconds:.3f}s)")

    print("\n--- machine zoo: the same sweep across every FC-class "
          "manifest ---")
    fc_zoo = [n for n in machines.list_machines("zoo/*")
              if machines.get(n).register_lanes <= 8]
    zres = gemm.sweep([(a.m, a.n, a.k)], backends=["analytic-gap8"],
                      machines=fc_zoo)
    for r in sorted(zres, key=lambda r: r.seconds):
        print(f"  {r.machine:>12}: {r.plan.estimate().micro_kernel} "
              f"{r.seconds:10.3f}s")

    print("\n--- TPU v5e: the analytic search over the Pallas design space ---")
    shape = GemmShape(a.m, a.n, a.k, "bf16")
    ranked = sorted(candidate_tiles(shape),
                    key=lambda t: estimate(shape, t).total())[:5]
    for t in ranked:
        c = estimate(shape, t)
        print(f"  {str(t):>24}: {c.total()*1e6:8.1f}us  "
              f"rf={c.roofline_fraction():.3f}  hbm={c.hbm_bytes/1e6:.1f}MB  "
              f"vmem={c.vmem_peak/1e6:.1f}MB")
    best = gemm.plan((a.m, a.n, a.k), backend="analytic-tpu", dtype="bf16")
    print(f"  chosen: {best.selection}  ({best.provenance['source']})")

    print("\n--- validate the chosen plan against the kernel (interpret) ---")
    rng = np.random.default_rng(0)
    m, n, k = min(a.m, 256), min(a.n, 256), min(a.k, 256)
    x = jnp.array(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.array(rng.normal(size=(k, n)), jnp.float32)
    run = gemm.plan((m, n, k), backend="pallas", dtype="f32",
                    tile=best.selection)
    got = run.execute(x, w, interpret=True)
    err = float(jnp.max(jnp.abs(got - gemm_ref(x, w))))
    print(f"  kernel vs oracle max|err| = {err:.2e} on {m}x{n}x{k} slice")
    print(f"  plan cache: {gemm.plan_cache_stats()}")


if __name__ == "__main__":
    main()
