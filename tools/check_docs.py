#!/usr/bin/env python
"""Docs checker: run the Python snippets in docs/*.md + README.md and
verify intra-repo links.

    PYTHONPATH=src python tools/check_docs.py [FILES...]

Every fenced ```python block is executed (blocks within one file share a
namespace, in order, so later snippets may build on earlier ones); a block
whose first line contains ``docs-check: skip`` is not run.  This is what
keeps the worked examples in docs/COST_MODELS.md et al. from drifting away
from the code — if the simulator's number changes, the doc's assert fails
CI.

Relative markdown links (``[text](path)``) must point at files that exist;
http(s)/mailto links and pure #anchors are not checked.
"""
from __future__ import annotations

import glob
import os
import re
import sys
import traceback

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SKIP_MARK = "docs-check: skip"
# any ``` line toggles a fence; the opener's info string starts with the
# language word ("python", "python title=x", ...)
_FENCE = re.compile(r"^```(.*)$")
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def default_files() -> list[str]:
    files = [os.path.join(ROOT, "README.md")]
    files += sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))
    return [f for f in files if os.path.exists(f)]


def python_blocks(text: str) -> list[tuple[int, str]]:
    """(start_line, source) for every fenced python block.

    Raises:
        ValueError: on an unterminated fence — a dangling ```python block
        would otherwise be silently skipped, which is exactly the drift
        this checker exists to catch.
    """
    blocks, buf, start, lang = [], None, 0, None
    for i, line in enumerate(text.splitlines(), 1):
        m = _FENCE.match(line.strip())
        if m and buf is None:
            info = m.group(1).strip().lower()
            lang = info.split()[0] if info else ""
            start, buf = i + 1, []
        elif m and buf is not None:
            if lang == "python":
                blocks.append((start, "\n".join(buf)))
            buf = None
        elif buf is not None:
            buf.append(line)
    if buf is not None:
        raise ValueError(f"unterminated ``` fence opened at line {start - 1}")
    return blocks


def run_snippets(path: str) -> list[str]:
    errors = []
    with open(path) as f:
        text = f.read()
    namespace: dict = {"__name__": "__docs__"}
    rel = os.path.relpath(path, ROOT)
    try:
        blocks = python_blocks(text)
    except ValueError as e:
        return [f"{rel}: {e}"]
    for start, src in blocks:
        first = src.splitlines()[0] if src.splitlines() else ""
        if SKIP_MARK in first:
            print(f"  SKIP {rel}:{start}")
            continue
        # pad so tracebacks report true line numbers within the md file
        code = "\n" * (start - 1) + src
        try:
            exec(compile(code, rel, "exec"), namespace)     # noqa: S102
            print(f"  ok   {rel}:{start} ({len(src.splitlines())} lines)")
        except Exception:
            errors.append(f"{rel}:{start}: snippet failed\n"
                          + traceback.format_exc(limit=8))
    return errors


def check_links(path: str) -> list[str]:
    errors = []
    with open(path) as f:
        text = f.read()
    # drop fenced code before scanning: JSON/snippet parens are not links
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    rel = os.path.relpath(path, ROOT)
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        fs = os.path.normpath(
            os.path.join(os.path.dirname(path), target.split("#", 1)[0]))
        if not os.path.exists(fs):
            errors.append(f"{rel}: broken link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    files = [os.path.abspath(a) for a in argv] or default_files()
    failures: list[str] = []
    for path in files:
        print(os.path.relpath(path, ROOT))
        failures += check_links(path)
        failures += run_snippets(path)
    if failures:
        print(f"\n{len(failures)} docs-check failure(s):", file=sys.stderr)
        for f in failures:
            print(f"- {f}", file=sys.stderr)
        return 1
    print(f"\ndocs-check OK ({len(files)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
