"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = simulator wall
time; derived = the figure's headline quantity), followed by the detailed
tables the paper shows.

  fig4      B3C2A0 cost decomposition, micro-kernels 4x4 / 4x8 / 4x12
  fig5      three variants x micro-kernels on MobileNetV1 layer #10
  table2    optimal micro-kernel per (layer, variant) + agreement vs paper
  fig6      per-layer execution time, variant ranking (B3A2C0 advantage)
  tpu_autotune   TileTuner on the assigned archs' GEMM shapes (paper-
                 faithful no-overlap vs beyond-paper overlapped model)
  roofline  per (arch x shape) roofline terms from the dry-run artifacts
"""
from __future__ import annotations

import glob
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import gemm as gemm_api
from repro.core.mobilenet import LAYER10, TABLE2
from repro.core.variants import MicroKernel, Variant
from repro.configs import ARCH_IDS, get_config


def _gap8_plan(prob, variant=None, mk=None, cache=True):
    opts = {}
    if variant is not None:
        opts["variant"] = variant
    if mk is not None:
        opts["micro_kernel"] = mk
    return gemm_api.plan(prob, backend="analytic-gap8", cache=cache, **opts)


def _timed(fn, reps=3):
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    return out, (time.perf_counter() - t0) / reps * 1e6


def bench_fig4() -> list[str]:
    """B3C2A0 decomposition for 4x4 / 4x8 / 4x12 (paper Fig. 4, <2% claim).
    One bulk ``sweep`` over the micro-kernel axis replaces the per-mk plan
    loop."""
    mks = (MicroKernel(4, 4), MicroKernel(4, 8), MicroKernel(4, 12))
    res, us = _timed(lambda: gemm_api.sweep(
        [LAYER10], backends=["analytic-gap8"], variants=[Variant.B3C2A0],
        micro_kernels=mks, cache=False))
    rows = []
    detail = ["  fig4 detail: mk, packing, unpacking, copy, stream_M, "
              "stream_L1, stream_L2, arith, total(s)"]
    for r in res:
        cb = r.plan.estimate()
        g = cb.grouped()
        rows.append(f"fig4_B3C2A0_{r.micro_kernel},{us / len(res):.1f},"
                    f"{cb.total:.4f}")
        detail.append(
            f"  {r.micro_kernel}: {g['packing']:.3f}, {g['unpacking']:.3f}, "
            f"{g['copy']:.3f}, {g['stream_M']:.3f}, {g['stream_L1']:.3f}, "
            f"{g['stream_L2']:.3f}, {g['arith']:.3f}, {cb.total:.3f}")
    return rows + detail


def bench_fig5() -> list[str]:
    """Layer-10 sweep: per-variant best micro-kernel + time (paper Fig. 5),
    one bulk ``sweep`` over the variant axis."""
    res, us = _timed(lambda: gemm_api.sweep(
        [LAYER10], backends=["analytic-gap8"], variants=list(Variant),
        cache=False))
    rows = []
    for r in res:
        cb = r.plan.estimate()
        rows.append(f"fig5_{r.variant},{us / len(res):.1f},{cb.total:.4f}")
        rows.append(f"  fig5 detail: {r.variant} best={cb.micro_kernel} "
                    f"blocking=(m_c={cb.blocking.m_c} n_c={cb.blocking.n_c} "
                    f"k_c={cb.blocking.k_c})")
    return rows


def bench_table2() -> list[str]:
    """Optimal micro-kernels for all MobileNetV1 layers vs paper Table 2 —
    the full (layer x variant) grid in one bulk ``sweep``."""
    probs = [row.problem for row in TABLE2]
    t0 = time.perf_counter()
    res = gemm_api.sweep(probs, backends=["analytic-gap8"],
                         variants=list(Variant), cache=False)
    us = (time.perf_counter() - t0) * 1e6 / len(TABLE2)
    by_variant = {v: res.filter(variant=v.value) for v in Variant}
    agree = {v: 0 for v in Variant}
    detail = []
    for i, row in enumerate(TABLE2):
        cells = []
        for v in Variant:
            cb = by_variant[v][i].plan.estimate()
            paper = row.best[v.value]
            ok = (cb.micro_kernel.rows, cb.micro_kernel.cols) == \
                 (paper.rows, paper.cols)
            agree[v] += ok
            mark = "=" if ok else "!"
            cells.append(f"{v.value}:{cb.micro_kernel}{mark}{paper}")
        detail.append(f"  L{row.layer:>14} " + "  ".join(cells))
    total = sum(agree.values())
    rows = [f"table2_agreement,{us:.1f},{total}/57"]
    for v in Variant:
        rows.append(f"table2_{v.value},{us:.1f},{agree[v]}/19")
    return rows + ["  (ours=paper '=' / ours!paper '!')"] + detail


def bench_fig6() -> list[str]:
    """Whole-MobileNetV1 totals per variant (paper Fig. 6)."""
    totals = {v: 0.0 for v in Variant}
    wins = {v: 0 for v in Variant}
    t0 = time.perf_counter()
    for row in TABLE2:
        best = {v: _gap8_plan(row.problem, v, cache=False).predicted_seconds
                for v in Variant}
        for v in Variant:
            totals[v] += best[v]
        wins[min(best, key=best.get)] += 1
    us = (time.perf_counter() - t0) * 1e6
    rows = []
    for v in Variant:
        rows.append(f"fig6_total_{v.value},{us:.0f},{totals[v]:.3f}")
    winner = min(totals, key=totals.get)
    rows.append(f"fig6_winner,{us:.0f},{winner.value}")
    rows.append(f"  fig6: per-layer wins {{'B3A2C0': {wins[Variant.B3A2C0]}, "
                f"'C3B2A0': {wins[Variant.C3B2A0]}, "
                f"'B3C2A0': {wins[Variant.B3C2A0]}}} "
                f"(paper: 'general advantage of the B3A2C0 variant')")
    return rows


def bench_tpu_autotune() -> list[str]:
    """TileTuner over each arch's transformer GEMMs: paper-faithful
    (no-overlap, §3.1) vs beyond-paper (double-buffered) estimates."""
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        t0 = time.perf_counter()
        plans = gemm_api.plan_model_gemms(cfg, backend="analytic-tpu")
        no_overlap = overlapped = 0.0
        worst = None
        for d in plans:
            s = d.problem
            no_overlap += d.cost.total_no_overlap
            overlapped += d.cost.total_overlapped
            rf = d.cost.roofline_fraction()
            if worst is None or rf < worst[1]:
                worst = (s, rf, d.selection)
        us = (time.perf_counter() - t0) * 1e6
        speedup = no_overlap / overlapped
        rows.append(f"tpu_autotune_{arch},{us:.0f},{speedup:.3f}x_overlap_gain")
        rows.append(f"  {arch}: {len(plans)} GEMMs, paper-mode "
                    f"{no_overlap*1e6:.1f}us -> overlapped "
                    f"{overlapped*1e6:.1f}us; worst rf={worst[1]:.3f} "
                    f"{worst[0].m}x{worst[0].n}x{worst[0].k} tile={worst[2]}")
    return rows


def bench_roofline() -> list[str]:
    """Roofline table from the dry-run artifacts (see EXPERIMENTS.md)."""
    files = sorted(glob.glob(os.path.join(
        os.path.dirname(__file__), "..", "experiments", "roofline", "*.json")))
    if not files:
        return ["roofline,0,run `python -m repro.launch.roofline_probe --all` first"]
    rows = []
    for f in files:
        r = json.load(open(f))
        rows.append(
            f"roofline_{r['arch']}_{r['shape']},0,"
            f"dom={r['dominant']}:rf={r['roofline_fraction']:.4f}")
    return rows


def main() -> None:
    print("name,us_per_call,derived")
    for fn in (bench_fig4, bench_fig5, bench_table2, bench_fig6,
               bench_tpu_autotune, bench_roofline):
        for line in fn():
            print(line)
    stats = gemm_api.plan_cache_stats()
    print(f"plan_cache,0,hits={stats['hits']}:misses={stats['misses']}"
          f":deduped={stats['deduped']}:size={stats['size']}")


if __name__ == "__main__":
    main()
