"""Planner-throughput benchmark: scalar loops vs the batched sweep engine.

The design-space search is the repo's hottest non-JAX path: TileTuner walks
up to ~810 candidate tiles per GEMM shape and the GAP8 simulator scores 14
micro-kernels x 3 variants per layer.  This benchmark times the pre-batching
scalar loops (``tune_scalar`` / ``best_microkernel_scalar``, the preserved
reference oracles) against the vectorized batch engine on the combined
Table-2 + all-arch planning workload, asserts the selections are identical,
and records the speedups.

Workloads:

  table2_gap8  the paper's Table-2 grid — 19 MobileNetV1 layers x 3
               variants; scalar = per-candidate ``simulate`` loop, batched =
               ``best_microkernel_batch`` per variant.
  allarch_tpu  every arch config's GEMM shapes through TileTuner; scalar =
               per-shape ``candidate_tiles`` + ``estimate`` loop, batched =
               one deduped ``tune_batch`` lattice evaluation.
  cold_tune    single-shape planning latency (scalar loop vs 1-shape batch).
  sim_latency  serving-simulator smoke — 2000 Poisson requests through an
               analytically priced tpu-v5e cell (``repro.simulate``);
               asserts a finite p99 and records events/second.
  sim_faults   overload-resilience smoke — the same cell driven at 2.5x its
               sustainable rate under the ``storm`` fault scenario with a
               per-request deadline; asserts the shedder keeps the run
               finite (shed > 0, unfinished == 0) and records the shed
               fraction and survivor tail.
  design_frontier
               design-space exploration smoke (``repro.design``) — scores
               the 64-point ``gap9-sweep`` generated space on the Table-2
               grid and reduces it to the Pareto frontier twice; asserts
               the two frontiers are byte-identical (determinism) and
               records designs/second so frontier-scoring cost is tracked
               per SHA.
  obs_overhead observability tax (``repro.obs``) — the Table-2 sweep with
               span tracing disabled vs the span entry point stubbed out;
               asserts the disabled instrumentation costs < 2% and records
               the enabled-mode cost alongside.
  mixed_precision_sweep
               the Table-2 grid re-planned under three mixed-precision
               configs on gap9-fc (``PrecisionConfig`` axis); scalar =
               per-problem ``best_microkernel_scalar`` loop, batched =
               ``best_microkernel_batch`` with quantize-traffic lattice
               rows; asserts batched selections match the scalar oracle
               and records the speedup plus the aggregate quantize share.

``BENCH_planner.json`` at the repo root is an **append-only perf
trajectory**: every run appends one record keyed by the current git SHA
(re-runs at the same SHA replace that SHA's record), so the file accumulates
one point per PR instead of overwriting history.  CI runs this script and
separately asserts the file parses.

  PYTHONPATH=src python benchmarks/bench_planner.py
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import ARCH_IDS, get_config
from repro.core.autotune import (
    clear_tune_cache,
    model_gemm_shapes,
    tune_batch,
    tune_scalar,
)
from repro.core.hardware import GAP8_FC
from repro.core.mobilenet import TABLE2
from repro.core.simulator import (
    best_microkernel_batch,
    best_microkernel_scalar,
)
from repro.core.variants import Variant

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_planner.json")
TRAJECTORY_SCHEMA = "bench_planner/trajectory-v1"


def git_sha() -> str:
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            text=True, stderr=subprocess.DEVNULL).strip()
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def load_trajectory(path: str) -> dict:
    """Read the trajectory; a legacy single-snapshot file (pre-trajectory
    format: the report dict itself) migrates to the first record."""
    if not os.path.exists(path):
        return {"schema": TRAJECTORY_SCHEMA, "records": []}
    with open(path) as f:
        data = json.load(f)
    if "records" not in data:
        data = {"schema": TRAJECTORY_SCHEMA,
                "records": [{"sha": "pre-trajectory", **data}]}
    return data


def _best_of(fn, reps=3):
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        times.append(time.perf_counter() - t0)
    return out, min(times)


def bench_table2_gap8() -> dict:
    probs = [row.problem for row in TABLE2]

    def scalar():
        return [[best_microkernel_scalar(GAP8_FC, v, p) for p in probs]
                for v in Variant]

    def batched():
        return [best_microkernel_batch(GAP8_FC, v, probs) for v in Variant]

    s_out, s_t = _best_of(scalar)
    b_out, b_t = _best_of(batched)
    for srow, brow in zip(s_out, b_out):
        for s, b in zip(srow, brow):
            assert s.micro_kernel == b.micro_kernel, "selection drift"
    return {"scalar_s": s_t, "batched_s": b_t, "speedup": s_t / b_t,
            "problems": len(probs), "grid_points": len(probs) * 3}


def bench_allarch_tpu() -> dict:
    shapes = []
    for arch in ARCH_IDS:
        shapes += model_gemm_shapes(get_config(arch))
    unique = list(dict.fromkeys(shapes))

    def scalar():
        return [tune_scalar(s) for s in unique]

    def batched():
        clear_tune_cache()  # cold: time the lattice evaluation, not the memo
        return tune_batch(shapes)

    s_out, s_t = _best_of(scalar)
    b_out, b_t = _best_of(batched)
    got = {s: d.tile for s, d in zip(shapes, b_out)}
    for s, d in zip(unique, s_out):
        assert got[s] == d.tile, f"selection drift on {s}"
    return {"scalar_s": s_t, "batched_s": b_t, "speedup": s_t / b_t,
            "shapes": len(shapes), "unique_shapes": len(unique)}


def bench_cold_tune() -> dict:
    from repro.core.tpu_model import GemmShape
    shape = GemmShape(4096, 11008, 4096, "bf16")
    _, s_t = _best_of(lambda: tune_scalar(shape), reps=5)

    def batched():
        clear_tune_cache()
        return tune_batch([shape])

    _, b_t = _best_of(batched, reps=5)
    return {"scalar_s": s_t, "batched_s": b_t, "speedup": s_t / b_t}


def bench_measure_fidelity() -> dict:
    """Host measure→fit→validate smoke loop (repro.measure): the fitted
    host MAPE joins the per-SHA trajectory, so model-accuracy regressions
    show up next to planner-perf regressions."""
    import tempfile

    from repro import measure

    with tempfile.TemporaryDirectory() as td:
        store = measure.SampleStore(os.path.join(td, "smoke.jsonl"))
        t0 = time.perf_counter()
        camp = measure.run_campaign("smoke", machine="host-cpu",
                                    harness="host-numpy", store=store)
        campaign_s = time.perf_counter() - t0
        spec, fit = measure.fit_from_store(store, "host-cpu",
                                           name="host-cpu-bench", date=None,
                                           on_nonpositive="free")
        val = measure.validate_spec(spec, store)
        return {
            "samples": len(camp.samples),
            "campaign_s": campaign_s,
            "fit_residual_rms_s": fit.residual_rms_s,
            "dropped_columns": list(fit.dropped),
            "mape_pct": val.mape,
            "median_ape_pct": val.median_ape,
            "worst_ape_pct": 100.0 * val.worst.ape,
        }


def bench_sim_latency() -> dict:
    """Serving-simulator smoke (repro.simulate): Poisson traffic through an
    analytically priced tpu-v5e cell.  Asserts the tail is finite (every
    request finished) and records the event-loop throughput so simulator
    perf regressions land in the trajectory."""
    from repro.simulate import PoissonTraffic, ServiceModel, simulate_serving

    cfg = get_config("qwen2-1.5b")
    service = ServiceModel.from_plans(cfg, batch=8, machine="tpu-v5e")
    traffic = PoissonTraffic(rate=500, prompt_len=(8, 200), decode_len=16,
                             seed=0)

    def run():
        return simulate_serving(service, traffic, max_batch=8,
                                requests=2000,
                                config={"machine": "tpu-v5e",
                                        "dtype": "bf16"})
    rep, t = _best_of(run)
    assert rep.finite, "simulated p99 latency must be finite"
    events = rep.steps + 2 * rep.requests["submitted"]
    return {
        "requests": rep.requests["submitted"],
        "steps": rep.steps,
        "wall_s": t,
        "events_per_s": events / t,
        "p99_latency_s": rep.latency["p99"],
        "goodput_tps": rep.goodput_tps,
    }


def bench_sim_faults() -> dict:
    """Overload-resilience smoke (repro.simulate.faults): the tpu-v5e cell
    from ``sim_latency`` driven at 2.5x its sustainable arrival rate under
    the ``storm`` scenario (throttle windows + slot failures + a flash
    crowd) with a per-request deadline.  Without shedding the queue would
    grow without bound; the deadline-armed simulator must shed the excess
    and finish everything else."""
    from repro.simulate import PoissonTraffic, ServiceModel, simulate_serving

    cfg = get_config("qwen2-1.5b")
    service = ServiceModel.from_plans(cfg, batch=8, machine="tpu-v5e")
    decode_len = 16
    sustainable_rps = 8 / (service.decode_step_s * decode_len)
    deadline_s = 5 * decode_len * service.decode_step_s
    traffic = PoissonTraffic(rate=2.5 * sustainable_rps, prompt_len=(8, 200),
                             decode_len=decode_len, seed=0)

    def run():
        return simulate_serving(service, traffic, max_batch=8,
                                requests=2000, deadline_s=deadline_s,
                                faults="storm",
                                config={"machine": "tpu-v5e",
                                        "dtype": "bf16"})
    rep, t = _best_of(run)
    assert rep.shed_count > 0, "a 2.5x overload must shed"
    assert rep.requests["unfinished"] == 0, "shedding must keep the run finite"
    assert rep.finite, "survivor tail must be finite"
    events = rep.steps + 2 * rep.requests["submitted"]
    return {
        "requests": rep.requests["submitted"],
        "finished": rep.requests["finished"],
        "shed": rep.shed_count,
        "shed_fraction": rep.shed_fraction,
        "shed_causes": rep.shed["causes"],
        "slot_failures": rep.faults.get("slot_failures", 0),
        "throttled_steps": rep.faults.get("throttled_steps", 0),
        "deadline_s": deadline_s,
        "overload_factor": 2.5,
        "wall_s": t,
        "events_per_s": events / t,
        "p99_latency_s": rep.latency["p99"],
    }


def bench_design_frontier() -> dict:
    """Design-space frontier smoke (repro.design): score the 64-point
    gap9-sweep generated space on the Table-2 grid and take the Pareto
    frontier.  Runs the scoring twice and asserts the frontiers are
    identical — the determinism the subsystem promises — while the
    trajectory records how much a 64-design sweep costs."""
    from repro.design import get_space, pareto, score_designs

    space = get_space("gap9-sweep")

    def run():
        return pareto(score_designs(space), workload="table2")

    front, t = _best_of(run, reps=2)
    again = pareto(score_designs(space), workload="table2")
    assert front.as_dict() == again.as_dict(), "frontier must be deterministic"
    assert front.frontier, "empty frontier on the gap9-sweep space"
    return {
        "designs": len(space),
        "frontier": len(front.frontier),
        "dominated": len(front.dominated),
        "wall_s": t,
        "designs_per_s": len(space) / t,
        "top_gops": front.frontier[0].throughput,
    }


def bench_obs_overhead() -> dict:
    """Observability tax (repro.obs): the Table-2 GEMM sweep timed with
    span tracing disabled (the shipping default) against the same sweep
    with the span entry point stubbed out entirely — the closest reachable
    approximation of un-instrumented code.  Disabled tracing must cost
    under 2% on the planner's hottest loop; the enabled-mode cost rides
    along in the trajectory (recorded, not asserted) so trace-buffer
    regressions show up per SHA too."""
    from repro import obs
    from repro.gemm import sweep
    from repro.obs.trace import _NULL

    probs = [row.problem for row in TABLE2]
    reps_inner = 25  # one sweep is ~4ms; batch them so 2% is above noise

    def run_sweeps():
        for _ in range(reps_inner):
            sweep(probs, backends=("analytic-gap8",), machines="gap8-fc",
                  cache=False)

    obs.disable()
    _, disabled_t = _best_of(run_sweeps, reps=5)
    stub, orig = (lambda *a, **k: _NULL), obs.span
    try:
        obs.span = stub
        _, stub_t = _best_of(run_sweeps, reps=5)
    finally:
        obs.span = orig
    obs.enable()
    try:
        _, enabled_t = _best_of(run_sweeps, reps=5)
    finally:
        obs.disable()
        obs.clear()
    overhead_pct = 100.0 * (disabled_t - stub_t) / stub_t
    assert overhead_pct < 2.0, (
        f"disabled-tracing overhead {overhead_pct:.2f}% >= 2% budget "
        f"(disabled {disabled_t:.4f}s vs stubbed {stub_t:.4f}s)")
    return {
        "sweeps": reps_inner,
        "stubbed_s": stub_t,
        "disabled_s": disabled_t,
        "enabled_s": enabled_t,
        "disabled_overhead_pct": overhead_pct,
        "enabled_overhead_pct": 100.0 * (enabled_t - stub_t) / stub_t,
        "budget_pct": 2.0,
    }


def bench_mixed_precision_sweep() -> dict:
    """Mixed-precision planning throughput (repro.core.precision): the
    Table-2 grid under three per-operand dtype configs on gap9-fc.  The
    quantize-traffic rows ride the same vectorized lattice, so the batch
    engine must keep both its speedup and its bit-identical selections."""
    from repro import machines
    from repro.core.precision import PrecisionConfig
    from repro.gemm.api import GemmProblem

    gap9 = machines.get("gap9-fc")
    configs = ["int8xint8", "int4xint8->int32", "f32xint8->int32"]
    probs = [GemmProblem.coerce((r.m, r.n, r.k), default_dtype="int8")
             .with_precision(PrecisionConfig.parse(c)).as_problem()
             for c in configs for r in TABLE2]

    def scalar():
        return [[best_microkernel_scalar(gap9, v, p) for p in probs]
                for v in Variant]

    def batched():
        return [best_microkernel_batch(gap9, v, probs) for v in Variant]

    s_out, s_t = _best_of(scalar)
    b_out, b_t = _best_of(batched)
    quant_s = total_s = 0.0
    for srow, brow in zip(s_out, b_out):
        for s, b in zip(srow, brow):
            assert s.micro_kernel == b.micro_kernel, "selection drift"
            assert s.total == b.total, "cost drift"
            quant_s += s.grouped()["quantize"]
            total_s += s.total
    return {"scalar_s": s_t, "batched_s": b_t, "speedup": s_t / b_t,
            "problems": len(probs), "grid_points": len(probs) * 3,
            "precision_configs": configs,
            "quantize_share": quant_s / total_s}


def main() -> None:
    table2 = bench_table2_gap8()
    allarch = bench_allarch_tpu()
    cold = bench_cold_tune()
    fidelity = bench_measure_fidelity()
    sim = bench_sim_latency()
    faults = bench_sim_faults()
    frontier = bench_design_frontier()
    obs_tax = bench_obs_overhead()
    mixed = bench_mixed_precision_sweep()
    combined_scalar = table2["scalar_s"] + allarch["scalar_s"]
    combined_batched = table2["batched_s"] + allarch["batched_s"]
    report = {
        "workloads": {
            "table2_gap8": table2,
            "allarch_tpu": allarch,
            "cold_tune": cold,
            "sim_latency": sim,
            "sim_faults": faults,
            "design_frontier": frontier,
            "obs_overhead": obs_tax,
            "mixed_precision_sweep": mixed,
        },
        "measure_fidelity": fidelity,
        "combined": {
            "scalar_s": combined_scalar,
            "batched_s": combined_batched,
            "speedup": combined_scalar / combined_batched,
        },
    }
    sha = git_sha()
    trajectory = load_trajectory(OUT_PATH)
    trajectory["records"] = (
        [r for r in trajectory["records"] if r.get("sha") != sha]
        + [{"sha": sha, **report}])
    tmp = OUT_PATH + ".tmp"
    with open(tmp, "w") as f:
        json.dump(trajectory, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, OUT_PATH)
    print(json.dumps(report, indent=1, sort_keys=True))
    print(f"\ncombined Table-2 + all-arch speedup: "
          f"{report['combined']['speedup']:.1f}x; smoke-campaign host MAPE "
          f"{fidelity['mape_pct']:.1f}%; sim {sim['events_per_s']:,.0f} "
          f"events/s; storm overload shed {faults['shed_fraction']:.0%} "
          f"with 0 unfinished; design frontier "
          f"{frontier['designs_per_s']:.0f} designs/s "
          f"({frontier['frontier']}/{frontier['designs']} on frontier); "
          f"obs tax {obs_tax['disabled_overhead_pct']:.2f}% disabled / "
          f"{obs_tax['enabled_overhead_pct']:.1f}% enabled; "
          f"mixed-precision sweep {mixed['speedup']:.1f}x batched "
          f"({mixed['quantize_share']:.0%} quantize share) "
          f"(record {sha[:12]} appended to {os.path.abspath(OUT_PATH)}; "
          f"{len(trajectory['records'])} records in trajectory)")


if __name__ == "__main__":
    main()
