"""AdamW in pure JAX, with global-norm clipping and dtype-configurable
moments (kimi-k2 keeps m/v in bf16: 1T params' f32 moments would not fit the
pod; DESIGN.md §4)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"


def init_opt_state(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_specs(param_specs):
    """Moments shard exactly like their parameters."""
    from jax.sharding import PartitionSpec as P
    return {
        "m": param_specs,
        "v": param_specs,
        "step": P(),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, opt_state, params, lr, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    mdt = jnp.dtype(cfg.moment_dtype)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:   # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(mdt), v_new.astype(mdt)

    flat = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm}


def lr_schedule(step, *, base_lr: float, warmup: int, total: int,
                min_ratio: float = 0.1):
    """Linear warmup -> cosine decay to min_ratio * base_lr."""
    step = step.astype(jnp.float32)
    warm = base_lr * step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5
                     * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)
