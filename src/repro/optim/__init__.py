from repro.optim.adamw import (
    AdamWConfig,
    adamw_update,
    global_norm,
    init_opt_state,
    lr_schedule,
    opt_state_specs,
)
from repro.optim.compression import (
    compress_tree,
    decompress_tree,
    init_error_buffer,
    psum_compressed,
    quantize_int8,
)

__all__ = [
    "AdamWConfig", "adamw_update", "global_norm", "init_opt_state",
    "lr_schedule", "opt_state_specs", "compress_tree", "decompress_tree",
    "init_error_buffer", "psum_compressed", "quantize_int8",
]
