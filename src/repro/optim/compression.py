"""int8 error-feedback gradient compression for cross-pod reduction.

At 2+ pods the data-parallel gradient all-reduce crosses the (slow) pod
interconnect.  ``compress``/``decompress`` quantise gradients to int8 with a
per-tensor scale; the quantisation error is fed back into the next step's
gradient (error feedback), which keeps SGD/Adam convergence (Karimireddy et
al., 2019).  Wired into the train step when
``ParallelConfig.grad_compression == "int8_ef"`` — the psum then moves 1/4
of the bytes on the pod axis, directly shrinking the roofline's collective
term (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    """x: float array -> (int8 values, f32 scale). Symmetric per-tensor."""
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_tree(grads, error_buf):
    """Apply error feedback then quantise every leaf.

    Returns (quantised tree of (q, scale), new error buffer)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        return (q, s), corrected - deq

    pairs = jax.tree.map(one, grads, error_buf)
    qtree = jax.tree.map(lambda t: t[0], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    etree = jax.tree.map(lambda t: t[1], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return qtree, etree


def decompress_tree(qtree, like):
    return jax.tree.map(
        lambda qs, g: dequantize_int8(qs[0], qs[1]).astype(g.dtype),
        qtree, like, is_leaf=lambda x: isinstance(x, tuple))


def psum_compressed(grads, error_buf, axis_name: str):
    """Error-feedback int8 all-reduce over ``axis_name`` (use inside
    shard_map).  int8 payloads are summed in int32 (no overflow for the
    axis sizes used here), then dequantised with the mean scale."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        local_deq = dequantize_int8(q, s)
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        s_mean = jax.lax.pmean(s, axis_name)       # scales are near-equal
        g_sum = q_sum.astype(jnp.float32) * s_mean
        return g_sum.astype(g.dtype), corrected - local_deq

    pairs = jax.tree.map(one, grads, error_buf)
    gtree = jax.tree.map(lambda t: t[0], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    etree = jax.tree.map(lambda t: t[1], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return gtree, etree


def init_error_buffer(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
