"""Mixture-of-Experts block: top-k router + capacity-based dispatch.

Dispatch is GShard/Switch-style with a fixed per-expert capacity so all
shapes are static, and — crucially for SPMD — it is **per-sequence**: the
scatter/gather that routes tokens into expert buffers carries the batch
dimension, so each data shard dispatches its own sequences locally.  (The
first implementation dispatched over the flattened global token axis; the
data-dependent scatter then defeated the partitioner, which replicated the
whole dispatch on every device — ~500x redundant compute and a 250 s
collective term on granite train_4k.  See EXPERIMENTS.md §Perf, iteration
G1.)  Capacity is enforced per sequence; overflow tokens fall back to the
residual path.

Expert FFNs run as one batched einsum over the expert dimension —
expert-parallel when ``n_experts`` divides the model axis (kimi-k2: 384/16),
TP-inside-expert otherwise (granite's 40 experts shard ``moe_d_ff``
instead; DESIGN.md §5).  The expert GEMM is exactly the shape class the
paper's TileTuner optimises.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import gemm as gemm_api
from repro.models.common import MeshInfo, dense_init


def padded_experts(cfg, mesh: MeshInfo) -> int:
    """Physical expert count: padded up to a model-axis multiple so the
    expert dim shards and the EP all-to-all path applies (granite's 40 -> 48
    on a 16-way axis).  Dead experts get -inf router logits, so routing is
    exactly the logical model's (EXPERIMENTS.md §Perf iteration G3)."""
    e, m = cfg.n_experts, mesh.model
    if m > 1 and e % m:
        return m * ((e + m - 1) // m)
    return e


def init_moe(key, cfg, mesh: MeshInfo, dtype):
    d, f = cfg.d_model, cfg.moe_d_ff
    e0 = cfg.n_experts
    e = padded_experts(cfg, mesh)
    e_ax = mesh.shard_if(e)
    f_ax = mesh.shard_if(f) if e_ax is None else None   # TP fallback
    fsdp = mesh.fsdp_if(d)
    ks = jax.random.split(key, 4)

    def pad_e(p, axis):
        """Draw logical-shape weights, zero-pad the expert dim — identical
        logical parameters regardless of mesh (dead experts stay zero: they
        receive no tokens, hence no gradient)."""
        if e == e0:
            return p
        pads = [(0, 0)] * p.value.ndim
        pads[axis] = (0, e - e0)
        from repro.models.common import Param
        return Param(jnp.pad(p.value, pads), p.spec)

    return {
        "router": pad_e(dense_init(ks[0], d, (d, e0), P(fsdp, None),
                                   jnp.float32), 1),
        "w_gate": pad_e(dense_init(ks[1], d, (e0, d, f),
                                   P(e_ax, fsdp, f_ax), dtype), 0),
        "w_up": pad_e(dense_init(ks[2], d, (e0, d, f),
                                 P(e_ax, fsdp, f_ax), dtype), 0),
        "w_down": pad_e(dense_init(ks[3], f, (e0, f, d),
                                   P(e_ax, f_ax, fsdp), dtype), 0),
    }


def _masked_router_logits(params, x, cfg):
    """Router logits over physical experts; padded tail masked to -inf."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"])
    e_phys = logits.shape[-1]
    if e_phys > cfg.n_experts:
        mask = jnp.arange(e_phys) >= cfg.n_experts
        logits = jnp.where(mask, -1e9, logits)
    return logits


def _capacity(tokens: int, cfg) -> int:
    c = int(cfg.capacity_factor * tokens * cfg.experts_per_token / cfg.n_experts)
    return max(8, (c + 7) // 8 * 8)


def _constrain(val, mesh: MeshInfo | None, spec: P):
    """with_sharding_constraint when a real mesh is ambient (the scatter's
    output sharding does not propagate through vmapped scatters; without the
    constraint the SPMD partitioner replicates the dispatch buffers —
    EXPERIMENTS.md §Perf iteration G2)."""
    if mesh is None or (mesh.data == 1 and mesh.model == 1):
        return val
    return jax.lax.with_sharding_constraint(val, spec)


def apply_moe(params, x, cfg, mesh: MeshInfo | None = None):
    """x: (B, S, D) -> (y, aux_loss).  Router in f32 for stability."""
    b, s, d = x.shape
    e, k = params["router"].shape[-1], cfg.experts_per_token
    cap = _capacity(s, cfg)

    logits = _masked_router_logits(params, x, cfg)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (B,S,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (per sequence, then mean)
    me = probs.mean(axis=1)                                  # (B,E)
    ce = jax.nn.one_hot(expert_idx[:, :, 0], e,
                        dtype=jnp.float32).mean(axis=1)      # (B,E)
    aux = cfg.router_aux_coef * e * jnp.mean(jnp.sum(me * ce, axis=-1))

    # --- per-sequence dispatch (batched scatter: local per data shard) ----
    flat_e = expert_idx.reshape(b, s * k)                    # (B, S*k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)      # (B, S*k, E)
    pos_all = jnp.cumsum(onehot, axis=1) - 1
    pos = jnp.take_along_axis(pos_all, flat_e[..., None],
                              axis=2)[..., 0]                # (B, S*k)
    keep = pos < cap
    safe_pos = jnp.where(keep, pos, 0)
    tok_idx = jnp.repeat(jnp.arange(s), k)                   # (S*k,)

    def scatter_one(xt, fe, sp, kp):
        src = jnp.where(kp[:, None], xt[tok_idx], 0).astype(xt.dtype)
        return jnp.zeros((e, cap, d), xt.dtype).at[fe, sp].add(src)

    buf = jax.vmap(scatter_one)(x, flat_e, safe_pos, keep)   # (B,E,cap,D)
    if mesh is not None:
        e_ax = mesh.shard_if(e)
        buf = _constrain(buf, mesh, P(mesh.dp(), e_ax, None, None))

    # --- expert FFN (SwiGLU), batched over experts ------------------------
    if mesh is None or (mesh.data == 1 and mesh.model == 1):
        # single host: route through the unified GEMM API (planned grouped
        # kernels — the shape class the paper's TileTuner optimises).
        g = gemm_api.grouped_matmul(buf, params["w_gate"])
        u = gemm_api.grouped_matmul(buf, params["w_up"])
        h = jax.nn.silu(g) * u
        out_buf = gemm_api.grouped_matmul(h, params["w_down"])
    else:
        # under a real mesh the einsum form stays: the SPMD partitioner
        # sees one op to shard over the expert axis.
        g = jnp.einsum("becd,edf->becf", buf, params["w_gate"])
        u = jnp.einsum("becd,edf->becf", buf, params["w_up"])
        h = jax.nn.silu(g) * u
        out_buf = jnp.einsum("becf,efd->becd", h, params["w_down"])
    if mesh is not None:
        out_buf = _constrain(out_buf, mesh,
                             P(mesh.dp(), mesh.shard_if(e), None, None))

    # --- combine (batched gather + gate weighting) ------------------------
    # The whole combine stays in bf16: the (S*k, D) gathered tensor crosses
    # the model axis (partial sums over expert shards), and in f32 its
    # forward+cotangent all-reduces dominated kimi-k2's collective term
    # (EXPERIMENTS.md §Perf iteration K1: 2x payload reduction).  The
    # gate-weighted sum has <= top_k terms per token — bf16-safe.
    def gather_one(ob, fe, sp, kp, gv):
        eo = ob[fe, sp]                                      # (S*k, D) bf16
        gvb = gv.reshape(-1).astype(ob.dtype)
        contrib = jnp.where(kp[:, None], eo, 0) * gvb[:, None]
        return jnp.zeros((s, d), ob.dtype).at[tok_idx].add(contrib)

    y = jax.vmap(gather_one)(out_buf, flat_e, safe_pos, keep, gate_vals)
    if mesh is not None:
        y = _constrain(y, mesh, P(mesh.dp(), None, None))
    return y.astype(x.dtype), aux


# ---------------------------------------------------------------------------
# True expert-parallel path (shard_map + all_to_all)
# ---------------------------------------------------------------------------


def ep_applicable(cfg, mesh: MeshInfo | None, seq_len: int) -> bool:
    if mesh is None or mesh.model <= 1 or seq_len % mesh.model:
        return False
    return padded_experts(cfg, mesh) % mesh.model == 0


def apply_moe_ep(params, x, cfg, mesh: MeshInfo):
    """Expert-parallel MoE via ``shard_map``: sequence-split routing + two
    ``all_to_all`` exchanges (dispatch / return).

    Under plain pjit the cross-expert-shard combine lowers to all-reduces of
    the full (B, S*k, D) activation (f32-promoted on top): kimi-k2's
    dominant collective.  Here each (data, model) device routes its own
    S/model-axis token slice, ships expert inputs directly to their owner
    shard and back — payload = tokens x top_k x D in bf16, no reduction op
    at all.  EXPERIMENTS.md §Perf iteration K2 (~7x on kimi's collective
    term).  Capacity is enforced per sequence-chunk (S/M tokens).
    """
    b, s, d = x.shape
    e, k = padded_experts(cfg, mesh), cfg.experts_per_token
    m_ax = mesh.model_axis
    mm = mesh.model
    e_loc = e // mm
    s_loc = s // mm
    cap = _capacity(s_loc, cfg)
    dp = mesh.dp()
    tok_idx = jnp.repeat(jnp.arange(s_loc), k)

    def body(router, w_gate, w_up, w_down, xs):
        # xs: (B_loc, S/M, D) — this device's sequence slice.
        bl = xs.shape[0]
        logits = _masked_router_logits({"router": router}, xs, cfg)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(axis=-1, keepdims=True), 1e-9)
        me = probs.mean(axis=1)
        ce = jax.nn.one_hot(expert_idx[:, :, 0], e,
                            dtype=jnp.float32).mean(axis=1)
        aux = cfg.router_aux_coef * e * jnp.mean(jnp.sum(me * ce, axis=-1))
        aux = jax.lax.pmean(jax.lax.pmean(aux, m_ax), dp)

        flat_e = expert_idx.reshape(bl, s_loc * k)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=1) - 1,
                                  flat_e[..., None], axis=2)[..., 0]
        keep = pos < cap
        safe_pos = jnp.where(keep, pos, 0)

        def scatter_one(xt, fe, sp, kp):
            src = jnp.where(kp[:, None], xt[tok_idx], 0).astype(xt.dtype)
            return jnp.zeros((e, cap, d), xt.dtype).at[fe, sp].add(src)

        buf = jax.vmap(scatter_one)(xs, flat_e, safe_pos, keep)  # (B,E,cap,D)
        # dispatch: experts go to their owner shard; sources stack on axis 1
        buf = buf.reshape(bl, mm, e_loc, cap, d)
        buf = jax.lax.all_to_all(buf, m_ax, split_axis=1, concat_axis=1,
                                 tiled=False)                  # (B,M_src,E_loc,cap,D)

        g = jnp.einsum("bmecd,edf->bmecf", buf, w_gate)
        u = jnp.einsum("bmecd,edf->bmecf", buf, w_up)
        h = jax.nn.silu(g) * u
        ob = jnp.einsum("bmecf,efd->bmecd", h, w_down)
        # return trip
        ob = jax.lax.all_to_all(ob, m_ax, split_axis=1, concat_axis=1,
                                tiled=False)
        ob = ob.reshape(bl, e, cap, d)

        def gather_one(o1, fe, sp, kp, gv):
            eo = o1[fe, sp]
            gvb = gv.reshape(-1).astype(o1.dtype)
            contrib = jnp.where(kp[:, None], eo, 0) * gvb[:, None]
            return jnp.zeros((s_loc, d), o1.dtype).at[tok_idx].add(contrib)

        y = jax.vmap(gather_one)(ob, flat_e, safe_pos, keep, gate_vals)
        return y.astype(xs.dtype), aux

    from jax.experimental.shard_map import shard_map
    from repro.runtime.sharding import ambient_mesh
    mesh_ctx = ambient_mesh()
    if mesh_ctx is None:
        raise RuntimeError(
            "apply_moe_ep needs an ambient mesh; wrap the call in "
            "`with repro.runtime.sharding.use_mesh(mesh):`")
    fn = shard_map(
        body,
        mesh=mesh_ctx,
        in_specs=(P(), P(mesh.model_axis, None, None),
                  P(mesh.model_axis, None, None),
                  P(mesh.model_axis, None, None),
                  P(dp, mesh.model_axis, None)),
        out_specs=(P(dp, mesh.model_axis, None), P()),
        check_rep=False,
    )
    y, aux = fn(params["router"], params["w_gate"], params["w_up"],
                params["w_down"], x)
    return y, aux
