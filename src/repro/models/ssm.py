"""Mamba2 (SSD) block: chunked-scan training path + recurrent decode path.

The SSD (state-space duality) recurrence per head (state ``h``: P x N):

    h_t = exp(a_t) * h_{t-1} + dt_t * (x_t  (x)  B_t)         a_t = dt_t * A
    y_t = (h_t @ C_t) + D * x_t

Training uses the chunked algorithm: intra-chunk quadratic term + inter-chunk
state carried by ``lax.scan`` (sub-quadratic in sequence length — this is why
the hybrid/SSM archs run the ``long_500k`` cell).  ``ssd_chunked`` is shared
with the mLSTM block (models/xlstm.py), whose matrix-memory recurrence is the
same computation with (q, k, v) playing (C, B, x) and sigmoid gates playing
(exp(a), dt).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import MeshInfo, Param, dense_init, ones_init, zeros_init


# ---------------------------------------------------------------------------
# Shared chunked-SSD core
# ---------------------------------------------------------------------------


def ssd_chunked(xh, a, dt, Bm, Cm, chunk: int, h0=None):
    """Chunked SSD scan.

    xh: (B, S, H, P)   per-head inputs ("v" in attention terms)
    a:  (B, S, H)      log-decay per step (<= 0)
    dt: (B, S, H)      input gate
    Bm: (B, S, H, N)   input mixing ("k"; broadcast over H for mamba2 groups=1)
    Cm: (B, S, H, N)   output mixing ("q")
    h0: optional initial state (B, H, P, N)

    Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    b, s, h, p = xh.shape
    n = Bm.shape[-1]
    nc = math.ceil(s / chunk)
    pad = nc * chunk - s
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    L = chunk
    xc = xh.reshape(b, nc, L, h, p).astype(jnp.float32)
    ac = a.reshape(b, nc, L, h).astype(jnp.float32)
    dtc = dt.reshape(b, nc, L, h).astype(jnp.float32)
    Bc = Bm.reshape(b, nc, L, h, n).astype(jnp.float32)
    Cc = Cm.reshape(b, nc, L, h, n).astype(jnp.float32)

    cum = jnp.cumsum(ac, axis=2)                       # (B,C,L,H)
    # intra-chunk "attention": att[i,j] = exp(cum_i - cum_j) dt_j (C_i.B_j), j<=i
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,C,L,L,H)
    causal = jnp.tril(jnp.ones((L, L), dtype=bool))[None, None, :, :, None]
    dec = jnp.where(causal, jnp.exp(jnp.minimum(seg, 0.0)), 0.0)
    cb = jnp.einsum("bcihn,bcjhn->bcijh", Cc, Bc)        # (B,C,L,L,H)
    att = dec * cb * dtc[:, :, None, :, :]              # (B,C,L,L,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att, xc)

    # per-chunk aggregated state: S_c = sum_j exp(cum_L - cum_j) dt_j x_j (x) B_j
    tail = jnp.exp(cum[:, :, -1:, :] - cum) * dtc        # (B,C,L,H)
    s_chunk = jnp.einsum("bclh,bclhp,bclhn->bchpn", tail, xc, Bc)
    a_chunk = jnp.exp(cum[:, :, -1, :])                  # (B,C,H) total decay

    def step(hprev, inp):
        s_c, a_c = inp                                   # (B,H,P,N), (B,H)
        hnew = hprev * a_c[:, :, None, None] + s_c
        return hnew, hprev

    h_init = (jnp.zeros((b, h, p, n), dtype=jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    h_last, h_befores = jax.lax.scan(
        step, h_init,
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(a_chunk, 1, 0)))
    h_befores = jnp.moveaxis(h_befores, 0, 1)            # (B,C,H,P,N)

    # inter-chunk contribution: y_i += C_i . (exp(cum_i) * h_before)
    y_inter = jnp.einsum("bcihn,bchpn,bcih->bcihp",
                         Cc, h_befores, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(b, nc * L, h, p)
    return y[:, :s].astype(xh.dtype), h_last


def ssd_decode_step(h, x_t, a_t, dt_t, B_t, C_t):
    """One recurrent step.  h: (B,H,P,N); x_t: (B,H,P); a/dt: (B,H);
    B_t/C_t: (B,H,N).  Returns (y_t (B,H,P), h_new)."""
    hf = h.astype(jnp.float32)
    contrib = (dt_t[:, :, None, None] * x_t[:, :, :, None].astype(jnp.float32)
               * B_t[:, :, None, :].astype(jnp.float32))
    h_new = hf * jnp.exp(a_t.astype(jnp.float32))[:, :, None, None] + contrib
    y = jnp.einsum("bhpn,bhn->bhp", h_new, C_t.astype(jnp.float32))
    return y.astype(x_t.dtype), h_new


# ---------------------------------------------------------------------------
# Causal depthwise conv (width cfg.ssm_conv) with decode cache
# ---------------------------------------------------------------------------


def causal_conv(x, w, b):
    """x: (B, S, C); w: (K, C); b: (C,) — depthwise causal conv."""
    k = w.shape[0]
    w = w.astype(x.dtype)
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return out + b.astype(x.dtype)


def causal_conv_step(cache, x_t, w, b):
    """cache: (B, K-1, C); x_t: (B, 1, C) -> (y_t, new_cache)."""
    window = jnp.concatenate([cache.astype(x_t.dtype), x_t], axis=1)  # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", window, w.astype(x_t.dtype))[:, None, :] \
        + b.astype(x_t.dtype)
    return y, window[:, 1:, :]


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------


def init_mamba2(key, cfg, mesh: MeshInfo, dtype):
    d, di, n, hh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    in_ax = mesh.shard_if(di)
    h_ax = mesh.shard_if(hh)
    fsdp = mesh.fsdp_if(d)
    ks = jax.random.split(key, 8)
    conv_ch = di  # conv over the x stream only (B/C kept conv-free for TP)
    return {
        "w_z": dense_init(ks[0], d, (d, di), P(fsdp, in_ax), dtype),
        "w_x": dense_init(ks[1], d, (d, di), P(fsdp, in_ax), dtype),
        "w_B": dense_init(ks[2], d, (d, n), P(fsdp, None), dtype),
        "w_C": dense_init(ks[3], d, (d, n), P(fsdp, None), dtype),
        "w_dt": dense_init(ks[4], d, (d, hh), P(fsdp, h_ax), dtype),
        "dt_bias": zeros_init((hh,), P(h_ax), jnp.float32),
        "A_log": Param(jnp.zeros((hh,), jnp.float32)
                       + jnp.log(jnp.arange(1, hh + 1, dtype=jnp.float32)),
                       P(h_ax)),
        "Dskip": ones_init((hh,), P(h_ax), jnp.float32),
        "conv_w": Param(jax.random.normal(ks[5], (cfg.ssm_conv, conv_ch),
                                          dtype=jnp.float32).astype(dtype)
                        * (1.0 / math.sqrt(cfg.ssm_conv)), P(None, in_ax)),
        "conv_b": zeros_init((conv_ch,), P(in_ax), dtype),
        "w_out": dense_init(ks[6], di, (di, d), P(in_ax, fsdp), dtype),
        "norm_scale": ones_init((di,), P(in_ax), dtype),
    }


def _mamba2_inner(params, x, cfg):
    z = x @ params["w_z"]
    xs = x @ params["w_x"]
    Bm = x @ params["w_B"]
    Cm = x @ params["w_C"]
    dt_raw = x @ params["w_dt"]
    return z, xs, Bm, Cm, dt_raw


def _gated_out(params, y, z, cfg, b, s):
    di = cfg.d_inner
    y = y.reshape(b, s, di)
    # grouped RMSNorm then gate (mamba2's norm-before-gate)
    yf = y.astype(jnp.float32)
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    scale = params["norm_scale"].astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(ms + cfg.norm_eps) * scale).astype(z.dtype)
    y = y * jax.nn.silu(z)
    return y @ params["w_out"]


def apply_mamba2(params, x, cfg):
    """Training / prefill path.  x: (B, S, D) -> (y, h_final, conv_tail)."""
    b, s, _ = x.shape
    hh, p = cfg.ssm_heads, cfg.ssm_head_dim
    z, xs, Bm, Cm, dt_raw = _mamba2_inner(params, x, cfg)
    xs_conv = jax.nn.silu(causal_conv(xs, params["conv_w"], params["conv_b"]))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])[None, None, :] * dt     # (B,S,H)
    xh = xs_conv.reshape(b, s, hh, p)
    n = cfg.ssm_state
    Bh = jnp.broadcast_to(Bm[:, :, None, :], (b, s, hh, n))  # groups=1
    Ch = jnp.broadcast_to(Cm[:, :, None, :], (b, s, hh, n))
    y, h_last = ssd_chunked(xh, a, dt, Bh, Ch, cfg.ssm_chunk)
    y = y + params["Dskip"][None, None, :, None] * xh.astype(jnp.float32)
    out = _gated_out(params, y.astype(x.dtype), z, cfg, b, s)
    conv_tail = xs[:, -(cfg.ssm_conv - 1):, :] if s >= cfg.ssm_conv - 1 else \
        jnp.pad(xs, ((0, 0), (cfg.ssm_conv - 1 - s, 0), (0, 0)))
    return out, h_last, conv_tail


def init_mamba2_cache(cfg, mesh: MeshInfo, batch: int, dtype,
                      batch_shard: bool = True):
    di, hh, p, n = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    in_ax = mesh.shard_if(di)
    h_ax = mesh.shard_if(hh)
    dp = mesh.dp() if batch_shard else None
    return {
        "h": Param(jnp.zeros((batch, hh, p, n), jnp.float32),
                   P(dp, h_ax, None, None)),
        "conv": Param(jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
                      P(dp, None, in_ax)),
    }


def decode_mamba2(params, cache, x, cfg):
    """One-token decode.  x: (B, 1, D) -> (y (B,1,D), new_cache)."""
    b = x.shape[0]
    hh, p = cfg.ssm_heads, cfg.ssm_head_dim
    z, xs, Bm, Cm, dt_raw = _mamba2_inner(params, x, cfg)
    xc, conv_new = causal_conv_step(cache["conv"], xs,
                                    params["conv_w"], params["conv_b"])
    xc = jax.nn.silu(xc)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])[None, :] * dt           # (B,H)
    xh = xc.reshape(b, hh, p)
    n = cfg.ssm_state
    Bh = jnp.broadcast_to(Bm[:, 0, None, :], (b, hh, n))
    Ch = jnp.broadcast_to(Cm[:, 0, None, :], (b, hh, n))
    y, h_new = ssd_decode_step(cache["h"], xh, a, dt, Bh, Ch)
    y = y + params["Dskip"][None, :, None] * xh.astype(jnp.float32)
    out = _gated_out(params, y[:, None].astype(x.dtype), z, cfg, b, 1)
    return out, {"h": h_new, "conv": conv_new}
