"""Core layers: norms, rotary embeddings, MLPs, embedding / logits heads.

All matmul-shaped operations route through the unified plan/execute API
(``repro.gemm.matmul``) so the analytic tile decisions (the paper's
technique) apply framework-wide; on the CPU/dry-run path the planner picks
the ``reference`` backend (XLA-native jnp dot), keeping 512-device SPMD
lowering clean (DESIGN.md §3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import gemm as gemm_api
from repro.models.common import (
    MeshInfo,
    Param,
    dense_init,
    embed_init,
    ones_init,
    zeros_init,
)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(cfg, mesh: MeshInfo, dtype):
    p = {"scale": ones_init((cfg.d_model,), P(None), dtype)}
    if cfg.norm_type == "layernorm":
        p["bias"] = zeros_init((cfg.d_model,), P(None), dtype)
    return p


def apply_norm(params, x, cfg):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * params["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_tables(positions, head_dim: int, theta: float):
    """positions: (...,) int32 -> (sin, cos) of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, sin, cos):
    """x: (..., seq, heads, head_dim); sin/cos: (..., seq, head_dim//2).
    Rotation in f32, result cast back to x.dtype."""
    half = x.shape[-1] // 2
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., :half], xf[..., half:]
    s = sin[..., None, :]  # broadcast over heads axis
    c = cos[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeLU)
# ---------------------------------------------------------------------------


def init_mlp(key, cfg, mesh: MeshInfo, dtype, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ff_ax = mesh.shard_if(f)
    fsdp = mesh.fsdp_if(d)
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(k1, d, (d, f), P(fsdp, ff_ax), dtype),
        "w_down": dense_init(k2, f, (f, d), P(ff_ax, fsdp), dtype),
    }
    if cfg.act in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(k3, d, (d, f), P(fsdp, ff_ax), dtype)
    return p


def apply_mlp(params, x, cfg):
    up = gemm_api.matmul(x, params["w_up"])
    if cfg.act == "swiglu":
        h = jax.nn.silu(gemm_api.matmul(x, params["w_gate"])) * up
    elif cfg.act == "geglu":
        h = jax.nn.gelu(gemm_api.matmul(x, params["w_gate"])) * up
    else:
        h = jax.nn.gelu(up)
    return gemm_api.matmul(h, params["w_down"])


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------


def init_embedding(key, cfg, mesh: MeshInfo, dtype):
    v = cfg.padded_vocab
    vax = mesh.shard_if(v)
    fsdp = mesh.fsdp_if(cfg.d_model)
    k1, k2 = jax.random.split(key)
    p = {"table": embed_init(k1, v, cfg.d_model, P(vax, fsdp), dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(k2, cfg.d_model, (cfg.d_model, v),
                                  P(fsdp, vax), dtype)
    return p


def embed_tokens(params, token_ids, cfg):
    return jnp.take(params["table"], token_ids, axis=0)


def logits_head(params, x, cfg):
    """x: (..., d) -> (..., padded_vocab); soft-capped if configured."""
    if cfg.tie_embeddings:
        logits = gemm_api.matmul(x, params["table"].T)
    else:
        logits = gemm_api.matmul(x, params["unembed"])
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def cross_entropy(logits, labels, vocab_size: int, z_coef: float = 1e-4,
                  mask=None):
    """Next-token CE over the *logical* vocab (padded tail masked out).

    logits: (B, S, Vp) f32/bf16; labels: (B, S) int32.  Returns scalar mean
    loss (+ small z-loss for logit drift) over unmasked positions.
    """
    logits = logits.astype(jnp.float32)
    vp = logits.shape[-1]
    if vp > vocab_size:
        neg = jnp.full((vp - vocab_size,), -1e9, dtype=logits.dtype)
        logits = logits.at[..., vocab_size:].set(neg)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    z = z_coef * jnp.square(lse)
    per_tok = nll + z
    if mask is None:
        return per_tok.mean()
    mask = mask.astype(jnp.float32)
    return (per_tok * mask).sum() / jnp.maximum(mask.sum(), 1.0)
