"""repro.models subpackage."""
