"""Modality frontends — STUBS per the assignment.

The [audio]/[vlm] architecture entries specify the transformer backbone only;
``input_specs()`` provides *precomputed* frame/patch embeddings.  These stubs
project the provided embeddings into the backbone width (a single learned
linear + norm), so the backbone remains end-to-end trainable while the real
EnCodec/SigLIP towers stay out of scope.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import MeshInfo, dense_init


def init_frontend(key, cfg, mesh: MeshInfo, dtype):
    if cfg.frontend == "none":
        return {}
    d = cfg.d_model
    return {"proj": dense_init(key, d, (d, d), P(None, None), dtype)}


def apply_frontend(params, embeddings, cfg):
    """embeddings: (B, T, D) precomputed frame/patch features -> (B, T, D)."""
    return embeddings @ params["proj"]
