"""GQA attention with RoPE, prefix-LM masks, KV caches and long-context decode.

Scalability decisions (DESIGN.md §5):

* **Head padding for TP** — query heads are padded up to a multiple of the
  model axis (qwen2-7b's 28 heads -> 32 on a 16-way axis).  Padded heads have
  zero output-projection rows, so results are exact; the cost is the padded
  fraction of attention FLOPs, far cheaper than replicating attention.
* **KV replication for narrow GQA** — when kv_heads doesn't divide the model
  axis (kv=1..8 vs 16), KV projections/caches replicate across TP, the
  standard Megatron GQA treatment.
* **Blockwise softmax** — the full-sequence path processes KV in chunks with
  a running (max, sum, acc) online softmax, so 32k-token prefill never
  materialises an S x S score matrix.  This is the pure-jnp twin of
  ``kernels/flash_attention.py`` (used on the dry-run path).
* **Sequence-parallel decode** — for ``long_500k`` the KV cache's sequence
  axis is sharded over the data axis; softmax over the sharded axis lowers to
  partial reductions + a tiny all-reduce (flash-decoding's LSE combine, done
  by the SPMD partitioner).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import MeshInfo, Param, dense_init, zeros_init
from repro.models.layers import apply_rope, rope_tables

NEG_INF = -1e30


def head_layout(cfg, mesh: MeshInfo) -> tuple[int, int]:
    """(hq_padded, hkv_padded) for TP.

    * both divisible by the model axis -> no padding;
    * MHA (kv == q heads) -> pad both to the axis multiple;
    * GQA -> replicate KV, pad query heads *per KV group* so the grouping
      ``q_head -> q_head // n_rep`` survives padding (n_rep stays integral).
    Padded positions are zero-initialised in wq/bq/wo (and wk/wv for padded
    KV), so forward results are exactly the unpadded model's.
    """
    tp = mesh.model
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    if hq % tp == 0 and (hkv % tp == 0 or hkv == hq):
        return hq, hkv
    if hkv == hq:                                   # MHA: pad both
        h = tp * math.ceil(hq / tp)
        return h, h
    g = math.gcd(hkv, tp)
    step = tp // g
    r = hq // hkv                                   # reps per KV group
    rp = step * math.ceil(r / step)
    return hkv * rp, hkv


def _scatter_heads(out, w, idx, axis):
    """Place w's head slices at positions ``idx`` along ``axis`` of out."""
    return out.at[(slice(None),) * axis + (idx,)].set(w)


def init_attention(key, cfg, mesh: MeshInfo, dtype):
    d, hd = cfg.d_model, cfg.head_dim
    hq0, hkv0 = cfg.n_heads, cfg.n_kv_heads
    hq, hkv = head_layout(cfg, mesh)
    h_ax = mesh.shard_if(hq)                  # always shardable after padding
    kv_ax = mesh.shard_if(hkv)                # may be None (replicated KV)
    fsdp = mesh.fsdp_if(d)
    ks = jax.random.split(key, 8)

    r0 = hq0 // hkv0
    rp = hq // hkv if hkv else 1

    def pad_q(w, head_axis):
        """w has hq0 logical heads on ``head_axis``; insert zero heads at the
        end of each KV group (and append zero groups if hkv > hkv0)."""
        if hq == hq0:
            return w
        shape = list(w.shape)
        shape[head_axis] = hq
        out = jnp.zeros(shape, w.dtype)
        # grouped layout: logical head (g, i) -> padded index g * rp + i
        idx = (jnp.arange(hq0) // r0) * rp + (jnp.arange(hq0) % r0)
        return _scatter_heads(out, w, idx, head_axis)

    def pad_kv(w, head_axis):
        if hkv == hkv0:
            return w
        shape = list(w.shape)
        shape[head_axis] = hkv
        out = jnp.zeros(shape, w.dtype)
        idx = jnp.arange(hkv0)
        return _scatter_heads(out, w, idx, head_axis)

    wq = dense_init(ks[0], d, (d, hq0, hd), P(fsdp, h_ax, None), dtype)
    wk = dense_init(ks[1], d, (d, hkv0, hd), P(fsdp, kv_ax, None), dtype)
    wv = dense_init(ks[2], d, (d, hkv0, hd), P(fsdp, kv_ax, None), dtype)
    wo = dense_init(ks[3], hq0 * hd, (hq0, hd, d), P(h_ax, None, fsdp), dtype)
    p = {
        "wq": Param(pad_q(wq.value, 1), wq.spec),
        "wk": Param(pad_kv(wk.value, 1), wk.spec),
        "wv": Param(pad_kv(wv.value, 1), wv.spec),
        "wo": Param(pad_q(wo.value, 0), wo.spec),
    }
    if cfg.qkv_bias:
        p["bq"] = Param(jnp.zeros((hq, hd), dtype), P(h_ax, None))
        p["bk"] = Param(jnp.zeros((hkv, hd), dtype), P(kv_ax, None))
        p["bv"] = Param(jnp.zeros((hkv, hd), dtype), P(kv_ax, None))
    return p


def _project_qkv(params, x, cfg, positions):
    """x: (B, S, D) -> q (B,S,Hq,hd), k/v (B,S,Hkv,hd), with RoPE applied."""
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, params["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    sin, cos = rope_tables(positions, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    return q, k, v


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)
                            ).reshape(b, s, h * n_rep, d)


def blockwise_attention(q, k, v, *, chunk: int, causal: bool,
                        prefix_len: int = 0, q_offset: int = 0):
    """Online-softmax attention over KV chunks; O(S*chunk) memory.

    q: (B, Sq, H, hd); k, v: (B, Skv, H, hd) (KV already repeated to H).
    ``causal`` masks with query positions offset by ``q_offset``;
    ``prefix_len`` positions attend bidirectionally (prefix-LM / PaliGemma).
    """
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    scale = hd ** -0.5
    qf = (q * scale).astype(jnp.float32)
    chunk = min(chunk, skv)
    n_chunks = math.ceil(skv / chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, chunk, h, hd).astype(jnp.float32)
    vc = v.reshape(b, n_chunks, chunk, h, hd).astype(jnp.float32)

    q_pos = q_offset + jnp.arange(sq)

    def step(carry, inputs):
        m, l, acc = carry
        idx, kb, vb = inputs
        kv_pos = idx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb)
        mask = kv_pos[None, :] < skv                      # padding
        if causal:
            vis = kv_pos[None, :] <= q_pos[:, None]
            if prefix_len:
                vis = vis | (kv_pos[None, :] < prefix_len)
            mask = mask & vis
        s = jnp.where(mask[None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vb)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((b, h, sq), dtype=jnp.float32)
    a0 = jnp.zeros((b, h, sq, hd), dtype=jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.arange(n_chunks), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)        # (B, Sq, H, hd)


def apply_attention(params, x, cfg, mesh: MeshInfo, *, positions=None,
                    prefix_len: int = 0):
    """Full-sequence (training / prefill) attention.  x: (B, S, D)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(params, x, cfg, positions)
    n_rep = q.shape[2] // k.shape[2]
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    out = blockwise_attention(q, k, v, chunk=cfg.attn_chunk, causal=True,
                              prefix_len=prefix_len)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"])


# ---------------------------------------------------------------------------
# Decode path (KV cache)
# ---------------------------------------------------------------------------


def init_kv_cache(cfg, mesh: MeshInfo, batch: int, max_len: int, dtype,
                  seq_shard: bool = False, batch_shard: bool = True):
    """Cache arrays + their specs.  ``seq_shard`` turns on SP for long decode
    (KV sequence axis over the data axis; batch is then unsharded).

    With ``cfg.kv_cache_dtype == "int8"`` the cache stores int8 entries plus
    one f32 scale per (position, head) — 2.2x less HBM read per decoded
    token (the dominant real decode cost; EXPERIMENTS.md §Perf D2)."""
    _, hkv = head_layout(cfg, mesh)
    kv_ax = mesh.shard_if(hkv)
    if seq_shard:
        spec = P(None, mesh.dp(), kv_ax, None)
        sspec = P(None, mesh.dp(), kv_ax)
    else:
        bspec = mesh.dp() if batch_shard else None
        spec = P(bspec, None, kv_ax, None)
        sspec = P(bspec, None, kv_ax)
    shape = (batch, max_len, hkv, cfg.head_dim)
    if cfg.kv_cache_dtype == "int8":
        return {
            "k": Param(jnp.zeros(shape, dtype=jnp.int8), spec),
            "v": Param(jnp.zeros(shape, dtype=jnp.int8), spec),
            "k_scale": Param(jnp.zeros(shape[:3], jnp.float32), sspec),
            "v_scale": Param(jnp.zeros(shape[:3], jnp.float32), sspec),
        }
    return {
        "k": Param(jnp.zeros(shape, dtype=dtype), spec),
        "v": Param(jnp.zeros(shape, dtype=dtype), spec),
    }


def _quant_kv(x):
    """x: (..., hd) -> (int8 values, f32 scale over the last dim)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def decode_attention(params, cache, x, cfg, mesh: MeshInfo, *, pos):
    """One-token decode.  x: (B, 1, D); pos: scalar int32 (current length).

    Returns (out (B, 1, D), new_cache).  Softmax over the (possibly
    data-sharded) cache sequence axis — XLA's SPMD partitioner lowers the
    max/sum to partial reductions + all-reduce, i.e. flash-decoding's LSE
    combine (DESIGN.md §5).
    """
    b = x.shape[0]
    pos = jnp.asarray(pos, dtype=jnp.int32)
    per_slot = pos.ndim == 1                  # continuous batching: (B,) pos
    positions = (pos[:, None] if per_slot
                 else jnp.full((b, 1), pos, dtype=jnp.int32))
    q, k_new, v_new = _project_qkv(params, x, cfg, positions)
    quant = "k_scale" in cache                # int8 KV cache (D2)
    if quant:
        k_q, k_s = _quant_kv(k_new)           # (B,1,H,hd) int8, (B,1,H) f32
        v_q, v_s = _quant_kv(v_new)
        k_new, v_new = k_q, v_q
    new_cache = {}
    if per_slot:
        idx = jnp.arange(b)
        k_cache = cache["k"].at[idx, pos].set(k_new[:, 0])
        v_cache = cache["v"].at[idx, pos].set(v_new[:, 0])
        if quant:
            new_cache["k_scale"] = cache["k_scale"].at[idx, pos].set(k_s[:, 0])
            new_cache["v_scale"] = cache["v_scale"].at[idx, pos].set(v_s[:, 0])
    else:
        # scalar path: dynamic_update_slice stays partitioner-friendly for
        # the seq-sharded long_500k cache.
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, pos,
                                                      axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, pos,
                                                      axis=1)
        if quant:
            new_cache["k_scale"] = jax.lax.dynamic_update_slice_in_dim(
                cache["k_scale"], k_s, pos, axis=1)
            new_cache["v_scale"] = jax.lax.dynamic_update_slice_in_dim(
                cache["v_scale"], v_s, pos, axis=1)

    hq = q.shape[2]
    hkv = k_cache.shape[2]
    n_rep = hq // hkv
    skv = k_cache.shape[1]
    scale = cfg.head_dim ** -0.5
    qg = (q * scale).reshape(b, 1, hkv, n_rep, cfg.head_dim
                             ).astype(jnp.float32)
    if quant:
        kf = k_cache.astype(jnp.float32) * new_cache["k_scale"][..., None]
        vf = v_cache.astype(jnp.float32) * new_cache["v_scale"][..., None]
    else:
        kf = k_cache.astype(jnp.float32)
        vf = v_cache.astype(jnp.float32)
    s = jnp.einsum("bqhrd,bkhd->bhrqk", qg, kf)            # (B,Hkv,rep,1,Skv)
    valid = jnp.arange(skv)[None, :] <= positions            # (B, Skv)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", p, vf)
    out = out.reshape(b, 1, hq, cfg.head_dim).astype(x.dtype)
    out = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    new_cache["k"] = k_cache
    new_cache["v"] = v_cache
    return out, new_cache
