"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

The mLSTM recurrence C_t = f_t C_{t-1} + i_t v_t k_t^T with read-out
q_t^T C_t / max(|q_t^T n_t|, 1) is the same computation as the SSD scan
(models/ssm.py) with (q, k, v) as (C, B, x), sigmoid gates as (exp(a), dt),
and the normalizer n tracked by extending v with a ones column.  We therefore
reuse ``ssd_chunked``/``ssd_decode_step`` — one scan core, two papers'
blocks.  (Stability note: we use the sigmoid-input-gate mLSTM variant rather
than exponential gating with running-max stabilisation; documented in
DESIGN.md.)

sLSTM has genuine recurrent mixing (R h_{t-1}) and cannot be parallelised
over time — it runs as a ``lax.scan`` over steps with block-diagonal
per-head recurrent matrices, exactly as the xLSTM paper prescribes.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import MeshInfo, Param, dense_init, ones_init, zeros_init
from repro.models.ssm import (
    causal_conv,
    causal_conv_step,
    ssd_chunked,
    ssd_decode_step,
)


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg, mesh: MeshInfo, dtype):
    d, di, hh = cfg.d_model, cfg.mlstm_inner, cfg.lstm_heads
    in_ax = mesh.shard_if(di)
    fsdp = mesh.fsdp_if(d)
    ks = jax.random.split(key, 10)
    return {
        "w_up": dense_init(ks[0], d, (d, di), P(fsdp, in_ax), dtype),
        "w_z": dense_init(ks[1], d, (d, di), P(fsdp, in_ax), dtype),
        "w_q": dense_init(ks[2], di, (di, di), P(in_ax, None), dtype),
        "w_k": dense_init(ks[3], di, (di, di), P(in_ax, None), dtype),
        "w_v": dense_init(ks[4], di, (di, di), P(in_ax, None), dtype),
        "w_i": dense_init(ks[5], di, (di, hh), P(in_ax, None), dtype),
        "w_f": dense_init(ks[6], di, (di, hh), P(in_ax, None), dtype),
        "f_bias": Param(jnp.full((hh,), 3.0, jnp.float32), P(None)),
        "conv_w": Param((jax.random.normal(ks[7], (cfg.ssm_conv, di))
                         / math.sqrt(cfg.ssm_conv)).astype(dtype), P(None, in_ax)),
        "conv_b": zeros_init((di,), P(in_ax), dtype),
        "norm_scale": ones_init((di,), P(in_ax), dtype),
        "w_down": dense_init(ks[8], di, (di, d), P(in_ax, fsdp), dtype),
    }


def _mlstm_qkvif(params, xc, cfg, b, s):
    hh = cfg.lstm_heads
    p = cfg.mlstm_inner // hh
    q = (xc @ params["w_q"]).reshape(b, s, hh, p)
    k = (xc @ params["w_k"]).reshape(b, s, hh, p) * (p ** -0.5)
    v = (xc @ params["w_v"]).reshape(b, s, hh, p)
    i_gate = jax.nn.sigmoid((xc @ params["w_i"]).astype(jnp.float32))
    logf = -jax.nn.softplus(
        -((xc @ params["w_f"]).astype(jnp.float32) + params["f_bias"]))
    return q, k, v, i_gate, logf


def _mlstm_out(params, y_ext, z, cfg, b, s):
    p = cfg.mlstm_inner // cfg.lstm_heads
    y = y_ext[..., :p]
    norm = y_ext[..., p:p + 1]
    y = y / jnp.maximum(jnp.abs(norm), 1.0)
    y = y.reshape(b, s, cfg.mlstm_inner)
    yf = y.astype(jnp.float32)
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    scale = params["norm_scale"].astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(ms + cfg.norm_eps) * scale).astype(z.dtype)
    y = y * jax.nn.silu(z)
    return y @ params["w_down"]


def apply_mlstm(params, x, cfg):
    """x: (B, S, D) -> (y, state, conv_tail)."""
    b, s, _ = x.shape
    xin = x @ params["w_up"]
    z = x @ params["w_z"]
    xc = jax.nn.silu(causal_conv(xin, params["conv_w"], params["conv_b"]))
    q, k, v, i_gate, logf = _mlstm_qkvif(params, xc, cfg, b, s)
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    v_ext = jnp.concatenate([v, ones], axis=-1)           # normalizer column
    y_ext, h_last = ssd_chunked(v_ext, logf, i_gate, k, q, cfg.xlstm_chunk)
    out = _mlstm_out(params, y_ext.astype(jnp.float32), z, cfg, b, s)
    kconv = cfg.ssm_conv - 1
    conv_tail = xin[:, -kconv:, :] if s >= kconv else \
        jnp.pad(xin, ((0, 0), (kconv - s, 0), (0, 0)))
    return out, h_last, conv_tail


def init_mlstm_cache(cfg, mesh: MeshInfo, batch: int, dtype,
                     batch_shard: bool = True):
    di, hh = cfg.mlstm_inner, cfg.lstm_heads
    p = di // hh
    dp = mesh.dp() if batch_shard else None
    return {
        "h": Param(jnp.zeros((batch, hh, p + 1, p), jnp.float32),
                   P(dp, None, None, None)),
        "conv": Param(jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
                      P(dp, None, mesh.shard_if(di))),
    }


def decode_mlstm(params, cache, x, cfg):
    b = x.shape[0]
    xin = x @ params["w_up"]
    z = x @ params["w_z"]
    xc, conv_new = causal_conv_step(cache["conv"], xin,
                                    params["conv_w"], params["conv_b"])
    xc = jax.nn.silu(xc)
    q, k, v, i_gate, logf = _mlstm_qkvif(params, xc, cfg, b, 1)
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    v_ext = jnp.concatenate([v, ones], axis=-1)[:, 0]     # (B,H,P+1)
    y_ext, h_new = ssd_decode_step(cache["h"], v_ext, logf[:, 0],
                                   i_gate[:, 0], k[:, 0], q[:, 0])
    out = _mlstm_out(params, y_ext[:, None].astype(jnp.float32), z, cfg, b, 1)
    return out, {"h": h_new, "conv": conv_new}


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------


def init_slstm(key, cfg, mesh: MeshInfo, dtype):
    d, hh = cfg.d_model, cfg.lstm_heads
    q = d // hh
    fsdp = mesh.fsdp_if(d)
    ks = jax.random.split(key, 6)
    ff = 2 * d
    return {
        "w_in": dense_init(ks[0], d, (d, 4, d), P(fsdp, None, None), dtype),
        "r": Param((jax.random.normal(ks[1], (hh, 4, q, q)) / math.sqrt(q)
                    ).astype(dtype), P(None, None, None, None)),
        "bias": zeros_init((4, d), P(None, None), jnp.float32),
        "f_bias": Param(jnp.full((d,), 3.0, jnp.float32), P(None)),
        "w_ff1": dense_init(ks[2], d, (d, ff), P(fsdp, mesh.shard_if(ff)), dtype),
        "w_ff2": dense_init(ks[3], ff, (ff, d), P(mesh.shard_if(ff), fsdp), dtype),
    }


def _slstm_cell(params, cfg, wx_t, state):
    """wx_t: (B, 4, D) pre-computed input part; state: (h, c, n) each (B, D)."""
    hh = cfg.lstm_heads
    d = cfg.d_model
    q = d // hh
    h, c, n = state
    hb = h.reshape(-1, hh, q)
    rec = jnp.einsum("bhq,hgqr->bghr", hb.astype(jnp.float32),
                     params["r"].astype(jnp.float32)).reshape(-1, 4, d)
    pre = wx_t.astype(jnp.float32) + rec + params["bias"]
    z = jnp.tanh(pre[:, 0])
    i = jax.nn.sigmoid(pre[:, 1])
    f = jax.nn.sigmoid(pre[:, 2] + params["f_bias"])
    o = jax.nn.sigmoid(pre[:, 3])
    c_new = f * c + i * z
    n_new = f * n + i
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return h_new, c_new, n_new


def apply_slstm(params, x, cfg):
    """x: (B, S, D) -> (y, final_state). Sequential scan over time."""
    b, s, d = x.shape
    wx = jnp.einsum("bsd,dge->bsge", x, params["w_in"])   # (B,S,4,D)
    state0 = tuple(jnp.zeros((b, d), jnp.float32) for _ in range(3))

    def step(state, wx_t):
        new = _slstm_cell(params, cfg, wx_t, state)
        return new, new[0]

    state, hs = jax.lax.scan(step, state0, jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)            # (B,S,D)
    # post-MLP (GeLU), as in the xLSTM sLSTM block
    y = jax.nn.gelu(y @ params["w_ff1"]) @ params["w_ff2"]
    return y, state


def init_slstm_cache(cfg, mesh: MeshInfo, batch: int, dtype,
                     batch_shard: bool = True):
    d = cfg.d_model
    dp = mesh.dp() if batch_shard else None
    mk = lambda: Param(jnp.zeros((batch, d), jnp.float32), P(dp, None))  # noqa: E731
    return {"h": mk(), "c": mk(), "n": mk()}


def decode_slstm(params, cache, x, cfg):
    wx = jnp.einsum("bsd,dge->bsge", x, params["w_in"])[:, 0]
    state = (cache["h"], cache["c"], cache["n"])
    h, c, n = _slstm_cell(params, cfg, wx, state)
    y = h[:, None, :].astype(x.dtype)
    y = jax.nn.gelu(y @ params["w_ff1"]) @ params["w_ff2"]
    return y, {"h": h, "c": c, "n": n}
