"""Parameter-pytree plumbing shared by all model code.

Models are pure-JAX: ``init_*`` functions build nested dicts whose leaves are
:class:`Param` — an array *plus* its logical PartitionSpec — and ``apply_*``
functions consume plain value trees.  ``split_params`` separates the two so
``jax.jit`` sees arrays while the launcher sees shardings of identical tree
structure (the property tests assert this invariant).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass
class Param:
    value: Any                 # jax.Array | ShapeDtypeStruct
    spec: P


def _param_flatten(p: Param):
    return (p.value,), p.spec


def _param_unflatten(spec, children):
    return Param(children[0], spec)


# Registered as a pytree with the spec as static aux data: jax.eval_shape
# over an init function then yields abstract values *and* concrete specs —
# exactly what the 512-device dry-run needs (no allocation).
jax.tree_util.register_pytree_node(Param, _param_flatten, _param_unflatten)


def is_param(x) -> bool:
    return isinstance(x, Param)


def split_params(tree):
    """(Param tree) -> (value tree, spec tree) with identical structure."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    specs = jax.tree.map(lambda p: p.spec, tree, is_leaf=is_param)
    return values, specs


def merge_params(values, specs):
    return jax.tree.map(Param, values, specs)


def param_count(values) -> int:
    return sum(x.size for x in jax.tree.leaves(values))


def param_bytes(values) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(values))


# ---------------------------------------------------------------------------
# Initialisers.  All take an explicit PRNG key and return Param leaves.
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, shape: tuple, spec: P, dtype) -> Param:
    """Fan-in-scaled normal init (the shape's contraction dim is d_in)."""
    std = d_in ** -0.5
    v = (jax.random.normal(key, shape, dtype=jnp.float32) * std).astype(dtype)
    return Param(v, spec)


def zeros_init(shape: tuple, spec: P, dtype) -> Param:
    return Param(jnp.zeros(shape, dtype=dtype), spec)


def ones_init(shape: tuple, spec: P, dtype) -> Param:
    return Param(jnp.ones(shape, dtype=dtype), spec)


def embed_init(key, vocab: int, d: int, spec: P, dtype) -> Param:
    v = (jax.random.normal(key, (vocab, d), dtype=jnp.float32)).astype(dtype)
    return Param(v, spec)


# ---------------------------------------------------------------------------
# Mesh-aware spec construction.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MeshInfo:
    """Sizes of the logical axes actually present on the mesh.

    ``shard_if`` returns the axis name only when it divides ``size`` — the
    framework's divisibility rule (DESIGN.md §5): non-divisible dims fall
    back to replication rather than failing (e.g. paligemma's single KV head
    vs a 16-way model axis).  ``fsdp_if`` is the same rule for the
    data(-parallel) axes when ZeRO-style parameter sharding is enabled.
    """
    data: int = 1                  # combined DP size (pod x data)
    model: int = 1
    data_axes: tuple = ("data",)   # mesh axis names folded into DP
    model_axis: str = "model"
    fsdp: bool = False

    def dp(self):
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]

    def shard_if(self, size: int):
        return self.model_axis if size % self.model == 0 else None

    def fsdp_if(self, size: int):
        if not self.fsdp:
            return None
        return self.dp() if size % self.data == 0 else None


HOST_MESH = MeshInfo(data=1, model=1)


def cast(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)


def cast_for_compute(tree, dtype):
    """Mixed-precision cast: matrices go to the compute dtype; small vectors
    and scalars (norm scales, gate biases, A_log, ...) keep their init dtype
    (f32) for numerical stability."""
    def f(x):
        if jnp.issubdtype(x.dtype, jnp.floating) and x.ndim >= 2:
            return x.astype(dtype)
        return x
    return jax.tree.map(f, tree)
