"""Full language-model assembly: blocks -> stacks -> train / prefill / decode.

Layer stacking uses *period scanning*: the per-layer block pattern is
factored into the smallest repeating period (dense archs: period ["attn"];
zamba2: 5 x mamba2 + 1 shared-attn site; xlstm: [mlstm, slstm]), the stack is
a ``lax.scan`` over stacked period parameters (bounded HLO size for 61-layer
models), and any non-periodic tail is unrolled.  zamba2's shared attention
block lives *outside* the scanned params and is closed over — weight tying
for free (DESIGN.md §4).

Three entry points per architecture:
  ``loss_fn``      — training forward + CE loss (train_4k cells)
  ``prefill``      — full-sequence forward emitting decode caches (prefill_32k)
  ``decode_step``  — one token against caches (decode_32k / long_500k)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import frontends, layers, moe, ssm, xlstm
from repro.models.common import MeshInfo, Param, cast_for_compute, split_params


# ---------------------------------------------------------------------------
# Pattern factoring
# ---------------------------------------------------------------------------


def factor_pattern(pattern: tuple) -> tuple[tuple, int, tuple]:
    """pattern -> (period, n_periods, tail). Chooses the smallest period that
    covers a maximal prefix of the pattern."""
    n = len(pattern)
    for plen in range(1, n + 1):
        period = pattern[:plen]
        k = n // plen
        if k >= 1 and tuple(period * k) == pattern[:plen * k]:
            tail = pattern[plen * k:]
            # accept only if tail shorter than one period
            if len(tail) < plen:
                return tuple(period), k, tuple(tail)
    return tuple(pattern), 1, ()


# ---------------------------------------------------------------------------
# Single blocks (norm + mixer (+ mlp)), init / apply / prefill / decode
# ---------------------------------------------------------------------------


def _init_block(key, kind: str, cfg, mesh, dtype):
    ks = jax.random.split(key, 4)
    if kind in ("attn", "shared_attn"):
        p = {"norm1": layers.init_norm(cfg, mesh, dtype),
             "attn": attn.init_attention(ks[0], cfg, mesh, dtype)}
        if cfg.d_ff:
            p["norm2"] = layers.init_norm(cfg, mesh, dtype)
            p["mlp"] = layers.init_mlp(ks[1], cfg, mesh, dtype)
        return p
    if kind == "moe":
        return {"norm1": layers.init_norm(cfg, mesh, dtype),
                "attn": attn.init_attention(ks[0], cfg, mesh, dtype),
                "norm2": layers.init_norm(cfg, mesh, dtype),
                "moe": moe.init_moe(ks[1], cfg, mesh, dtype)}
    if kind == "mamba2":
        return {"norm1": layers.init_norm(cfg, mesh, dtype),
                "mamba": ssm.init_mamba2(ks[0], cfg, mesh, dtype)}
    if kind == "mlstm":
        return {"norm1": layers.init_norm(cfg, mesh, dtype),
                "mlstm": xlstm.init_mlstm(ks[0], cfg, mesh, dtype)}
    if kind == "slstm":
        return {"norm1": layers.init_norm(cfg, mesh, dtype),
                "slstm": xlstm.init_slstm(ks[0], cfg, mesh, dtype)}
    raise ValueError(kind)


def _apply_block(params, kind: str, x, cfg, mesh, *, prefix_len=0):
    """Training/prefill-forward; returns (x, aux_loss, cache_out)."""
    aux = 0.0
    cache = None
    if kind in ("attn", "shared_attn", "moe"):
        h = layers.apply_norm(params["norm1"], x, cfg)
        b, s, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        q, k, v = attn._project_qkv(params["attn"], h, cfg, positions)
        n_rep = q.shape[2] // k.shape[2]
        out = attn.blockwise_attention(
            q, attn._repeat_kv(k, n_rep), attn._repeat_kv(v, n_rep),
            chunk=cfg.attn_chunk, causal=True, prefix_len=prefix_len)
        x = x + jnp.einsum("bshe,hed->bsd", out, params["attn"]["wo"])
        cache = {"k": k, "v": v}
        if kind == "moe":
            h2 = layers.apply_norm(params["norm2"], x, cfg)
            if moe.ep_applicable(cfg, mesh, h2.shape[1]):
                y, aux = moe.apply_moe_ep(params["moe"], h2, cfg, mesh)
            else:
                y, aux = moe.apply_moe(params["moe"], h2, cfg, mesh)
            x = x + y
        elif cfg.d_ff:
            h2 = layers.apply_norm(params["norm2"], x, cfg)
            x = x + layers.apply_mlp(params["mlp"], h2, cfg)
        return x, aux, cache
    if kind == "mamba2":
        h = layers.apply_norm(params["norm1"], x, cfg)
        y, h_last, conv_tail = ssm.apply_mamba2(params["mamba"], h, cfg)
        return x + y, aux, {"h": h_last, "conv": conv_tail}
    if kind == "mlstm":
        h = layers.apply_norm(params["norm1"], x, cfg)
        y, h_last, conv_tail = xlstm.apply_mlstm(params["mlstm"], h, cfg)
        return x + y, aux, {"h": h_last, "conv": conv_tail}
    if kind == "slstm":
        h = layers.apply_norm(params["norm1"], x, cfg)
        y, (hs, cs, ns) = xlstm.apply_slstm(params["slstm"], h, cfg)
        return x + y, aux, {"h": hs, "c": cs, "n": ns}
    raise ValueError(kind)


def _decode_block(params, kind: str, cache, x, cfg, mesh, *, pos):
    if kind in ("attn", "shared_attn", "moe"):
        h = layers.apply_norm(params["norm1"], x, cfg)
        out, cache = attn.decode_attention(params["attn"], cache, h, cfg,
                                           mesh, pos=pos)
        x = x + out
        if kind == "moe":
            h2 = layers.apply_norm(params["norm2"], x, cfg)
            y, _ = moe.apply_moe(params["moe"], h2, cfg, mesh)
            x = x + y
        elif cfg.d_ff:
            h2 = layers.apply_norm(params["norm2"], x, cfg)
            x = x + layers.apply_mlp(params["mlp"], h2, cfg)
        return x, cache
    if kind == "mamba2":
        h = layers.apply_norm(params["norm1"], x, cfg)
        y, cache = ssm.decode_mamba2(params["mamba"], cache, h, cfg)
        return x + y, cache
    if kind == "mlstm":
        h = layers.apply_norm(params["norm1"], x, cfg)
        y, cache = xlstm.decode_mlstm(params["mlstm"], cache, h, cfg)
        return x + y, cache
    if kind == "slstm":
        h = layers.apply_norm(params["norm1"], x, cfg)
        y, cache = xlstm.decode_slstm(params["slstm"], cache, h, cfg)
        return x + y, cache
    raise ValueError(kind)


def _init_block_cache(kind: str, cfg, mesh, batch: int, max_len: int, dtype,
                      seq_shard: bool, batch_shard: bool = True):
    if kind in ("attn", "shared_attn", "moe"):
        return attn.init_kv_cache(cfg, mesh, batch, max_len, dtype,
                                  seq_shard=seq_shard,
                                  batch_shard=batch_shard)
    if kind == "mamba2":
        return ssm.init_mamba2_cache(cfg, mesh, batch, dtype,
                                     batch_shard=batch_shard)
    if kind == "mlstm":
        return xlstm.init_mlstm_cache(cfg, mesh, batch, dtype,
                                      batch_shard=batch_shard)
    if kind == "slstm":
        return xlstm.init_slstm_cache(cfg, mesh, batch, dtype,
                                      batch_shard=batch_shard)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# The LM
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LM:
    cfg: ModelConfig
    mesh: MeshInfo
    # unroll=True replaces the layer-period lax.scan with a Python loop —
    # used by the roofline probes (XLA cost_analysis counts a while-loop
    # body once regardless of trip count; see launch/roofline_probe.py).
    unroll: bool = False

    # -- init ---------------------------------------------------------------
    def init(self, key) -> dict:
        cfg, mesh = self.cfg, self.mesh
        dtype = jnp.dtype(cfg.param_dtype)
        period, k, tail = factor_pattern(cfg.block_pattern)
        keys = jax.random.split(key, 4 + k * len(period) + len(tail))
        p: dict[str, Any] = {
            "embed": layers.init_embedding(keys[0], cfg, mesh, dtype),
            "final_norm": layers.init_norm(cfg, mesh, dtype),
            "frontend": frontends.init_frontend(keys[1], cfg, mesh, dtype),
        }
        if cfg.shared_block:
            p["shared"] = _init_block(keys[2], "attn", cfg, mesh, dtype)

        def period_params(i):
            out = {}
            for j, kind in enumerate(period):
                if kind == "shared_attn" and cfg.shared_block:
                    continue  # tied weights live in p["shared"]
                out[f"b{j}_{kind}"] = _init_block(
                    keys[4 + i * len(period) + j], kind, cfg, mesh, dtype)
            return out

        if k > 0 and period:
            per = [period_params(i) for i in range(k)]

            # stack Param leaves: value -> stacked, spec -> (None, *spec)
            def stack_params(*ps):
                vals = jnp.stack([q.value for q in ps])
                spec = P(*((None,) + tuple(ps[0].spec)))
                return Param(vals, spec)

            p["stack"] = jax.tree.map(
                stack_params, *per,
                is_leaf=lambda x: isinstance(x, Param))
        p["tail"] = {
            f"t{j}_{kind}": _init_block(keys[3 + k * len(period) + j], kind,
                                        cfg, mesh, dtype)
            for j, kind in enumerate(tail)
        }
        return p

    # -- shared helpers -------------------------------------------------------
    def _embed_inputs(self, params, batch) -> tuple[jax.Array, int]:
        """Returns (x (B,S,D), prefix_len)."""
        cfg = self.cfg
        parts = []
        prefix_len = 0
        if cfg.frontend == "vision_stub":
            patches = frontends.apply_frontend(params["frontend"],
                                               batch["patches"], cfg)
            parts.append(patches)
            prefix_len = patches.shape[1]
        if cfg.frontend == "audio_stub":
            frames = frontends.apply_frontend(params["frontend"],
                                              batch["frames"], cfg)
            parts.append(frames)
        if "tokens" in batch:
            parts.append(layers.embed_tokens(params["embed"],
                                             batch["tokens"], cfg))
        x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
        return x.astype(jnp.dtype(cfg.compute_dtype)), prefix_len

    def _run_stack(self, params, x, *, prefix_len: int, want_caches: bool,
                   remat: bool):
        """Forward through periods + tail; returns (x, aux, caches|None)."""
        cfg, mesh = self.cfg, self.mesh
        period, k, tail = factor_pattern(cfg.block_pattern)

        def period_body(x, pparams):
            aux_p = 0.0
            caches = {}
            for j, kind in enumerate(period):
                if kind == "shared_attn" and cfg.shared_block:
                    bp = params["shared"]
                else:
                    bp = pparams[f"b{j}_{kind}"]
                x, aux, cache = _apply_block(bp, kind, x, cfg, mesh,
                                             prefix_len=prefix_len)
                aux_p = aux_p + aux
                if want_caches:
                    caches[f"b{j}_{kind}"] = cache
            return x, aux_p, caches

        if remat == "dots":
            period_body = jax.checkpoint(
                period_body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        elif remat:  # "block" / True: full recompute
            period_body = jax.checkpoint(period_body)

        aux_total = 0.0
        caches_out: dict[str, Any] = {}
        if k > 0 and period:
            stack_vals = params["stack"]
            if self.unroll:
                percaches = []
                for i in range(k):
                    pparams = jax.tree.map(lambda v: v[i], stack_vals)
                    x, aux_p, caches = period_body(x, pparams)
                    aux_total = aux_total + aux_p
                    percaches.append(caches)
                if want_caches:
                    caches_out["stack"] = jax.tree.map(
                        lambda *xs: jnp.stack(xs), *percaches)
            else:
                def scan_body(x, pparams):
                    x, aux_p, caches = period_body(x, pparams)
                    return x, (aux_p, caches)

                x, (aux_periods, period_caches) = jax.lax.scan(
                    scan_body, x, stack_vals)
                aux_total = aux_total + jnp.sum(aux_periods)
                if want_caches:
                    caches_out["stack"] = period_caches  # leading axis = period
        if want_caches:
            caches_out.setdefault("tail", {})
        for j, kind in enumerate(tail):
            x, aux, cache = _apply_block(params["tail"][f"t{j}_{kind}"], kind,
                                         x, cfg, mesh, prefix_len=prefix_len)
            aux_total = aux_total + aux
            if want_caches:
                caches_out["tail"][f"t{j}_{kind}"] = cache
        return x, aux_total, (caches_out if want_caches else None)

    # -- training -------------------------------------------------------------
    def loss_fn(self, params, batch, *, remat="block"):
        cfg = self.cfg
        params = cast_for_compute(params, jnp.dtype(cfg.compute_dtype))
        x, prefix_len = self._embed_inputs(params, batch)
        x, aux, _ = self._run_stack(params, x, prefix_len=prefix_len,
                                    want_caches=False, remat=remat)
        x = layers.apply_norm(params["final_norm"], x, cfg)
        if prefix_len:
            x = x[:, prefix_len:]
        logits = layers.logits_head(params["embed"], x, cfg)
        loss = layers.cross_entropy(logits, batch["labels"], cfg.vocab_size,
                                    mask=batch.get("loss_mask"))
        return loss + aux, {"ce_loss": loss, "aux_loss": aux}

    # -- serving: prefill -------------------------------------------------------
    def prefill(self, params, batch):
        """Full-sequence forward; returns (last_logits, caches)."""
        cfg = self.cfg
        params = cast_for_compute(params, jnp.dtype(cfg.compute_dtype))
        x, prefix_len = self._embed_inputs(params, batch)
        x, _, caches = self._run_stack(params, x, prefix_len=prefix_len,
                                       want_caches=True, remat=False)
        x = layers.apply_norm(params["final_norm"], x, cfg)
        logits = layers.logits_head(params["embed"], x[:, -1:], cfg)
        return logits[:, 0], caches

    # -- serving: decode ---------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, *, seq_shard: bool = False,
                   batch_shard: bool = True):
        cfg, mesh = self.cfg, self.mesh
        dtype = jnp.dtype(cfg.compute_dtype)
        period, k, tail = factor_pattern(cfg.block_pattern)
        out: dict[str, Any] = {}
        if k > 0 and period:
            def one_period():
                return {f"b{j}_{kind}": _init_block_cache(
                    kind, cfg, mesh, batch, max_len, dtype, seq_shard,
                    batch_shard)
                    for j, kind in enumerate(period)}
            per = [one_period() for _ in range(k)]

            def stack_caches(*cs):
                vals = jnp.stack([c.value for c in cs])
                spec = P(*((None,) + tuple(cs[0].spec)))
                return Param(vals, spec)
            out["stack"] = jax.tree.map(stack_caches, *per,
                                        is_leaf=lambda x: isinstance(x, Param))
        out["tail"] = {f"t{j}_{kind}": _init_block_cache(
            kind, cfg, mesh, batch, max_len, dtype, seq_shard, batch_shard)
            for j, kind in enumerate(tail)}
        return out

    def decode_step(self, params, caches, token, pos):
        """token: (B, 1) int32 (or (B,1,D) frames for audio); pos: scalar.
        Returns (logits (B, V), new caches)."""
        cfg, mesh = self.cfg, self.mesh
        params = cast_for_compute(params, jnp.dtype(cfg.compute_dtype))
        period, k, tail = factor_pattern(cfg.block_pattern)
        if token.ndim == 3:  # audio frames passthrough
            x = frontends.apply_frontend(params["frontend"], token, cfg)
        else:
            x = layers.embed_tokens(params["embed"], token, cfg)
        x = x.astype(jnp.dtype(cfg.compute_dtype))

        new_caches: dict[str, Any] = {}
        if k > 0 and period:
            def scan_body(x, inp):
                pparams, pcache = inp
                new_c = {}
                for j, kind in enumerate(period):
                    bp = (params["shared"] if kind == "shared_attn"
                          and cfg.shared_block else pparams[f"b{j}_{kind}"])
                    x, c = _decode_block(bp, kind, pcache[f"b{j}_{kind}"], x,
                                         cfg, mesh, pos=pos)
                    new_c[f"b{j}_{kind}"] = c
                return x, new_c

            if self.unroll:
                outs = []
                for i in range(k):
                    inp = jax.tree.map(lambda v: v[i],
                                       (params["stack"], caches["stack"]))
                    x, new_c = scan_body(x, inp)
                    outs.append(new_c)
                stacked_new = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
            else:
                x, stacked_new = jax.lax.scan(
                    scan_body, x, (params["stack"], caches["stack"]))
            new_caches["stack"] = stacked_new
        new_caches["tail"] = {}
        for j, kind in enumerate(tail):
            x, c = _decode_block(params["tail"][f"t{j}_{kind}"], kind,
                                 caches["tail"][f"t{j}_{kind}"], x, cfg, mesh,
                                 pos=pos)
            new_caches["tail"][f"t{j}_{kind}"] = c
        x = layers.apply_norm(params["final_norm"], x, cfg)
        logits = layers.logits_head(params["embed"], x, cfg)
        return logits[:, 0], new_caches
