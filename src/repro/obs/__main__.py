"""``python -m repro.obs`` — report | export | drift over saved traces.

File-based so it composes across processes: point it at a
``repro.serving/trace-v1`` JSON (``launch/serve.py --trace``,
``ServingEngine.trace_json()``, or the simulator's engine-format trace)
and get a unified summary, a Chrome-trace export, or a drift verdict.

    python -m repro.obs report --trace /tmp/trace.json
    python -m repro.obs export --trace /tmp/trace.json --out /tmp/chrome.json
    python -m repro.obs drift  --trace /tmp/trace.json --max-drift 0.2
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.drift import (
    DEFAULT_MAX_DRIFT,
    DEFAULT_WARN_DRIFT,
    DriftMonitor,
)
from repro.obs.trace import chrome_trace_from_serving


def _load_trace(path: str) -> dict:
    with open(path) as fh:
        doc = json.load(fh)
    if "events" not in doc:
        raise SystemExit(f"{path}: no 'events' — not a serving trace "
                         f"(schema {doc.get('schema')!r})")
    return doc


def _drift_from_trace(doc: dict, *, warn_drift: float,
                      max_drift: float, min_samples: int) -> dict:
    """Replay a trace's step events through a DriftMonitor: measured
    ``dt`` per step vs the engine's frozen ``predicted_step_s``."""
    mon = DriftMonitor(warn_drift=warn_drift, max_drift=max_drift,
                       min_samples=min_samples)
    predicted = float(doc.get("predicted_step_s") or 0.0)
    key = str(doc.get("machine", "trace"))
    for e in doc.get("events", []):
        if e.get("type") == "step" and "dt" in e:
            mon.observe(predicted, float(e["dt"]), key=key)
    return mon.report()


def cmd_report(args) -> int:
    doc = _load_trace(args.trace)
    events = doc.get("events", [])
    by_type: dict[str, int] = {}
    for e in events:
        by_type[e.get("type", "?")] = by_type.get(e.get("type", "?"), 0) + 1
    steps = [e for e in events if e.get("type") == "step" and "dt" in e]
    dts = sorted(float(e["dt"]) for e in steps)
    out = {
        "schema": "repro.obs/report-v1",
        "trace_schema": doc.get("schema"),
        "events": len(events),
        "events_by_type": by_type,
        "predicted_step_s": doc.get("predicted_step_s"),
        "steps": {
            "count": len(dts),
            "mean_dt_s": (sum(dts) / len(dts)) if dts else None,
            "p95_dt_s": (dts[min(len(dts) - 1,
                                 int(0.95 * (len(dts) - 1) + 0.5))]
                         if dts else None),
        },
        "drift": _drift_from_trace(
            doc, warn_drift=args.warn_drift, max_drift=args.max_drift,
            min_samples=args.min_samples),
    }
    json.dump(out, sys.stdout, indent=2)
    print()
    return 0


def cmd_export(args) -> int:
    doc = _load_trace(args.trace)
    chrome = chrome_trace_from_serving(doc)
    with open(args.out, "w") as fh:
        json.dump(chrome, fh)
    print(f"wrote {args.out}: {len(chrome['traceEvents'])} trace events "
          f"({chrome['metadata']['spans']} spans, "
          f"{chrome['metadata']['events']} instants)")
    return 0


def cmd_drift(args) -> int:
    doc = _load_trace(args.trace)
    rep = _drift_from_trace(
        doc, warn_drift=args.warn_drift, max_drift=args.max_drift,
        min_samples=args.min_samples)
    json.dump(rep, sys.stdout, indent=2)
    print()
    return 0 if rep["status"] == "ok" or not args.strict else 3


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="observability over saved serving traces")
    sub = ap.add_subparsers(dest="cmd", required=True)

    def common(p):
        p.add_argument("--trace", required=True,
                       help="path to a repro.serving/trace-v1 JSON")
        p.add_argument("--warn-drift", type=float,
                       default=DEFAULT_WARN_DRIFT)
        p.add_argument("--max-drift", type=float, default=DEFAULT_MAX_DRIFT)
        p.add_argument("--min-samples", type=int, default=8)

    p = sub.add_parser("report", help="unified summary of one trace")
    common(p)
    p.set_defaults(fn=cmd_report)

    p = sub.add_parser("export", help="convert a trace to Chrome-trace JSON")
    p.add_argument("--trace", required=True)
    p.add_argument("--out", required=True,
                   help="output path (open in chrome://tracing / perfetto)")
    p.set_defaults(fn=cmd_export)

    p = sub.add_parser("drift", help="ok/warn/stale verdict for one trace")
    common(p)
    p.add_argument("--strict", action="store_true",
                   help="exit 3 when status is not ok")
    p.set_defaults(fn=cmd_drift)
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
