"""Online prediction-drift monitoring: is the calibration still true?

The paper's premise is that a calibrated analytic model *predicts* GEMM
wall time; ``repro.measure.fit_from_store`` already gates offline refits
on the median measured/predicted ratio (raising
:class:`~repro.measure.campaign.CalibrationDriftError` beyond
``max_drift``).  :class:`DriftMonitor` brings the same statistic online:
every serving/simulation step feeds one ``(predicted_s, measured_s)``
pair, keyed by the machine's ``geometry_fingerprint()`` (the identity
``repro.measure.SampleStore`` keys samples on), and the monitor keeps a
rolling window of ratios per key.

Status vocabulary (surfaced in ``perf_report()["drift"]``,
``SimReport.drift`` and ``python -m repro.obs drift``):

* ``ok``    — too few samples, or |median ratio − 1| ≤ ``warn_drift``;
* ``warn``  — drift above ``warn_drift`` but within ``max_drift``:
  predictions are sliding, watch the machine;
* ``stale`` — drift beyond ``max_drift``, the exact boundary the offline
  gate refuses to fit at (0.2 by repo convention): the calibration no
  longer describes the hardware, re-measure and refit.
"""
from __future__ import annotations

import statistics
from collections import deque
from typing import Any

DRIFT_SCHEMA = "repro.obs/drift-v1"

STATUS_OK = "ok"
STATUS_WARN = "warn"
STATUS_STALE = "stale"

#: The offline refit gate's conventional threshold (see
#: ``fit_from_store(..., max_drift=0.2)`` in docs/RESILIENCE.md) — reused
#: here as the online ok/warn → stale boundary.
DEFAULT_MAX_DRIFT = 0.2
DEFAULT_WARN_DRIFT = 0.1


class DriftMonitor:
    """Rolling measured/predicted ratio windows, one per machine key.

    Args:
        window: samples retained per key (older ratios age out, so the
            monitor tracks *current* drift and recovers after transient
            faults clear).
        warn_drift / max_drift: the ok→warn and warn→stale boundaries on
            ``|median(measured/predicted) − 1|``.
        min_samples: stay ``ok`` (verdict withheld) until a key has this
            many ratios — a single noisy step should not page anyone.
    """

    def __init__(self, *, window: int = 64,
                 warn_drift: float = DEFAULT_WARN_DRIFT,
                 max_drift: float = DEFAULT_MAX_DRIFT,
                 min_samples: int = 8):
        if not 0 < warn_drift <= max_drift:
            raise ValueError(
                f"need 0 < warn_drift <= max_drift, got "
                f"warn_drift={warn_drift} max_drift={max_drift}")
        self.window = int(window)
        self.warn_drift = float(warn_drift)
        self.max_drift = float(max_drift)
        self.min_samples = int(min_samples)
        self._ratios: dict[str, deque[float]] = {}
        self._observed: dict[str, int] = {}

    # -- producers -----------------------------------------------------------

    def observe(self, predicted_s: float, measured_s: float,
                *, key: str = "default") -> float | None:
        """Feed one prediction/measurement pair; returns the ratio
        recorded (or ``None`` for degenerate inputs, which are ignored —
        a zero-cost predicted step carries no drift information)."""
        if predicted_s <= 0 or measured_s <= 0:
            return None
        ratio = measured_s / predicted_s
        self._ratios.setdefault(
            key, deque(maxlen=self.window)).append(ratio)
        self._observed[key] = self._observed.get(key, 0) + 1
        return ratio

    # -- consumers -----------------------------------------------------------

    def keys(self) -> list[str]:
        return sorted(self._ratios)

    def median_ratio(self, key: str = "default") -> float | None:
        win = self._ratios.get(key)
        return statistics.median(win) if win else None

    def drift(self, key: str = "default") -> float | None:
        """``|median(measured/predicted) − 1|`` over the current window."""
        med = self.median_ratio(key)
        return None if med is None else abs(med - 1.0)

    def status(self, key: str = "default") -> str:
        win = self._ratios.get(key)
        if not win or len(win) < self.min_samples:
            return STATUS_OK
        d = abs(statistics.median(win) - 1.0)
        if d > self.max_drift:
            return STATUS_STALE
        if d > self.warn_drift:
            return STATUS_WARN
        return STATUS_OK

    def report(self, key: str | None = None) -> dict:
        """Machine-readable drift report (``repro.obs/drift-v1``).

        Per key: sample counts, current median ratio, drift, status, and
        the thresholds, so a dashboard can re-derive the verdict."""
        keys = [key] if key is not None else self.keys()
        per_key: dict[str, Any] = {}
        worst = STATUS_OK
        order = {STATUS_OK: 0, STATUS_WARN: 1, STATUS_STALE: 2}
        for k in keys:
            med = self.median_ratio(k)
            st = self.status(k)
            per_key[k] = {
                "samples": len(self._ratios.get(k, ())),
                "observed": self._observed.get(k, 0),
                "median_ratio": med,
                "drift": None if med is None else abs(med - 1.0),
                "status": st,
            }
            if order[st] > order[worst]:
                worst = st
        return {
            "schema": DRIFT_SCHEMA,
            "status": worst,
            "warn_drift": self.warn_drift,
            "max_drift": self.max_drift,
            "min_samples": self.min_samples,
            "window": self.window,
            "keys": per_key,
        }

    def check(self, key: str = "default", *,
              baseline: str = "online", store: str = "obs.DriftMonitor"):
        """Raise the *offline* gate's error type when a key is stale —
        so online monitoring and refit gating share one exception/dict
        shape (``CalibrationDriftError.as_dict()``)."""
        if self.status(key) != STATUS_STALE:
            return None
        from repro.measure.campaign import CalibrationDriftError
        med = self.median_ratio(key)
        raise CalibrationDriftError(
            baseline=baseline, store=store,
            samples=len(self._ratios.get(key, ())),
            median_ratio=med, drift=abs(med - 1.0),
            max_drift=self.max_drift)

    def reset(self) -> "DriftMonitor":
        self._ratios.clear()
        self._observed.clear()
        return self
