"""Span tracing: a process-local :class:`Recorder` + Chrome-trace export.

The subsystem has two channels with different on/off semantics:

* **Spans** — nested, named intervals (``obs.span("gemm.sweep")``) emitted
  from the hot paths (planner, sweep, serving steps, simulator, calibrator
  fits).  Spans are *disabled by default*: ``span()`` returns a shared
  no-op singleton when the recorder is off, so an instrumented hot loop
  pays one attribute load + one branch per call site (the
  ``obs_overhead`` bench workload asserts <2% on the Table-2 sweep).
* **Events** — the serving engine's ``repro.serving/trace-v1`` payloads.
  These were always-on before ``repro.obs`` existed and stay always-on:
  the engine appends them through :meth:`Recorder.add_event` and
  ``ServingEngine.trace_json()`` is now a *view* over this recorder.

Both channels export to one Chrome-trace/Perfetto JSON
(:meth:`Recorder.to_chrome_trace`): spans become complete ``"ph": "X"``
slices, events become instants, and each span's ``track`` ("wall" for
perf-counter timestamps, "sim" for simulator time) maps to its own tid
with a ``thread_name`` metadata row.  Timestamps are microseconds, per
the `Trace Event Format
<https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU>`_.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Mapping

#: Schema tag stamped on every Chrome-trace export's ``metadata`` block.
TRACE_EXPORT_SCHEMA = "repro.obs/chrome-trace-v1"


@dataclasses.dataclass
class Span:
    """One closed (or still-open) named interval on a track."""

    sid: int
    name: str
    t0: float
    t1: float | None = None
    attrs: dict[str, Any] = dataclasses.field(default_factory=dict)
    track: str = "wall"
    parent: int | None = None

    @property
    def duration_s(self) -> float | None:
        return None if self.t1 is None else self.t1 - self.t0

    def as_dict(self) -> dict:
        return {"sid": self.sid, "name": self.name, "t0": self.t0,
                "t1": self.t1, "track": self.track, "parent": self.parent,
                "attrs": dict(self.attrs)}


class _NullSpan:
    """Shared no-op returned by ``span()`` when tracing is disabled.

    Implements just enough surface (context manager + ``set``) that call
    sites never branch on enablement themselves.
    """

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


_NULL = _NullSpan()


class _LiveSpan:
    """Context-manager handle for one recorder-backed span."""

    __slots__ = ("_rec", "_span")

    def __init__(self, rec: "Recorder", span: Span):
        self._rec = rec
        self._span = span

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            self._span.attrs.setdefault("error", exc_type.__name__)
        self._rec._close(self._span)
        return False

    def set(self, **attrs):
        """Attach attributes to the span while it is open."""
        self._span.attrs.update(attrs)
        return self


class Recorder:
    """Process-local store of spans and serving events.

    One module-level instance (``repro.obs.recorder``) backs the whole
    process; tests may construct private recorders.  Not thread-safe by
    design — the repo's hot paths are single-threaded, and a lock on the
    disabled fast path would defeat the <2% overhead budget.
    """

    def __init__(self, enabled: bool = False):
        self.enabled = bool(enabled)
        self.spans: list[Span] = []
        self.events: list[dict] = []
        self._stack: list[Span] = []
        self._next_sid = 0
        self.clock = time.perf_counter

    # -- lifecycle -----------------------------------------------------------

    def enable(self) -> "Recorder":
        self.enabled = True
        return self

    def disable(self) -> "Recorder":
        self.enabled = False
        return self

    def clear(self) -> "Recorder":
        """Drop all recorded spans and events (enablement unchanged)."""
        self.spans.clear()
        self.events.clear()
        self._stack.clear()
        self._next_sid = 0
        return self

    # -- span channel (gated on ``enabled``) ---------------------------------

    def span(self, name: str, *, track: str = "wall", **attrs):
        """Open a nested span; no-op singleton when disabled."""
        if not self.enabled:
            return _NULL
        s = Span(sid=self._next_sid, name=name, t0=self.clock(),
                 attrs=dict(attrs), track=track,
                 parent=self._stack[-1].sid if self._stack else None)
        self._next_sid += 1
        self.spans.append(s)
        self._stack.append(s)
        return _LiveSpan(self, s)

    def _close(self, span: Span) -> None:
        span.t1 = self.clock()
        # tolerate out-of-order exits (generators, re-raised errors)
        if span in self._stack:
            while self._stack and self._stack[-1] is not span:
                self._stack.pop()
            if self._stack:
                self._stack.pop()

    def add_span(self, name: str, t0: float, t1: float, *,
                 track: str = "wall", parent: int | None = None,
                 **attrs) -> Span | None:
        """Record a retrospective span from externally-taken timestamps
        (serving-step wall clocks, simulator virtual time).  Gated on
        ``enabled`` like :meth:`span`; returns the span or ``None``."""
        if not self.enabled:
            return None
        s = Span(sid=self._next_sid, name=name, t0=float(t0), t1=float(t1),
                 attrs=dict(attrs), track=track, parent=parent)
        self._next_sid += 1
        self.spans.append(s)
        return s

    # -- event channel (always on) -------------------------------------------

    def add_event(self, payload: dict, *, track: str = "wall",
                  tag: str | None = None) -> dict:
        """Append one serving trace-v1 event payload.  Always on: the
        engine's event trace predates ``repro.obs`` and stays cheap and
        unconditional.  ``tag`` names the producer (one serving engine
        among several sharing this recorder); :meth:`events_for` filters
        on it.  Returns the payload (stored by reference, so the producer
        may keep mutating it until export)."""
        payload["_track"] = track
        if tag is not None:
            payload["_tag"] = tag
        self.events.append(payload)
        return payload

    _PRIVATE_KEYS = ("_track", "_tag")

    def events_for(self, track: str | None = None,
                   tag: str | None = None) -> list[dict]:
        """Event payloads (without the private ``_track``/``_tag`` keys),
        optionally filtered by track and/or producer tag."""
        out = []
        for e in self.events:
            if track is not None and e.get("_track", "wall") != track:
                continue
            if tag is not None and e.get("_tag") != tag:
                continue
            out.append({k: v for k, v in e.items()
                        if k not in self._PRIVATE_KEYS})
        return out

    # -- export --------------------------------------------------------------

    def to_chrome_trace(self, *, pid: int = 1) -> dict:
        """Render spans + events as a Chrome-trace JSON object."""
        tracks: dict[str, int] = {}

        def tid_of(track: str) -> int:
            if track not in tracks:
                tracks[track] = len(tracks) + 1
            return tracks[track]

        trace_events: list[dict] = []
        for s in self.spans:
            t1 = s.t1 if s.t1 is not None else s.t0
            trace_events.append({
                "name": s.name, "ph": "X", "cat": "repro",
                "ts": s.t0 * 1e6, "dur": max(0.0, (t1 - s.t0) * 1e6),
                "pid": pid, "tid": tid_of(s.track),
                "args": _jsonable(s.attrs),
            })
        for e in self.events:
            track = e.get("_track", "wall")
            args = {k: v for k, v in e.items()
                    if k not in ("_track", "_tag", "type", "t")}
            trace_events.append({
                "name": f"event.{e.get('type', '?')}", "ph": "i",
                "cat": "repro", "ts": float(e.get("t", 0.0)) * 1e6,
                "pid": pid, "tid": tid_of(track), "s": "t",
                "args": _jsonable(args),
            })
        for track, tid in sorted(tracks.items(), key=lambda kv: kv[1]):
            trace_events.append({
                "name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
                "args": {"name": track},
            })
        return {
            "traceEvents": trace_events,
            "displayTimeUnit": "ms",
            "metadata": {"schema": TRACE_EXPORT_SCHEMA,
                         "spans": len(self.spans),
                         "events": len(self.events)},
        }

    def save_chrome_trace(self, path) -> dict:
        doc = self.to_chrome_trace()
        with open(path, "w") as fh:
            json.dump(doc, fh)
        return doc


def _jsonable(attrs: Mapping[str, Any]) -> dict:
    """Chrome-trace args must be JSON — stringify anything exotic."""
    out = {}
    for k, v in attrs.items():
        if isinstance(v, (str, int, float, bool)) or v is None:
            out[k] = v
        elif isinstance(v, (list, tuple)):
            out[k] = [x if isinstance(x, (str, int, float, bool)) else str(x)
                      for x in v]
        else:
            out[k] = str(v)
    return out


def chrome_trace_from_serving(trace: Mapping[str, Any]) -> dict:
    """Convert a saved ``repro.serving/trace-v1`` document into a
    Chrome-trace JSON — the file-based path used by
    ``python -m repro.obs export`` when no live recorder exists.

    Mapping (documented in docs/OBSERVABILITY.md):

    * every ``step`` event (which carries ``t`` + ``dt``) becomes a
      ``serve.step`` slice on the "wall" track;
    * every request's ``submit -> finish|shed`` pair becomes a
      ``request.<id>`` slice on the "requests" track (TTFT and cause in
      ``args``);
    * all other events become instants.
    """
    rec = Recorder(enabled=True)
    events = trace.get("events", [])
    submits: dict[Any, dict] = {}
    firsts: dict[Any, float] = {}

    def rid_of(e: Mapping[str, Any]):
        return e.get("rid", e.get("id"))

    for e in events:
        typ = e.get("type")
        if typ == "step":
            t0 = float(e["t"])
            rec.add_span("serve.step", t0, t0 + float(e.get("dt", 0.0)),
                         track="wall", admitted=len(e.get("admitted", [])),
                         active=e.get("active"),
                         queue_depth=e.get("queue_depth"))
        elif typ == "submit":
            submits[rid_of(e)] = e
        elif typ == "first_token":
            firsts[rid_of(e)] = float(e["t"])
        elif typ in ("finish", "shed"):
            sub = submits.pop(rid_of(e), None)
            if sub is not None:
                attrs = {"outcome": typ}
                if typ == "shed" and "cause" in e:
                    attrs["cause"] = e["cause"]
                ttft = firsts.pop(rid_of(e), None)
                if ttft is not None:
                    attrs["ttft_s"] = ttft - float(sub["t"])
                rec.add_span(f"request.{rid_of(e)}", float(sub["t"]),
                             float(e["t"]), track="requests", **attrs)
            else:
                rec.add_event(dict(e))
        else:
            rec.add_event(dict(e))
    # unfinished requests: open slices to the last event timestamp
    horizon = max((float(e.get("t", 0.0)) for e in events), default=0.0)
    for rid, sub in submits.items():
        rec.add_span(f"request.{rid}", float(sub["t"]), horizon,
                     track="requests", outcome="unfinished")
    doc = rec.to_chrome_trace()
    doc["metadata"]["source_schema"] = trace.get("schema")
    return doc
