"""``repro.obs`` — tracing, metrics, and prediction-drift observability.

One vocabulary across every layer of plan → serve → simulate → calibrate:

* :func:`span` / :func:`add_span` — nestable named intervals recorded by
  a process-local :class:`Recorder`, exported as Chrome-trace/Perfetto
  JSON (:func:`to_chrome_trace`, ``chrome://tracing`` / ui.perfetto.dev).
  Disabled by default; :func:`enable` turns the span channel on.  The
  serving engine's always-on ``repro.serving/trace-v1`` events flow
  through the same recorder, so ``ServingEngine.trace_json()`` is a view
  over it.
* :data:`metrics` — the process :class:`MetricsRegistry`; producers
  (plan cache, sweep, serving, simulator, faults) increment dotted
  counters at the same sites as their legacy report fields, and
  ``obs.metrics.snapshot()`` (schema ``repro.obs/v1``) is the union view.
* :class:`DriftMonitor` — online measured-vs-predicted ratio windows
  keyed by machine geometry fingerprint; surfaces ok/warn/stale in
  ``perf_report()``, ``SimReport`` and ``python -m repro.obs drift``.

Overhead contract: with tracing disabled every ``obs.span(...)`` call
site costs one method call returning a shared no-op — the
``obs_overhead`` workload in ``benchmarks/bench_planner.py`` asserts
<2% on the Table-2 sweep.  See docs/OBSERVABILITY.md.
"""
from repro.obs.drift import (
    DEFAULT_MAX_DRIFT,
    DEFAULT_WARN_DRIFT,
    DRIFT_SCHEMA,
    STATUS_OK,
    STATUS_STALE,
    STATUS_WARN,
    DriftMonitor,
)
from repro.obs.metrics import METRICS_SCHEMA, MetricsRegistry
from repro.obs.trace import (
    TRACE_EXPORT_SCHEMA,
    Recorder,
    Span,
    chrome_trace_from_serving,
)

#: The process-local recorder every instrumented layer writes to.
recorder = Recorder()

#: The process-local metrics registry every instrumented layer increments.
metrics = MetricsRegistry()


def span(name: str, *, track: str = "wall", **attrs):
    """Open a span on the process recorder (no-op while disabled)."""
    return recorder.span(name, track=track, **attrs)


def add_span(name: str, t0: float, t1: float, *, track: str = "wall",
             **attrs):
    """Record a retrospective span from external timestamps."""
    return recorder.add_span(name, t0, t1, track=track, **attrs)


def enable():
    """Turn the span channel on (events and metrics are always on)."""
    return recorder.enable()


def disable():
    return recorder.disable()


def enabled() -> bool:
    return recorder.enabled


def clear():
    """Drop recorded spans/events and zero the metrics registry."""
    recorder.clear()
    metrics.reset()


def to_chrome_trace() -> dict:
    """Chrome-trace JSON of everything the process recorder holds."""
    return recorder.to_chrome_trace()


def save_chrome_trace(path) -> dict:
    return recorder.save_chrome_trace(path)


__all__ = [
    "DEFAULT_MAX_DRIFT", "DEFAULT_WARN_DRIFT", "DRIFT_SCHEMA",
    "DriftMonitor", "METRICS_SCHEMA", "MetricsRegistry", "Recorder",
    "Span", "STATUS_OK", "STATUS_STALE", "STATUS_WARN",
    "TRACE_EXPORT_SCHEMA", "add_span", "chrome_trace_from_serving",
    "clear", "disable", "enable", "enabled", "metrics", "recorder",
    "save_chrome_trace", "span", "to_chrome_trace",
]
