"""Counter/gauge/histogram registry — one ``snapshot()`` for the process.

Before ``repro.obs`` the repo's operational counts were scattered:
plan-cache hits/misses/dedupes in ``gemm.cache.CacheStats``, sweep
pruned/scored cells inside ``SweepResult.stats``, shed/expired/degraded
requests in ``ServingEngine._resilience_report()``, fault injections in
``SimReport``.  Each producer still owns its legacy surface (those report
fields are byte-compatible); this registry is the *union* view, fed by
the same increment sites, so ``obs.metrics.snapshot()`` always agrees
with the legacy numbers.

Naming convention: dotted ``<layer>.<thing>`` —
``plan_cache.hits``, ``sweep.cells_pruned``, ``serving.shed``,
``sim.faults.throttled_steps``.  The snapshot schema is
``repro.obs/v1`` and is stable: counters/gauges are flat name→number
maps, histograms summarize to count/sum/min/max/mean/p50/p95.
"""
from __future__ import annotations

from typing import Mapping

#: Stable schema tag of :meth:`MetricsRegistry.snapshot`.
METRICS_SCHEMA = "repro.obs/v1"


def _percentile(sorted_vals: list[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(q * (len(sorted_vals) - 1) + 0.5))
    return sorted_vals[idx]


class MetricsRegistry:
    """Process-local metrics store.  Always on (increments are dict ops,
    far cheaper than the spans they usually accompany)."""

    def __init__(self):
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self._hists: dict[str, list[float]] = {}

    # -- producers -----------------------------------------------------------

    def counter(self, name: str, inc: float = 1) -> float:
        """Increment (and return) a monotonically-growing count."""
        v = self.counters.get(name, 0) + inc
        self.counters[name] = v
        return v

    def gauge(self, name: str, value: float) -> None:
        """Set a point-in-time value (last write wins)."""
        self.gauges[name] = value

    def observe(self, name: str, value: float) -> None:
        """Add one sample to a histogram."""
        self._hists.setdefault(name, []).append(float(value))

    # -- consumers -----------------------------------------------------------

    def snapshot(self) -> dict:
        """The ``repro.obs/v1`` view of everything recorded so far."""
        hists = {}
        for name, vals in self._hists.items():
            sv = sorted(vals)
            hists[name] = {
                "count": len(sv), "sum": sum(sv),
                "min": sv[0] if sv else 0.0, "max": sv[-1] if sv else 0.0,
                "mean": (sum(sv) / len(sv)) if sv else 0.0,
                "p50": _percentile(sv, 0.50), "p95": _percentile(sv, 0.95),
            }
        return {
            "schema": METRICS_SCHEMA,
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "histograms": hists,
        }

    def get(self, name: str, default: float = 0) -> float:
        """Current value of one counter."""
        return self.counters.get(name, default)

    def reset(self) -> "MetricsRegistry":
        """Zero everything — the cross-layer analogue of the plan-cache
        ``reset`` satellite: back-to-back experiments in one process
        should not report cumulative numbers."""
        self.counters.clear()
        self.gauges.clear()
        self._hists.clear()
        return self

    def delta_since(self, before: Mapping[str, float]) -> dict[str, float]:
        """Counter deltas vs a previously-captured ``counters`` map —
        the before/after subtraction pattern ``gemm.sweep`` uses, offered
        here so every consumer applies it consistently."""
        return {name: v - before.get(name, 0)
                for name, v in self.counters.items()
                if v != before.get(name, 0)}
