"""repro.checkpoint subpackage."""
