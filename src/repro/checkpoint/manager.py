"""Checkpointing: atomic, keep-N, preemption-safe, elastic-restorable.

Layout::

    <dir>/step_000123/arrays.npz     flattened pytree (path-keyed)
    <dir>/step_000123/meta.json      step, tree structure, extra state
    <dir>/step_000123/.complete      commit marker (atomic rename)

Save path: write into ``step_N.tmp`` then ``os.replace`` — a crash mid-save
never corrupts the latest checkpoint.  Restore loads full (unsharded)
arrays and re-``device_put``s them under the *current* mesh's shardings, so
a run may resume on a different topology (elastic restart; DESIGN.md §5 and
tests/test_fault.py).
"""
from __future__ import annotations

import json
import os
import shutil
import signal
import threading
from typing import Any, Callable

import jax
import numpy as np


def _flatten_with_paths(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._preempted = threading.Event()

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: dict | None = None) -> str:
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten_with_paths(tree)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        meta = {"step": step, "extra": extra or {},
                "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex()
                if hasattr(jax.tree_util.tree_structure(tree),
                           "serialize_using_proto") else None}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        open(os.path.join(tmp, ".complete"), "w").close()
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for d in sorted(os.listdir(self.dir)):
            if d.startswith("step_") and not d.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, d, ".complete")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, shardings: Any = None
                ) -> tuple[Any, dict]:
        """Restore into the structure of ``like``; re-shard under
        ``shardings`` (same structure) when given — this is what makes the
        checkpoint elastic across mesh shapes."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(path, "arrays.npz"))
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for p, leaf in paths:
            key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                           for q in p)
            arr = data[key]
            assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
            leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, meta["extra"]

    def restore_latest(self, like: Any, shardings: Any = None):
        step = self.latest_step()
        if step is None:
            return None, None, None
        tree, extra = self.restore(step, like, shardings)
        return step, tree, extra

    # -- preemption -------------------------------------------------------------
    def install_preemption_handler(self) -> None:
        """SIGTERM -> set the preempted flag; the train loop checks it each
        step and performs an emergency save + clean exit."""
        def handler(signum, frame):
            self._preempted.set()
        signal.signal(signal.SIGTERM, handler)

    @property
    def preempted(self) -> bool:
        return self._preempted.is_set()

    def simulate_preemption(self) -> None:   # for tests
        self._preempted.set()
