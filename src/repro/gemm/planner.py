"""``plan()`` — the single entry point of the predict→choose→run loop."""
from __future__ import annotations

import jax

from repro import obs
from repro.core.precision import PrecisionConfig
from repro.gemm.api import GemmPlan, GemmProblem, resolve_machine
from repro.gemm.backends import dtype_tag, register_builtin_backends
from repro.gemm.cache import PlanCache
from repro.gemm.registry import backend_names, get_backend

register_builtin_backends()

_CACHE = PlanCache()


def plan(problem, *, backend: str = "analytic-tpu", machine=None,
         dtype: str | None = None, policy: str = "analytic",
         precision=None, cache: bool = True, **options) -> GemmPlan:
    """Plan one GEMM: run ``backend``'s analytic model / search and freeze
    the decision.  ``plan`` is the one-problem case of :func:`plan_many`.

    Args:
        problem: a :class:`GemmProblem`, an ``(m, n, k)`` tuple, a
            ``core.variants.Problem`` or a ``core.tpu_model.GemmShape``.
        backend: backend name (see :func:`backends`).
        machine: a registry name or :class:`MachineSpec` (default: the
            backend's native target machine).
        dtype: dtype tag overriding the problem's own.
        precision: a :class:`~repro.core.precision.PrecisionConfig` (or its
            key string, e.g. ``"int4xint8->int32"``) applied to the problem.
            Uniform configs normalize to the plain dtype path and plan
            bit-identically; mixed configs add quantize/dequantize traffic
            and use the machine's ``rates_mixed`` arithmetic table.
        policy: partial-tile accounting of the GAP8 simulator
            (``"analytic"`` — exact byte ratios — or ``"padded"`` — edge
            tiles at full-tile cost).
        cache: consult/populate the process-wide plan cache; False forces
            a fresh search.  A manifest warmed via :func:`warm_cache`
            satisfies tile-backend plans without searching.
        **options: backend-specific.  ``analytic-gap8``: ``variant=``,
            ``micro_kernel=`` pin the search; ``analytic-tpu`` /
            ``pallas``: ``overlap=`` picks the composition rule, ``tile=``
            bypasses the search with an explicit TileConfig.

    Returns:
        A frozen :class:`GemmPlan` carrying the chosen selection, the
        predicted cost (``plan.estimate()`` / ``plan.predicted_seconds``)
        and search provenance.

    Raises:
        UnknownBackendError: for an unregistered backend name.
        KeyError: for an unknown machine name.
        ValueError: for a degenerate problem, unknown dtype tag, or a
            ``micro_kernel`` override without an explicit ``variant``.
    """
    return plan_many([problem], backend=backend, machine=machine,
                     dtype=dtype, policy=policy, precision=precision,
                     cache=cache, **options)[0]


def plan_many(problems, *, backend: str = "analytic-tpu", machine=None,
              dtype: str | None = None, policy: str = "analytic",
              precision=None, cache: bool = True,
              **options) -> list[GemmPlan]:
    """Plan many GEMMs in one bulk operation.

    Problems are deduped before any evaluation (the dropped count is
    reported as ``deduped`` in :func:`plan_cache_stats`), cache and manifest
    tiers are consulted per unique problem, and the remaining misses go to
    the backend's batched ``make_plans`` engine as a single vectorized
    lattice evaluation.

    Args:
        problems: iterable of anything :func:`plan`'s ``problem`` accepts.
        backend / machine / dtype / policy / precision / cache / **options:
            exactly as for :func:`plan`, applied to every problem.

    Returns:
        One :class:`GemmPlan` per input problem, in input order; duplicate
        problems share the same plan object.

    Raises:
        Everything :func:`plan` raises, for any problem of the batch.
    """
    b = get_backend(backend)
    mspec = resolve_machine(machine, b.default_machine)
    with obs.span("gemm.plan_many", backend=b.name, machine=mspec.name,
                  problems=len(problems)) as sp:
        probs = [b.coerce_problem(p, dtype) for p in problems]
        if precision is not None:
            pc = PrecisionConfig.coerce(precision)
            probs = [p.with_precision(pc) for p in probs]
        with obs.span("gemm.plan_many.dedupe"):
            unique: dict[GemmProblem, None] = {}
            for p in probs:
                unique.setdefault(p)
            _CACHE.note_deduped(len(probs) - len(unique))
        sp.set(unique=len(unique))
        if not cache:
            with obs.span("gemm.plan_many.batch_score",
                          missing=len(unique)):
                built = dict(zip(unique, b.make_plans(list(unique), mspec,
                                                      policy, options)))
            return [built[p] for p in probs]
        resolved: dict[GemmProblem, GemmPlan] = {}
        missing: list[GemmProblem] = []
        for p in unique:
            # cache_token = name@content-fingerprint: same-named machines
            # with different rate tables (derived specs, re-registered
            # calibrations) must not share plans.
            key = _CACHE.key(p, b.name, mspec.cache_token, policy, options)
            hit = _CACHE.get(key)
            if hit is not None:
                resolved[p] = hit
                continue
            # The manifest persists only the default search (tile selected
            # under overlap=True, no pinned options); requests with explicit
            # options must re-search rather than inherit a tile chosen under
            # different rules.
            built = None
            if not options:
                tile = _CACHE.manifest_tile(p)
                if tile is not None:
                    built = b.plan_from_tile(p, mspec, policy, tile)
            if built is not None:
                _CACHE.put(key, built)
                resolved[p] = built
            else:
                missing.append(p)
        sp.set(missing=len(missing))
        if missing:
            with obs.span("gemm.plan_many.batch_score",
                          missing=len(missing)):
                for p, made in zip(missing, b.make_plans(missing, mspec,
                                                         policy, options)):
                    _CACHE.put(_CACHE.key(p, b.name, mspec.cache_token,
                                          policy, options),
                               made)
                    resolved[p] = made
        return [resolved[p] for p in probs]


def backends() -> list[str]:
    """Names of every registered GEMM backend."""
    return backend_names()


def clear_plan_cache() -> None:
    _CACHE.clear()


def plan_cache_stats(reset: bool = False) -> dict:
    """Counter snapshot of the process plan cache.

    The counters are process-cumulative; ``reset=True`` returns the
    snapshot and then zeros them (cached plans stay), so back-to-back
    experiments in one process each start from zero instead of reporting
    everything since import.  ``sweep()`` additionally reports per-call
    deltas in ``SweepResult.stats`` regardless of resets.
    """
    d = _CACHE.stats.as_dict()
    d["size"] = len(_CACHE)
    if reset:
        _CACHE.reset_stats()
    return d


def reset_plan_cache_stats() -> None:
    """Zero the plan-cache counters without dropping cached plans."""
    _CACHE.reset_stats()


def warm_cache(manifest_path: str) -> int:
    """Attach a TileTuner JSON manifest as the cache's persisted tier."""
    return _CACHE.warm(manifest_path)


def save_cache(manifest_path: str) -> int:
    """Persist the cache's tile decisions to a TileTuner JSON manifest."""
    return _CACHE.save(manifest_path)


# ---------------------------------------------------------------------------
# Convenience execution helpers for in-framework consumers.
# ---------------------------------------------------------------------------


def default_execute_backend() -> str:
    """The executable backend matching the ambient jax platform: Pallas on
    TPU, the jnp reference elsewhere (keeps 512-device SPMD lowering clean —
    DESIGN.md §3)."""
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def matmul(x, w, *, backend: str | None = None, interpret: bool = False):
    """Planned matmul over arbitrary leading dims: ``(..., k) @ (k, n)``.

    Folds leading dims into M, plans on the ambient executable backend and
    executes the plan — the framework-wide route by which every dense layer
    inherits the paper's analytic tile selection.
    """
    lead = x.shape[:-1]
    a2 = x if x.ndim == 2 else x.reshape(-1, x.shape[-1])
    m, k = a2.shape
    n = w.shape[-1]
    p = plan((m, n, k), backend=backend or default_execute_backend(),
             dtype=dtype_tag(x.dtype))
    out = p.execute(a2, w, interpret=interpret)
    return out if x.ndim == 2 else out.reshape(*lead, n)


def grouped_matmul(x, w, *, interpret: bool = False):
    """Planned grouped (expert-batched) matmul: ``(..., E, C, D) @ (E, D, F)``.

    Routes through ``kernels.ops.grouped_gemm`` (Pallas on TPU / interpret,
    jnp reference elsewhere), vmapped over any extra leading batch dims.
    """
    from repro.kernels import ops
    if x.ndim == 3:
        return ops.grouped_gemm(x, w, interpret=interpret)
    lead = x.shape[:-3]
    x4 = x.reshape((-1,) + x.shape[-3:])
    out = jax.vmap(lambda xb: ops.grouped_gemm(xb, w, interpret=interpret))(x4)
    return out.reshape(lead + out.shape[-3:])


def plan_model_gemms(cfg, *, tokens: int = 4096,
                     backend: str = "analytic-tpu",
                     **plan_kwargs) -> list[GemmPlan]:
    """Plans for every GEMM shape of one transformer architecture config —
    the per-arch workload view (serving/benchmarks consume this instead of
    calling TileTuner directly).  Routed through :func:`plan_many`: repeated
    shapes are deduped and the misses are planned in one batched lattice
    evaluation."""
    from repro.core.autotune import model_gemm_shapes
    shapes = model_gemm_shapes(cfg, tokens=tokens)
    return plan_many(shapes, backend=backend, **plan_kwargs)
