"""The unified plan/execute GEMM API — the paper's workflow as one façade.

The paper's contribution is *simulate-before-implement*: an analytic cost
model predicts which GEMM variant/tiling wins before anything runs on
hardware.  This module makes that predict→choose→run loop a first-class
citizen:

    plan = repro.gemm.plan((m, n, k), backend="analytic-tpu")
    plan.estimate()            # the predicted TpuCost / CostBreakdown
    plan.execute(a, b)         # NotExecutableError: analytic-only backend

    plan = repro.gemm.plan((m, n, k), backend="pallas", dtype="bf16")
    c = plan.execute(a, b, interpret=True)   # tuned Pallas kernel

Every backend (``repro.gemm.backends()``) maps a :class:`GemmProblem` to a
frozen :class:`GemmPlan` carrying the chosen variant-or-tile, the predicted
cost, and provenance describing how the choice was made.  Plans are memoised
in a process-level cache (``repro.gemm.cache``) whose persistence layer is
TileTuner's JSON manifest.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

from repro.core.precision import PrecisionConfig
from repro.core.simulator import CostBreakdown
from repro.core.tpu_model import DTYPE_BYTES, GemmShape, TpuCost
from repro.core.variants import Blocking, MicroKernel, Problem, Variant
from repro.machines import MachineSpec
from repro.machines import resolve as _resolve_machine


class NotExecutableError(RuntimeError):
    """Raised when ``execute`` is called on an analytic-only plan."""


class UnknownBackendError(KeyError):
    """Raised for a backend name absent from the registry."""


@dataclasses.dataclass(frozen=True)
class GemmProblem:
    """Canonical description of one GEMM ``C (+)= A (m x k) . B (k x n)``."""

    m: int
    n: int
    k: int
    dtype: str = "bf16"
    accumulate: bool = False
    # per-operand dtype config (PrecisionConfig / key string / None).
    # Normalized on construction: a *uniform* config collapses into the
    # plain ``dtype`` path (precision becomes None — bit-identical plans,
    # same cache identity); a *mixed* config forces ``dtype`` to its
    # compute (narrower-operand) dtype.
    precision: Any = None

    def __post_init__(self):
        if min(self.m, self.n, self.k) < 1:
            raise ValueError(f"degenerate GEMM problem {self}")
        pc = PrecisionConfig.coerce(self.precision)
        if pc is not None:
            if pc.is_uniform:
                object.__setattr__(self, "dtype", pc.a_dtype)
                pc = None
            else:
                object.__setattr__(self, "dtype", pc.compute_dtype)
            object.__setattr__(self, "precision", pc)
        if self.dtype not in DTYPE_BYTES:
            raise ValueError(
                f"unknown dtype {self.dtype!r}; have {sorted(DTYPE_BYTES)}")

    def with_precision(self, precision) -> "GemmProblem":
        """This problem under a per-operand dtype config (None clears it);
        construction re-normalizes ``dtype``/``precision`` as above."""
        pc = PrecisionConfig.coerce(precision)
        if pc is None and self.precision is None:
            return self
        return dataclasses.replace(self, precision=pc)

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.n * self.k

    @property
    def elem_bytes(self) -> int:
        return DTYPE_BYTES[self.dtype]

    def as_shape(self) -> GemmShape:
        """The TPU cost-model view of this problem."""
        return GemmShape(m=self.m, n=self.n, k=self.k, dtype=self.dtype,
                         accumulate=self.accumulate,
                         precision=self.precision)

    def as_problem(self) -> Problem:
        """The GAP8 simulator view of this problem."""
        return Problem(m=self.m, n=self.n, k=self.k,
                       elem_bytes=self.elem_bytes, dtype=self.dtype,
                       precision=self.precision)

    @classmethod
    def coerce(cls, obj: Any, dtype: str | None = None,
               default_dtype: str = "bf16") -> "GemmProblem":
        """Accept a GemmProblem, (m, n, k) tuple, core Problem or GemmShape."""
        if isinstance(obj, cls):
            p = obj
        elif isinstance(obj, GemmShape):
            p = cls(obj.m, obj.n, obj.k, dtype=obj.dtype,
                    accumulate=obj.accumulate, precision=obj.precision)
        elif isinstance(obj, Problem):
            p = cls(obj.m, obj.n, obj.k, dtype=obj.dtype,
                    precision=obj.precision)
        elif isinstance(obj, (tuple, list)) and len(obj) == 3:
            p = cls(int(obj[0]), int(obj[1]), int(obj[2]),
                    dtype=dtype or default_dtype)
        else:
            raise TypeError(
                f"cannot interpret {obj!r} as a GEMM problem; pass a "
                "GemmProblem, (m, n, k), core.variants.Problem or GemmShape")
        if dtype is not None and p.dtype != dtype:
            # an explicit dtype override reasserts the uniform path: it
            # replaces any attached mixed config rather than fighting the
            # compute-dtype normalization.
            p = dataclasses.replace(p, dtype=dtype, precision=None)
        return p


@dataclasses.dataclass(frozen=True)
class VariantChoice:
    """The GAP8 backends' selection: loop-order variant + micro-kernel."""
    variant: Variant
    micro_kernel: MicroKernel
    blocking: Blocking

    def __str__(self) -> str:
        return f"{self.variant.value}/{self.micro_kernel}"


@dataclasses.dataclass(frozen=True)
class GemmPlan:
    """A frozen predict→choose decision for one GEMM problem.

    ``selection`` is backend-specific: a :class:`TileConfig` for the
    TPU/Pallas backends, a :class:`VariantChoice` for the GAP8 simulator,
    ``None`` for the reference backend.  ``cost`` is the backend's predicted
    :class:`TpuCost` / :class:`CostBreakdown`.  ``provenance`` records how
    the selection was made (search / cache / manifest / explicit override).
    """

    problem: GemmProblem
    backend: str
    machine: str
    selection: Any
    cost: TpuCost | CostBreakdown | None
    provenance: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def estimate(self) -> TpuCost | CostBreakdown:
        """The predicted cost object this plan was chosen by."""
        if self.cost is None:
            raise ValueError(f"plan via {self.backend!r} carries no estimate")
        return self.cost

    @property
    def predicted_seconds(self) -> float:
        """Scalar predicted execution time (backend's headline estimate)."""
        c = self.estimate()
        if isinstance(c, TpuCost):
            return c.total(bool(self.provenance.get("overlap", True)))
        return c.total

    @property
    def executable(self) -> bool:
        return _backend_of(self.backend).executable

    def execute(self, a, b, c=None, *, interpret: bool = False,
                force: bool = False):
        """Run ``C (+)= A.B`` with this plan's selection.

        Args:
            a / b / c: operands matching the planned problem's shapes
                (``c`` only for accumulate semantics).
            interpret: run the Pallas kernel in interpret mode (works
                off-TPU).
            force: attempt real (non-interpret) Pallas lowering even
                off-TPU.

        Returns:
            The product array, computed by the Pallas kernels (``pallas``)
            or the pure-jnp reference (``reference``).

        Raises:
            NotExecutableError: on analytic-only backends.
            ValueError: when operand shapes do not match the planned
                problem.
        """
        return _backend_of(self.backend).execute(self, a, b, c,
                                                 interpret=interpret,
                                                 force=force)

    def blocking_dims(self) -> tuple[int, int, int]:
        """The plan's cache blocking as ``(bm, bn, bk)`` loop-nest trip
        sizes — the uniform view the measurement harness replays as a
        blocked loop nest (``repro.measure.harness``).  GAP8-simulator
        plans map ``(m_c, n_c, k_c)``; tile plans map the TileConfig;
        selection-free plans are a single whole-problem block."""
        sel = self.selection
        if isinstance(sel, VariantChoice):
            b = sel.blocking
            return (int(b.m_c), int(b.n_c), int(b.k_c))
        if sel is not None and hasattr(sel, "bm"):
            return (int(sel.bm), int(sel.bn), int(sel.bk))
        return (self.problem.m, self.problem.n, self.problem.k)

    def describe(self) -> str:
        p, sel = self.problem, self.selection
        cost = (f"{self.predicted_seconds * 1e6:.1f}us"
                if self.cost is not None else "n/a")
        return (f"GemmPlan[{self.backend}@{self.machine}] "
                f"{p.m}x{p.n}x{p.k}:{p.dtype} -> "
                f"{sel if sel is not None else 'as-is'} ({cost}, "
                f"{self.provenance.get('source', 'search')})")

    def explain(self) -> dict:
        """Cost attribution: where does this plan's predicted time go?

        Returns a ``repro.obs/explain-v1`` dict whose ``terms`` decompose
        the estimate per traffic/arithmetic component — which memory
        level, how many bytes, at what effective rate, and what fraction
        of the total — so "why is this cell slow" is answerable from the
        façade without touching the cost-model internals.

        Composition semantics mirror :attr:`predicted_seconds`:

        * GAP8-simulator plans (:class:`CostBreakdown`) and no-overlap
          TPU plans compose by plain sum (paper §3.1), so the term
          ``fraction`` values sum to 1 and ``seconds`` sum to
          ``estimate().total`` exactly (``composition: "sum"``).
        * Overlapped TPU plans are bound by the slowest resource plus
          pipeline fill (``composition: "overlapped"``); fractions are
          still reported against the no-overlap sum (``sum_s``) so they
          remain a partition, with the headline ``total_s`` carrying the
          overlapped time.
        """
        c = self.estimate()
        terms: list[dict] = []
        if isinstance(c, TpuCost):
            overlap = bool(self.provenance.get("overlap", True))
            flops = self.problem.flops
            hbm_rate = c.hbm_bytes / c.t_hbm if c.t_hbm else None
            terms = [
                {"name": "compute", "kind": "compute", "level": "MXU",
                 "seconds": c.t_compute, "bytes": None,
                 "rate": flops / c.t_compute if c.t_compute else None},
                {"name": "stream_hbm", "kind": "traffic", "level": "HBM",
                 "seconds": c.t_hbm, "bytes": c.hbm_bytes,
                 "rate": hbm_rate},
                {"name": "stream_vmem", "kind": "traffic", "level": "VMEM",
                 "seconds": c.t_vmem, "bytes": c.vmem_bytes,
                 "rate": c.vmem_bytes / c.t_vmem if c.t_vmem else None},
            ]
            # mixed-precision shapes: split the quantize/dequantize share
            # out of the HBM stream so the extra traffic is attributed,
            # keeping the terms a partition of the same totals.
            q = getattr(c, "quant_bytes", 0.0)
            if q:
                t_q = c.t_hbm * (q / c.hbm_bytes) if c.hbm_bytes else 0.0
                terms[1]["seconds"] = c.t_hbm - t_q
                terms[1]["bytes"] = c.hbm_bytes - q
                terms.append(
                    {"name": "quantize", "kind": "quantize", "level": "HBM",
                     "seconds": t_q, "bytes": q, "rate": hbm_rate})
            composition = "overlapped" if overlap else "sum"
        else:
            flops = self.problem.flops
            for name, secs in c.components.items():
                if name == "arith":
                    terms.append(
                        {"name": name, "kind": "compute", "level": "R",
                         "seconds": secs, "bytes": None,
                         "rate": flops / secs if secs else None})
                else:
                    nbytes = c.traffic_bytes.get(name)
                    terms.append(
                        {"name": name,
                         "kind": "quantize" if name.startswith("quant_")
                         else "traffic",
                         "level": c.origins.get(name),
                         "seconds": secs, "bytes": nbytes,
                         "rate": (nbytes / secs)
                                 if (secs and nbytes is not None) else None})
            composition = "sum"
        sum_s = float(sum(t["seconds"] for t in terms))
        for t in terms:
            t["fraction"] = (t["seconds"] / sum_s) if sum_s else 0.0
        terms.sort(key=lambda t: -t["seconds"])
        return {
            "schema": "repro.obs/explain-v1",
            "backend": self.backend,
            "machine": self.machine,
            "problem": f"{self.problem.m}x{self.problem.n}x{self.problem.k}"
                       f":{self.problem.dtype}"
                       + (f"|{self.problem.precision.key()}"
                          if self.problem.precision is not None else ""),
            "composition": composition,
            "total_s": self.predicted_seconds,
            "sum_s": sum_s,
            "terms": terms,
        }


def _backend_of(name: str):
    from repro.gemm.registry import get_backend
    return get_backend(name)


def resolve_machine(machine: str | MachineSpec | None,
                    default: str) -> MachineSpec:
    """Resolve a plan's machine argument through the ``repro.machines``
    registry (names and aliases; specs pass through unchanged)."""
    return _resolve_machine(machine, default)
