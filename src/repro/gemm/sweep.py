"""Design-space sweeps: the paper's §4 exploration as one bulk operation.

The paper's whole point is to *search* the algorithmic design space —
variants x micro-kernels x blockings on the GAP8, tile configurations on the
TPU — with a cheap analytic model before implementing anything.  ``sweep``
makes that a table-producing primitive: it crosses a problem list with
machine / backend / dtype / policy (and, for the GAP8 simulator, variant /
micro-kernel) axes, routes every grid point through the batched planning
engine via :func:`repro.gemm.planner.plan_many` (deduped, cached,
vectorized), and returns a :class:`SweepResult` whose rows carry the frozen
plan and its cost breakdown.

    >>> from repro import gemm
    >>> from repro.core.variants import Variant
    >>> res = gemm.sweep([(256, 784, 2304), (64, 3136, 576)],
    ...                  backends=["analytic-gap8"], variants=list(Variant))
    >>> res.best((256, 784, 2304)).selection
    VariantChoice(variant=<Variant.B3A2C0: 'B3A2C0'>, ...)
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Iterable, Sequence

from repro import obs
from repro.core.precision import PrecisionConfig
from repro.core.simulator import CostBreakdown
from repro.core.tpu_model import TpuCost
from repro.gemm.api import GemmPlan, GemmProblem
from repro.gemm.planner import plan_cache_stats, plan_many
from repro.machines import MachineSpec, expand_many


@dataclasses.dataclass(frozen=True)
class SweepRow:
    """One grid point: a problem planned under one axis combination."""

    problem: GemmProblem
    backend: str
    machine: str
    policy: str
    variant: str | None
    micro_kernel: str | None
    plan: GemmPlan
    scenario: str | None = None
    # precision-axis tag: the PrecisionConfig key ("int4xint8->int32") this
    # row was planned under, or None for the plain dtype axis.
    precision: str | None = None

    @property
    def selection(self) -> Any:
        return self.plan.selection

    @property
    def seconds(self) -> float:
        return self.plan.predicted_seconds

    def breakdown(self) -> dict[str, float]:
        """Per-component predicted seconds (grouped like the paper's
        figures for the GAP8 simulator; compute/HBM/VMEM for the TPU)."""
        c = self.plan.cost
        if isinstance(c, CostBreakdown):
            return c.grouped()
        if isinstance(c, TpuCost):
            return {"compute": c.t_compute, "hbm": c.t_hbm, "vmem": c.t_vmem}
        return {}

    def as_dict(self) -> dict:
        p = self.problem
        return {
            "m": p.m, "n": p.n, "k": p.k, "dtype": p.dtype,
            "backend": self.backend, "machine": self.machine,
            "policy": self.policy, "variant": self.variant,
            "micro_kernel": self.micro_kernel,
            "scenario": self.scenario,
            "precision": self.precision,
            "selection": str(self.selection), "seconds": self.seconds,
            "breakdown": self.breakdown(),
        }


def _problem_matches(row_problem: GemmProblem, query) -> bool:
    if isinstance(query, GemmProblem):
        return row_problem == query
    if isinstance(query, (tuple, list)) and len(query) == 3:
        # bare (m, n, k): dtype-agnostic by design
        return (row_problem.m, row_problem.n, row_problem.k) == tuple(query)
    if (row_problem.m, row_problem.n, row_problem.k) != (
            getattr(query, "m", None), getattr(query, "n", None),
            getattr(query, "k", None)):
        return False
    dtype = getattr(query, "dtype", None)
    return dtype is None or row_problem.dtype == dtype


@dataclasses.dataclass
class SweepResult:
    """The full grid of planned points plus sweep-level bookkeeping.

    ``pruned`` records the ``(backend, machine, dtype)`` axis combinations a
    ``feasible`` mask rejected before any planning work, each with the
    mask's reason string.
    """

    rows: list[SweepRow]
    grid: dict[str, list]
    stats: dict = dataclasses.field(default_factory=dict)
    pruned: list[dict] = dataclasses.field(default_factory=list)

    def __len__(self) -> int:
        return len(self.rows)

    def __iter__(self):
        return iter(self.rows)

    def filter(self, **axes) -> list[SweepRow]:
        """Rows matching every given axis value, e.g.
        ``filter(variant="B3A2C0", policy="analytic")``."""
        out = self.rows
        for name, want in axes.items():
            out = [r for r in out if getattr(r, name) == want]
        return out

    def best(self, problem=None) -> SweepRow:
        """The cheapest row overall, or for one problem (a
        :class:`GemmProblem`, ``(m, n, k)`` tuple, or core problem/shape)."""
        rows = self.rows if problem is None else \
            [r for r in self.rows if _problem_matches(r.problem, problem)]
        if not rows:
            raise ValueError(f"no sweep rows match problem {problem!r}")
        return min(rows, key=lambda r: r.seconds)

    def best_per_problem(self) -> dict[GemmProblem, SweepRow]:
        out: dict[GemmProblem, SweepRow] = {}
        for r in self.rows:
            cur = out.get(r.problem)
            if cur is None or r.seconds < cur.seconds:
                out[r.problem] = r
        return out

    def to_json(self) -> dict:
        def tag(v):
            if isinstance(v, MachineSpec):
                return v.name
            name = getattr(v, "name", None)
            return name if isinstance(name, str) else str(v)
        return {
            "grid": {k: [tag(v) for v in vs] for k, vs in self.grid.items()},
            "stats": self.stats,
            "pruned": list(self.pruned),
            "rows": [r.as_dict() for r in self.rows],
        }

    def table(self, limit: int | None = None) -> str:
        """Human-readable grid table (rows sorted as produced)."""
        lines = ["problem                  backend@machine       "
                 "variant/mk     policy    selection                 "
                 "seconds"]
        for r in self.rows[:limit]:
            p = r.problem
            vm = "/".join(x for x in (r.variant, r.micro_kernel) if x) or "-"
            lines.append(
                f"{p.m}x{p.n}x{p.k}:{p.dtype}".ljust(25)
                + f"{r.backend}@{r.machine}".ljust(22)
                + vm.ljust(15) + r.policy.ljust(10)
                + f"{r.selection}".ljust(26) + f"{r.seconds:.6g}")
        if limit is not None and len(self.rows) > limit:
            lines.append(f"... ({len(self.rows) - limit} more rows)")
        return "\n".join(lines)


def _axis(values, default=(None,)) -> list:
    if values is None:
        return list(default)
    if isinstance(values, (str, bytes)):
        return [values]
    return list(values)


def sweep(problems: Iterable, *,
          machines: Sequence | None = None,
          backends: Sequence[str] = ("analytic-tpu",),
          dtypes: Sequence[str] | None = None,
          policies: Sequence[str] = ("analytic",),
          variants: Sequence | None = None,
          micro_kernels: Sequence | None = None,
          scenarios: Sequence | None = None,
          precisions: Sequence | None = None,
          feasible=None,
          cache: bool = True,
          **options) -> SweepResult:
    """Plan every point of the problems x machines x backends x dtypes x
    policies (x variants x micro-kernels) grid as a bulk operation.

    Args:
        problems: GEMM problems (anything :meth:`GemmProblem.coerce`
            accepts); repeated problems are deduped before evaluation.
        machines: machines axis; entries may be registry names, raw
            :class:`MachineSpec` objects, or glob patterns (``"zoo/*"``
            expands to every manifest-backed machine, ``"gap*"``
            fnmatch-globs all registered names).  None means "the backend's
            native default".
        backends: backend-name axis (see ``repro.gemm.backends()``).
        dtypes: dtype-tag axis; None means the problems' own dtypes.
        policies: partial-tile accounting axis of the GAP8 simulator
            (``"analytic"`` | ``"padded"``).
        variants: GAP8-simulator loop-order axis, forwarded as the
            ``variant`` plan option.
        micro_kernels: GAP8-simulator micro-kernel axis (requires a variant
            axis, as with :func:`repro.gemm.plan`).  Backends whose search
            does not consume an axis (``Backend.sweep_axes``) get one grid
            point with that axis collapsed to None, rather than duplicate
            rows stamped with labels that had no effect.
        scenarios: workload-scenario axis.  Each entry is a label whose
            ``name`` attribute (or ``str()``) tags the rows it produced, and
            whose optional ``problems(base)`` hook maps the base problem
            list to the scenario's own — e.g. a
            :class:`repro.simulate.traffic.TrafficScenario` bound via
            ``.bind(cfg, max_len)`` appends the prefill-bucket GEMMs its
            prompt-length distribution can hit, so one sweep plans every
            shape a simulated serving run will price.  ``None`` (the
            default) keeps the classic un-tagged single-scenario grid.
        precisions: mixed-precision axis.  Each entry is a
            :class:`~repro.core.precision.PrecisionConfig` (or its key
            string, e.g. ``"int4xint8->int32"``) applied to every problem of
            the grid point via ``plan_many(..., precision=)``; rows are
            tagged with the config key in ``SweepRow.precision``.  A
            *uniform* entry normalizes to the plain dtype path and plans
            bit-identically to the equivalent ``dtypes`` axis point;
            ``None`` (the default) keeps the problems' own precision.
        feasible: optional feasibility mask ``feasible(machine, dtype) ->
            bool | (bool, reason)`` evaluated once per (machine, dtype)
            combination *before* any planning work; rejected combinations
            produce no rows and are recorded in ``SweepResult.pruned`` (and
            counted in ``stats["pruned"]``).  ``machine`` arrives as the
            expanded axis entry (name, spec, or None), ``dtype`` as the axis
            tag or None.  This is how deployment planning
            (``repro.serving``) prunes memory-infeasible cells without
            paying for their lattice evaluation.
        cache: consult/populate the process-level plan cache (default True).
        **options: forwarded to :func:`plan_many` (e.g. ``overlap=``).

    Returns:
        A :class:`SweepResult`: one :class:`SweepRow` per surviving grid
        point, carrying the frozen plan and its cost breakdown.

    Raises:
        UnknownBackendError: for a backend name absent from the registry.
        KeyError: for a machine name/pattern matching nothing.
    """
    from repro.gemm.registry import get_backend

    problems = list(problems)
    grid = {
        "backends": _axis(backends), "machines": expand_many(machines),
        "dtypes": _axis(dtypes), "policies": _axis(policies),
        "variants": _axis(variants), "micro_kernels": _axis(micro_kernels),
        "scenarios": _axis(scenarios),
        "precisions": [PrecisionConfig.coerce(pc)
                       for pc in _axis(precisions)],
    }
    before = plan_cache_stats()
    rows: list[SweepRow] = []
    pruned: list[dict] = []
    verdicts: dict[tuple, tuple[bool, str | None]] = {}

    def admissible(be: str, ma, dt) -> bool:
        if feasible is None:
            return True
        key = (id(ma) if isinstance(ma, MachineSpec) else ma, dt)
        if key not in verdicts:
            with obs.span("gemm.sweep.prune", dtype=dt,
                          machine=(ma.name if isinstance(ma, MachineSpec)
                                   else ma)):
                verdict = feasible(ma, dt)
            ok, reason = verdict if isinstance(verdict, tuple) \
                else (verdict, None)
            verdicts[key] = (bool(ok), reason)
        ok, reason = verdicts[key]
        if not ok:
            tag = ma.name if isinstance(ma, MachineSpec) else ma
            pruned.append({"backend": be, "machine": tag, "dtype": dt,
                           "reason": reason or "infeasible"})
        return ok

    with obs.span("gemm.sweep", problems=len(problems),
                  backends=len(grid["backends"]),
                  machines=len(grid["machines"])) as sweep_span:
        for sc in grid["scenarios"]:
            sc_tag = None if sc is None else str(getattr(sc, "name", sc))
            sc_problems = problems
            transform = getattr(sc, "problems", None)
            if callable(transform):
                sc_problems = list(transform(problems))
            for be in grid["backends"]:
                axes = get_backend(be).sweep_axes
                vas = grid["variants"] if "variant" in axes else [None]
                mks = grid["micro_kernels"] if "micro_kernel" in axes \
                    else [None]
                for ma, dt in itertools.product(grid["machines"],
                                                grid["dtypes"]):
                    if not admissible(be, ma, dt):
                        continue
                    for po, va, mk, pc in itertools.product(
                            grid["policies"], vas, mks, grid["precisions"]):
                        opts = dict(options)
                        if va is not None:
                            opts["variant"] = va
                        if mk is not None:
                            opts["micro_kernel"] = mk
                        plans = plan_many(sc_problems, backend=be,
                                          machine=ma, dtype=dt, policy=po,
                                          precision=pc,
                                          cache=cache, **opts)
                        va_tag = None if va is None \
                            else str(getattr(va, "value", va))
                        mk_tag = None if mk is None else \
                            (str(mk) if not isinstance(mk, (tuple, list))
                             else f"{mk[0]}x{mk[1]}")
                        pc_tag = None if pc is None else pc.key()
                        rows.extend(SweepRow(
                            problem=p.problem, backend=be, machine=p.machine,
                            policy=po, variant=va_tag, micro_kernel=mk_tag,
                            plan=p, scenario=sc_tag, precision=pc_tag,
                        ) for p in plans)
        after = plan_cache_stats()
        # every counter the cache exposes is reported as a per-call delta
        # (manifest_hits included — it used to be missing, so back-to-back
        # sweeps leaked cumulative numbers into SweepResult.stats).
        stats = {
            "problems": len(problems),
            "grid_points": len(rows),
            "pruned": len(pruned),
            "deduped": after["deduped"] - before["deduped"],
            "cache_hits": after["hits"] - before["hits"],
            "cache_misses": after["misses"] - before["misses"],
            "manifest_hits": after["manifest_hits"]
                             - before["manifest_hits"],
        }
        sweep_span.set(grid_points=len(rows), pruned=len(pruned))
    obs.metrics.counter("sweep.cells_scored", len(rows))
    obs.metrics.counter("sweep.cells_pruned", len(pruned))
    return SweepResult(rows=rows, grid=grid, stats=stats, pruned=pruned)
