"""``repro.gemm`` — one façade over the analytic simulators and kernels.

The paper's predict→choose→run loop as a first-class API:

    >>> from repro import gemm
    >>> gemm.backends()
    ['analytic-gap8', 'analytic-tpu', 'pallas', 'reference']
    >>> p = gemm.plan((512, 2048, 1024), backend="pallas", dtype="f32")
    >>> p.estimate().total()        # predicted seconds (TPU cost model)
    >>> c = p.execute(a, b, interpret=True)   # tuned Pallas kernel

Planning is a bulk operation: ``plan_many`` dedupes problems and routes
misses through the backends' vectorized batch engines, and ``sweep``
crosses problems x machines x backends x dtypes x policies (x variants x
micro-kernels) into one table of planned grid points:

    >>> res = gemm.sweep(problems, backends=["analytic-gap8"],
    ...                  variants=list(Variant))
    >>> res.best(problems[0]).selection

Machines come from the declarative zoo (``repro.machines``): ``plan`` /
``sweep`` accept registry names, raw ``MachineSpec`` objects, or glob
patterns (``machines=["zoo/*"]`` sweeps every manifest-backed machine).

See ``api.py`` for the plan/problem types, ``registry.py`` for the backend
protocol, ``backends.py`` for the built-ins, ``cache.py`` for memoisation +
manifest persistence, ``sweep.py`` for the sweep table.
"""
from repro.core.precision import PrecisionConfig
from repro.gemm.api import (
    GemmPlan,
    GemmProblem,
    NotExecutableError,
    UnknownBackendError,
    VariantChoice,
)
from repro.gemm.backends import dtype_tag
from repro.gemm.planner import (
    backends,
    clear_plan_cache,
    default_execute_backend,
    grouped_matmul,
    matmul,
    plan,
    plan_cache_stats,
    plan_many,
    plan_model_gemms,
    reset_plan_cache_stats,
    save_cache,
    warm_cache,
)
from repro.gemm.registry import Backend, get_backend, register_backend
from repro.gemm.sweep import SweepResult, SweepRow, sweep

__all__ = [
    "Backend", "GemmPlan", "GemmProblem", "NotExecutableError",
    "PrecisionConfig", "SweepResult", "SweepRow", "UnknownBackendError",
    "VariantChoice",
    "backends", "clear_plan_cache", "default_execute_backend", "dtype_tag",
    "get_backend", "grouped_matmul", "matmul", "plan", "plan_cache_stats",
    "plan_many", "plan_model_gemms", "register_backend",
    "reset_plan_cache_stats", "save_cache", "sweep", "warm_cache",
]
