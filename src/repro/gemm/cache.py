"""Process-level plan cache, persisted through TileTuner's JSON manifest.

Every ``repro.gemm.plan()`` decision is memoised in-process, keyed by
``(problem, backend, machine, policy, options)``.  The persistence layer is
:class:`repro.core.autotune.Manifest` — the same ``{m x n x k:dtype -> tile}``
JSON file TileTuner has always written — so kernels, benchmarks and the perf
log keep agreeing on the tiles used across processes.  A warmed manifest
satisfies tile-backend planning without re-running the search (provenance
``source="manifest"``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

from repro import obs
from repro.core.autotune import Manifest, TileDecision
from repro.core.tpu_model import TileConfig, TpuCost
from repro.gemm.api import GemmPlan, GemmProblem


def _freeze(value: Any) -> Any:
    if isinstance(value, (list, tuple)):
        return tuple(_freeze(v) for v in value)
    if isinstance(value, dict):
        return tuple(sorted((k, _freeze(v)) for k, v in value.items()))
    return value


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    manifest_hits: int = 0
    # problems dropped by bulk-planning dedupe before any evaluation
    # (repeated QKV/logits shapes across arch configs, sweep grid points).
    deduped: int = 0

    def as_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "manifest_hits": self.manifest_hits,
                "deduped": self.deduped}


class PlanCache:
    """In-memory plan store + manifest warm/persist layer."""

    def __init__(self):
        self._plans: dict[tuple, GemmPlan] = {}
        self._manifest: Manifest | None = None
        self.stats = CacheStats()

    @staticmethod
    def key(problem: GemmProblem, backend: str, machine: str, policy: str,
            options: Mapping) -> tuple:
        return (problem, backend, machine, policy, _freeze(dict(options)))

    def get(self, key: tuple) -> GemmPlan | None:
        plan = self._plans.get(key)
        if plan is None:
            self.stats.misses += 1
            obs.metrics.counter("plan_cache.misses")
        else:
            self.stats.hits += 1
            obs.metrics.counter("plan_cache.hits")
        return plan

    def put(self, key: tuple, plan: GemmPlan) -> None:
        self._plans[key] = plan

    def note_deduped(self, n: int) -> None:
        """Account problems dropped by bulk-planning dedupe (kept next to
        the other counters so the obs mirror stays in lock-step)."""
        if n:
            self.stats.deduped += n
            obs.metrics.counter("plan_cache.deduped", n)

    def reset_stats(self) -> CacheStats:
        """Zero the counters without touching the cached plans — the
        back-to-back-sweeps fix: each experiment snapshots deltas against
        a fresh zero instead of a process-cumulative total."""
        old = self.stats
        self.stats = CacheStats()
        return old

    def clear(self) -> None:
        self._plans.clear()
        self.stats = CacheStats()

    def __len__(self) -> int:
        return len(self._plans)

    # -- manifest persistence ------------------------------------------------
    def warm(self, path: str) -> int:
        """Load a TileTuner manifest as the cache's persisted tier; returns
        the number of entries now available for lookup."""
        self._manifest = Manifest(path)
        return len(self._manifest)

    def manifest_tile(self, problem: GemmProblem) -> TileConfig | None:
        if self._manifest is None:
            return None
        tile = self._manifest.lookup(problem.as_shape())
        if tile is not None:
            self.stats.manifest_hits += 1
            obs.metrics.counter("plan_cache.manifest_hits")
        return tile

    def save(self, path: str) -> int:
        """Persist every tile-shaped plan through the Manifest format;
        returns the number of entries written."""
        manifest = Manifest(path)
        for plan in self._plans.values():
            if isinstance(plan.selection, TileConfig) and \
                    isinstance(plan.cost, TpuCost):
                manifest.record(TileDecision(
                    shape=plan.problem.as_shape(), tile=plan.selection,
                    cost=plan.cost,
                    overlap=bool(plan.provenance.get("overlap", True))))
        manifest.save()
        return len(manifest)
