"""Backend protocol + registry for the unified GEMM API.

A backend owns one point in the "model + kernel + dtype" space: it *plans*
(runs its analytic model / search and freezes the decision into a
:class:`GemmPlan`) and, if it owns real kernels, *executes* a plan.  New
backends (CPU reference BLAS, grouped/batched GEMM, mixed precision) register
by name — consumers never hard-wire a simulator/kernel pair again.
"""
from __future__ import annotations

import abc
from typing import Mapping, Sequence

from repro.gemm.api import (
    GemmPlan,
    GemmProblem,
    NotExecutableError,
    UnknownBackendError,
)


class Backend(abc.ABC):
    """One pluggable (analytic model, kernel) pair."""

    #: registry name, e.g. "analytic-gap8".
    name: str = ""
    #: whether ``GemmPlan.execute`` is supported.
    executable: bool = False
    #: machine-spec name used when ``plan(..., machine=None)``.
    default_machine: str = "tpu-v5e"
    #: dtype assumed when the problem is given as a bare (m, n, k) tuple.
    default_dtype: str = "bf16"
    #: per-grid-point sweep axes this backend's search consumes
    #: (``repro.gemm.sweep`` collapses inapplicable axes to one point per
    #: backend instead of stamping meaningless labels on duplicate rows).
    sweep_axes: frozenset = frozenset()

    @abc.abstractmethod
    def make_plan(self, problem: GemmProblem, machine, policy: str,
                  options: Mapping) -> GemmPlan:
        """Run the backend's analytic model / search and freeze the result."""

    def make_plans(self, problems: Sequence[GemmProblem], machine,
                   policy: str, options: Mapping) -> list[GemmPlan]:
        """Plan many problems in one call.  Backends with a vectorized
        engine override this with a bulk array evaluation; the default just
        loops ``make_plan``.  Must return one plan per problem, in order."""
        return [self.make_plan(p, machine, policy, options)
                for p in problems]

    def plan_from_tile(self, problem: GemmProblem, machine, policy: str,
                       tile) -> GemmPlan | None:
        """Rebuild a plan from a persisted tile decision (manifest hit).
        Backends without a tile-shaped selection return None."""
        return None

    def execute(self, plan: GemmPlan, a, b, c=None, *,
                interpret: bool = False, force: bool = False):
        raise NotExecutableError(
            f"backend {self.name!r} is analytic-only (it predicts, it does "
            f"not run); plan with backend='pallas' or 'reference' to execute")

    def coerce_problem(self, problem, dtype: str | None) -> GemmProblem:
        return GemmProblem.coerce(problem, dtype=dtype,
                                  default_dtype=self.default_dtype)


_REGISTRY: dict[str, Backend] = {}


def register_backend(backend: Backend, *, overwrite: bool = False) -> Backend:
    if not backend.name:
        raise ValueError("backend must carry a non-empty .name")
    if backend.name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {backend.name!r} already registered")
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownBackendError(
            f"unknown GEMM backend {name!r}; registered: {backend_names()}"
        ) from None


def backend_names() -> list[str]:
    return sorted(_REGISTRY)
