"""The four built-in GEMM backends.

* ``analytic-gap8``  — the paper's calibrated GAP8 simulator (§3, Table 2):
  searches loop-order variants x register-feasible micro-kernels.  Predicts
  only.
* ``analytic-tpu``   — the TPU adaptation: TileTuner's search over Pallas
  ``(bm, bn, bk, grid-order)`` candidates.  Predicts only.
* ``pallas``         — plans exactly like ``analytic-tpu`` and executes the
  plan with the Pallas kernels (TPU or ``interpret=True``); off-TPU without
  interpret it falls back to the jnp reference, keeping SPMD lowering clean
  (same dispatch rule as the old ``kernels.ops.matmul``).
* ``reference``      — no tiling decision; executes the pure-jnp oracle.
  Its estimate is the whole-array (single-tile) cost — the model's lower
  bound on blocking, useful as a sanity baseline.
"""
from __future__ import annotations

from typing import Mapping, Sequence

import jax
import jax.numpy as jnp

from repro.core.autotune import tune_batch
from repro.core.hardware import MachineSpec
from repro.core.simulator import (
    best_microkernel_batch,
    search_batch,
    simulate,
)
from repro.core.tpu_model import GridOrder, TileConfig, estimate
from repro.core.variants import MicroKernel, Variant
from repro.gemm.api import GemmPlan, GemmProblem, VariantChoice
from repro.gemm.registry import Backend, register_backend

_JNP_DTYPE_TAGS = {"bfloat16": "bf16", "float32": "f32", "int8": "int8"}


def dtype_tag(dtype) -> str:
    """Map a jnp/numpy dtype to the cost models' dtype tag."""
    return _JNP_DTYPE_TAGS.get(jnp.dtype(dtype).name, "bf16")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, mults):
    pads = [(0, (m - d % m) % m) for d, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


def _coerce_variant(v) -> Variant:
    return v if isinstance(v, Variant) else Variant(v)


def _coerce_mk(mk) -> MicroKernel:
    if isinstance(mk, MicroKernel):
        return mk
    return MicroKernel(int(mk[0]), int(mk[1]))


class AnalyticGap8Backend(Backend):
    """The paper's simulator instance: Table-2's exhaustive search.

    Planning is a bulk operation: ``make_plans`` scores the whole
    (problem x variant x micro-kernel) lattice through the batched simulator
    and argmin-selects per problem; ``make_plan`` is the one-problem case.
    """

    name = "analytic-gap8"
    executable = False
    default_machine = "gap8-fc"
    default_dtype = "int8"
    sweep_axes = frozenset({"variant", "micro_kernel"})

    def make_plan(self, problem: GemmProblem, machine: MachineSpec,
                  policy: str, options: Mapping) -> GemmPlan:
        return self.make_plans([problem], machine, policy, options)[0]

    def make_plans(self, problems: Sequence[GemmProblem],
                   machine: MachineSpec, policy: str,
                   options: Mapping) -> list[GemmPlan]:
        variant = options.get("variant")
        mk = options.get("micro_kernel")
        variants = ([_coerce_variant(variant)] if variant is not None
                    else list(Variant))
        probs = [p.as_problem() for p in problems]
        if mk is not None:
            if variant is None:
                raise ValueError(
                    "micro_kernel override requires an explicit variant")
            cbs = [simulate(machine, variants[0], _coerce_mk(mk), pr,
                            policy=policy) for pr in probs]
            source = "explicit"
        elif variant is not None:
            cbs = best_microkernel_batch(machine, variants[0], probs,
                                         policy=policy)
            source = "search"
        else:
            cbs = search_batch(machine, probs, variants, policy=policy)
            source = "search"
        return [GemmPlan(
            problem=p, backend=self.name, machine=machine.name,
            selection=VariantChoice(cb.variant, cb.micro_kernel, cb.blocking),
            cost=cb,
            provenance={"source": source, "method": "best_microkernel",
                        "policy": policy,
                        "variants": [v.value for v in variants]},
        ) for p, cb in zip(problems, cbs)]


class AnalyticTpuBackend(Backend):
    """TileTuner's analytic search over the Pallas tiling design space."""

    name = "analytic-tpu"
    executable = False
    default_machine = "tpu-v5e"
    default_dtype = "bf16"

    def make_plan(self, problem: GemmProblem, machine: MachineSpec,
                  policy: str, options: Mapping) -> GemmPlan:
        return self.make_plans([problem], machine, policy, options)[0]

    def make_plans(self, problems: Sequence[GemmProblem],
                   machine: MachineSpec, policy: str,
                   options: Mapping) -> list[GemmPlan]:
        overlap = bool(options.get("overlap", True))
        tile = options.get("tile")
        if tile is not None:
            return [self.plan_from_tile(p, machine, policy, tile,
                                        source="explicit", overlap=overlap)
                    for p in problems]
        # TileTuner's batched lattice search (deduped + memoised per machine).
        decisions = tune_batch([p.as_shape() for p in problems],
                               overlap=overlap, machine=machine)
        return [GemmPlan(
            problem=p, backend=self.name, machine=machine.name,
            selection=d.tile, cost=d.cost,
            provenance={"source": "search", "method": "tile_tuner",
                        "overlap": overlap, "policy": policy},
        ) for p, d in zip(problems, decisions)]

    def plan_from_tile(self, problem: GemmProblem, machine: MachineSpec,
                       policy: str, tile: TileConfig, *,
                       source: str = "manifest",
                       overlap: bool = True) -> GemmPlan:
        cost = estimate(problem.as_shape(), tile, machine)
        return GemmPlan(
            problem=problem, backend=self.name, machine=machine.name,
            selection=tile, cost=cost,
            provenance={"source": source, "method": "tile_tuner",
                        "overlap": overlap, "policy": policy},
        )


class PallasBackend(AnalyticTpuBackend):
    """analytic-tpu planning + Pallas execution (the full paper loop)."""

    name = "pallas"
    executable = True

    def execute(self, plan: GemmPlan, a, b, c=None, *,
                interpret: bool = False, force: bool = False):
        from repro.kernels import gemm as gemm_kernel
        from repro.kernels import ref

        p = plan.problem
        if a.shape != (p.m, p.k) or b.shape != (p.k, p.n):
            raise ValueError(
                f"operands {a.shape} @ {b.shape} do not match the planned "
                f"problem {p.m}x{p.n}x{p.k}")
        if not (_on_tpu() or interpret or force):
            # off-TPU the Pallas lowering is unavailable: same reference
            # fallback the kernels have always used on the dry-run path.
            return ref.gemm_ref(a, b, c)
        t = plan.selection
        bm, bn, bk = min(t.bm, p.m), min(t.bn, p.n), min(t.bk, p.k)
        tile = TileConfig(bm, bn, bk, t.order)
        ap = _pad_to(a, (bm, bk))
        bp = _pad_to(b, (bk, bn))
        cp = None if c is None else _pad_to(c, (bm, bn))
        out = gemm_kernel.gemm(ap, bp, cp, tile=tile, interpret=interpret)
        return out[:p.m, :p.n]


class ReferenceBackend(Backend):
    """Pure-jnp oracle: always correct, never tiled."""

    name = "reference"
    executable = True
    default_machine = "tpu-v5e"
    default_dtype = "bf16"

    def make_plan(self, problem: GemmProblem, machine: MachineSpec,
                  policy: str, options: Mapping) -> GemmPlan:
        shape = problem.as_shape()
        whole = TileConfig(problem.m, problem.n, problem.k,
                           GridOrder.K_INNER)
        return GemmPlan(
            problem=problem, backend=self.name, machine=machine.name,
            selection=None, cost=estimate(shape, whole, machine),
            provenance={"source": "closed-form", "method": "single-tile",
                        "policy": policy},
        )

    def execute(self, plan: GemmPlan, a, b, c=None, *,
                interpret: bool = False, force: bool = False):
        from repro.kernels import ref
        return ref.gemm_ref(a, b, c)


def register_builtin_backends() -> None:
    for cls in (AnalyticGap8Backend, AnalyticTpuBackend, PallasBackend,
                ReferenceBackend):
        register_backend(cls(), overwrite=True)
