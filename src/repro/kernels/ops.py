"""Public jit'd wrappers for the Pallas kernels.

``matmul`` routes through the unified plan/execute API (``repro.gemm``): it
plans on the ``pallas`` backend — the paper's analytical tile selection,
memoised in the process-level plan cache — and executes the frozen plan.
``grouped_gemm`` / ``flash_attention`` dispatch on backend directly:

* on TPU (``jax.default_backend() == 'tpu'``) or with ``interpret=True``
  they run the Pallas kernels;
* otherwise (CPU container, 512-device dry-run) they fall back to the
  pure-jnp reference path so XLA-native SPMD lowering stays clean
  (DESIGN.md §3).

Padding to tile multiples happens inside the pallas backend's execute (zero
K-padding is mathematically exact; M/N padding is sliced off).
"""
from __future__ import annotations

import warnings

import jax

from repro import gemm as gemm_api
from repro.core.tpu_model import GridOrder, TileConfig
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.grouped_gemm import grouped_gemm_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pick_tile(m: int, n: int, k: int, dtype: str,
              order: GridOrder | None = None) -> TileConfig:
    """Deprecated shim: use ``repro.gemm.plan(...).selection`` instead."""
    warnings.warn(
        "kernels.ops.pick_tile is deprecated; use "
        "repro.gemm.plan((m, n, k), backend='analytic-tpu').selection",
        DeprecationWarning, stacklevel=2)
    t = gemm_api.plan((m, n, k), backend="analytic-tpu", dtype=dtype).selection
    if order is not None and t.order is not order:
        t = TileConfig(t.bm, t.bn, t.bk, order)
    return t


def matmul(a, b, *, tile: TileConfig | None = None,
           interpret: bool = False, force_pallas: bool = False):
    """C = A @ B through the planned Pallas kernel (TPU) or jnp (elsewhere).

    The TPU/interpret-vs-reference dispatch lives in one place: the pallas
    backend's ``execute`` (off-TPU without interpret it runs the jnp
    reference), so every call routes through the plan cache.
    """
    m, k = a.shape
    n = b.shape[1]
    options = {} if tile is None else {"tile": tile}
    plan = gemm_api.plan((m, n, k), backend="pallas",
                         dtype=gemm_api.dtype_tag(a.dtype), **options)
    return plan.execute(a, b, interpret=interpret, force=force_pallas)


def grouped_gemm(x, w, *, block_c: int = 128, block_f: int = 128,
                 interpret: bool = False):
    """x: (E, C, D) @ w: (E, D, F) -> (E, C, F) (MoE expert FFN)."""
    if not (_on_tpu() or interpret):
        return ref.grouped_gemm_ref(x, w)
    return grouped_gemm_kernel(x, w, block_c=block_c, block_f=block_f,
                               interpret=interpret)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q,k,v: (B, S, H, D) -> (B, S, H, D)."""
    if not (_on_tpu() or interpret):
        return ref.flash_attention_ref(q, k, v, causal=causal)
    return flash_attention_fwd(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=interpret)
