"""Public jit'd wrappers for the Pallas kernels.

``matmul`` / ``grouped_gemm`` / ``flash_attention`` dispatch on backend:

* on TPU (``jax.default_backend() == 'tpu'``) or with ``interpret=True``
  they run the Pallas kernels with tiles chosen by TileTuner — the paper's
  analytical selection applied at call time;
* otherwise (CPU container, 512-device dry-run) they fall back to the
  pure-jnp reference path so XLA-native SPMD lowering stays clean
  (DESIGN.md §3).

Padding to tile multiples happens here (zero K-padding is mathematically
exact; M/N padding is sliced off).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.autotune import tune
from repro.core.tpu_model import GemmShape, GridOrder, TileConfig
from repro.kernels import gemm as gemm_kernel
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.grouped_gemm import grouped_gemm_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, mults):
    pads = [(0, (m - d % m) % m) for d, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads), True
    return x, False


def pick_tile(m: int, n: int, k: int, dtype: str,
              order: GridOrder | None = None) -> TileConfig:
    """TileTuner decision for a GEMM shape (cached)."""
    d = tune(GemmShape(m, n, k, dtype))
    t = d.tile
    if order is not None and t.order is not order:
        t = TileConfig(t.bm, t.bn, t.bk, order)
    return t


def matmul(a, b, *, tile: TileConfig | None = None,
           interpret: bool = False, force_pallas: bool = False):
    """C = A @ B through the tuned Pallas kernel (TPU) or jnp (elsewhere)."""
    m, k = a.shape
    n = b.shape[1]
    if not (_on_tpu() or interpret or force_pallas):
        return ref.gemm_ref(a, b)
    dtype = {jnp.dtype(jnp.bfloat16): "bf16", jnp.dtype(jnp.float32): "f32",
             jnp.dtype(jnp.int8): "int8"}.get(jnp.dtype(a.dtype), "bf16")
    t = tile or pick_tile(m, n, k, dtype)
    bm, bn, bk = min(t.bm, m), min(t.bn, n), min(t.bk, k)
    ap, _ = _pad_to(a, (bm, bk))
    bp, _ = _pad_to(b, (bk, bn))
    out = gemm_kernel.gemm(ap, bp, tile=TileConfig(bm, bn, bk, t.order),
                           interpret=interpret)
    return out[:m, :n]


def grouped_gemm(x, w, *, block_c: int = 128, block_f: int = 128,
                 interpret: bool = False):
    """x: (E, C, D) @ w: (E, D, F) -> (E, C, F) (MoE expert FFN)."""
    if not (_on_tpu() or interpret):
        return ref.grouped_gemm_ref(x, w)
    return grouped_gemm_kernel(x, w, block_c=block_c, block_f=block_f,
                               interpret=interpret)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q,k,v: (B, S, H, D) -> (B, S, H, D)."""
    if not (_on_tpu() or interpret):
        return ref.flash_attention_ref(q, k, v, causal=causal)
    return flash_attention_fwd(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=interpret)
