"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gemm_ref(a, b, c=None):
    acc = jnp.int32 if jnp.issubdtype(a.dtype, jnp.integer) else jnp.float32
    out = jnp.dot(a.astype(acc) if jnp.issubdtype(a.dtype, jnp.integer) else a,
                  b.astype(acc) if jnp.issubdtype(b.dtype, jnp.integer) else b,
                  preferred_element_type=acc)
    out = out.astype(acc if jnp.issubdtype(a.dtype, jnp.integer) else a.dtype)
    return out if c is None else c + out


def gemm_ref_streamed(a, b, c, bk: int):
    """Oracle for the C-streamed (k-outer) variant: C is rounded to its
    storage dtype after every k-block pass — the exact function
    ``gemm_k_outer`` computes (and the numerical price of the paper's
    C3B2A0/B3C2A0 loop orders on reduced-precision storage)."""
    k = a.shape[1]
    acc = jnp.int32 if jnp.issubdtype(a.dtype, jnp.integer) else jnp.float32
    for kk in range(0, k, bk):
        part = jnp.dot(a[:, kk:kk + bk], b[kk:kk + bk],
                       preferred_element_type=acc)
        c = (c.astype(acc) + part).astype(c.dtype)
    return c


def grouped_gemm_ref(x, w):
    """x: (E, C, D); w: (E, D, F) -> (E, C, F)."""
    return jnp.einsum("ecd,edf->ecf", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q,k,v: (B, S, H, D) -> (B, S, H, D), plain softmax attention."""
    b, s, h, d = q.shape
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (d ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((s, k.shape[1]), bool))
        scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
