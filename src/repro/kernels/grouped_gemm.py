"""Grouped (per-expert) GEMM for MoE layers (Pallas TPU).

Computes ``y[e] = x[e] @ w[e]`` for all experts in one kernel, tiling the
capacity and feature dims.  The expert dim is the outermost grid axis so the
kernel composes with expert-parallel sharding via ``shard_map`` (each shard
runs its local experts).  Tiles follow TileTuner's choices for the
per-expert GEMM shape — the small ``moe_d_ff`` GEMMs of granite (512) vs the
wide ones of kimi (2048) land on different tiles, exactly the shape
sensitivity the paper's Table 2 documents for MobileNet layers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _grouped_kernel(x_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0], w_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == k_steps - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def grouped_gemm_kernel(x, w, *, block_c: int = 128, block_f: int = 128,
                        block_k: int = 512, interpret: bool = False):
    """x: (E, C, D); w: (E, D, F) -> (E, C, F)."""
    e, c, d = x.shape
    e2, d2, f = w.shape
    assert e == e2 and d == d2
    bc, bf, bk = min(block_c, c), min(block_f, f), min(block_k, d)
    assert c % bc == 0 and f % bf == 0 and d % bk == 0, (x.shape, w.shape)
    grid = (e, c // bc, f // bf, d // bk)
    return pl.pallas_call(
        functools.partial(_grouped_kernel, k_steps=grid[3]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bk), lambda g, i, j, kk: (g, i, kk)),
            pl.BlockSpec((1, bk, bf), lambda g, i, j, kk: (g, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda g, i, j, kk: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w)
