"""Blocked causal flash attention (Pallas TPU).

Online-softmax over KV blocks with running (max, sum, accumulator) held in
VMEM scratch — the attention instance of the paper's blocking methodology:
the score matrix never touches HBM, so the HBM term of the roofline drops
from O(S^2) to O(S * D).  Causal block skipping prunes fully-masked blocks'
contributions via masking (the grid is still full; Mosaic handles the
revisit pipeline).

Grid: (batch*heads, S/block_q, S/block_k), k innermost.  Shapes must divide
the blocks (ops.flash_attention handles padding upstream by construction —
model sequence lengths are block-multiples).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, block_q: int, block_k: int, k_steps: int,
                  scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0].astype(jnp.float32)                  # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

    if causal:
        q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                        (block_q, block_k), 0)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                        (block_q, block_k), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1)[:, None])   # (bq, 1)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)[:, None]
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(ki == k_steps - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, block_q: int = 128,
                        block_k: int = 128, interpret: bool = False):
    """q,k,v: (B, S, H, D) -> (B, S, H, D)."""
    b, s, h, d = q.shape
    skv = k.shape[1]
    block_q = min(block_q, s)
    block_k = min(block_k, skv)
    assert s % block_q == 0 and skv % block_k == 0, (s, skv, block_q, block_k)
    # fold batch and heads: (B*H, S, D)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, skv, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, skv, d)
    grid = (b * h, s // block_q, skv // block_k)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, causal=causal, block_q=block_q,
                          block_k=block_k, k_steps=grid[2],
                          scale=d ** -0.5),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)
