"""Fused RMSNorm Pallas kernel.

Norms are pure memory-bound ops; unfused they read/write the activation
stream several times (square, mean, rsqrt, scale).  One VMEM pass computes
the row statistics and the scaled output — the memory-hierarchy discipline
of the paper applied to the framework's most common non-GEMM op.  Rows are
tiled along the token axis; the feature axis stays whole in VMEM (d_model ≤
8192 ≈ 32 KB/row, far under the tile budget).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * scale_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: bool = False):
    """x: (..., D); scale: (D,) -> same shape as x."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    assert rows % br == 0, (rows, br)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(orig_shape)
