"""Pallas TPU GEMM kernels — the paper's algorithm family on real hardware.

Two kernels realise the two cost-model variants (core/tpu_model.GridOrder):

* ``gemm_k_inner`` — grid ``(M/bm, N/bn, K/bk)``, k innermost: the C block
  accumulates in a VMEM scratch and is written to HBM once — the **B3A2C0
  analogue** (output-stationary; "reduces the number of stores of C",
  paper §4).
* ``gemm_k_outer`` — k outermost: one aliased ``C += A_k @ B_k`` pass per k
  block, so C is re-fetched / re-written from HBM on every k step — the
  **C3B2A0/B3C2A0 analogue** (C streamed).  Strictly more HBM traffic; it
  exists so the simulator's predictions are observable in real artifacts,
  and because it needs no f32 accumulator resident in VMEM.

Kernels require tile-divisible shapes; ``ops.matmul`` pads (zero K-padding
is exact) and slices.  Block shapes come from TileTuner (core/autotune) —
the paper's "simulate-before-implement" workflow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

from repro.core.tpu_model import GridOrder, TileConfig


def _acc_dtype(dtype) -> jnp.dtype:
    return jnp.int32 if jnp.issubdtype(dtype, jnp.integer) else jnp.float32


def _check_divisible(m, n, k, bm, bn, bk):
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{n},{k}) not divisible by tile ({bm},{bn},{bk}); "
        "use kernels.ops.matmul which pads")


def _k_inner_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=acc_ref.dtype)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gemm_k_inner(a, b, *, tile: TileConfig, interpret: bool = False):
    """C = A @ B with the output-stationary grid (B3A2C0 analogue)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    bm, bn, bk = min(tile.bm, m), min(tile.bn, n), min(tile.bk, k)
    _check_divisible(m, n, k, bm, bn, bk)
    grid = (m // bm, n // bn, k // bk)
    acc = _acc_dtype(a.dtype)
    out_dtype = acc if jnp.issubdtype(a.dtype, jnp.integer) else a.dtype
    return pl.pallas_call(
        functools.partial(_k_inner_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)


def _k_step_kernel(a_ref, b_ref, c_ref, o_ref):
    acc = _acc_dtype(a_ref.dtype)
    part = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=acc)
    o_ref[...] = (c_ref[...].astype(acc) + part).astype(o_ref.dtype)


@functools.lru_cache(maxsize=512)
def _k_step_call(m: int, n: int, bk: int, bm: int, bn: int,
                 out_dtype: str, interpret: bool, donate: bool = False):
    """One ``C += A_k @ B_k`` pass over the full C (grid (M/bm, N/bn)),
    built once per (shape, tile, dtype) configuration and jitted so the
    tracing/lowering cost is paid once, then reused across every k step of
    every call with that configuration."""
    call = pl.pallas_call(
        _k_step_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.dtype(out_dtype)),
        input_output_aliases={2: 0},
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )
    return jax.jit(call, donate_argnums=(2,) if donate else ())


def gemm_k_outer(a, b, c, *, tile: TileConfig, interpret: bool = False):
    """C += A @ B with C streamed per k block (C3B2A0/B3C2A0 analogue)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and c.shape == (m, n)
    bm, bn, bk = min(tile.bm, m), min(tile.bn, n), min(tile.bk, k)
    _check_divisible(m, n, k, bm, bn, bk)
    dt = jnp.dtype(c.dtype).name
    # Step 0 must not donate: c is the caller's array there.  Later steps
    # rebind c to the previous step's output, which is dead after the call —
    # donating it lets XLA honour the in-place input_output_aliases update
    # instead of copying C per step (donation is a no-op under interpret).
    first = _k_step_call(m, n, bk, bm, bn, dt, interpret)
    rest = first if interpret else \
        _k_step_call(m, n, bk, bm, bn, dt, interpret, donate=True)
    for kk in range(k // bk):
        a_k = jax.lax.slice_in_dim(a, kk * bk, (kk + 1) * bk, axis=1)
        b_k = jax.lax.slice_in_dim(b, kk * bk, (kk + 1) * bk, axis=0)
        c = (first if kk == 0 else rest)(a_k, b_k, c)
    return c


def gemm(a, b, c=None, *, tile: TileConfig, interpret: bool = False):
    if tile.order is GridOrder.K_INNER:
        out = gemm_k_inner(a, b, tile=tile, interpret=interpret)
        return out if c is None else c + out
    if c is None:
        dt = (_acc_dtype(a.dtype)
              if jnp.issubdtype(a.dtype, jnp.integer) else a.dtype)
        c = jnp.zeros((a.shape[0], b.shape[1]), dt)
    return gemm_k_outer(a, b, c, tile=tile, interpret=interpret)
