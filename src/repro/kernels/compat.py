"""Version shims for the Pallas TPU API.

``pltpu.TPUCompilerParams`` was renamed ``pltpu.CompilerParams`` in newer
jax releases; the kernels import the alias from here so they run on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
assert CompilerParams is not None, "no Pallas TPU CompilerParams class found"
