"""Design-space command line.

    python -m repro.design expand --space smoke
    python -m repro.design sweep --space smoke
    python -m repro.design frontier --space gap9-sweep --json frontier.json
    python -m repro.design frontier --space gap9-sweep --arch qwen2-1.5b \\
        --smoke --batch 8 --slo-p99 0.35
    python -m repro.design ground --space smoke --index 0 \\
        --store /tmp/design.jsonl --synthetic

``expand`` lists (or writes manifests for) a space's generated specs;
``sweep`` registers a space under the ``gen/`` namespace, runs the GEMM
grid over ``machines="gen/*"`` through ``repro.gemm.sweep``, and cleans
the namespace up; ``frontier`` scores the space and prints the Pareto
frontier (optionally SLO-re-ranked via the serving simulator); ``ground``
runs the expand -> sample -> fit -> validate loop for one design point
(``--synthetic`` prices the campaign against a perturbed ground truth, so
the path is exercisable without hardware).  Everything is config-only —
no jax.
"""
from __future__ import annotations

import argparse
import json
import sys


def _space(args):
    from repro.design.space import get_space

    return get_space(args.space)


def cmd_expand(args) -> int:
    import os

    space = _space(args)
    n = len(space) if args.limit is None else min(args.limit, len(space))
    print(f"{space!r}")
    rows = []
    for pt in space.points():
        if pt.index >= n:
            break
        spec = pt.spec()
        rows.append({"index": pt.index, "name": spec.name,
                     "params": dict(pt.params),
                     "fingerprint": spec.fingerprint()})
        print(f"  [{pt.index:>3}] {spec.name:<26} {pt.label()}")
        if args.out:
            spec.to_manifest(os.path.join(args.out, f"{spec.name}.json"))
    if args.out:
        print(f"wrote {n} manifests under {args.out}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"space": space.name, "points": rows}, f, indent=1)
        print(f"wrote {args.json}")
    return 0


def cmd_sweep(args) -> int:
    from repro import gemm, machines
    from repro.measure.campaign import grid_problems

    space = _space(args)
    names = space.register_all(limit=args.limit)
    try:
        problems = grid_problems(args.grid, dtype=args.dtype)
        result = gemm.sweep(problems, machines="gen/*",
                            backends=[args.backend])
        per_machine: dict[str, float] = {}
        for row in result.rows:
            per_machine[row.machine] = (per_machine.get(row.machine, 0.0)
                                        + row.seconds)
        flops = sum(2.0 * p.m * p.n * p.k for p in problems)
        print(f"{space!r}: {len(names)} designs x {len(problems)} "
              f"{args.grid} problems ({args.backend})")
        for name in sorted(per_machine):
            s = per_machine[name]
            print(f"  {name:<26} {s:.6g} s   {flops / s / 1e9:8.2f} GOPS")
        stats = result.stats
        print(f"[{stats.get('rows', len(result.rows))} rows planned]")
    finally:
        machines.unregister_prefix("gen/")
    return 0


def cmd_frontier(args) -> int:
    from repro.design.explore import pareto, rerank_by_slo, score_designs

    space = _space(args)
    cfg = None
    if args.arch:
        from repro.configs import get_config
        cfg = get_config(args.arch, smoke=args.smoke)
    points = (space.sample(args.sample, method=args.method)
              if args.sample else list(space.points()))
    scores = score_designs(points, cfg=cfg, grid=args.grid,
                           dtype=args.dtype, batch=args.batch,
                           max_len=args.max_len, backend=args.backend)
    workload = f"{args.grid}+{cfg.name}" if cfg is not None else args.grid
    frontier = pareto(scores, workload=workload)
    print(f"{space!r} scored on {workload}")
    print(frontier.table())
    out = frontier.as_dict()
    if args.slo_p99 is not None:
        if cfg is None:
            print("--slo-p99 needs --arch", file=sys.stderr)
            return 2
        traffic = None
        if args.rps is not None:
            from repro.simulate.traffic import PoissonTraffic
            traffic = PoissonTraffic(rate=args.rps, prompt_len=32,
                                     decode_len=16)
        ranked = rerank_by_slo(frontier, points, cfg,
                               slo={"p99_latency_s": args.slo_p99},
                               dtype=args.dtype, batch=args.batch,
                               max_len=args.max_len, backend=args.backend,
                               requests=args.requests, traffic=traffic)
        out["slo_rerank"] = {"p99_latency_s": args.slo_p99,
                             "ranked": ranked}
        print(f"\nSLO re-rank (p99 <= {args.slo_p99:g}s, batch "
              f"{args.batch}):")
        for r in ranked:
            mark = "ok " if r["attained"] else "VIOLATES"
            print(f"  {mark} {r['design']:<26} goodput "
                  f"{r['goodput_tps']:8.4g} tok/s  p99 "
                  f"{r['p99_latency_s']:.4g}s  area {r['area_proxy']:.1f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1, sort_keys=True)
        print(f"wrote {args.json}")
    return 0 if frontier.frontier else 1


def cmd_ground(args) -> int:
    from repro.design.ground import ground, sample_design, synthetic_truth
    from repro.measure.store import SampleStore

    space = _space(args)
    pt = space.point(args.index)
    spec = pt.spec()
    store = SampleStore(args.store)
    if args.synthetic:
        truth = synthetic_truth(spec, bw=args.truth_bw,
                                arith=args.truth_arith)
        camp = sample_design(pt, store, grid=args.grid, dtype=args.dtype,
                             truth=truth)
        print(f"sampled {len(camp.samples)} cells for {spec.name} against "
              f"synthetic truth (bw x{args.truth_bw:g}, arith "
              f"x{args.truth_arith:g})")
    result = ground(pt, store, date=args.date,
                    overhead_per_block=args.overhead_per_block,
                    manifest_dir=args.out)
    fit = result.fit
    print(f"grounded {result.spec.name}: residual "
          f"{fit.residual_rms_s:.3g}s over {fit.samples} samples, "
          f"validated MAPE {result.mape:.3g}%")
    assert result.spec.provenance.get("grounded") is True
    if args.out:
        print(f"wrote manifest under {args.out}")
    return 0


def main(argv=None) -> int:
    from repro.design.space import space_names

    ap = argparse.ArgumentParser(prog="python -m repro.design",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    def common(p, sweep_knobs: bool = True):
        p.add_argument("--space", default="gap9-sweep",
                       choices=space_names(),
                       help="named design space (default: gap9-sweep)")
        if sweep_knobs:
            p.add_argument("--grid", default="table2",
                           help="GEMM grid to score (default: table2)")
            p.add_argument("--dtype", default="int8")
            p.add_argument("--backend", default="analytic-gap8")

    p = sub.add_parser("expand", help="list / write a space's specs")
    common(p, sweep_knobs=False)
    p.add_argument("--limit", type=int, default=None)
    p.add_argument("--out", default=None, help="write manifests here")
    p.add_argument("--json", default=None)
    p.set_defaults(fn=cmd_expand)

    p = sub.add_parser("sweep", help="register gen/* and sweep the grid")
    common(p)
    p.add_argument("--limit", type=int, default=None)
    p.set_defaults(fn=cmd_sweep)

    p = sub.add_parser("frontier", help="score a space, print the Pareto "
                                        "frontier")
    common(p)
    p.add_argument("--arch", default=None,
                   help="model config: score decode tokens/s instead of "
                        "grid GOPS")
    p.add_argument("--smoke", action="store_true",
                   help="smoke-reduce the arch (tiny layers)")
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--max-len", type=int, default=512)
    p.add_argument("--sample", type=int, default=None,
                   help="score a deterministic subset of this size")
    p.add_argument("--method", default="grid", choices=("grid", "halton"))
    p.add_argument("--slo-p99", type=float, default=None,
                   help="re-rank the frontier by simulated p99 attainment")
    p.add_argument("--rps", type=float, default=None,
                   help="fixed Poisson arrival rate for the SLO re-rank "
                        "(default: each design at 0.6x its own peak)")
    p.add_argument("--requests", type=int, default=200)
    p.add_argument("--json", default=None)
    p.set_defaults(fn=cmd_frontier)

    p = sub.add_parser("ground", help="expand -> sample -> fit -> validate "
                                      "one design point")
    common(p)
    p.add_argument("--index", type=int, default=0,
                   help="design-point index within the space")
    p.add_argument("--store", required=True, help="sample store (JSONL)")
    p.add_argument("--synthetic", action="store_true",
                   help="run a simulated campaign against a perturbed "
                        "truth first")
    p.add_argument("--truth-bw", type=float, default=0.8)
    p.add_argument("--truth-arith", type=float, default=0.9)
    p.add_argument("--overhead-per-block", action="store_true",
                   help="fit the per-block dispatch-overhead column too")
    p.add_argument("--date", default=None)
    p.add_argument("--out", default=None, help="manifest output dir")
    p.set_defaults(fn=cmd_ground)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
