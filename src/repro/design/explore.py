"""Score generated designs on real workloads and take the Pareto frontier.

``score_designs`` runs each design point through the *existing* engines —
the Table-2 GEMM grid via ``repro.gemm.sweep`` (the batched planners) and,
when a model config is given, decode-GEMM serving throughput via
``repro.serving.plan_deployment`` (which also applies the deployment
memory budget, so a design too small to hold the model is recorded
infeasible rather than scored on fiction).

``pareto`` then reduces the scores to a deterministic frontier over

* ``throughput``  — maximize (tokens/s when a model config is scored,
  else Table-2 grid GOPS),
* ``sram_bytes``  — minimize (on-chip L1+L2 the design must provision),
* ``area_proxy``  — minimize (the template's closed-form area estimate),

with one machine-readable :class:`DominanceRecord` per dominated design
(who dominated it, and by how much per objective).  ``rerank_by_slo``
optionally re-orders the frontier by simulated p99 SLO attainment using
``repro.simulate.evaluate_deployment`` — the frontier says what is
*efficient*; the simulator says what actually *serves*.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Sequence

from repro.design.space import DesignPoint, DesignSpace
from repro.design.template import AcceleratorTemplate

#: frontier objectives, in record order: (name, direction)
OBJECTIVES = (("throughput", "max"), ("sram_bytes", "min"),
              ("area_proxy", "min"))


@dataclasses.dataclass(frozen=True)
class DesignScore:
    """One scored design: the objectives plus the evidence behind them."""

    name: str                       # gen/<family>-<digest>
    params: dict                    # axis overrides of the point
    throughput: float               # tokens/s (model) or GOPS (grid)
    throughput_unit: str            # "tokens/s" | "GOPS"
    sram_bytes: int
    area_proxy: float
    feasible: bool = True
    reject_reason: str | None = None
    detail: dict = dataclasses.field(default_factory=dict)

    def objectives(self) -> dict[str, float]:
        return {"throughput": self.throughput,
                "sram_bytes": float(self.sram_bytes),
                "area_proxy": self.area_proxy}

    def as_dict(self) -> dict:
        return {"design": self.name, "params": dict(self.params),
                "throughput": self.throughput,
                "throughput_unit": self.throughput_unit,
                "sram_bytes": int(self.sram_bytes),
                "area_proxy": self.area_proxy,
                "feasible": self.feasible,
                "reject_reason": self.reject_reason,
                "detail": dict(self.detail)}


@dataclasses.dataclass(frozen=True)
class DominanceRecord:
    """Why one design fell off the frontier: its dominator and the
    per-objective margins (dominator value minus this design's value;
    positive throughput delta / negative cost deltas mean "strictly
    better")."""

    design: str
    dominated_by: str
    deltas: dict[str, float]

    def as_dict(self) -> dict:
        return {"design": self.design, "dominated_by": self.dominated_by,
                "deltas": dict(self.deltas)}


@dataclasses.dataclass
class Frontier:
    """A deterministic Pareto frontier plus full dominance accounting."""

    frontier: list[DesignScore]         # throughput desc, area asc, name
    dominated: list[DominanceRecord]    # sorted by design name
    infeasible: list[DesignScore]       # memory-rejected designs, by name
    workload: str

    def __len__(self) -> int:
        return len(self.frontier)

    def as_dict(self) -> dict:
        return {
            "workload": self.workload,
            "objectives": [{"name": n, "direction": d}
                           for n, d in OBJECTIVES],
            "frontier": [s.as_dict() for s in self.frontier],
            "dominated": [r.as_dict() for r in self.dominated],
            "infeasible": [s.as_dict() for s in self.infeasible],
        }

    def table(self) -> str:
        unit = (self.frontier[0].throughput_unit if self.frontier
                else "throughput")
        head = (f"{'design':<28} {unit:>12} {'sram_KiB':>9} "
                f"{'area':>8}  params")
        lines = [head, "-" * len(head)]
        for s in self.frontier:
            lines.append(f"{s.name:<28} {s.throughput:>12.4g} "
                         f"{s.sram_bytes / 1024:>9.0f} "
                         f"{s.area_proxy:>8.1f}  "
                         + " ".join(f"{k}={v}" for k, v in s.params.items()))
        lines.append(f"[{len(self.frontier)} on frontier, "
                     f"{len(self.dominated)} dominated, "
                     f"{len(self.infeasible)} infeasible]")
        return "\n".join(lines)


def _dominates(a: DesignScore, b: DesignScore) -> bool:
    ge = (a.throughput >= b.throughput and a.sram_bytes <= b.sram_bytes
          and a.area_proxy <= b.area_proxy)
    strict = (a.throughput > b.throughput or a.sram_bytes < b.sram_bytes
              or a.area_proxy < b.area_proxy)
    return ge and strict


def pareto(scores: Iterable[DesignScore], *,
           workload: str = "table2") -> Frontier:
    """The non-dominated subset of ``scores`` (see module docstring).

    Deterministic: candidates are examined in sorted-name order and a
    dominated design records its first (lowest-named) dominator, so the
    same scores always produce the identical frontier and records.
    """
    feasible = sorted((s for s in scores if s.feasible),
                      key=lambda s: s.name)
    infeasible = sorted((s for s in scores if not s.feasible),
                        key=lambda s: s.name)
    front: list[DesignScore] = []
    dominated: list[DominanceRecord] = []
    for s in feasible:
        winner = next((o for o in feasible
                       if o.name != s.name and _dominates(o, s)), None)
        if winner is None:
            front.append(s)
        else:
            deltas = {k: winner.objectives()[k] - v
                      for k, v in s.objectives().items()}
            dominated.append(DominanceRecord(
                design=s.name, dominated_by=winner.name, deltas=deltas))
    front.sort(key=lambda s: (-s.throughput, s.area_proxy, s.name))
    return Frontier(frontier=front, dominated=dominated,
                    infeasible=infeasible, workload=workload)


# -- scoring -------------------------------------------------------------------


def _as_points(designs) -> list[DesignPoint]:
    if isinstance(designs, DesignSpace):
        return list(designs.points())
    out = []
    for i, d in enumerate(designs):
        if isinstance(d, DesignPoint):
            out.append(d)
        elif isinstance(d, AcceleratorTemplate):
            out.append(DesignPoint(index=i, params={}, template=d))
        else:
            raise TypeError(f"cannot score {d!r}; pass a DesignSpace, "
                            f"DesignPoints, or AcceleratorTemplates")
    return out


def score_designs(designs, *, cfg=None, grid: str = "table2",
                  dtype: str = "int8", batch: int = 8, max_len: int = 512,
                  backend: str = "analytic-gap8",
                  sample: int | None = None, method: str = "grid",
                  precision=None) -> list[DesignScore]:
    """Score each design of ``designs`` on the workload bundle.

    Args:
        designs: a :class:`DesignSpace` (optionally sub-``sample``-d), or
            an iterable of :class:`DesignPoint` / template objects.
        cfg: optional :class:`~repro.configs.base.ModelConfig`; when given
            the throughput objective is decode tokens/s at ``batch`` from
            ``plan_deployment`` (memory-infeasible designs are recorded,
            not scored) and the Table-2 grid lands in ``detail`` only.
        grid: the GEMM grid for the grid objective (``repro.measure``
            grid names; int8 by default to match the paper's Table 2).
        dtype / batch / max_len / backend: serving-cell knobs, forwarded
            to ``plan_deployment``.
        sample / method: when ``designs`` is a space, score only a
            deterministic ``sample``-point subset ("grid" or "halton").
        precision: optional mixed-precision workload
            (:class:`~repro.core.precision.PrecisionConfig` or key string):
            the grid GEMMs are planned under it (quantize traffic + mixed
            arithmetic rates) and, with ``cfg``, the serving throughput
            comes from that precision's deployment cell.

    Returns:
        One :class:`DesignScore` per design, in input (index) order.
    """
    from repro import gemm
    from repro.core.precision import PrecisionConfig
    from repro.measure.campaign import grid_problems

    pc = PrecisionConfig.coerce(precision)
    if isinstance(designs, DesignSpace) and sample is not None:
        points = designs.sample(sample, method=method)
    else:
        points = _as_points(designs)
    problems = grid_problems(grid, dtype=dtype)
    flops = sum(2.0 * p.m * p.n * p.k for p in problems)
    scores: list[DesignScore] = []
    for pt in points:
        spec = pt.spec()
        tpl = pt.template
        res = gemm.sweep(problems, machines=[spec], backends=[backend],
                         precisions=[pc] if pc is not None else None)
        grid_s = sum(r.seconds for r in res.best_per_problem().values())
        detail: dict[str, Any] = {
            "grid": grid, "grid_seconds": grid_s,
            "grid_gops": flops / grid_s / 1e9,
            "label": pt.label(), "index": pt.index,
        }
        if pc is not None:
            detail["precision"] = pc.key()
        throughput, unit = detail["grid_gops"], "GOPS"
        feasible, reason = True, None
        if cfg is not None:
            report = plan_point(spec, cfg, dtype=dtype, batch=batch,
                                max_len=max_len, backend=backend,
                                precision=pc)
            detail["arch"] = cfg.name
            detail["batch"] = batch
            # score the requested precision's cell (the plain dtype cell
            # rides along in the report for reference only)
            want = None if pc is None else pc.key()
            opts = [o for o in report.options if o.precision == want]
            if opts:
                best = opts[0]
                throughput, unit = best.tokens_per_second, "tokens/s"
                detail["tokens_per_second"] = best.tokens_per_second
                detail["footprint_bytes"] = best.footprint.total_bytes
            else:
                feasible = False
                reason = (report.rejected[0].reason if report.rejected
                          else "no_feasible_cell")
                throughput, unit = 0.0, "tokens/s"
        scores.append(DesignScore(
            name=spec.name, params=dict(pt.params), throughput=throughput,
            throughput_unit=unit, sram_bytes=tpl.sram_bytes,
            area_proxy=tpl.area_proxy(), feasible=feasible,
            reject_reason=reason, detail=detail))
    return scores


def plan_point(spec, cfg, *, dtype: str = "int8", batch: int = 8,
               max_len: int = 512, backend: str = "analytic-gap8",
               precision=None):
    """One design's deployment report for one serving cell (a thin
    ``plan_deployment`` wrapper; generated specs pass through unregistered).
    ``precision`` adds that mixed-precision cell next to the dtype cell."""
    from repro.serving.report import plan_deployment

    return plan_deployment(cfg, machines=[spec], dtypes=(dtype,),
                           batches=(batch,), max_len=max_len,
                           backend=backend,
                           precisions=() if precision is None
                           else (precision,))


def rerank_by_slo(frontier: Frontier, designs, cfg, *, slo,
                  dtype: str = "int8", batch: int = 8, max_len: int = 512,
                  backend: str = "analytic-gap8", requests: int = 200,
                  seed: int = 0, traffic=None,
                  utilization: float = 0.6) -> list[dict]:
    """Re-rank a frontier by simulated SLO attainment.

    Every frontier design's serving cell is simulated via
    ``repro.simulate.evaluate_deployment``; the result is a ranked record
    list — attaining designs first (by simulated goodput, then name),
    then the violators (by name) with their violation lists.  The Pareto
    frontier itself is untouched: this is the "which efficient design
    actually serves" view of it.

    Traffic: pass an explicit ``traffic`` (e.g. a ``PoissonTraffic`` at
    the demand the product must serve) to load every design with the
    *same* arrival stream — the design-comparison question.  Without it,
    each design faces the report-default open-loop traffic at
    ``utilization`` x *its own* peak throughput, which compares designs
    at equal relative load (a faster design is also asked to serve
    proportionally more).
    """
    from repro.simulate.autoconf import SLO, default_traffic, \
        evaluate_deployment

    slo = SLO.coerce(slo)
    by_name = {pt.template.name: pt for pt in _as_points(designs)}
    records: list[dict] = []
    for s in frontier.frontier:
        pt = by_name.get(s.name)
        if pt is None:
            continue
        spec = pt.spec()
        report = plan_point(spec, cfg, dtype=dtype, batch=batch,
                            max_len=max_len, backend=backend)
        if not report.options:
            continue
        rec: dict[str, Any] = {"design": s.name, "params": dict(s.params),
                               "area_proxy": s.area_proxy,
                               "sram_bytes": s.sram_bytes}
        cell_traffic = (traffic if traffic is not None
                        else default_traffic(report,
                                             utilization=utilization))
        try:
            sel = evaluate_deployment(cfg, report, slo=slo,
                                      traffic=cell_traffic,
                                      requests=requests, seed=seed,
                                      machines={spec.name: spec},
                                      attach=False)
            sim = sel.sim.summary()
            rec.update(attained=True, policy=sel.policy,
                       goodput_tps=sim["goodput_tps"],
                       p99_latency_s=sim["latency"]["p99"])
        except ValueError as e:
            rec.update(attained=False, error=str(e).splitlines()[0],
                       goodput_tps=0.0, p99_latency_s=float("inf"))
        records.append(rec)
    records.sort(key=lambda r: (not r["attained"], -r["goodput_tps"],
                                r["design"]))
    return records


__all__ = ["DesignScore", "DominanceRecord", "Frontier", "OBJECTIVES",
           "pareto", "plan_point", "rerank_by_slo", "score_designs"]
