"""Design spaces: named axes over a base template, lazily expanded.

A :class:`DesignSpace` is a base :class:`AcceleratorTemplate` plus named
axes (template field -> candidate values).  Points are indexed in
row-major order over the axes as given (first axis slowest), so the space
is fully deterministic: point ``i`` is the same template in every process.
Expansion is *lazy* throughout — ``points()`` / ``specs()`` are
generators and ``sample()`` returns index-addressed points, so a
10^4-point space never materializes 10^4 ``MachineSpec`` objects unless
the caller iterates them all.

Sampling is deterministic by construction (no RNG):

* ``"grid"`` — an evenly strided sub-lattice of the flat index range.
* ``"halton"`` — a low-discrepancy Halton sequence (radical-inverse per
  axis with distinct prime bases), mapped onto each axis's value list;
  the classic choice when the axes interact and a strided sub-lattice
  would alias.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterator, Mapping, Sequence

from repro.design.template import AcceleratorTemplate, GEN_PREFIX
from repro.machines.spec import MachineSpec

_PRIMES = (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37)


def _radical_inverse(i: int, base: int) -> float:
    """van der Corput radical inverse of ``i`` in ``base`` — the Halton
    sequence's per-dimension coordinate."""
    inv, denom = 0.0, 1.0
    i += 1                      # skip the degenerate all-zeros point
    while i > 0:
        denom *= base
        i, digit = divmod(i, base)
        inv += digit / denom
    return inv


@dataclasses.dataclass(frozen=True)
class DesignPoint:
    """One indexed point of a space: the overridden parameters and the
    derived template.  ``spec()`` expands lazily; ``name`` is available
    without expanding."""

    index: int
    params: Mapping[str, object]        # axis overrides only
    template: AcceleratorTemplate

    @property
    def name(self) -> str:
        return self.template.name       # gen/<family>-<digest>, no expand

    def spec(self, *, register: bool = False) -> MachineSpec:
        return self.template.expand(register=register)

    def label(self) -> str:
        """Human-readable axis settings, e.g. ``lanes=8 l1_bytes=65536``."""
        return " ".join(f"{k}={v}" for k, v in self.params.items())


class DesignSpace:
    """Named axes over a base template; see module docstring."""

    def __init__(self, base: AcceleratorTemplate,
                 axes: Mapping[str, Sequence], *, name: str = "custom"):
        fields = {f.name for f in dataclasses.fields(AcceleratorTemplate)}
        self.base = base
        self.name = name
        self.axes: dict[str, tuple] = {}
        for axis, values in axes.items():
            if axis not in fields:
                raise KeyError(f"unknown template field {axis!r}; "
                               f"axes must name AcceleratorTemplate fields")
            values = tuple(values)
            if not values:
                raise ValueError(f"axis {axis!r} has no values")
            self.axes[axis] = values
        if not self.axes:
            raise ValueError("a design space needs at least one axis")

    def __len__(self) -> int:
        return math.prod(len(v) for v in self.axes.values())

    def __repr__(self) -> str:
        dims = " x ".join(f"{k}[{len(v)}]" for k, v in self.axes.items())
        return f"DesignSpace({self.name!r}, {dims} = {len(self)} points)"

    def point(self, index: int) -> DesignPoint:
        """Decode a flat index (row-major, first axis slowest)."""
        n = len(self)
        if not 0 <= index < n:
            raise IndexError(f"point {index} out of range for {n}-point "
                             f"space {self.name!r}")
        rem, params = index, {}
        for axis, values in reversed(self.axes.items()):
            rem, j = divmod(rem, len(values))
            params[axis] = values[j]
        params = dict(reversed(params.items()))
        return DesignPoint(index=index, params=params,
                           template=self.base.with_params(**params))

    def points(self) -> Iterator[DesignPoint]:
        """Every point, lazily, in index order."""
        for i in range(len(self)):
            yield self.point(i)

    def specs(self, *, register: bool = False) -> Iterator[MachineSpec]:
        """Every point's spec, lazily (one expansion per iteration step)."""
        for pt in self.points():
            yield pt.spec(register=register)

    def sample(self, n: int, *, method: str = "grid") -> list[DesignPoint]:
        """``n`` deterministic points (see module docstring for methods).
        ``n >= len(self)`` returns the whole space in index order."""
        total = len(self)
        if n >= total:
            return list(self.points())
        if n < 1:
            raise ValueError(f"sample size must be >= 1, got {n}")
        if method == "grid":
            idx = sorted({(i * total) // n for i in range(n)})
            return [self.point(i) for i in idx]
        if method == "halton":
            seen: dict[int, None] = {}
            sizes = [len(v) for v in self.axes.values()]
            i = 0
            # distinct prime base per axis; collisions (two Halton draws
            # landing on the same lattice cell) are skipped, so this
            # terminates once n distinct cells are found.
            while len(seen) < n and i < 64 * total:
                flat = 0
                for d, size in enumerate(sizes):
                    j = min(int(_radical_inverse(i, _PRIMES[d % len(_PRIMES)])
                                * size), size - 1)
                    flat = flat * size + j
                seen.setdefault(flat, None)
                i += 1
            return [self.point(i) for i in sorted(seen)]
        raise ValueError(f"unknown sampling method {method!r}; "
                         f"use 'grid' or 'halton'")

    def register_all(self, *, limit: int | None = None) -> list[str]:
        """Eagerly expand + register points (first ``limit`` of them) under
        the ``gen/`` namespace; returns the registered names in index
        order.  Pair with ``machines.unregister_prefix("gen/")``."""
        names = []
        for pt in self.points():
            if limit is not None and len(names) >= limit:
                break
            names.append(pt.spec(register=True).name)
        return names


# -- named spaces -------------------------------------------------------------

_KI = 1024


def _gap9ish(**overrides) -> AcceleratorTemplate:
    return AcceleratorTemplate(family="gap9ish").with_params(**overrides)


def _spaces() -> dict[str, DesignSpace]:
    return {
        # CI-sized: 8 points, seconds to score.
        "smoke": DesignSpace(
            _gap9ish(),
            {"lanes": (4, 8),
             "l1_bytes": (32 * _KI, 64 * _KI),
             "dma_bw": (8.8e6, 1.76e7)},
            name="smoke"),
        # the default frontier space: a gap9-like template swept over
        # MAC width x L1 capacity x DMA bandwidth — 4 x 4 x 4 = 64 points.
        "gap9-sweep": DesignSpace(
            _gap9ish(),
            {"lanes": (2, 4, 8, 16),
             "l1_bytes": (16 * _KI, 32 * _KI, 64 * _KI, 128 * _KI),
             "dma_bw": (4.4e6, 8.8e6, 1.76e7, 3.52e7)},
            name="gap9-sweep"),
        # the serving-study space (experiments/design_space_study.py): the
        # same three axes pushed upward, on a 64-entry register file —
        # the stock 32 leaves no register-feasible micro-kernel above 16
        # lanes, and the extra DMA headroom is what buys a sub-0.35s p99.
        "gap9-wide": DesignSpace(
            _gap9ish(num_vector_registers=64),
            {"lanes": (4, 8, 16, 32),
             "l1_bytes": (16 * _KI, 32 * _KI, 64 * _KI, 128 * _KI),
             "dma_bw": (1.76e7, 3.52e7, 7.04e7, 1.408e8)},
            name="gap9-wide"),
        # a 10^4-scale space for lazy-expansion / sampling exercises: never
        # expand it eagerly.
        "wide": DesignSpace(
            _gap9ish(),
            {"lanes": (2, 4, 8, 16),
             "mac_units": (1, 2, 4),
             "l1_bytes": tuple(2 ** e * _KI for e in range(3, 10)),
             "l2_bytes": tuple(2 ** e * _KI for e in range(7, 12)),
             "dma_bw": (2.2e6, 4.4e6, 8.8e6, 1.76e7, 3.52e7),
             "noc_bw": (7.2e6, 1.44e7, 2.88e7),
             "pack_bw": (1.62e6, 3.24e6)},
            name="wide"),
    }


def space_names() -> list[str]:
    return sorted(_spaces())


def get_space(name: str) -> DesignSpace:
    """Look up a named space ("smoke", "gap9-sweep", "gap9-wide", "wide")."""
    spaces = _spaces()
    try:
        return spaces[name]
    except KeyError:
        raise KeyError(f"unknown design space {name!r}; "
                       f"have {sorted(spaces)}") from None


__all__ = ["DesignPoint", "DesignSpace", "GEN_PREFIX", "get_space",
           "space_names"]
