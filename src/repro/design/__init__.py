"""``repro.design`` — parametric accelerator generation + design-space
exploration.

The paper answers "which algorithm wins on this machine?"; this subsystem
inverts the question: *which machine should we build for this workload?*

* :class:`AcceleratorTemplate` (``template.py``) — architecture knobs
  (MAC array, buffer capacities, DMA/NoC bandwidths, frequency) that
  ``expand()`` into a valid ``repro.machines/v1`` spec under the ``gen/``
  registry namespace, so every existing consumer — ``gemm.sweep``,
  ``plan_deployment``, the SLO simulator, the Calibrator — takes
  generated machines unchanged.
* :class:`DesignSpace` (``space.py``) — named axes over a template with
  deterministic grid / Halton sampling and lazy expansion.
* ``score_designs`` / ``pareto`` / ``rerank_by_slo`` (``explore.py``) —
  score designs on the Table-2 grid and model decode GEMMs, reduce to a
  deterministic Pareto frontier over (throughput, SRAM, area proxy) with
  machine-readable dominance records, optionally re-rank by simulated
  p99 SLO attainment.
* ``ground`` / ``sample_design`` (``ground.py``) — fit a built design's
  generated rate table from a measurement ``SampleStore`` with the
  existing Calibrator; the emitted spec is provenance-marked
  ``grounded``.

CLI: ``python -m repro.design expand|sweep|frontier|ground``.
"""
from repro.design.explore import (
    DesignScore,
    DominanceRecord,
    Frontier,
    OBJECTIVES,
    pareto,
    plan_point,
    rerank_by_slo,
    score_designs,
)
from repro.design.ground import (
    GroundingResult,
    ground,
    sample_design,
    synthetic_truth,
)
from repro.design.space import (
    DesignPoint,
    DesignSpace,
    GEN_PREFIX,
    get_space,
    space_names,
)
from repro.design.template import AcceleratorTemplate, template_of

__all__ = [
    "AcceleratorTemplate", "DesignPoint", "DesignScore", "DesignSpace",
    "DominanceRecord", "Frontier", "GEN_PREFIX", "GroundingResult",
    "OBJECTIVES", "get_space", "ground", "pareto", "plan_point",
    "rerank_by_slo", "sample_design", "score_designs", "space_names",
    "synthetic_truth", "template_of",
]
