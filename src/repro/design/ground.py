"""Ground a paper design in measurements: generated rates -> fitted rates.

A generated spec's rate table is a *derivation* (template parameters
through the rules of ``repro.design.template``); once a frontier design
gets built — silicon, FPGA, or a firmware port — its rates should come
from the machine, not the template.  ``ground`` closes that loop with the
existing calibration machinery: samples from a
:class:`~repro.measure.store.SampleStore` (geometry-fingerprint guarded,
so they provably belong to this design's geometry) feed
``repro.measure.fit_from_store`` / :class:`~repro.machines.Calibrator`,
and the emitted spec carries ``provenance["grounded"] = True`` on top of
the original template parameters — a spec that records both what it was
designed as and what it measured as.

``sample_design`` covers the pre-silicon case: it runs a standard
measurement campaign against a *simulated* ground truth (any spec sharing
the design's geometry — by default a bandwidth/arith-perturbed copy), so
the full expand -> sample -> fit -> validate loop is exercisable today
and tests can assert the fit recovers a known truth.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.design.space import DesignPoint
from repro.design.template import AcceleratorTemplate
from repro.machines import registry as _registry
from repro.machines.spec import MachineSpec


def _as_spec(design) -> MachineSpec:
    if isinstance(design, MachineSpec):
        return design
    if isinstance(design, AcceleratorTemplate):
        return design.expand()
    if isinstance(design, DesignPoint):
        return design.spec()
    if isinstance(design, str):
        return _registry.get(design)
    raise TypeError(f"cannot ground {design!r}; pass a MachineSpec, "
                    f"AcceleratorTemplate, DesignPoint, or registry name")


@dataclasses.dataclass
class GroundingResult:
    """The grounded spec plus the fit and validation evidence."""

    spec: MachineSpec
    fit: Any                    # repro.machines.FitReport
    validation: Any | None      # repro.measure ValidationReport (or None)

    @property
    def mape(self) -> float | None:
        return self.validation.mape if self.validation is not None else None


def synthetic_truth(spec: MachineSpec, *, bw: float = 0.8,
                    arith: float = 0.9) -> MachineSpec:
    """A deterministic "reality" for pre-silicon grounding runs: the
    design's own spec with every bandwidth scaled by ``bw`` and the
    arithmetic rates by ``arith`` — same geometry (the fingerprint the
    sample store keys on), different rates (something for the fit to
    find)."""
    return spec.scaled(arith=arith, bw=bw, name=f"{spec.name}-truth")


def sample_design(design, store, *, grid: str = "table2",
                  dtype: str = "int8", truth: MachineSpec | None = None,
                  policy: str = "padded"):
    """Run a measurement campaign for a (typically unbuilt) design.

    The design's spec plans the campaign; the ``simulated`` harness prices
    each planned GEMM under ``truth`` (default: :func:`synthetic_truth`),
    standing in for the hardware run.  Samples land in ``store`` stamped
    with the design's geometry fingerprint — exactly what a real harness
    would produce on the built machine.  Returns the
    ``repro.measure.CampaignResult``.
    """
    from repro.measure.campaign import run_campaign

    spec = _as_spec(design)
    truth = truth if truth is not None else synthetic_truth(spec)
    return run_campaign(grid, machine=spec, harness="simulated",
                        store=store, dtype=dtype, policy=policy,
                        truth=truth)


def ground(design, store, *, date: str | None, name: str | None = None,
           weighting: str = "relative", on_nonpositive: str = "free",
           overhead_per_block: bool = False, policy: str | None = None,
           register: bool = False, manifest_dir: str | None = None,
           validate: bool = True) -> GroundingResult:
    """Fit a generated design's rate table from measured samples.

    Args:
        design: the design to ground — a generated spec, template,
            :class:`DesignPoint`, or registered ``gen/*`` name.
        store: the :class:`~repro.measure.store.SampleStore` (or path)
            holding the design's measurements.
        date: calibration date for provenance (pass None explicitly for
            an undated fit, as with ``Calibrator.fit``).
        name: name for the grounded spec (default: the design's name —
            the grounded spec *replaces* the derivation under ``gen/``).
        weighting / on_nonpositive / overhead_per_block / policy:
            forwarded to ``repro.measure.fit_from_store``.
        register: land the grounded spec in the registry.
        manifest_dir: also persist it as a manifest.
        validate: price the store's samples under the grounded spec and
            attach the ``ValidationReport`` (its MAPE is the headline
            "how well does the grounded model predict" number).

    Returns:
        A :class:`GroundingResult`; ``result.spec.provenance`` carries
        ``grounded: True``, the original template parameters, and the
        full fit record.
    """
    from repro.measure.campaign import fit_from_store
    from repro.measure.validate import validate_spec

    spec = _as_spec(design)
    fitted, fit = fit_from_store(
        store, spec, name=name or spec.name, date=date, policy=policy,
        weighting=weighting, on_nonpositive=on_nonpositive,
        overhead_per_block=overhead_per_block)
    prov = dict(fitted.provenance)
    prov["grounded"] = True
    for key in ("generator", "template", "design_id"):
        if key in (spec.provenance or {}):
            prov.setdefault(key, spec.provenance[key])
    grounded = dataclasses.replace(fitted, provenance=prov)
    grounded.validate()
    if register:
        _registry.register(grounded, overwrite=True, source="calibrated")
    if manifest_dir:
        import os
        grounded.to_manifest(os.path.join(manifest_dir,
                                          f"{grounded.name}.json"))
    report = validate_spec(grounded, store) if validate else None
    return GroundingResult(spec=grounded, fit=fit, validation=report)


__all__ = ["GroundingResult", "ground", "sample_design", "synthetic_truth"]
