"""Parametric accelerator templates: architecture parameters in, cost
models out.

The paper calibrates a *fixed* zoo of edge processors; this module makes
the machines themselves data.  An :class:`AcceleratorTemplate` holds the
architecture-level knobs a designer actually turns — MAC-array dims,
per-level buffer capacities, DMA/NoC bandwidths, clock frequency — and
:meth:`AcceleratorTemplate.expand` deterministically derives a valid
``repro.machines/v1`` :class:`~repro.machines.spec.MachineSpec` from them,
so architecture search is just another sweep: every existing consumer
(``gemm.sweep``, ``plan_deployment``, the SLO simulator, the Calibrator)
takes the generated spec unchanged.

Derivation rules (each is one line of :meth:`expand`; the constants mirror
the structure of the paper's Table 1 rate tables):

* arithmetic — ``arith_rate[dt] = 2 * mac_units * lanes * frequency_hz *
  dtype_rates[dt]`` (a MAC is two ops; ``dtype_rates`` are relative
  throughputs, e.g. f32 at 1/4 of int8 on a lane-packed datapath).
* register streaming — ``L1->R = reg_bytes_per_cycle * frequency_hz``:
  the micro-kernel's operand stream scales with the clock.
* DMA / NoC — ``M->L1 = dma_bw`` and ``L2->R = noc_bw``, straight
  bandwidth parameters in bytes/s.
* packing — ``M->M = pack_bw`` at ``reference_chunk``; the remaining
  strided-copy rates derive via the :data:`PACK_RATIOS` family.  The
  ratios (0.33 / 0.40 / 0.30) are stable across the paper's calibrated
  GAP8 and GAP9 tables, so they are fixed derivation constants rather
  than free axes.
* register file — ``capacity(R) = num_vector_registers * lanes *
  elem_bytes`` (GAP-style: 32 registers x 4 int8 lanes = 128 B).

Generated specs carry their full parameter set in provenance
(``provenance["template"]``) and are named ``gen/<family>-<digest>`` —
the ``gen/`` registry namespace that ``gemm.sweep(machines="gen/*")``
globs and ``machines.unregister_prefix("gen/")`` bulk-drops.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Mapping

from repro.machines import registry as _registry
from repro.machines.spec import MachineSpec

#: generated-machine registry namespace (also the ``source_of`` tag)
GEN_PREFIX = "gen/"

#: strided-packing rate family, relative to the ``M->M`` packing rate at
#: the reference chunk: the paper's calibrated GAP8/GAP9 tables both land
#: within a few percent of these ratios.
PACK_RATIOS: Mapping[tuple[str, str], float] = {
    ("M", "M"): 1.00,       # pack into the L3-resident buffer
    ("M", "L2"): 0.33,      # pack into the L2 scratchpad
    ("L2", "M"): 0.40,      # unpack back to memory
    ("M", "R"): 0.30,       # strided stream straight to registers
}

#: area-proxy coefficients (arbitrary units — only ratios matter to a
#: Pareto frontier): per MAC lane, per KiB of on-chip SRAM (L1+L2), per
#: byte/cycle of DMA+NoC wiring, per register-file byte.
AREA_PER_MAC = 1.0
AREA_PER_SRAM_KIB = 0.25
AREA_PER_WIRE_BPC = 2.0
AREA_PER_REG_BYTE = 0.05


@dataclasses.dataclass(frozen=True)
class AcceleratorTemplate:
    """One point of the generator's parameter space.

    Defaults approximate the calibrated gap9-fc manifest, so
    ``AcceleratorTemplate().expand()`` is a plausible edge machine out of
    the box and named design spaces perturb around it.
    """

    family: str = "edge"
    # -- MAC array / register file -------------------------------------------
    lanes: int = 8                      # SIMD lanes per vector register
    mac_units: int = 2                  # parallel per-lane MAC issue
    num_vector_registers: int = 32
    frequency_hz: float = 370.0e6
    # -- memory hierarchy capacities (bytes) ---------------------------------
    main_bytes: int = 8 << 20
    l2_bytes: int = 1536 << 10
    l1_bytes: int = 64 << 10
    # -- interconnect bandwidths ---------------------------------------------
    dma_bw: float = 1.76e7              # M->L1 block DMA, bytes/s
    noc_bw: float = 1.44e7              # L2->R streaming fabric, bytes/s
    pack_bw: float = 3.24e6             # M->M strided packing, bytes/s
    reg_bytes_per_cycle: float = 0.96   # L1->R register streaming
    # -- dtype-rate derivation rules -----------------------------------------
    reference_chunk: int = 4
    elem_bytes: int = 1
    dtype_rates: tuple = (("int8", 1.0), ("f32", 0.25))
    # -- deployment memory view ----------------------------------------------
    deployment_level: str = "M"
    memory_reserved_fraction: float = 0.0
    # -- optional energy proxy (pJ per int8 op; None = unmodelled) -----------
    energy_per_op_pj: float | None = None

    def __post_init__(self) -> None:
        for field in ("lanes", "mac_units", "num_vector_registers",
                      "main_bytes", "l2_bytes", "l1_bytes",
                      "reference_chunk", "elem_bytes"):
            if int(getattr(self, field)) < 1:
                raise ValueError(f"{field} must be >= 1, got "
                                 f"{getattr(self, field)!r}")
        for field in ("frequency_hz", "dma_bw", "noc_bw", "pack_bw",
                      "reg_bytes_per_cycle"):
            if not float(getattr(self, field)) > 0.0:
                raise ValueError(f"{field} must be positive, got "
                                 f"{getattr(self, field)!r}")
        if not self.dtype_rates:
            raise ValueError("dtype_rates must name at least one dtype")

    # -- identity -------------------------------------------------------------

    def params(self) -> dict[str, Any]:
        """The full parameter set, JSON-ready (tuples become lists)."""
        d = dataclasses.asdict(self)
        d["dtype_rates"] = [list(p) for p in self.dtype_rates]
        return d

    def design_id(self) -> str:
        """Deterministic content identity: the family plus a digest of the
        canonical parameter JSON.  Same parameters, same id — across
        processes and sessions."""
        payload = json.dumps(self.params(), sort_keys=True)
        return (f"{self.family}-"
                f"{hashlib.sha1(payload.encode()).hexdigest()[:10]}")

    @property
    def name(self) -> str:
        """The registry name :meth:`expand` gives the generated spec."""
        return f"{GEN_PREFIX}{self.design_id()}"

    @classmethod
    def from_params(cls, params: Mapping[str, Any]) -> "AcceleratorTemplate":
        """Rebuild a template from :meth:`params` output (e.g. a generated
        spec's ``provenance["template"]``)."""
        d = dict(params)
        d["dtype_rates"] = tuple((str(t), float(r))
                                 for t, r in d["dtype_rates"])
        return cls(**d)

    def with_params(self, **overrides) -> "AcceleratorTemplate":
        """A derived template with some parameters replaced."""
        return dataclasses.replace(self, **overrides)

    def scaled_bandwidth(self, factor: float) -> "AcceleratorTemplate":
        """Every interconnect bandwidth scaled by ``factor`` (DMA, NoC,
        packing, register streaming); compute and capacities unchanged."""
        return dataclasses.replace(
            self, dma_bw=self.dma_bw * factor, noc_bw=self.noc_bw * factor,
            pack_bw=self.pack_bw * factor,
            reg_bytes_per_cycle=self.reg_bytes_per_cycle * factor)

    # -- proxies ---------------------------------------------------------------

    @property
    def sram_bytes(self) -> int:
        """On-chip SRAM a silicon implementation must provision (L1 + L2) —
        the memory-cost objective of the Pareto frontier.  Main memory is
        off-chip and excluded."""
        return int(self.l1_bytes) + int(self.l2_bytes)

    def area_proxy(self) -> float:
        """Closed-form area estimate in arbitrary units: MAC lanes + SRAM
        + interconnect wiring + register file.  A proxy for frontier
        trade-offs, not a floorplan."""
        macs = self.mac_units * self.lanes
        sram_kib = self.sram_bytes / 1024.0
        wire_bpc = (self.dma_bw + self.noc_bw) / self.frequency_hz
        reg_bytes = self.num_vector_registers * self.lanes * self.elem_bytes
        return (AREA_PER_MAC * macs
                + AREA_PER_SRAM_KIB * sram_kib
                + AREA_PER_WIRE_BPC * wire_bpc
                + AREA_PER_REG_BYTE * reg_bytes)

    def energy_proxy_j(self, ops: float) -> float | None:
        """Energy for ``ops`` operations under the optional per-op proxy."""
        if self.energy_per_op_pj is None:
            return None
        return self.energy_per_op_pj * 1e-12 * ops

    # -- expansion -------------------------------------------------------------

    def expand(self, *, name: str | None = None,
               register: bool = False) -> MachineSpec:
        """Derive the ``repro.machines/v1`` spec for this design point.

        Deterministic: the same template always emits the same spec (same
        name, same rates, same fingerprint).  ``register=True`` lands it in
        the registry under its ``gen/`` name (source ``"generated"``,
        overwrite-safe since the name is content-addressed).
        """
        arith = {dt: 2.0 * self.mac_units * self.lanes * self.frequency_hz
                 * float(rel) for dt, rel in self.dtype_rates}
        rates = {pair: self.pack_bw * ratio
                 for pair, ratio in PACK_RATIOS.items()}
        rates[("M", "L1")] = float(self.dma_bw)
        rates[("L2", "R")] = float(self.noc_bw)
        rates[("L1", "R")] = self.reg_bytes_per_cycle * self.frequency_hz
        reg_bytes = (self.num_vector_registers * self.lanes
                     * self.elem_bytes)
        prov: dict[str, Any] = {
            "generator": "repro.design/v1",
            "template": self.params(),
            "design_id": self.design_id(),
            "area_proxy": self.area_proxy(),
        }
        spec = MachineSpec(
            name=name or self.name,
            levels=("M", "L2", "L1", "R"),
            capacities={"M": int(self.main_bytes),
                        "L2": int(self.l2_bytes),
                        "L1": int(self.l1_bytes),
                        "R": int(reg_bytes)},
            transfer_rates=rates,
            arith_rate=arith,
            reference_chunk=int(self.reference_chunk),
            elem_bytes=int(self.elem_bytes),
            num_vector_registers=int(self.num_vector_registers),
            register_lanes=int(self.lanes),
            deployment_level=self.deployment_level,
            memory_reserved_fraction=float(self.memory_reserved_fraction),
            provenance=prov,
        ).validate()
        if register:
            _registry.register(spec, overwrite=True, source="generated")
        return spec


def template_of(spec: MachineSpec) -> AcceleratorTemplate:
    """Recover the generating template from a generated spec's provenance.

    Raises ``ValueError`` for specs that did not come out of
    :meth:`AcceleratorTemplate.expand` (nothing to recover)."""
    params = (spec.provenance or {}).get("template")
    if not params:
        raise ValueError(f"{spec.name}: no template provenance — not a "
                         f"generated spec")
    return AcceleratorTemplate.from_params(params)
