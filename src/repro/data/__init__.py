from repro.data.synthetic import DataIterator, make_batch

__all__ = ["DataIterator", "make_batch"]
