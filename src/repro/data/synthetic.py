"""Deterministic synthetic LM data pipeline.

The stream is a pure function of ``(seed, step, shard)`` — resuming from a
checkpoint at step N reproduces exactly the batches a non-preempted run
would have seen (the fault-tolerance contract; tests/test_fault.py).

Tokens follow a Zipf-like marginal with short-range structure (a noisy
copy/shift process) so the LM loss actually decreases — enough signal for
the end-to-end example to show learning without shipping a corpus.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _zipf_tokens(key, shape, vocab: int):
    """Zipf(1.1)-ish sampling via inverse-CDF on a uniform draw."""
    u = jax.random.uniform(key, shape, minval=1e-6, maxval=1.0)
    # rank ~ u^(-1/alpha); clip to vocab
    alpha = 1.1
    rank = jnp.floor(u ** (-1.0 / alpha)) - 1.0
    return jnp.clip(rank, 0, vocab - 1).astype(jnp.int32)


def make_batch(cfg: ModelConfig, shape: ShapeConfig, step: int, seed: int = 0,
               host_id: int = 0, num_hosts: int = 1):
    """One training batch (this host's slice) as numpy-backed jnp arrays."""
    b = shape.global_batch // num_hosts
    s = shape.seq_len
    key = jax.random.fold_in(jax.random.fold_in(
        jax.random.key(seed), step), host_id)
    k1, k2 = jax.random.split(key)
    base = _zipf_tokens(k1, (b, s + 1), cfg.vocab_size)
    # structure: with p=0.5 copy the previous token (learnable bigram signal)
    copy_mask = jax.random.bernoulli(k2, 0.5, (b, s))

    def step_fn(prev_tok, inp):
        m, bt = inp
        t = jnp.where(m, prev_tok, bt)
        return t, t
    _, out = jax.lax.scan(step_fn, base[:, 0],
                          (copy_mask.T, base[:, 1:].T))
    tokens = jnp.concatenate([base[:, :1], out.T], axis=1)  # (b, s+1)

    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    if cfg.frontend == "audio_stub":
        kf = jax.random.fold_in(key, 99)
        frames = jax.random.normal(kf, (b, s, cfg.d_model)) * 0.02
        batch = {"frames": frames.astype(jnp.dtype(cfg.compute_dtype)),
                 "labels": tokens[:, 1:]}
    elif cfg.frontend == "vision_stub":
        kp = jax.random.fold_in(key, 98)
        npx = cfg.num_prefix_tokens
        st = s - npx
        patches = jax.random.normal(kp, (b, npx, cfg.d_model)) * 0.02
        batch = {"patches": patches.astype(jnp.dtype(cfg.compute_dtype)),
                 "tokens": tokens[:, :st], "labels": tokens[:, 1:st + 1]}
    return batch


@dataclasses.dataclass
class DataIterator:
    """Stateful wrapper with checkpointable position."""
    cfg: ModelConfig
    shape: ShapeConfig
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    step: int = 0

    def __next__(self):
        batch = make_batch(self.cfg, self.shape, self.step, self.seed,
                           self.host_id, self.num_hosts)
        self.step += 1
        return batch

    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, d: dict) -> None:
        self.step = int(d["step"])
        self.seed = int(d["seed"])
