"""Zoo-wide deployment planning: rank ``(machine, dtype, batch)`` cells.

``plan_deployment`` turns the paper's predict-before-run loop into a
deployment decision: for every machine of the zoo (or any glob of it) it
crosses the serving dtype and decode-batch axes, prunes the cells whose
modelled memory footprint (``repro.serving.footprint``) exceeds the
machine's deployment-level budget *before* the design-space sweep plans
them (via ``repro.gemm.sweep``'s feasibility mask), and scores the
survivors by predicted decode throughput.  The result is a ranked
:class:`DeploymentReport`: per-machine best configurations with memory
headroom, plus a machine-readable rejection record for every infeasible
cell — the planner answers "where and how should this model serve", not
just "which GEMM is fastest".

Only the model *config* is needed (no parameters are instantiated), so the
report is cheap enough for a CLI: ``python -m repro.serving plan``.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Sequence

from repro import gemm as gemm_api
from repro.configs.base import ModelConfig
from repro.core.precision import DTYPE_BITS, PrecisionConfig
from repro.machines import registry as _machines
from repro.serving.footprint import Footprint, footprint

#: machine-readable rejection reasons, in the order they are diagnosed:
#: weights alone blow the budget (no batch can ever fit), the KV/state cache
#: pushes past it (a smaller batch may fit), or the activation workspace
#: tips the total over.  SLO-mode autoconfiguration appends further
#: rejections with ``slo_*`` codes (``repro.simulate.autoconf``) — cells
#: that fit memory but fail their simulated tail-latency/goodput targets.
REJECT_WEIGHTS = "weights_exceed_budget"
REJECT_KV_CACHE = "kv_cache_exceeds_budget"
REJECT_FOOTPRINT = "footprint_exceeds_budget"


@dataclasses.dataclass(frozen=True)
class CellRejection:
    """One rejected ``(machine, dtype, batch)`` cell: memory-pruned before
    the sweep, or SLO-pruned by the simulator (``detail`` then carries the
    observed-vs-limit numbers and the admission policy)."""

    machine: str
    dtype: str
    batch: int
    reason: str             # a REJECT_* or slo_* code
    footprint_bytes: int
    budget_bytes: int
    detail: Any = None      # optional structured context (SLO violations)

    @property
    def deficit_bytes(self) -> int:
        """How far past the budget the modelled footprint lands."""
        return self.footprint_bytes - self.budget_bytes

    def as_dict(self) -> dict:
        out = {
            "machine": self.machine, "dtype": self.dtype,
            "batch": self.batch, "reason": self.reason,
            "footprint_bytes": self.footprint_bytes,
            "budget_bytes": self.budget_bytes,
            "deficit_bytes": self.deficit_bytes,
        }
        if self.detail is not None:
            out["detail"] = self.detail
        return out


@dataclasses.dataclass(frozen=True)
class DeploymentOption:
    """One feasible operating point: frozen plans + memory accounting."""

    machine: str
    dtype: str
    batch: int
    seconds_per_step: float
    tokens_per_second: float
    footprint: Footprint
    budget_bytes: int
    rows: tuple = ()        # the sweep rows (with plans) behind this point
    sim: Any = None         # per-policy simulated metrics (SLO mode)
    # mixed-precision cells: the PrecisionConfig key (None for the plain
    # dtype axis) and the bits-based accuracy proxy the ranking table shows
    # next to throughput (1.0 = full precision, 0.5 = int8, 0.25 = int4).
    precision: str | None = None
    accuracy_proxy: float = 1.0

    @property
    def headroom_bytes(self) -> int:
        return self.budget_bytes - self.footprint.total_bytes

    @property
    def headroom_fraction(self) -> float:
        return self.headroom_bytes / self.budget_bytes if self.budget_bytes \
            else 0.0

    def as_dict(self) -> dict:
        out = {
            "machine": self.machine, "dtype": self.dtype,
            "batch": self.batch,
            "seconds_per_step": self.seconds_per_step,
            "tokens_per_second": self.tokens_per_second,
            "footprint": self.footprint.as_dict(),
            "budget_bytes": self.budget_bytes,
            "headroom_bytes": self.headroom_bytes,
            "headroom_fraction": self.headroom_fraction,
            "precision": self.precision,
            "accuracy_proxy": self.accuracy_proxy,
        }
        if self.sim is not None:
            out["sim"] = self.sim
        return out


def _rank_key(o: DeploymentOption):
    # throughput first; name/dtype/batch tie-breaks keep the zoo-wide pick
    # deterministic across runs and machine-registration orders.
    return (-o.tokens_per_second, o.machine, o.dtype, -o.batch)


@dataclasses.dataclass
class DeploymentReport:
    """Ranked feasible operating points + machine-readable rejections."""

    model: str
    backend: str
    max_len: int
    native_dtype: str
    options: list[DeploymentOption]         # ranked, best first
    rejected: list[CellRejection]
    grid: dict = dataclasses.field(default_factory=dict)
    # populated by SLO-mode autoconfiguration (repro.simulate.autoconf):
    # the traffic scenario, per-cell simulated results, and the selection
    slo: dict | None = None

    def best(self, *, machine: str | None = None,
             dtype: str | None = None) -> DeploymentOption:
        """The highest-ranked option, optionally filtered by machine/dtype.

        Raises:
            ValueError: when no feasible option matches (every cell was
                memory-pruned, or the filters exclude all survivors).
        """
        for o in self.options:
            if machine is not None and o.machine != machine:
                continue
            if dtype is not None and o.dtype != dtype:
                continue
            return o
        if self.options:
            # feasible cells exist — the filters matched none of them, a
            # different condition than everything being memory-pruned.
            raise ValueError(
                f"{len(self.options)} feasible option(s) exist for "
                f"{self.model} but none match machine={machine!r} "
                f"dtype={dtype!r}; feasible machines "
                f"{sorted({o.machine for o in self.options})}, dtypes "
                f"{sorted({o.dtype for o in self.options})}")
        why = "; ".join(sorted({f"{r.machine}/{r.dtype}: {r.reason}"
                                for r in self.rejected})) or "empty grid"
        raise ValueError(
            f"no feasible deployment for {self.model} (machine={machine}, "
            f"dtype={dtype}); rejections: {why}")

    def select(self) -> DeploymentOption:
        """The operating point autoconfigure freezes: best among the
        model's native-dtype options when any survive (the engine really
        decodes in that dtype; what-if dtypes and mixed-precision cells
        inform the ranking only), otherwise best overall."""
        for o in self.options:
            if o.precision is None and o.dtype == self.native_dtype:
                return o
        for o in self.options:
            if o.precision is None:
                return o
        return self.best()

    def per_machine_best(self) -> dict[str, DeploymentOption]:
        """Best option per machine, in rank order (dict preserves it)."""
        out: dict[str, DeploymentOption] = {}
        for o in self.options:
            out.setdefault(o.machine, o)
        return out

    def rejections_for(self, machine: str | None = None,
                       batch: int | None = None) -> list[CellRejection]:
        """Rejected cells, optionally filtered by machine and/or batch."""
        return [r for r in self.rejected
                if (machine is None or r.machine == machine)
                and (batch is None or r.batch == batch)]

    def table(self, limit: int | None = None) -> str:
        """Human-readable ranked table (options, then rejection summary)."""
        gib = 1024.0 ** 3
        lines = ["rank machine            dtype              batch  tok/s "
                 "     acc   footprint   headroom"]
        for i, o in enumerate(self.options[:limit], 1):
            lines.append(
                f"{i:<4} {o.machine:<18} {o.dtype:<18} {o.batch:<6}"
                f"{o.tokens_per_second:<10.3g} "
                f"{o.accuracy_proxy:<5.2f} "
                f"{o.footprint.total_bytes / gib:>8.3f}Gi "
                f"{o.headroom_fraction:>7.1%}")
        if limit is not None and len(self.options) > limit:
            lines.append(f"... ({len(self.options) - limit} more options)")
        if self.rejected:
            by_reason: dict[str, int] = {}
            for r in self.rejected:
                by_reason[r.reason] = by_reason.get(r.reason, 0) + 1
            lines.append(f"rejected {len(self.rejected)} cells: " + ", ".join(
                f"{n}x {reason}" for reason, n in sorted(by_reason.items())))
        return "\n".join(lines)

    def to_json(self) -> dict:
        out = {
            "model": self.model, "backend": self.backend,
            "max_len": self.max_len, "native_dtype": self.native_dtype,
            "grid": dict(self.grid),
            "options": [o.as_dict() for o in self.options],
            "rejected": [r.as_dict() for r in self.rejected],
        }
        if self.slo is not None:
            out["slo"] = self.slo
        return out

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)
            f.write("\n")
        return path


def diagnose_rejection(fp: Footprint, budget: int) -> str:
    """The REJECT_* code for an over-budget footprint (weights alone, then
    weights+KV, then the full total — the first component that breaks)."""
    if fp.weights_bytes > budget:
        return REJECT_WEIGHTS
    if fp.weights_bytes + fp.kv_cache_bytes > budget:
        return REJECT_KV_CACHE
    return REJECT_FOOTPRINT


def plan_deployment(cfg: ModelConfig, *,
                    machines=None,
                    dtypes: Sequence[str] = ("bf16",),
                    batches: Sequence[int] = (1, 2, 4, 8, 16),
                    max_len: int = 512,
                    backend: str = "analytic-tpu",
                    memory: bool = True,
                    kv_dtype: str | None = None,
                    precisions: Sequence = ()) -> DeploymentReport:
    """Rank every feasible ``(machine, dtype, batch)`` serving cell.

    Args:
        cfg: model config; only shape fields are read (no params built).
        machines: machines axis — names, specs, globs (``"zoo/*"`` sweeps
            the whole registry), a list of any of those, or None for the
            backend's native default machine.
        dtypes: serving-dtype axis (weights/activations; the KV dtype
            follows ``kv_dtype``).
        batches: candidate decode-slot counts (``max_batch`` values).
        max_len: per-slot cache length the KV footprint is charged at.
        backend: planning backend for the decode-GEMM sweep.
        memory: enforce the deployment-memory budget (True, the default)
            or score every cell unconstrained (False — the pre-PR
            throughput-only behaviour, kept for what-ifs and tests).
        kv_dtype: KV-cache dtype override, forwarded to
            :func:`repro.serving.footprint.footprint`.
        precisions: extra mixed-precision cells, each a
            :class:`~repro.core.precision.PrecisionConfig` or key string
            (``"int4xint8->int32"``).  Each config adds one column per
            machine/batch next to the plain ``dtypes`` axis: weights are
            footprinted in the config's B (weights) dtype, the KV cache in
            its ``kv_dtype`` (falling back to ``kv_dtype``/serving-dtype
            rules), the decode GEMMs are planned with quantize traffic and
            mixed arithmetic rates, and the option carries the config key
            in ``DeploymentOption.precision`` plus its bits-based
            ``accuracy_proxy`` so the ranking reads as a
            throughput-vs-memory-vs-accuracy frontier.  ``select()`` never
            freezes a mixed cell (they inform the ranking only).

    Returns:
        A :class:`DeploymentReport` with options ranked by predicted decode
        tokens/second (deterministic tie-breaks) and one
        :class:`CellRejection` per memory-pruned cell.  Every option's
        footprint fits its machine's ``memory_budget()`` by construction.

    Raises:
        KeyError: unknown machine name or pattern matching nothing.
        ValueError: empty dtype/batch axes.
    """
    from repro.core.autotune import model_gemm_shapes
    from repro.gemm.backends import dtype_tag
    from repro.gemm.registry import get_backend

    dtypes = list(dtypes)
    batches = sorted(set(int(b) for b in batches))
    if not dtypes or not batches:
        raise ValueError("plan_deployment needs non-empty dtypes and "
                         "batches axes")
    pcs = [PrecisionConfig.coerce(p) for p in precisions]
    native = dtype_tag(cfg.compute_dtype)
    default_machine = get_backend(backend).default_machine
    # expand_many canonicalizes names/globs; MachineSpec entries (possibly
    # unregistered derived machines) pass through and are keyed by name.
    default_name = _machines.resolve(None, default_machine).name

    def tag_of(entry) -> str:
        if isinstance(entry, _machines.MachineSpec):
            return entry.name
        return default_name if entry is None else entry

    # overlapping globs/names (machines=["zoo/*", "tpu-v5e"]) must not plan
    # a machine twice — duplicate rows would double-count seconds_per_step
    # in the by_point merge below.  First occurrence wins.
    entries, seen = [], set()
    for e in _machines.expand_many(machines):
        if tag_of(e) not in seen:
            seen.add(tag_of(e))
            entries.append(e)

    budgets = {tag_of(e): _machines.resolve(e, default_machine)
               .memory_budget() for e in entries}

    options: list[DeploymentOption] = []
    rejected: list[CellRejection] = []
    for batch in batches:
        shapes = model_gemm_shapes(cfg, tokens=batch)
        fps = {dt: footprint(cfg, batch=batch, max_len=max_len, dtype=dt,
                             kv_dtype=kv_dtype) for dt in dtypes}

        def mask(ma, dt, _batch=batch, _fps=fps):
            fp = _fps[dt]
            budget = budgets[tag_of(ma)]
            if fp.fits(budget):
                return True
            return (False, diagnose_rejection(fp, budget))

        res = gemm_api.sweep(shapes, machines=entries, backends=[backend],
                             dtypes=dtypes,
                             feasible=mask if memory else None)
        for pr in res.pruned:
            fp = fps[pr["dtype"]]
            rejected.append(CellRejection(
                machine=tag_of(pr["machine"]), dtype=pr["dtype"],
                batch=batch, reason=pr["reason"],
                footprint_bytes=fp.total_bytes,
                budget_bytes=budgets[tag_of(pr["machine"])]))
        by_point: dict[tuple, list] = {}
        for r in res.rows:
            by_point.setdefault((r.machine, r.problem.dtype), []).append(r)
        for (ma, dt), rows in sorted(by_point.items()):
            step = sum(r.seconds for r in rows)
            options.append(DeploymentOption(
                machine=ma, dtype=dt, batch=batch,
                seconds_per_step=step,
                tokens_per_second=(batch / step) if step else float("inf"),
                footprint=fps[dt], budget_bytes=budgets[ma],
                rows=tuple(rows),
                accuracy_proxy=min(1.0, DTYPE_BITS.get(dt, 16) / 16.0)))

        # mixed-precision cells ride the same machinery: one sweep per
        # config (the precision axis replaces the dtype axis — the config
        # pins every operand dtype itself), footprinted with weights in the
        # B-operand dtype and the cache in the config's kv_dtype.
        for pc in pcs:
            label = pc.key()
            fp = footprint(cfg, batch=batch, max_len=max_len,
                           dtype=pc.b_dtype,
                           kv_dtype=pc.kv_dtype or kv_dtype)

            def pmask(ma, dt, _fp=fp):
                budget = budgets[tag_of(ma)]
                if _fp.fits(budget):
                    return True
                return (False, diagnose_rejection(_fp, budget))

            pres = gemm_api.sweep(shapes, machines=entries,
                                  backends=[backend], precisions=[pc],
                                  feasible=pmask if memory else None)
            for pr in pres.pruned:
                rejected.append(CellRejection(
                    machine=tag_of(pr["machine"]), dtype=label,
                    batch=batch, reason=pr["reason"],
                    footprint_bytes=fp.total_bytes,
                    budget_bytes=budgets[tag_of(pr["machine"])]))
            p_by_machine: dict[str, list] = {}
            for r in pres.rows:
                p_by_machine.setdefault(r.machine, []).append(r)
            for ma, rows in sorted(p_by_machine.items()):
                step = sum(r.seconds for r in rows)
                options.append(DeploymentOption(
                    machine=ma, dtype=label, batch=batch,
                    seconds_per_step=step,
                    tokens_per_second=(batch / step) if step
                    else float("inf"),
                    footprint=fp, budget_bytes=budgets[ma],
                    rows=tuple(rows), precision=label,
                    accuracy_proxy=pc.accuracy_proxy))
    options.sort(key=_rank_key)
    return DeploymentReport(
        model=cfg.name, backend=backend, max_len=max_len,
        native_dtype=native, options=options, rejected=rejected,
        grid={"machines": sorted(budgets), "dtypes": dtypes,
              "batches": batches, "memory": memory,
              "precisions": [pc.key() for pc in pcs]},
    )
