"""Prefill length bucketing, shared by the real engine and the simulator.

The serving engine prefills prompts through per-bucket jitted functions so
the jit cache stays small; the discrete-event simulator
(``repro.simulate``) must charge prefill cost at the *same* bucket lengths
or its service times drift from what the engine actually executes.  Both
sides import this module (it has no jax dependency, so the simulator stays
config-only).
"""
from __future__ import annotations

#: the engine's jit-bucket ladder; prompts longer than the last rung round
#: up to the next multiple of it.
PREFILL_BUCKETS = (32, 64, 128, 256, 512, 1024)


def bucket_len(n: int, buckets=PREFILL_BUCKETS) -> int:
    """The bucket a prefill of ``n`` tokens runs at."""
    for b in buckets:
        if n <= b:
            return b
    last = buckets[-1]
    return ((n + last - 1) // last) * last


def bucket_cover(max_len: int, buckets=PREFILL_BUCKETS) -> list[int]:
    """Every bucket a prompt of length ``<= max_len`` can land in — the
    lengths a service model must price prefill at."""
    out = [b for b in buckets if b < max_len]
    out.append(bucket_len(max_len, buckets))
    return sorted(set(out))
