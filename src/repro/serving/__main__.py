"""Deployment-planning command line.

    python -m repro.serving plan --arch qwen2-1.5b --machine 'zoo/*'
    python -m repro.serving plan --arch qwen2-7b --dtypes bf16 int8 \\
        --batches 1 2 4 8 16 32 --max-len 2048 --json plan.json
    python -m repro.serving footprint --arch qwen2-7b --batch 8 \\
        --max-len 2048

``plan`` ranks every feasible ``(machine, dtype, batch)`` serving cell of
the given machines (globs sweep the zoo) by predicted decode throughput,
with memory-infeasible cells pruned against each machine's deployment-level
budget and reported with machine-readable reasons.  Only the model config
is used — no parameters are instantiated, so full-size architectures plan
in seconds.  ``footprint`` prints the memory model for one cell.
"""
from __future__ import annotations

import argparse
import sys

from repro.configs import ARCH_IDS, get_config


def cmd_plan(args) -> int:
    from repro.serving.report import plan_deployment

    cfg = get_config(args.arch, smoke=args.smoke)
    report = plan_deployment(
        cfg, machines=args.machine, dtypes=args.dtypes,
        batches=args.batches, max_len=args.max_len, backend=args.backend,
        memory=not args.no_memory, precisions=args.precision or ())
    print(f"deployment plan for {cfg.name} (max_len={args.max_len}, "
          f"native dtype {report.native_dtype})")
    print(report.table(limit=args.limit))
    if report.options:
        best = report.select()
        print(f"selected: {best.machine} dtype={best.dtype} "
              f"max_batch={best.batch} "
              f"({best.tokens_per_second:.3g} pred tok/s, "
              f"{best.headroom_fraction:.1%} memory headroom)")
    else:
        print("no feasible deployment — every cell was rejected",
              file=sys.stderr)
    if args.json:
        report.save(args.json)
        print(f"wrote {args.json}")
    return 0 if report.options else 1


def cmd_footprint(args) -> int:
    from repro.serving.footprint import footprint

    cfg = get_config(args.arch, smoke=args.smoke)
    fp = footprint(cfg, batch=args.batch, max_len=args.max_len,
                   dtype=args.dtype)
    gib = 1024.0 ** 3
    print(f"{cfg.name} batch={fp.batch} max_len={fp.max_len} "
          f"dtype={fp.dtype} kv_dtype={fp.kv_dtype}")
    for key in ("weights_bytes", "kv_cache_bytes", "activation_bytes"):
        val = getattr(fp, key)
        print(f"  {key:<18} {val:>16,d}  ({val / gib:.3f} GiB)")
    print(f"  {'total_bytes':<18} {fp.total_bytes:>16,d}  "
          f"({fp.total_bytes / gib:.3f} GiB)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.serving")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("plan", help="rank (machine, dtype, batch) cells")
    p.add_argument("--arch", choices=ARCH_IDS, default="qwen2-1.5b")
    p.add_argument("--machine", nargs="*", default=None,
                   help="names/globs; 'zoo/*' ranks the whole registry "
                        "(default: the backend's native machine)")
    p.add_argument("--dtypes", nargs="+", default=["bf16", "int8"])
    p.add_argument("--batches", nargs="+", type=int,
                   default=[1, 2, 4, 8, 16])
    p.add_argument("--max-len", type=int, default=512)
    p.add_argument("--backend", default="analytic-tpu")
    p.add_argument("--no-memory", action="store_true",
                   help="skip the memory-budget pruning (throughput only)")
    p.add_argument("--precision", nargs="*", default=None,
                   metavar="AxB[->ACC][@kv=KV]",
                   help="extra mixed-precision what-if cells, e.g. "
                        "int8xint8 int4xint8->int32 bf16xint8->f32@kv=int8")
    p.add_argument("--smoke", action="store_true",
                   help="plan the smoke-size reduction of the arch")
    p.add_argument("--limit", type=int, default=12)
    p.add_argument("--json", default=None, help="also write the report JSON")
    p.set_defaults(fn=cmd_plan)

    p = sub.add_parser("footprint", help="memory model for one cell")
    p.add_argument("--arch", choices=ARCH_IDS, default="qwen2-1.5b")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--max-len", type=int, default=512)
    p.add_argument("--dtype", default="bf16")
    p.add_argument("--smoke", action="store_true")
    p.set_defaults(fn=cmd_footprint)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
