"""Resilience primitives shared by the real engine and the simulator.

Three mechanisms, one vocabulary:

* **Load shedding** — a request is *shed* (rejected before holding a
  slot) when serving it is pointless or impossible; the cause constants
  here are the shared vocabulary between ``ServingEngine.perf_report()``,
  the trace-v1 event log, and ``SimReport.shed`` so sim-vs-real
  accounting lines up key for key.
* **Backpressure** — a bounded queue raises :class:`QueueFullError`
  instead of buffering unboundedly; :func:`retry_with_backoff` is the
  matching client-side helper (injectable clock/sleep, so tests run on a
  fake clock).
* **Graceful degradation** — under sustained overload the engine steps
  down a :class:`DegradationRung` ladder (fewer decode slots, then a
  modeled int8 KV cache) instead of dying in ``DrainTruncatedError``.

Kept dependency-free (no engine / simulator imports) so both sides can
import it without cycles.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

# -- shed causes (shared sim/real vocabulary) --------------------------------
#: the request's deadline had already passed when a slot came up
SHED_DEADLINE_EXPIRED = "deadline_expired"
#: the deadline was still ahead, but the modeled decode time alone
#: (``decision_step_s * max_new_tokens``) would blow it
SHED_DEADLINE_UNMEETABLE = "deadline_unmeetable"
#: the bounded queue was full at arrival
SHED_QUEUE_FULL = "queue_full"

SHED_CAUSES = (SHED_DEADLINE_EXPIRED, SHED_DEADLINE_UNMEETABLE,
               SHED_QUEUE_FULL)


class QueueFullError(RuntimeError):
    """Raised by ``ServingEngine.submit`` when the bounded queue is full.

    Carries enough to make a retry decision: the queue limit and current
    depth.  (The open-loop simulator *drops* instead — an arrival process
    cannot be asked to wait — and records the drop as a ``queue_full``
    shed; same vocabulary, opposite flow control.)
    """

    def __init__(self, *, limit: int, depth: int):
        self.limit = int(limit)
        self.depth = int(depth)
        super().__init__(f"serving queue full ({depth}/{limit}); "
                         "retry with backoff or raise the limit")


def retry_with_backoff(fn: Callable[[], object], *,
                       retries: int = 5, base_delay_s: float = 0.05,
                       multiplier: float = 2.0, max_delay_s: float = 2.0,
                       sleep: Callable[[float], None] | None = None,
                       should_retry: Callable[[Exception], bool]
                       | None = None):
    """Call ``fn`` until it succeeds, sleeping exponentially longer after
    each :class:`QueueFullError` (delays ``base * multiplier**k`` capped
    at ``max_delay_s``).

    Args:
        fn: zero-arg callable — typically ``lambda: engine.submit(...)``.
        retries: attempts *after* the first (so ``retries + 1`` calls max).
        base_delay_s / multiplier / max_delay_s: the backoff schedule.
        sleep: injectable sleep (defaults to ``time.sleep``); tests pass a
            fake-clock recorder.
        should_retry: predicate on the raised exception; defaults to
            retrying exactly :class:`QueueFullError`.

    Returns:
        ``fn()``'s return value on first success.

    Raises:
        The last exception when every attempt failed.
    """
    if sleep is None:
        import time
        sleep = time.sleep
    if should_retry is None:
        def should_retry(exc):
            return isinstance(exc, QueueFullError)
    delay = float(base_delay_s)
    for attempt in range(retries + 1):
        try:
            return fn()
        except Exception as exc:                 # noqa: BLE001 — predicate
            if attempt >= retries or not should_retry(exc):
                raise
        sleep(min(delay, max_delay_s))
        delay *= multiplier


# -- degradation ladder ------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DegradationRung:
    """One step of the graceful-degradation ladder.

    ``decode_slots`` caps how many slots the engine admits into (fewer
    active sequences = smaller effective batch = shorter modeled step on
    compute-bound parts); ``kv_dtype`` is the modeled KV-cache dtype of
    this rung (``"int8"`` halves the modeled cache footprint — the real
    engine keeps computing in its native dtype; the rung is a *capacity*
    statement the footprint model prices).
    """

    name: str
    decode_slots: int
    kv_dtype: str = "native"

    def __post_init__(self):
        if self.decode_slots < 1:
            raise ValueError(f"a rung needs >= 1 decode slot, "
                             f"got {self.decode_slots}")

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def default_ladder(max_batch: int) -> tuple[DegradationRung, ...]:
    """The stock two-rung ladder for a ``max_batch``-slot engine: halve
    the decode slots, then additionally drop the modeled KV cache to
    int8.  Empty for a single-slot engine (nothing to step down to)."""
    if max_batch <= 1:
        return ()
    half = max(1, max_batch // 2)
    return (DegradationRung(name=f"half-batch{half}", decode_slots=half),
            DegradationRung(name=f"half-batch{half}-int8kv",
                            decode_slots=half, kv_dtype="int8"))


def coerce_ladder(spec: Sequence | None,
                  max_batch: int) -> tuple[DegradationRung, ...]:
    """``None`` -> :func:`default_ladder`, dicts -> rungs, pass-through;
    validates every rung fits under ``max_batch``."""
    rungs = default_ladder(max_batch) if spec is None else tuple(
        r if isinstance(r, DegradationRung) else DegradationRung(**r)
        for r in spec)
    for r in rungs:
        if r.decode_slots > max_batch:
            raise ValueError(f"rung {r.name!r} wants {r.decode_slots} slots "
                             f"but the engine has {max_batch}")
    return rungs
