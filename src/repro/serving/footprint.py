"""Serving memory-footprint model: weights + decode state + workspace.

The paper's central discipline is that a blocked algorithm is only feasible
when its working set fits each level of the memory hierarchy; deployment
planning applies the same rule one level up.  A serving configuration
``(model config, batch, dtype)`` occupies the machine's *deployment* memory
level (HBM on the TPU, main memory on the edge parts — see
:meth:`repro.machines.MachineSpec.memory_budget`) with three components:

* **weights** — every parameter stored once in the serving dtype;
* **KV cache / recurrent state** — per-slot decode state for ``batch``
  concurrent sequences at ``max_len`` positions, charged per block kind of
  the config's ``block_pattern`` (attention layers hold K/V panels, Mamba-2
  and xLSTM layers hold fixed-size recurrent state);
* **activation workspace** — the transient per-step buffers of one decode
  step (double-buffered widest layer activation, logits included).

All formulas are closed-form functions of :class:`repro.configs.ModelConfig`
fields — no model is instantiated — mirroring how the analytic GEMM
simulators predict from shapes alone.  ``ServingEngine.autoconfigure`` uses
:func:`footprint` to prune infeasible ``(machine, dtype, batch)`` cells
*before* the design-space sweep plans them.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig
from repro.core.tpu_model import DTYPE_BYTES

#: dtype tags accepted by the footprint model, with byte widths; the
#: cost-model tags (``repro.core.tpu_model.DTYPE_BYTES``) plus the configs'
#: long-form jnp names.
_BYTES = dict(DTYPE_BYTES, bfloat16=2, float32=4)

#: recurrent/accumulator state is carried in f32 by the model zoo
#: (``models/ssm.py``, ``models/xlstm.py``) regardless of compute dtype.
_STATE_BYTES = 4


def dtype_bytes(tag: str) -> int:
    """Bytes per element of a footprint dtype tag.

    Raises:
        KeyError: for a tag neither the cost models nor the configs use.
    """
    try:
        return _BYTES[tag]
    except KeyError:
        raise KeyError(f"unknown dtype tag {tag!r}; have "
                       f"{sorted(_BYTES)}") from None


@dataclasses.dataclass(frozen=True)
class Footprint:
    """Modelled deployment-memory occupancy of one serving configuration."""

    config: str                 # model-config name
    batch: int
    max_len: int
    dtype: str                  # serving (weights/activation) dtype tag
    kv_dtype: str               # KV-cache dtype tag
    weights_bytes: int
    kv_cache_bytes: int         # attention K/V panels + recurrent state
    activation_bytes: int       # transient per-step workspace

    @property
    def total_bytes(self) -> int:
        return self.weights_bytes + self.kv_cache_bytes \
            + self.activation_bytes

    def fits(self, budget_bytes: int) -> bool:
        """Whether this configuration fits a deployment-memory budget."""
        return self.total_bytes <= budget_bytes

    def headroom_bytes(self, budget_bytes: int) -> int:
        """Budget minus footprint; negative when the config does not fit."""
        return int(budget_bytes) - self.total_bytes

    def as_dict(self) -> dict:
        return {
            "config": self.config, "batch": self.batch,
            "max_len": self.max_len, "dtype": self.dtype,
            "kv_dtype": self.kv_dtype,
            "weights_bytes": self.weights_bytes,
            "kv_cache_bytes": self.kv_cache_bytes,
            "activation_bytes": self.activation_bytes,
            "total_bytes": self.total_bytes,
        }


def _per_slot_state_bytes(cfg: ModelConfig, max_len: int, kv_dtype: str,
                          act_bytes: int) -> int:
    """Decode-state bytes one sequence slot holds across all layers.

    Charged per block kind (``cfg.block_counts()``), matching the cache
    layouts of the model zoo:

    * ``attn`` / ``shared_attn`` / ``moe`` (whose attention half caches
      identically): K and V panels ``(n_kv_heads, max_len, head_dim)`` in
      the KV dtype; an int8 cache adds two f32 scale vectors per position
      (``models/attention.py``).
    * ``mamba2``: the f32 SSM state ``(heads, head_dim, state)`` plus the
      conv ring buffer ``(conv-1, d_inner)`` in the serving dtype
      (``models/ssm.py``).
    * ``mlstm``: the f32 matrix state ``(heads, head_dim+1, head_dim)``
      plus the conv ring buffer (``models/xlstm.py``).
    * ``slstm``: the three f32 ``d_model`` vectors ``(h, c, n)``.

    Raises:
        ValueError: on a block kind the model zoo does not define (the
        model constructor would reject it too — better than silently
        billing a cache the block does not have).
    """
    kv_bytes = dtype_bytes(kv_dtype)
    per_slot = 0
    for kind, count in cfg.block_counts().items():
        if kind in ("attn", "shared_attn", "moe"):
            panel = cfg.n_kv_heads * max_len * cfg.head_dim
            per = 2 * panel * kv_bytes
            if kv_dtype == "int8":
                per += 2 * cfg.n_kv_heads * max_len * 4   # k/v scales, f32
        elif kind == "mamba2":
            per = (cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
                   * _STATE_BYTES
                   + (cfg.ssm_conv - 1) * cfg.d_inner * act_bytes)
        elif kind == "mlstm":
            head = cfg.mlstm_inner // cfg.lstm_heads
            per = (cfg.lstm_heads * (head + 1) * head * _STATE_BYTES
                   + (cfg.ssm_conv - 1) * cfg.mlstm_inner * act_bytes)
        elif kind == "slstm":
            per = 3 * cfg.d_model * _STATE_BYTES
        else:
            raise ValueError(f"{cfg.name}: unknown block kind {kind!r} in "
                             f"block_pattern — cannot model its decode "
                             f"state")
        per_slot += count * per
    return per_slot


def footprint(cfg: ModelConfig, *, batch: int, max_len: int,
              dtype: str = "bf16", kv_dtype: str | None = None) -> Footprint:
    """Model the deployment-memory footprint of one serving configuration.

    Args:
        cfg: the model config (only its shape fields are read).
        batch: number of concurrent decode slots (``ServingEngine``'s
            ``max_batch``).
        max_len: per-slot cache length in tokens.
        dtype: serving dtype tag for weights and activations (the
            autoconfigure dtype axis: ``"bf16"``, ``"int8"``, ``"f32"`` or
            the configs' long-form names).
        kv_dtype: KV-cache dtype tag; defaults to the config's
            ``kv_cache_dtype`` when that is int8, else to ``dtype``.

    Returns:
        A :class:`Footprint` with the weights / KV-state / workspace split.

    Raises:
        KeyError: on an unknown dtype tag.
        ValueError: on a non-positive batch or max_len.
    """
    if batch < 1 or max_len < 1:
        raise ValueError(f"degenerate serving config batch={batch} "
                         f"max_len={max_len}")
    wbytes = dtype_bytes(dtype)
    if kv_dtype is None:
        kv_dtype = "int8" if cfg.kv_cache_dtype == "int8" else dtype
    dtype_bytes(kv_dtype)   # validate the tag up front

    weights = cfg.param_count() * wbytes
    kv_cache = batch * _per_slot_state_bytes(cfg, max_len, kv_dtype, wbytes)

    # transient decode-step workspace: the widest single-layer activation
    # (QKV / gate+up / routed-expert / logits row block), double-buffered
    # (producer + consumer live across one planned GEMM).
    widest = max(
        cfg.n_heads * cfg.head_dim + 2 * cfg.n_kv_heads * cfg.head_dim,
        2 * cfg.d_ff,
        2 * cfg.moe_d_ff * max(1, cfg.experts_per_token),
        cfg.padded_vocab,
    )
    activations = 2 * batch * (cfg.d_model + widest) * wbytes

    return Footprint(
        config=cfg.name, batch=batch, max_len=max_len, dtype=dtype,
        kv_dtype=kv_dtype, weights_bytes=int(weights),
        kv_cache_bytes=int(kv_cache), activation_bytes=int(activations),
    )
