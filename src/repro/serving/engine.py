"""Slot-based continuous-batching serving engine.

A fixed pool of ``max_batch`` decode slots, each holding one sequence's
KV/state caches at its own position (the decode step takes an (B,) position
vector).  New requests prefill individually (bucketed lengths keep the jit
cache small) and are *inserted* into a free slot's cache region; finished
slots free immediately — no batch-wide barrier, the defining property of
continuous batching.

Everything is jitted once per bucket shape; the engine itself is plain
Python and runs on CPU in the tests/examples with a smoke model.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import gemm as gemm_api
from repro import obs
from repro.configs.base import ModelConfig
from repro.models.common import split_params
from repro.models.model import LM
from repro.obs import DriftMonitor
from repro.serving.buckets import bucket_len as _bucket
from repro.serving.resilience import (SHED_DEADLINE_EXPIRED,
                                      SHED_DEADLINE_UNMEETABLE,
                                      DegradationRung, QueueFullError,
                                      coerce_ladder)

#: the event-trace format ``repro.simulate.replay`` consumes
TRACE_SCHEMA = "repro.serving/trace-v1"


class DrainTruncatedError(RuntimeError):
    """``run_until_drained`` hit ``max_steps`` with work still in flight.

    Raised instead of silently returning a partial result: a truncated
    drain would otherwise masquerade as a complete trace and poison any
    sim-vs-real replay comparison.  ``finished`` / ``queued`` / ``active``
    carry the state at truncation.
    """

    def __init__(self, *, finished: int, queued: int, active: int,
                 max_steps: int):
        self.finished = finished
        self.queued = queued
        self.active = active
        self.max_steps = max_steps
        super().__init__(
            f"run_until_drained truncated after {max_steps} steps: "
            f"{queued} request(s) still queued, {active} still decoding "
            f"({finished} finished) — raise max_steps or submit less work")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list            # token ids
    max_new_tokens: int = 16
    eos_id: int | None = None
    # end-to-end latency budget in seconds from submission; None defers to
    # the engine's default deadline (which may also be None: no deadline)
    deadline_s: float | None = None
    generated: list = dataclasses.field(default_factory=list)
    # lifecycle timestamps (time.perf_counter seconds), stamped by the
    # engine: submission, slot admission, first decoded token, last token
    t_submit: float | None = None
    t_admit: float | None = None
    t_first_token: float | None = None
    t_finish: float | None = None
    # load shedding: when and why the engine rejected this request at
    # admission time instead of serving it
    t_shed: float | None = None
    shed_cause: str | None = None

    @property
    def shed(self) -> bool:
        return self.shed_cause is not None

    @property
    def done(self) -> bool:
        if self.eos_id is not None and self.generated \
                and self.generated[-1] == self.eos_id:
            return True
        return len(self.generated) >= self.max_new_tokens

    @property
    def wait_s(self) -> float | None:
        """Queue time: submit -> admission."""
        if self.t_submit is None or self.t_admit is None:
            return None
        return self.t_admit - self.t_submit

    @property
    def service_s(self) -> float | None:
        """Admission -> last token."""
        if self.t_admit is None or self.t_finish is None:
            return None
        return self.t_finish - self.t_admit

    @property
    def latency_s(self) -> float | None:
        """End to end: submit -> last token."""
        if self.t_submit is None or self.t_finish is None:
            return None
        return self.t_finish - self.t_submit

    @property
    def ttft_s(self) -> float | None:
        """Submit -> first decoded token."""
        if self.t_submit is None or self.t_first_token is None:
            return None
        return self.t_first_token - self.t_submit


class ServingEngine:
    """See the module docstring for the serving model.

    Resilience knobs (all off by default — a default-constructed engine
    behaves bit-identically to one without them):

    * ``deadline_s``: default end-to-end budget for requests that carry
      none; enables deadline-aware admission — a queued request whose
      deadline already passed (``deadline_expired``) or whose modeled
      decode time no longer fits (``deadline_unmeetable``, using the
      frozen-plan step estimate at the current slot cap) is *shed* at
      admission instead of wasting a slot.
    * ``queue_limit``: bounded queue; ``submit`` raises
      :class:`~repro.serving.resilience.QueueFullError` (backpressure —
      pair with :func:`~repro.serving.resilience.retry_with_backoff`).
    * ``ladder`` / ``overload_patience``: graceful degradation — after
      ``overload_patience`` consecutive steps with every allowed slot
      busy *and* work still queued, the engine steps down one
      :class:`~repro.serving.resilience.DegradationRung` (fewer decode
      slots, then a modeled int8 KV cache); it steps back up after the
      same number of calm (empty-queue) steps.  ``ladder=None`` with a
      deadline or queue limit set installs the stock
      :func:`~repro.serving.resilience.default_ladder`; ``ladder=()``
      disables degradation outright.
    """

    def __init__(self, lm: LM, params, *, max_batch: int = 4,
                 max_len: int = 512,
                 deadline_s: float | None = None,
                 queue_limit: int | None = None,
                 ladder=None, overload_patience: int = 8):
        self.lm = lm
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        if queue_limit is not None and queue_limit < 1:
            raise ValueError(f"queue limit must be >= 1, got {queue_limit}")
        if overload_patience < 1:
            raise ValueError(f"overload patience must be >= 1, "
                             f"got {overload_patience}")
        self.deadline_s = deadline_s
        self.queue_limit = queue_limit
        resilient = deadline_s is not None or queue_limit is not None \
            or ladder is not None
        self.ladder: tuple[DegradationRung, ...] = \
            coerce_ladder(ladder, max_batch) if resilient else ()
        self.overload_patience = int(overload_patience)
        self._rung = -1                  # -1 = nominal, else ladder index
        self._overload_streak = 0
        self._calm_streak = 0
        self.degradations: list[dict] = []
        self.shed_requests: list[Request] = []
        self.rejected_submits = 0
        self.truncated: dict | None = None
        self._step_s_cache: dict[int, float] = {}
        caches, _ = split_params(lm.init_cache(max_batch, max_len))
        self.caches = caches
        self.slot_pos = [0] * max_batch          # next write position
        self.slot_req: list[Request | None] = [None] * max_batch
        # deque: admission pops from the front per free slot, so the queue
        # must not pay O(n) per admission like list.pop(0) did.
        self.queue: collections.deque[Request] = collections.deque()
        self.finished: list[Request] = []
        self._decode = jax.jit(self._decode_impl)
        self._prefill = {}
        self._insert = jax.jit(self._insert_impl, static_argnums=(2,),
                               donate_argnums=(0,))
        # Frozen GEMM plans for this engine's decode workload (M = the slot
        # pool size): the paper's predict-before-run loop applied to serving,
        # surfaced through perf_report().  Planned lazily on first access so
        # autoconfigure() can install its sweep-chosen plans without the
        # constructor paying for a default pass it would discard;
        # plan_model_gemms is a bulk operation (one batched lattice
        # evaluation over the deduped decode shapes).  On TPU the decode
        # step's pallas plans reach the same tiles through TileTuner's
        # shared search cache.
        self._gemm_plans: list | None = None
        # populated by autoconfigure(): the sweep-chosen operating point and
        # the full ranked DeploymentReport it was selected from.
        self.autoconfig: dict | None = None
        self.deployment_report = None
        # event trace (repro.serving/trace-v1): submits, admissions, steps
        # with wall durations, first tokens, finishes — what
        # repro.simulate.replay re-enacts.  Cheap (a dict append per
        # event), so always on.  The events live in the process
        # ``repro.obs`` recorder tagged with this engine's identity;
        # ``trace_events`` / ``trace_json()`` are views over it.
        self._obs_tag = f"serving-engine-{id(self):x}"
        # online prediction drift: measured step wall time vs the frozen
        # plans' decode-step estimate, keyed by the deployment machine's
        # geometry fingerprint (see docs/OBSERVABILITY.md).
        self.drift = DriftMonitor()
        self._drift_key: str | None = None

    def _trace(self, payload: dict) -> None:
        obs.recorder.add_event(payload, track="wall", tag=self._obs_tag)

    @property
    def trace_events(self) -> list[dict]:
        """This engine's trace-v1 event payloads, in emission order — a
        view over the process ``repro.obs`` recorder."""
        return obs.recorder.events_for(tag=self._obs_tag)

    @property
    def gemm_plans(self) -> list:
        if self._gemm_plans is None:
            self._gemm_plans = gemm_api.plan_model_gemms(
                self.lm.cfg, tokens=self.max_batch, backend="analytic-tpu")
        return self._gemm_plans

    @gemm_plans.setter
    def gemm_plans(self, plans) -> None:
        self._gemm_plans = list(plans)

    @classmethod
    def autoconfigure(cls, lm: LM, params, *, machine=None,
                      dtypes=("bf16",), batches=(1, 2, 4, 8, 16),
                      max_len: int = 512,
                      backend: str = "analytic-tpu",
                      memory: bool = True,
                      kv_dtype: str | None = None,
                      precisions=(),
                      slo=None, traffic=None,
                      robust: bool = False, faults=None,
                      deadline_s: float | None = None,
                      queue_limit: int | None = None,
                      ladder=None,
                      sim_policies=("greedy",),
                      sim_requests: int = 200,
                      sim_seed: int = 0) -> "ServingEngine":
        """Pick ``max_batch``, the deployment machine and the frozen decode
        plans by ranking the whole (machine x dtype x batch) serving grid.

        Wraps :func:`repro.serving.report.plan_deployment`: every cell's
        memory footprint (weights + KV/recurrent state + activation
        workspace, ``repro.serving.footprint``) is checked against the
        machine's deployment-level budget and infeasible cells are pruned
        *before* the ``repro.gemm.sweep`` plans them; among the surviving
        cells, the one maximising predicted decode tokens/second wins —
        ``max_batch`` is therefore the largest batch that both fits memory
        and pays off in throughput, not the fastest-GEMM batch.

        The dtype axis is an analytic what-if over the machine's rate
        table; since the engine really computes in the model's configured
        dtype, the operating point is chosen among that native dtype's
        feasible cells — what-if dtypes inform the ranking only.  If no
        native-dtype cell survives, the overall best feasible cell wins (an
        explicit choice to configure against a foreign dtype).

        Args:
            lm / params: the model the engine will serve.
            machine: machines axis — a name, spec, glob (``"zoo/*"`` ranks
                the whole registry), a list of any of those, or None for
                the backend's default machine.
            dtypes: serving-dtype what-if axis.
            batches: candidate ``max_batch`` values.
            max_len: per-slot cache length (bounds the KV footprint).
            backend: planning backend for the decode-GEMM sweep.
            memory: enforce the deployment-memory budget (default True);
                False restores the pre-memory throughput-only grid.
            kv_dtype: KV-cache dtype override for the footprint model.
            precisions: extra mixed-precision what-if cells
                (:class:`~repro.core.precision.PrecisionConfig` objects or
                key strings like ``"int4xint8->int32"``), forwarded to
                :func:`~repro.serving.report.plan_deployment`.  Like
                what-if dtypes they inform the ranking only — the frozen
                operating point always comes from a plain-dtype cell.
            slo: optional service-level objective (a
                :class:`repro.simulate.SLO`, kwargs dict, or bare p99
                latency bound).  When given, the memory-feasible cells are
                additionally run through the discrete-event simulator
                (``repro.simulate``) under ``traffic`` and the engine is
                configured from the cell with the best *simulated* goodput
                among those attaining the SLO — usually a smaller batch
                than the peak-throughput pick, since every decode step
                slows down with the slot-pool size.  SLO-failing cells
                join ``deployment_report.rejected`` with machine-readable
                ``slo_*`` reasons.
            traffic: traffic scenario for SLO mode (a
                ``repro.simulate.Traffic``); None derives a Poisson
                scenario from the report
                (:func:`repro.simulate.default_traffic`).
            robust: perturbation-robust SLO mode (requires ``slo``): the
                cells are simulated *under a fault scenario* — by default
                the ``"throttle20"`` duty-cycled thermal throttle — so
                the pick is the cell that still attains the SLO when the
                machine slows down, not the fair-weather winner.  Cells
                that only fail under the faults are rejected with
                ``fault_``-prefixed reasons.
            faults: the fault scenario for robust mode (a
                ``repro.simulate.FaultScenario``, registry name, or
                dict); implies ``robust=True`` when given.
            deadline_s / queue_limit / ladder: resilience knobs for the
                *configured* engine (per-request deadline shedding,
                bounded-queue backpressure, degradation ladder — see
                ``resilience.py``); deadline and queue limit also apply
                to the SLO-mode simulations so the pick accounts for
                shedding.
            sim_policies / sim_requests / sim_seed: SLO-mode simulation
                knobs — admission policies to consider, stream length per
                cell, and the default-traffic seed.

        Returns:
            A configured engine.  ``engine.deployment_report`` holds the
            ranked :class:`~repro.serving.report.DeploymentReport`;
            ``engine.autoconfig`` keeps the flat JSON-friendly grid (one
            entry per feasible cell, plus ``rejected`` cells with
            machine-readable reasons) consumed by ``perf_report``.

        Raises:
            ValueError: when every (machine, dtype, batch) cell is memory-
                infeasible — the error lists the per-cell rejection
                reasons.
        """
        from repro.serving.report import plan_deployment

        report = plan_deployment(
            lm.cfg, machines=machine, dtypes=dtypes, batches=batches,
            max_len=max_len, backend=backend, memory=memory,
            kv_dtype=kv_dtype, precisions=precisions)
        if faults is not None:
            robust = True
        if robust and slo is None:
            raise ValueError("autoconfigure(robust=True) needs an slo: "
                             "robustness is defined as SLO attainment "
                             "under perturbation")
        selection = None
        if slo is not None:
            from repro.machines import MachineSpec, expand_many
            from repro.simulate import evaluate_deployment

            if robust and faults is None:
                faults = "throttle20"
            overrides = {e.name: e for e in expand_many(machine)
                         if isinstance(e, MachineSpec)}
            selection = evaluate_deployment(
                lm.cfg, report, slo=slo, traffic=traffic,
                policies=sim_policies, requests=sim_requests,
                seed=sim_seed, machines=overrides, faults=faults,
                deadline_s=deadline_s, queue_limit=queue_limit)
            best = selection.option
        else:
            best = report.select()
        eng = cls(lm, params, max_batch=best.batch, max_len=max_len,
                  deadline_s=deadline_s, queue_limit=queue_limit,
                  ladder=ladder)
        eng.gemm_plans = [r.plan for r in best.rows]
        eng.deployment_report = report
        grid = [{
            "max_batch": o.batch, "machine": o.machine, "dtype": o.dtype,
            "predicted_gemm_seconds_per_step": o.seconds_per_step,
            "predicted_tokens_per_second": o.tokens_per_second,
            "footprint_bytes": o.footprint.total_bytes,
            "memory_budget_bytes": o.budget_bytes,
            "memory_headroom_bytes": o.headroom_bytes,
        } for o in report.options]
        eng.autoconfig = {
            "max_batch": best.batch, "machine": best.machine,
            "dtype": best.dtype, "native_dtype": report.native_dtype,
            "backend": backend,
            "predicted_tokens_per_second": best.tokens_per_second,
            "footprint_bytes": best.footprint.total_bytes,
            "memory_budget_bytes": best.budget_bytes,
            "memory_headroom_bytes": best.headroom_bytes,
            "grid": grid,
            "rejected": [r.as_dict() for r in report.rejected],
        }
        if selection is not None:
            eng.autoconfig["slo"] = {
                "slo": selection.slo.as_dict(),
                "policy": selection.policy,
                "traffic": selection.traffic_name,
                "faults": selection.faults,
                "sim": selection.sim.summary(),
                "rejected": [r.as_dict() for r in selection.rejections],
            }
        return eng

    def perf_report(self) -> dict:
        """Predicted per-decode-step GEMM cost from the frozen plans, plus
        measured per-request wait/service/latency stats once requests have
        finished (the timestamps the event trace records) — the real-side
        half of a sim-vs-real comparison."""
        total = sum(p.predicted_seconds for p in self.gemm_plans)
        report = {
            "predicted_gemm_seconds_per_step": total,
            "predicted_tokens_per_second":
                (self.max_batch / total) if total else float("inf"),
            "plans": [p.describe() for p in self.gemm_plans],
        }
        timed = [r for r in self.finished if r.latency_s is not None]
        if timed:
            def stats(vals):
                vals = sorted(vals)
                return {"mean": sum(vals) / len(vals), "max": vals[-1],
                        "p95": vals[min(len(vals) - 1,
                                        int(0.95 * (len(vals) - 1) + 0.5))]}
            report["measured_requests"] = {
                "finished": len(timed),
                "wait_s": stats([r.wait_s for r in timed]),
                "service_s": stats([r.service_s for r in timed]),
                "latency_s": stats([r.latency_s for r in timed]),
                "ttft_s": stats([r.ttft_s for r in timed
                                 if r.ttft_s is not None] or [0.0]),
            }
        resilience = self._resilience_report()
        if resilience is not None:
            report["resilience"] = resilience
        if self.autoconfig is not None:
            report["autoconfig"] = self.autoconfig
        # online prediction-drift verdict (repro.obs): every step feeds
        # measured wall time vs the frozen-plan estimate; ok/warn/stale
        # uses the offline CalibrationDriftError threshold.  On a host
        # running the smoke model against an analytic TPU spec, "stale"
        # is the *honest* verdict — the calibration really does not
        # describe this machine.
        drift = self.drift.report()
        report["drift"] = drift
        report["drift_status"] = drift["status"]
        return report

    def _resilience_report(self) -> dict | None:
        """Shed/expired/degraded accounting for ``perf_report()``; None
        when no resilience feature is configured or ever fired (keeping
        the default report shape unchanged)."""
        engaged = (self.deadline_s is not None
                   or self.queue_limit is not None or bool(self.ladder)
                   or self.shed_requests or self.rejected_submits
                   or self.truncated is not None)
        if not engaged:
            return None
        causes: dict[str, int] = {}
        for r in self.shed_requests:
            causes[r.shed_cause] = causes.get(r.shed_cause, 0) + 1
        out = {
            "deadline_s": self.deadline_s,
            "queue_limit": self.queue_limit,
            "shed": {"count": len(self.shed_requests), "causes": causes},
            "expired": causes.get(SHED_DEADLINE_EXPIRED, 0),
            "rejected_submits": self.rejected_submits,
            "degraded": {
                "ladder": [r.as_dict() for r in self.ladder],
                "rung": self.rung.name if self.rung else None,
                "events": list(self.degradations),
            },
        }
        if self.truncated is not None:
            out["truncated"] = dict(self.truncated)
        return out

    # -- jitted pieces --------------------------------------------------------
    def _decode_impl(self, params, caches, tokens, pos_vec, active):
        logits, caches = self.lm.decode_step(params, caches, tokens, pos_vec)
        logits = logits.astype(jnp.float32)
        vp = logits.shape[-1]
        if vp > self.lm.cfg.vocab_size:
            logits = logits.at[..., self.lm.cfg.vocab_size:].set(-1e9)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, caches

    def _prefill_fn(self, bucket: int) -> Callable:
        if bucket not in self._prefill:
            def fn(params, tokens):
                _, caches = self.lm.prefill(params, {"tokens": tokens})
                return caches
            self._prefill[bucket] = jax.jit(fn)
        return self._prefill[bucket]

    def _insert_impl(self, caches, pref, slot: int):
        """Insert a single-sequence prefill cache into slot ``slot``.

        Stack caches have batch axis 1 ((periods, B, ...)); tail caches axis
        0.  Sequence axes shorter than the slot's are zero-padded."""
        stack_key = jax.tree_util.DictKey("stack")

        def ins(path, slot_leaf, pref_leaf):
            baxis = 1 if path and path[0] == stack_key else 0
            pl = pref_leaf
            # pad every non-batch dim up to the slot leaf's size
            pads = [(0, 0) if (i == baxis or a == b) else (0, b - a)
                    for i, (a, b) in enumerate(zip(pl.shape, slot_leaf.shape))]
            if any(p[1] for p in pads):
                pl = jnp.pad(pl, pads)
            start = [0] * slot_leaf.ndim
            start[baxis] = slot
            return jax.lax.dynamic_update_slice(
                slot_leaf, pl.astype(slot_leaf.dtype), tuple(start))

        return jax.tree_util.tree_map_with_path(ins, caches, pref)

    # -- public API ---------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Enqueue one request.

        Raises:
            QueueFullError: the bounded queue (``queue_limit``) is full —
                backpressure, not shedding: the request was never
                accepted, the caller owns the retry (see
                :func:`repro.serving.resilience.retry_with_backoff`).
        """
        req.t_submit = time.perf_counter()
        if self.queue_limit is not None \
                and len(self.queue) >= self.queue_limit:
            self.rejected_submits += 1
            obs.metrics.counter("serving.rejected_submits")
            self._trace({
                "type": "reject", "rid": req.rid, "t": req.t_submit,
                "queue_depth": len(self.queue), "limit": self.queue_limit})
            raise QueueFullError(limit=self.queue_limit,
                                 depth=len(self.queue))
        self.queue.append(req)
        obs.metrics.counter("serving.submitted")
        event = {
            "type": "submit", "rid": req.rid, "t": req.t_submit,
            "prompt_len": len(req.prompt),
            "max_new_tokens": req.max_new_tokens}
        dl = self._deadline_for(req)
        if dl is not None:
            event["deadline_s"] = dl
        self._trace(event)

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    # -- resilience ---------------------------------------------------------
    def _deadline_for(self, req: Request) -> float | None:
        return req.deadline_s if req.deadline_s is not None \
            else self.deadline_s

    @property
    def rung(self) -> DegradationRung | None:
        """The active degradation rung (``None`` at nominal service)."""
        return self.ladder[self._rung] if self._rung >= 0 else None

    @property
    def slot_cap(self) -> int:
        """How many decode slots admission may fill right now."""
        r = self.rung
        return self.max_batch if r is None else r.decode_slots

    def decision_step_s(self, cap: int | None = None) -> float:
        """The modeled decode-step seconds the shedding decision prices
        with: the frozen plans' prediction at the current slot cap
        (re-planned per cap — a degraded engine admits against its own,
        smaller, modeled step).  The full-batch value is exactly
        ``perf_report()``'s ``predicted_gemm_seconds_per_step``."""
        cap = self.slot_cap if cap is None else cap
        if cap not in self._step_s_cache:
            if cap == self.max_batch:
                plans = self.gemm_plans
            else:
                plans = gemm_api.plan_model_gemms(
                    self.lm.cfg, tokens=cap, backend="analytic-tpu")
            self._step_s_cache[cap] = \
                sum(p.predicted_seconds for p in plans)
        return self._step_s_cache[cap]

    def _shed_cause(self, req: Request, now: float) -> str | None:
        """Why this queued request should be shed rather than admitted:
        deadline already passed, or the modeled decode time alone
        (``decision_step_s * max_new_tokens``; prefill excluded — the
        simulator excludes it identically) no longer fits the budget."""
        dl = self._deadline_for(req)
        if dl is None:
            return None
        waited = now - req.t_submit
        if waited >= dl:
            return SHED_DEADLINE_EXPIRED
        if waited + self.decision_step_s() * req.max_new_tokens > dl:
            return SHED_DEADLINE_UNMEETABLE
        return None

    def _shed(self, req: Request, cause: str, now: float) -> None:
        req.t_shed = now
        req.shed_cause = cause
        self.shed_requests.append(req)
        obs.metrics.counter("serving.shed")
        obs.metrics.counter(f"serving.shed.{cause}")
        self._trace({
            "type": "shed", "rid": req.rid, "t": now, "cause": cause,
            "waited_s": now - req.t_submit})

    def _next_admissible(self) -> Request | None:
        """Pop the queue until an admissible request surfaces, shedding
        hopeless ones along the way (a shed never consumes the slot, so
        an expired backlog drains in one step)."""
        while self.queue:
            req = self.queue.popleft()
            now = time.perf_counter()
            cause = self._shed_cause(req, now)
            if cause is None:
                return req
            self._shed(req, cause, now)
        return None

    def _update_ladder(self, active: int) -> None:
        """Degradation bookkeeping, once per step: sustained overload
        (every allowed slot busy, work still queued) steps down a rung;
        the same patience of calm steps back up."""
        if not self.ladder:
            return
        overloaded = bool(self.queue) and active >= self.slot_cap
        self._overload_streak = self._overload_streak + 1 if overloaded \
            else 0
        self._calm_streak = self._calm_streak + 1 if not self.queue else 0
        if self._overload_streak >= self.overload_patience \
                and self._rung < len(self.ladder) - 1:
            self._rung += 1
            self._overload_streak = 0
            obs.metrics.counter("serving.degraded")
            event = {"type": "degrade", "t": time.perf_counter(),
                     "rung": self.rung.name,
                     "decode_slots": self.rung.decode_slots,
                     "kv_dtype": self.rung.kv_dtype}
            self._trace(event)
            self.degradations.append(dict(event))
        elif self._calm_streak >= self.overload_patience and self._rung >= 0:
            self._rung -= 1
            self._calm_streak = 0
            obs.metrics.counter("serving.restored")
            name = self.rung.name if self.rung else "nominal"
            event = {"type": "restore", "t": time.perf_counter(),
                     "rung": name, "decode_slots": self.slot_cap}
            self._trace(event)
            self.degradations.append(dict(event))

    def _admit(self) -> list[Request]:
        admitted = []
        for slot in self._free_slots():
            if self.max_batch - len(self._free_slots()) >= self.slot_cap:
                break
            req = self._next_admissible()
            if req is None:
                break
            ptoks = req.prompt[-self.max_len + req.max_new_tokens:]
            # prefill all but the last prompt token; the first decode step
            # feeds prompt[-1] at position len-1 (cache then logits in one).
            prefix = ptoks[:-1]
            bucket = 0
            if prefix:
                # recurrent blocks fold every token into their state, so pad
                # tokens would corrupt it: exact-length prefill for those.
                recurrent = any(k in ("mamba2", "mlstm", "slstm")
                                for k in self.lm.cfg.block_pattern)
                bucket = (len(prefix) if recurrent
                          else min(_bucket(len(prefix)), self.max_len))
                with obs.span("serve.prefill", rid=req.rid, bucket=bucket,
                              slot=slot):
                    toks = jnp.zeros((1, bucket), jnp.int32)
                    toks = toks.at[0, :len(prefix)].set(
                        jnp.array(prefix, jnp.int32))
                    pref = self._prefill_fn(bucket)(self.params, toks)
                    self.caches = self._insert(self.caches, pref, slot)
            self.slot_pos[slot] = len(ptoks) - 1
            self.slot_req[slot] = req
            req.t_admit = time.perf_counter()
            obs.metrics.counter("serving.admitted")
            self._trace({
                "type": "admit", "rid": req.rid, "t": req.t_admit,
                "slot": slot, "prefix_len": len(prefix), "bucket": bucket})
            admitted.append(req)
        return admitted

    def step(self) -> list[Request]:
        """Admit + one decode step for all active slots; returns newly
        finished requests."""
        t_start = time.perf_counter()
        admitted = self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        self._update_ladder(len(active))
        if not active:
            return []
        tokens = jnp.zeros((self.max_batch, 1), jnp.int32)
        for i in active:
            r = self.slot_req[i]
            last = r.generated[-1] if r.generated else r.prompt[-1]
            tokens = tokens.at[i, 0].set(last)
        # inactive slots decode harmlessly at position 0 (outputs ignored;
        # admission overwrites their cache region)
        pos_vec = jnp.minimum(jnp.array(self.slot_pos, jnp.int32),
                              self.max_len - 1)
        active_mask = jnp.array([r is not None for r in self.slot_req])
        nxt, self.caches = self._decode(self.params, self.caches, tokens,
                                        pos_vec, active_mask)
        out, firsts = [], []
        for i in active:
            r = self.slot_req[i]
            r.generated.append(int(nxt[i]))
            if len(r.generated) == 1:
                firsts.append(r)
            self.slot_pos[i] += 1
            if r.done or self.slot_pos[i] >= self.max_len - 1:
                self.finished.append(r)
                out.append(r)
                self.slot_req[i] = None
                self.slot_pos[i] = 0
        # one stamp for the whole step: tokens materialise at the step
        # boundary (the simulator's model of it), not per slot
        t_end = time.perf_counter()
        for r in firsts:
            r.t_first_token = t_end
            self._trace(
                {"type": "first_token", "rid": r.rid, "t": t_end})
        for r in out:
            r.t_finish = t_end
            obs.metrics.counter("serving.finished")
            self._trace(
                {"type": "finish", "rid": r.rid, "t": t_end,
                 "tokens": len(r.generated)})
        self._trace({
            "type": "step", "t": t_start, "dt": t_end - t_start,
            "admitted": [r.rid for r in admitted], "active": len(active),
            "queue_depth": len(self.queue)})
        obs.metrics.counter("serving.steps")
        obs.metrics.observe("serving.step_dt_s", t_end - t_start)
        obs.add_span("serve.step", t_start, t_end,
                     admitted=len(admitted), active=len(active),
                     queue_depth=len(self.queue))
        self.drift.observe(self.decision_step_s(), t_end - t_start,
                           key=self._drift_machine_key())
        return out

    def _drift_machine_key(self) -> str:
        """``name@geometry_fingerprint`` of the machine the frozen plans
        price against — the identity drift windows are keyed by (the same
        key ``repro.measure.SampleStore`` uses for samples)."""
        if self._drift_key is None:
            name = (self.gemm_plans[0].machine if self.gemm_plans
                    else "unknown")
            try:
                from repro.machines import resolve
                self._drift_key = f"{name}@" \
                    f"{resolve(name, name).geometry_fingerprint()}"
            except Exception:
                self._drift_key = name
        return self._drift_key

    def drain(self, max_steps: int = 10_000, *,
              on_truncate: str = "raise") -> list[Request]:
        """Step until queue and slots are empty.

        Args:
            max_steps: give up after this many steps.
            on_truncate: ``"raise"`` (default) raises
                :class:`DrainTruncatedError` on a partial drain;
                ``"report"`` records the truncation (``self.truncated``,
                surfaced by ``perf_report()``) and returns what *did*
                finish — for CLI/benchmark paths that would otherwise
                lose every measurement to the exception.

        Raises:
            DrainTruncatedError: truncated and ``on_truncate="raise"`` —
                a partial drain must not pass for a complete trace (see
                ``repro.simulate.replay``).
        """
        if on_truncate not in ("raise", "report"):
            raise ValueError(f"on_truncate must be 'raise' or 'report', "
                             f"got {on_truncate!r}")
        for _ in range(max_steps):
            self.step()
            if not self.queue and all(r is None for r in self.slot_req):
                return self.finished
        state = dict(finished=len(self.finished), queued=len(self.queue),
                     active=sum(r is not None for r in self.slot_req),
                     max_steps=max_steps)
        if on_truncate == "raise":
            raise DrainTruncatedError(**state)
        self.truncated = state
        self._trace({
            "type": "truncated", "t": time.perf_counter(), **state})
        return self.finished

    def run_until_drained(self, max_steps: int = 10_000, *,
                          on_truncate: str = "raise") -> list[Request]:
        """Alias of :meth:`drain` (the historical name)."""
        return self.drain(max_steps, on_truncate=on_truncate)

    def trace_json(self) -> dict:
        """The engine's event trace (``repro.serving/trace-v1``) — feed it
        to :func:`repro.simulate.replay.replay` for sim-vs-real
        validation, or persist it next to a measurement campaign.
        ``predicted_step_s`` is the frozen-plan decode-step estimate the
        engine's shedding decisions price with; replay hands it to the
        simulator so both sides decide on identical inputs."""
        return {"schema": TRACE_SCHEMA, "max_batch": self.max_batch,
                "max_len": self.max_len,
                "predicted_step_s": self.decision_step_s(self.max_batch),
                "events": list(self.trace_events)}
