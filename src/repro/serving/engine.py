"""Slot-based continuous-batching serving engine.

A fixed pool of ``max_batch`` decode slots, each holding one sequence's
KV/state caches at its own position (the decode step takes an (B,) position
vector).  New requests prefill individually (bucketed lengths keep the jit
cache small) and are *inserted* into a free slot's cache region; finished
slots free immediately — no batch-wide barrier, the defining property of
continuous batching.

Everything is jitted once per bucket shape; the engine itself is plain
Python and runs on CPU in the tests/examples with a smoke model.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import gemm as gemm_api
from repro.configs.base import ModelConfig
from repro.models.common import split_params
from repro.models.model import LM


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list            # token ids
    max_new_tokens: int = 16
    eos_id: int | None = None
    generated: list = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        if self.eos_id is not None and self.generated \
                and self.generated[-1] == self.eos_id:
            return True
        return len(self.generated) >= self.max_new_tokens


def _bucket(n: int, buckets=(32, 64, 128, 256, 512, 1024)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return ((n + 1023) // 1024) * 1024


class ServingEngine:
    def __init__(self, lm: LM, params, *, max_batch: int = 4,
                 max_len: int = 512):
        self.lm = lm
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        caches, _ = split_params(lm.init_cache(max_batch, max_len))
        self.caches = caches
        self.slot_pos = [0] * max_batch          # next write position
        self.slot_req: list[Request | None] = [None] * max_batch
        # deque: admission pops from the front per free slot, so the queue
        # must not pay O(n) per admission like list.pop(0) did.
        self.queue: collections.deque[Request] = collections.deque()
        self.finished: list[Request] = []
        self._decode = jax.jit(self._decode_impl)
        self._prefill = {}
        self._insert = jax.jit(self._insert_impl, static_argnums=(2,),
                               donate_argnums=(0,))
        # Frozen GEMM plans for this engine's decode workload (M = the slot
        # pool size): the paper's predict-before-run loop applied to serving,
        # surfaced through perf_report().  Planned lazily on first access so
        # autoconfigure() can install its sweep-chosen plans without the
        # constructor paying for a default pass it would discard;
        # plan_model_gemms is a bulk operation (one batched lattice
        # evaluation over the deduped decode shapes).  On TPU the decode
        # step's pallas plans reach the same tiles through TileTuner's
        # shared search cache.
        self._gemm_plans: list | None = None
        # populated by autoconfigure(): the sweep-chosen operating point and
        # the full ranked DeploymentReport it was selected from.
        self.autoconfig: dict | None = None
        self.deployment_report = None

    @property
    def gemm_plans(self) -> list:
        if self._gemm_plans is None:
            self._gemm_plans = gemm_api.plan_model_gemms(
                self.lm.cfg, tokens=self.max_batch, backend="analytic-tpu")
        return self._gemm_plans

    @gemm_plans.setter
    def gemm_plans(self, plans) -> None:
        self._gemm_plans = list(plans)

    @classmethod
    def autoconfigure(cls, lm: LM, params, *, machine=None,
                      dtypes=("bf16",), batches=(1, 2, 4, 8, 16),
                      max_len: int = 512,
                      backend: str = "analytic-tpu",
                      memory: bool = True,
                      kv_dtype: str | None = None) -> "ServingEngine":
        """Pick ``max_batch``, the deployment machine and the frozen decode
        plans by ranking the whole (machine x dtype x batch) serving grid.

        Wraps :func:`repro.serving.report.plan_deployment`: every cell's
        memory footprint (weights + KV/recurrent state + activation
        workspace, ``repro.serving.footprint``) is checked against the
        machine's deployment-level budget and infeasible cells are pruned
        *before* the ``repro.gemm.sweep`` plans them; among the surviving
        cells, the one maximising predicted decode tokens/second wins —
        ``max_batch`` is therefore the largest batch that both fits memory
        and pays off in throughput, not the fastest-GEMM batch.

        The dtype axis is an analytic what-if over the machine's rate
        table; since the engine really computes in the model's configured
        dtype, the operating point is chosen among that native dtype's
        feasible cells — what-if dtypes inform the ranking only.  If no
        native-dtype cell survives, the overall best feasible cell wins (an
        explicit choice to configure against a foreign dtype).

        Args:
            lm / params: the model the engine will serve.
            machine: machines axis — a name, spec, glob (``"zoo/*"`` ranks
                the whole registry), a list of any of those, or None for
                the backend's default machine.
            dtypes: serving-dtype what-if axis.
            batches: candidate ``max_batch`` values.
            max_len: per-slot cache length (bounds the KV footprint).
            backend: planning backend for the decode-GEMM sweep.
            memory: enforce the deployment-memory budget (default True);
                False restores the pre-memory throughput-only grid.
            kv_dtype: KV-cache dtype override for the footprint model.

        Returns:
            A configured engine.  ``engine.deployment_report`` holds the
            ranked :class:`~repro.serving.report.DeploymentReport`;
            ``engine.autoconfig`` keeps the flat JSON-friendly grid (one
            entry per feasible cell, plus ``rejected`` cells with
            machine-readable reasons) consumed by ``perf_report``.

        Raises:
            ValueError: when every (machine, dtype, batch) cell is memory-
                infeasible — the error lists the per-cell rejection
                reasons.
        """
        from repro.serving.report import plan_deployment

        report = plan_deployment(
            lm.cfg, machines=machine, dtypes=dtypes, batches=batches,
            max_len=max_len, backend=backend, memory=memory,
            kv_dtype=kv_dtype)
        best = report.select()
        eng = cls(lm, params, max_batch=best.batch, max_len=max_len)
        eng.gemm_plans = [r.plan for r in best.rows]
        eng.deployment_report = report
        grid = [{
            "max_batch": o.batch, "machine": o.machine, "dtype": o.dtype,
            "predicted_gemm_seconds_per_step": o.seconds_per_step,
            "predicted_tokens_per_second": o.tokens_per_second,
            "footprint_bytes": o.footprint.total_bytes,
            "memory_budget_bytes": o.budget_bytes,
            "memory_headroom_bytes": o.headroom_bytes,
        } for o in report.options]
        eng.autoconfig = {
            "max_batch": best.batch, "machine": best.machine,
            "dtype": best.dtype, "native_dtype": report.native_dtype,
            "backend": backend,
            "predicted_tokens_per_second": best.tokens_per_second,
            "footprint_bytes": best.footprint.total_bytes,
            "memory_budget_bytes": best.budget_bytes,
            "memory_headroom_bytes": best.headroom_bytes,
            "grid": grid,
            "rejected": [r.as_dict() for r in report.rejected],
        }
        return eng

    def perf_report(self) -> dict:
        """Predicted per-decode-step GEMM cost from the frozen plans."""
        total = sum(p.predicted_seconds for p in self.gemm_plans)
        report = {
            "predicted_gemm_seconds_per_step": total,
            "predicted_tokens_per_second":
                (self.max_batch / total) if total else float("inf"),
            "plans": [p.describe() for p in self.gemm_plans],
        }
        if self.autoconfig is not None:
            report["autoconfig"] = self.autoconfig
        return report

    # -- jitted pieces --------------------------------------------------------
    def _decode_impl(self, params, caches, tokens, pos_vec, active):
        logits, caches = self.lm.decode_step(params, caches, tokens, pos_vec)
        logits = logits.astype(jnp.float32)
        vp = logits.shape[-1]
        if vp > self.lm.cfg.vocab_size:
            logits = logits.at[..., self.lm.cfg.vocab_size:].set(-1e9)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, caches

    def _prefill_fn(self, bucket: int) -> Callable:
        if bucket not in self._prefill:
            def fn(params, tokens):
                _, caches = self.lm.prefill(params, {"tokens": tokens})
                return caches
            self._prefill[bucket] = jax.jit(fn)
        return self._prefill[bucket]

    def _insert_impl(self, caches, pref, slot: int):
        """Insert a single-sequence prefill cache into slot ``slot``.

        Stack caches have batch axis 1 ((periods, B, ...)); tail caches axis
        0.  Sequence axes shorter than the slot's are zero-padded."""
        stack_key = jax.tree_util.DictKey("stack")

        def ins(path, slot_leaf, pref_leaf):
            baxis = 1 if path and path[0] == stack_key else 0
            pl = pref_leaf
            # pad every non-batch dim up to the slot leaf's size
            pads = [(0, 0) if (i == baxis or a == b) else (0, b - a)
                    for i, (a, b) in enumerate(zip(pl.shape, slot_leaf.shape))]
            if any(p[1] for p in pads):
                pl = jnp.pad(pl, pads)
            start = [0] * slot_leaf.ndim
            start[baxis] = slot
            return jax.lax.dynamic_update_slice(
                slot_leaf, pl.astype(slot_leaf.dtype), tuple(start))

        return jax.tree_util.tree_map_with_path(ins, caches, pref)

    # -- public API ---------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self) -> None:
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.popleft()
            ptoks = req.prompt[-self.max_len + req.max_new_tokens:]
            # prefill all but the last prompt token; the first decode step
            # feeds prompt[-1] at position len-1 (cache then logits in one).
            prefix = ptoks[:-1]
            if prefix:
                # recurrent blocks fold every token into their state, so pad
                # tokens would corrupt it: exact-length prefill for those.
                recurrent = any(k in ("mamba2", "mlstm", "slstm")
                                for k in self.lm.cfg.block_pattern)
                bucket = (len(prefix) if recurrent
                          else min(_bucket(len(prefix)), self.max_len))
                toks = jnp.zeros((1, bucket), jnp.int32)
                toks = toks.at[0, :len(prefix)].set(
                    jnp.array(prefix, jnp.int32))
                pref = self._prefill_fn(bucket)(self.params, toks)
                self.caches = self._insert(self.caches, pref, slot)
            self.slot_pos[slot] = len(ptoks) - 1
            self.slot_req[slot] = req

    def step(self) -> list[Request]:
        """Admit + one decode step for all active slots; returns newly
        finished requests."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return []
        tokens = jnp.zeros((self.max_batch, 1), jnp.int32)
        for i in active:
            r = self.slot_req[i]
            last = r.generated[-1] if r.generated else r.prompt[-1]
            tokens = tokens.at[i, 0].set(last)
        # inactive slots decode harmlessly at position 0 (outputs ignored;
        # admission overwrites their cache region)
        pos_vec = jnp.minimum(jnp.array(self.slot_pos, jnp.int32),
                              self.max_len - 1)
        active_mask = jnp.array([r is not None for r in self.slot_req])
        nxt, self.caches = self._decode(self.params, self.caches, tokens,
                                        pos_vec, active_mask)
        out = []
        for i in active:
            r = self.slot_req[i]
            r.generated.append(int(nxt[i]))
            self.slot_pos[i] += 1
            if r.done or self.slot_pos[i] >= self.max_len - 1:
                self.finished.append(r)
                out.append(r)
                self.slot_req[i] = None
                self.slot_pos[i] = 0
        return out

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        for _ in range(max_steps):
            self.step()
            if not self.queue and all(r is None for r in self.slot_req):
                break
        return self.finished
