"""repro.serving subpackage."""
