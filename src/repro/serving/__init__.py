"""``repro.serving`` — continuous batching + memory-aware deployment
planning.

* :class:`ServingEngine` / :class:`Request` — the slot-based
  continuous-batching engine (``engine.py``).
* :func:`footprint` / :class:`Footprint` — the closed-form serving
  memory model: weights + KV/recurrent state + activation workspace per
  ``(model config, batch, dtype)`` (``footprint.py``).
* :func:`plan_deployment` / :class:`DeploymentReport` — rank every
  feasible ``(machine, dtype, batch)`` cell of the zoo by predicted decode
  throughput, pruning memory-infeasible cells before the GEMM sweep
  (``report.py``); ``ServingEngine.autoconfigure`` freezes an engine from
  the winning cell, and ``python -m repro.serving plan`` prints the report
  without instantiating a model.
* ``resilience.py`` — overload primitives shared with the simulator:
  shed-cause vocabulary, :class:`QueueFullError` +
  :func:`retry_with_backoff` backpressure, and the
  :class:`DegradationRung` ladder (see ``docs/RESILIENCE.md``).

The engine and report modules import jax (and, for the engine, the model
zoo); they load lazily so the config-only analytic surfaces
(``footprint``, the ``python -m repro.serving`` CLI startup) stay light.
"""
import importlib

from repro.serving.buckets import PREFILL_BUCKETS, bucket_cover, bucket_len
from repro.serving.footprint import Footprint, dtype_bytes, footprint
from repro.serving.resilience import (SHED_CAUSES, SHED_DEADLINE_EXPIRED,
                                      SHED_DEADLINE_UNMEETABLE,
                                      SHED_QUEUE_FULL, DegradationRung,
                                      QueueFullError, default_ladder,
                                      retry_with_backoff)

_LAZY = {
    "DrainTruncatedError": "repro.serving.engine",
    "Request": "repro.serving.engine",
    "ServingEngine": "repro.serving.engine",
    "TRACE_SCHEMA": "repro.serving.engine",
    "CellRejection": "repro.serving.report",
    "DeploymentOption": "repro.serving.report",
    "DeploymentReport": "repro.serving.report",
    "plan_deployment": "repro.serving.report",
}

__all__ = [
    "CellRejection", "DegradationRung", "DeploymentOption",
    "DeploymentReport", "DrainTruncatedError", "Footprint",
    "PREFILL_BUCKETS", "QueueFullError", "Request", "SHED_CAUSES",
    "SHED_DEADLINE_EXPIRED", "SHED_DEADLINE_UNMEETABLE", "SHED_QUEUE_FULL",
    "ServingEngine", "TRACE_SCHEMA", "bucket_cover", "bucket_len",
    "default_ladder", "dtype_bytes", "footprint", "plan_deployment",
    "retry_with_backoff",
]


def __getattr__(name):
    if name in _LAZY:
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
