"""The machine registry: zoo manifests + runtime registration + globs.

The zoo (``repro/machines/zoo/*.json``) is loaded lazily on first access;
every manifest becomes a registered :class:`MachineSpec`.  Calibrated or
derived machines register at runtime (``register``), names can be aliased
(``alias``), and consumers resolve machines by name, by spec object, or by
glob patterns — ``"zoo/*"`` matches every manifest-backed machine,
``"gap*"`` fnmatch-globs all registered names.

Glob expansion is deterministic: patterns always expand over the *sorted*
registry, so repeated sweeps over the same registry contents return rows in
the same order.  Generated machines (``repro.design``) register under a
literal ``gen/`` name prefix — ``"gen/*"`` globs them like any other
pattern, and ``unregister_prefix("gen/")`` bulk-drops them for test/CLI
cleanup.
"""
from __future__ import annotations

import fnmatch
import glob as _glob
import os
from typing import Iterable

from repro.machines.spec import MachineSpec

_REGISTRY: dict[str, MachineSpec] = {}
_ALIASES: dict[str, str] = {}
_SOURCES: dict[str, str] = {}       # name -> "zoo" | "runtime" | "calibrated"
_GLOB_CHARS = ("*", "?", "[")
_zoo_loaded = False


def zoo_dir() -> str:
    """The built-in manifest directory."""
    return os.path.join(os.path.dirname(__file__), "zoo")


def _ensure_zoo() -> None:
    global _zoo_loaded
    if not _zoo_loaded:
        _zoo_loaded = True
        load_zoo()


def load_zoo(directory: str | None = None, *,
             source: str = "zoo") -> list[str]:
    """Register every ``*.json`` manifest in ``directory`` (default: the
    built-in zoo).  Returns the registered names, manifest-path order."""
    global _zoo_loaded
    directory = directory or zoo_dir()
    if os.path.abspath(directory) != os.path.abspath(zoo_dir()):
        # a custom manifest dir *adds to* the registry; it must not stand in
        # for the built-in zoo, which still loads (once) underneath it.
        _ensure_zoo()
    _zoo_loaded = True
    names = []
    for path in sorted(_glob.glob(os.path.join(directory, "*.json"))):
        spec = MachineSpec.from_manifest(path)
        register(spec, overwrite=True, source=source)
        names.append(spec.name)
    return names


def register(spec: MachineSpec, *, overwrite: bool = False,
             source: str = "runtime") -> MachineSpec:
    """Validate + register a spec under its name."""
    _ensure_zoo()
    spec.validate()
    if spec.name in _ALIASES:
        raise ValueError(f"machine name {spec.name!r} is taken by an alias "
                         f"for {_ALIASES[spec.name]!r}")
    if spec.name in _REGISTRY and not overwrite:
        raise ValueError(f"machine {spec.name!r} already registered; pass "
                         f"overwrite=True to replace it")
    _REGISTRY[spec.name] = spec
    _SOURCES[spec.name] = source
    return spec


def unregister(name: str) -> None:
    """Drop a machine (and any aliases pointing at it)."""
    _ensure_zoo()
    _REGISTRY.pop(name, None)
    _SOURCES.pop(name, None)
    for a, target in list(_ALIASES.items()):
        if a == name or target == name:
            del _ALIASES[a]


def unregister_prefix(prefix: str) -> list[str]:
    """Drop every registered machine whose name starts with ``prefix``
    (and any aliases pointing at one).  Returns the dropped names, sorted.

    The canonical use is ``unregister_prefix("gen/")`` after a generated
    design-space sweep (`repro.design`), so bulk registration never leaks
    into later sweeps or tests.
    """
    _ensure_zoo()
    if not prefix:
        raise ValueError("refusing to unregister an empty prefix (that "
                         "would drop the whole registry)")
    dropped = sorted(n for n in _REGISTRY if n.startswith(prefix))
    for name in dropped:
        unregister(name)
    return dropped


def get(name: str) -> MachineSpec:
    """Look a machine up by name (alias-aware)."""
    _ensure_zoo()
    name = _ALIASES.get(name, name)
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown machine {name!r}; registered: "
                       f"{list_machines()}") from None


def alias(name: str, target: str) -> None:
    """Make ``name`` resolve to the registered machine ``target``."""
    _ensure_zoo()
    if name in _REGISTRY:
        raise ValueError(f"alias {name!r} would shadow a registered machine")
    get(target)                     # must exist (and resolves chains eagerly)
    _ALIASES[name] = _ALIASES.get(target, target)


def list_machines(pattern: str | None = None) -> list[str]:
    """Registered machine names, optionally filtered by a glob pattern.
    ``"zoo/<glob>"`` (or bare ``"zoo/*"``) restricts to manifest-backed
    machines; any other pattern fnmatch-globs all names."""
    _ensure_zoo()
    names = sorted(_REGISTRY)
    if pattern is None:
        return names
    if pattern == "zoo" or pattern.startswith("zoo/"):
        sub = pattern[4:] or "*"
        return [n for n in names
                if _SOURCES.get(n) == "zoo" and fnmatch.fnmatch(n, sub)]
    return [n for n in names if fnmatch.fnmatch(n, pattern)]


def expand(entry) -> list:
    """Expand one machines-axis entry for ``repro.gemm.sweep``: a
    :class:`MachineSpec` or None passes through, a glob pattern expands to
    the matching registered names, a plain name is validated and
    canonicalized (aliases resolve)."""
    if entry is None or isinstance(entry, MachineSpec):
        return [entry]
    if not isinstance(entry, str):
        raise TypeError(f"cannot interpret {entry!r} as a machine; pass a "
                        f"name, a MachineSpec, or a glob pattern")
    if entry.startswith("zoo/") or any(c in entry for c in _GLOB_CHARS):
        names = list_machines(entry)
        if not names:
            raise KeyError(f"machine pattern {entry!r} matched nothing; "
                           f"registered: {list_machines()}")
        return names
    return [get(entry).name]


def expand_many(entries: Iterable | str | MachineSpec | None) -> list:
    """Expand a machines axis (None, a single entry, or a sequence)."""
    if entries is None:
        return [None]
    if isinstance(entries, (str, MachineSpec)):
        entries = [entries]
    out: list = []
    for e in entries:
        out.extend(expand(e))
    return out


def resolve(machine, default: str | None = None) -> MachineSpec:
    """Resolve a machine argument (name | spec | None-with-default) to a
    :class:`MachineSpec`."""
    if machine is None:
        if default is None:
            raise ValueError("no machine given and no default to fall back "
                             "to")
        machine = default
    if isinstance(machine, MachineSpec):
        return machine
    return get(machine)


def source_of(name: str) -> str | None:
    """Where a registered machine came from ("zoo" | "runtime" |
    "calibrated"), or None if unknown."""
    _ensure_zoo()
    return _SOURCES.get(_ALIASES.get(name, name))
