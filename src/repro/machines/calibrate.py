"""The calibrate→register→plan pipeline (paper §3.2, made first-class).

The paper builds a machine from a handful of micro-experiments; this module
closes the loop so a calibrated spec feeds the planner instead of vanishing:

1. **measure** — :meth:`Calibrator.measure_host` wraps the
   ``repro.core.calibrate`` micro-experiments (packing / copy / arithmetic
   rates) into a seed :class:`MachineSpec`.
2. **fit** — :meth:`Calibrator.fit` refines a spec against measured GEMM
   wall times.  The simulators are *linear in the inverse rates*: a GEMM's
   predicted time is ``sum_r bytes_r / rate_r + flops / arith``, so fitting
   all rates at once is one least-squares solve ``A x = t`` where ``x`` are
   inverse rates and the design matrix ``A`` comes from the **batched**
   engines (``traffic_terms_batch`` for the BLIS-variant model,
   ``estimate_batch`` for the Pallas/TPU model) — no scalar per-sample
   loops.  ``design_matrix_scalar`` replays the same accounting through the
   scalar simulators and is kept as the equivalence oracle for the tests.
3. **register / persist** — the fitted spec lands in the
   :mod:`repro.machines` registry and (optionally) a JSON manifest, carrying
   fit provenance: RMS residual, sample count, and the calibration date
   passed in by the caller.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Mapping, Sequence

import numpy as np

from repro import obs
from repro.machines import registry as _registry
from repro.machines.spec import MachineSpec


def _traced_fit(fn):
    """Wrap :meth:`Calibrator.fit` in an ``obs`` span carrying the fit's
    headline numbers — a refit shows up on the same timeline as the
    sweeps and serving steps it recalibrates."""
    @functools.wraps(fn)
    def wrapped(self, *args, **kwargs):
        with obs.span("calibrate.fit", template=self.template.name,
                      model=self.model) as sp:
            spec, report = fn(self, *args, **kwargs)
            sp.set(samples=report.samples, columns=len(report.columns),
                   residual_rms_s=report.residual_rms_s)
            obs.metrics.counter("calibrate.fits")
            return spec, report
    return wrapped

_RATE = "rate:"
_ARITH = "arith:"
#: design column of the opt-in per-block constant-overhead term
#: (``overhead_per_block=True``): coefficient = micro-kernel invocation
#: count, solution = seconds per innermost dispatch.
OVERHEAD_COL = "overhead:block"

#: rate assigned to design columns the fit marks as effectively free
#: (on_nonpositive="free"): large enough that the term contributes ~nothing,
#: finite so the spec still validates.
FREE_RATE = 1.0e18


@dataclasses.dataclass(frozen=True)
class FitReport:
    """Provenance of one vectorized rate fit."""

    columns: list[str]          # "rate:M->L2" / "arith:int8" design columns
    inverse_rates: np.ndarray   # the lstsq solution x (seconds per byte/op;
                                # NaN for dropped columns)
    residual_rms_s: float       # RMS of (A@x - t) over the samples
    samples: int
    date: str | None
    # columns the measurements could not support (solved non-positive) and
    # that fit(on_nonpositive="drop") eliminated; their template rates stand.
    dropped: list[str] = dataclasses.field(default_factory=list)
    # the robust estimator used ("huber" / "trim"), None for plain lstsq
    robust: str | None = None
    # sample indices the robust solve down-weighted below 0.5 — the rows it
    # treated as outliers; residual_rms_s excludes them when robust is set
    outliers: list[int] = dataclasses.field(default_factory=list)
    # fitted constant cost per innermost micro-kernel dispatch
    # (overhead_per_block=True); None when the column was not requested or
    # was dropped.  Lives in provenance only — the spec's rate tables stay
    # pure rates, and the simulators do not charge it.
    overhead_per_block_s: float | None = None
    # in-sample MAPE of the fitted design-matrix model over the samples the
    # fit trusted (inliers under robust) — comparable across fits with and
    # without the overhead column.
    insample_mape_pct: float | None = None

    def as_provenance(self) -> dict[str, Any]:
        d = {
            "method": "vectorized-lstsq",
            "columns": list(self.columns),
            "residual_rms_s": float(self.residual_rms_s),
            "samples": int(self.samples),
            "date": self.date,
        }
        if self.dropped:
            d["dropped_columns"] = list(self.dropped)
        if self.robust:
            d["robust"] = self.robust
            d["outlier_samples"] = [int(i) for i in self.outliers]
        if self.overhead_per_block_s is not None:
            d["overhead_per_block_s"] = float(self.overhead_per_block_s)
        if self.insample_mape_pct is not None:
            d["insample_mape_pct"] = float(self.insample_mape_pct)
        return d


def _robust_weights(A: np.ndarray, b: np.ndarray, kind: str,
                    trim_fraction: float) -> np.ndarray:
    """Outlier-resistant row weights via IRLS on the full-column system.

    Solve, measure residuals, re-weight, repeat until the weights settle.
    ``"huber"`` gives weight 1 to rows within 1.345 robust standard
    deviations (MAD scale) and ``k*scale/|r|`` beyond — a smooth
    down-weighting; ``"trim"`` is least-trimmed-squares: the worst
    ``trim_fraction`` of rows get weight exactly 0.  The weights live in
    the solve's weighting space, so under ``weighting="relative"`` a
    20x-slow thermal outlier has a 20x residual no matter how small the
    cell — which is exactly what makes it separable from honest noise.
    """
    n, p = A.shape
    w = np.ones(n)
    for _ in range(50):
        sw = np.sqrt(w)
        x, *_ = np.linalg.lstsq(A * sw[:, None], b * sw, rcond=None)
        r = np.abs(b - A @ x)
        if kind == "huber":
            med = float(np.median(r))
            scale = 1.4826 * float(np.median(np.abs(r - med)))
            if scale <= 0.0:
                # majority of rows fit exactly (synthetic data): any scale
                # dominated by the outliers keeps z tiny for the exact rows
                scale = max(float(np.mean(r)), 1e-300)
            z = r / scale
            w_new = np.minimum(1.0, 1.345 / np.maximum(z, 1e-300))
        else:  # trim
            keep_n = int(np.ceil((1.0 - trim_fraction) * n))
            keep_n = min(max(keep_n, p + 1), n)
            thresh = np.partition(r, keep_n - 1)[keep_n - 1]
            w_new = (r <= thresh).astype(np.float64)
        if np.allclose(w_new, w, rtol=0.0, atol=1e-6):
            return w_new
        w = w_new
    return w


class Calibrator:
    """Fit a machine's rate tables from measured GEMM times.

    ``template`` (name or spec) supplies the geometry — levels, capacities,
    register file — and any rates the fit does not exercise.  ``model``
    picks the cost model the design matrix replays: ``"blis"`` (the paper's
    variant simulator; default for int8-style scratchpad machines) or
    ``"pallas"`` (the TPU tile model; default when the template declares a
    ``bf16`` rate).
    """

    def __init__(self, template, *, model: str | None = None,
                 variant=None, micro_kernel=None, policy: str = "analytic"):
        from repro.core.variants import Variant, feasible_microkernels

        self.template = _registry.resolve(template)
        if model is None:
            model = "pallas" if "bf16" in self.template.arith_rate else "blis"
        if model not in ("blis", "pallas"):
            raise ValueError(f"unknown cost model {model!r}; "
                             f"use 'blis' or 'pallas'")
        self.model = model
        self.policy = policy
        if model == "blis":
            self.variant = variant or Variant.B3A2C0
            cands = feasible_microkernels(self.template, self.variant)
            if micro_kernel is None:
                if not cands:
                    raise ValueError(
                        f"{self.template.name}: no feasible micro-kernel to "
                        f"calibrate with")
                micro_kernel = cands[0]
            self.micro_kernel = micro_kernel
        else:
            self.variant = None
            self.micro_kernel = None

    # -- design matrices ------------------------------------------------------

    def _coerce_problems(self, problems) -> list:
        from repro.gemm.api import GemmProblem
        default = "int8" if self.model == "blis" else "bf16"
        return [GemmProblem.coerce(p, default_dtype=default)
                for p in problems]

    def _coerce_mks(self, probs, micro_kernels) -> list:
        from repro.core.variants import MicroKernel
        if micro_kernels is None:
            return [self.micro_kernel] * len(probs)
        mks = [mk if isinstance(mk, MicroKernel)
               else MicroKernel(int(mk[0]), int(mk[1]))
               for mk in micro_kernels]
        if len(mks) != len(probs):
            raise ValueError(f"{len(probs)} problems vs {len(mks)} "
                             f"micro-kernels")
        return mks

    def design_matrix(self, problems, micro_kernels=None, *,
                      per_mk_arith: bool = False,
                      overhead_per_block: bool = False
                      ) -> tuple[np.ndarray, list[str]]:
        """(samples x columns) coefficients of the inverse rates, built with
        the batched engines — one vectorized evaluation for all samples.

        For the BLIS model, ``micro_kernels`` optionally gives a per-sample
        micro-kernel.  Calibration samples should span several micro-kernel
        shapes: under a single one every register-streaming term and the
        arithmetic term are exactly proportional to ``m*n*k``, which makes
        the system rank-deficient (the paper's calibration likewise varies
        the micro-kernel across its experiments).

        ``per_mk_arith`` splits the arithmetic column per (dtype,
        micro-kernel) — the paper-§4 refinement — so the fit lands an
        ``arith_per_mk`` table instead of one rate per dtype.  Caveat:
        under the *analytic* policy with a single dtype the register
        streaming terms are exactly proportional to ``m*n*k`` within each
        micro-kernel group, i.e. collinear with the per-mk arithmetic
        columns, and :meth:`fit` will correctly refuse the rank-deficient
        system — calibrate per-mk rates from ``padded``-policy samples
        (the ceil trip counts break the proportionality, mirroring a real
        edge-tiled implementation) or measure them directly like the paper.

        ``overhead_per_block`` (BLIS model) appends the carried-over
        constant-cost column :data:`OVERHEAD_COL`: its coefficient is the
        per-sample micro-kernel invocation count, so the solved entry is
        seconds per innermost dispatch — loop bookkeeping the pure rate
        model attributes (wrongly) to traffic on small blocks.
        """
        probs = self._coerce_problems(problems)
        if self.model == "blis":
            return self._design_blis_batch(
                probs, self._coerce_mks(probs, micro_kernels), per_mk_arith,
                overhead_per_block)
        if micro_kernels is not None:
            raise ValueError("micro_kernels only applies to the blis model")
        if per_mk_arith:
            raise ValueError("per_mk_arith only applies to the blis model")
        if overhead_per_block:
            raise ValueError("overhead_per_block only applies to the blis "
                             "model")
        return self._design_pallas_batch(probs)

    @staticmethod
    def _arith_tag(p) -> str:
        """Arithmetic design-column tag: the PrecisionConfig key for a
        mixed-precision sample (fitted into ``rates_mixed``), the dtype
        otherwise.  Coerced ``GemmProblem``s normalize uniform configs to
        ``precision=None``, so ``precision is not None`` means mixed."""
        return p.precision.key() if p.precision is not None else p.dtype

    @staticmethod
    def _check_mixed(probs, *, per_mk_arith: bool, model: str) -> None:
        if not any(p.precision is not None for p in probs):
            return
        if model == "pallas":
            raise ValueError(
                "mixed-precision calibration samples need the 'blis' cost "
                "model; the pallas design matrix folds quantize traffic "
                "into hbm_bytes and cannot separate a per-config rate")
        if per_mk_arith:
            raise ValueError(
                "per_mk_arith cannot be combined with mixed-precision "
                "samples: rates_mixed is a flat per-config table, not a "
                "per-micro-kernel one")

    def _design_blis_batch(self, probs, mks, per_mk_arith: bool = False,
                           overhead_per_block: bool = False):
        from repro.core.variants import (
            derive_blocking_batch,
            microkernel_invocations_batch,
            quant_ratio_arrays,
            traffic_terms_batch,
        )

        self._check_mixed(probs, per_mk_arith=per_mk_arith, model="blis")
        mach = self.template
        # per-sample (P,) arrays: micro-kernel dims align elementwise with
        # the problems, so every batched closed form broadcasts to (P,).
        rows = np.array([mk.rows for mk in mks], np.int64)
        cols = np.array([mk.cols for mk in mks], np.int64)
        m = np.array([p.m for p in probs], np.int64)
        n = np.array([p.n for p in probs], np.int64)
        k = np.array([p.k for p in probs], np.int64)
        s = np.array([p.elem_bytes for p in probs], np.int64)
        blk = derive_blocking_batch(self.variant, rows, cols, mach,
                                    m, n, k, s)
        # quant ratios come as (P, 1) lattice columns; this design matrix
        # broadcasts everything at (P,), so squeeze them to match.
        qa = quant_ratio_arrays(probs)
        if qa is not None:
            qa = {op: col[:, 0] for op, col in qa.items()}
        terms = traffic_terms_batch(self.variant, rows, cols, blk,
                                    m, n, k, s, policy=self.policy,
                                    quant=qa)
        cols_map: dict[str, np.ndarray] = {}
        for t in terms:
            key = (f"{_RATE}{mach.level(t.origin)}->"
                   f"{mach.level(t.dest)}")
            coeff = np.broadcast_to(t.bytes, (len(probs),)).astype(np.float64)
            if t.chunk is not None:
                # time = bytes / (rate * chunk/ref): fold the chunk scaling
                # into the coefficient of x = 1/rate.
                chunk = np.broadcast_to(np.asarray(t.chunk, np.float64),
                                        (len(probs),))
                coeff = coeff * (mach.reference_chunk / chunk)
            cols_map[key] = cols_map.get(key, 0.0) + coeff
        flops = np.array([p.flops for p in probs], np.float64)
        if per_mk_arith:
            # one column per (dtype, micro-kernel), in first-seen sample
            # order (mirrors the scalar oracle's insertion order).
            for dt, mk_s in dict.fromkeys(
                    (p.dtype, str(mk)) for p, mk in zip(probs, mks)):
                sel = np.array([p.dtype == dt and str(mk) == mk_s
                                for p, mk in zip(probs, mks)], np.float64)
                cols_map[f"{_ARITH}{dt}@{mk_s}"] = sel * flops
        else:
            for dt in sorted({self._arith_tag(p) for p in probs}):
                sel = np.array([self._arith_tag(p) == dt for p in probs],
                               np.float64)
                cols_map[f"{_ARITH}{dt}"] = sel * flops
        if overhead_per_block:
            cols_map[OVERHEAD_COL] = np.broadcast_to(
                microkernel_invocations_batch(
                    self.variant, rows, cols, blk, m, n, k,
                    policy=self.policy),
                (len(probs),)).astype(np.float64)
        names = list(cols_map)
        return np.stack([cols_map[c] for c in names], axis=1), names

    def _design_pallas_batch(self, probs):
        from repro.core.autotune import tune_batch

        self._check_mixed(probs, per_mk_arith=False, model="pallas")
        from repro.core.tpu_model import (
            DTYPE_BYTES,
            GridOrder,
            SUBLANE,
            estimate_batch,
            machine_peak,
        )

        mach = self.template
        shapes = [p.as_shape() for p in probs]
        tiles = [d.tile for d in tune_batch(shapes, machine=mach)]
        m = np.array([p.m for p in probs], np.int64)
        n = np.array([p.n for p in probs], np.int64)
        k = np.array([p.k for p in probs], np.int64)
        s = np.array([DTYPE_BYTES[p.dtype] for p in probs], np.int64)
        sub = np.array([SUBLANE[p.dtype] for p in probs], np.int64)
        peak = np.array([machine_peak(mach, p.dtype) for p in probs],
                        np.float64)
        bm = np.array([t.bm for t in tiles], np.int64)
        bn = np.array([t.bn for t in tiles], np.int64)
        bk = np.array([t.bk for t in tiles], np.int64)
        inner = np.array([t.order is GridOrder.K_INNER for t in tiles], bool)
        costs = estimate_batch(m, n, k, s, sub, peak, bm, bn, bk, inner,
                               machine=mach)
        cols_map: dict[str, np.ndarray] = {
            f"{_RATE}{mach.level('M')}->{mach.level('L1')}":
                np.asarray(costs.hbm_bytes, np.float64),
            f"{_RATE}{mach.level('L1')}->{mach.level('R')}":
                np.asarray(costs.vmem_bytes, np.float64),
        }
        # t_compute = flops / (peak * eff) -> coefficient of 1/peak.
        flops = 2.0 * (m * n * k).astype(np.float64)
        for dt in sorted({p.dtype for p in probs}):
            sel = np.array([p.dtype == dt for p in probs], np.float64)
            tag = "bf16" if dt == "f32" else dt
            cols_map[f"{_ARITH}{tag}"] = cols_map.get(
                f"{_ARITH}{tag}", 0.0) + sel * flops / np.asarray(
                    costs.mxu_efficiency, np.float64)
        names = list(cols_map)
        return np.stack([cols_map[c] for c in names], axis=1), names

    def design_matrix_scalar(self, problems,
                             micro_kernels=None, *,
                             per_mk_arith: bool = False,
                             overhead_per_block: bool = False
                             ) -> tuple[np.ndarray, list[str]]:
        """The per-sample scalar-loop design matrix, kept as the reference
        oracle the vectorized :meth:`design_matrix` must agree with
        (the tests assert exact equality)."""
        probs = self._coerce_problems(problems)
        self._check_mixed(probs, per_mk_arith=per_mk_arith,
                          model=self.model)
        mach = self.template
        cols_map: dict[str, list[float]] = {}
        rows_acc: list[dict[str, float]] = []
        if self.model == "blis":
            from repro.core.variants import (
                derive_blocking,
                microkernel_invocations,
                traffic_terms,
            )
            mks = self._coerce_mks(probs, micro_kernels)
            for p, mk in zip(probs, mks):
                pr = p.as_problem()
                blk = derive_blocking(self.variant, mk, mach, pr)
                row: dict[str, float] = {}
                for t in traffic_terms(self.variant, mk, blk,
                                       pr, policy=self.policy):
                    key = (f"{_RATE}{mach.level(t.origin)}->"
                           f"{mach.level(t.dest)}")
                    coeff = t.bytes
                    if t.chunk is not None:
                        coeff = coeff * (mach.reference_chunk / t.chunk)
                    row[key] = row.get(key, 0.0) + coeff
                arith_key = f"{_ARITH}{p.dtype}@{mk}" if per_mk_arith \
                    else f"{_ARITH}{self._arith_tag(p)}"
                row[arith_key] = pr.flops
                if overhead_per_block:
                    row[OVERHEAD_COL] = microkernel_invocations(
                        self.variant, mk, blk, pr, policy=self.policy)
                rows_acc.append(row)
        elif overhead_per_block:
            raise ValueError("overhead_per_block only applies to the blis "
                             "model")
        else:
            from repro.core.autotune import tune_batch
            from repro.core.tpu_model import estimate
            for p in probs:
                shape = p.as_shape()
                tile = tune_batch([shape], machine=mach)[0].tile
                c = estimate(shape, tile, mach)
                tag = "bf16" if p.dtype == "f32" else p.dtype
                rows_acc.append({
                    f"{_RATE}{mach.level('M')}->{mach.level('L1')}":
                        c.hbm_bytes,
                    f"{_RATE}{mach.level('L1')}->{mach.level('R')}":
                        c.vmem_bytes,
                    f"{_ARITH}{tag}": shape.flops / c.mxu_efficiency,
                })
        arith_keys: list[str] = []
        for row in rows_acc:
            for key in row:
                if key == OVERHEAD_COL:     # always the last column, as in
                    continue                # the batched builder
                if key.startswith(_ARITH):
                    if key not in arith_keys:
                        arith_keys.append(key)
                else:
                    cols_map.setdefault(key, [])
        # shared arith columns are sorted by tag in the batched builder;
        # per-mk columns keep first-seen sample order there too.
        for key in (arith_keys if per_mk_arith else sorted(arith_keys)):
            cols_map.setdefault(key, [])
        if overhead_per_block:
            cols_map.setdefault(OVERHEAD_COL, [])
        names = list(cols_map)
        A = np.zeros((len(rows_acc), len(names)))
        for i, row in enumerate(rows_acc):
            for j, key in enumerate(names):
                A[i, j] = row.get(key, 0.0)
        return A, names

    def _template_rate(self, col: str) -> float:
        """The template's rate for one design column (what a dropped column
        keeps charging under ``on_nonpositive="drop"``)."""
        if col == OVERHEAD_COL:
            return FREE_RATE        # templates charge no per-block overhead
        if col.startswith(_RATE):
            o, _, d = col[len(_RATE):].partition("->")
            return self.template.transfer_rates[(o, d)]
        dt, sep, mk_s = col[len(_ARITH):].partition("@")
        if "->" in dt:           # mixed-precision column, key "AxB->ACC"
            from repro.core.precision import PrecisionConfig
            return self.template.arith_rate_mixed(
                dt, PrecisionConfig.parse(dt).compute_dtype)
        return self.template.arith_rate_for(dt, mk_s if sep else None)

    # -- the fit --------------------------------------------------------------

    @_traced_fit
    def fit(self, problems, seconds: Sequence[float], *, date: str | None,
            micro_kernels=None, name: str | None = None,
            register: bool = False, manifest_dir: str | None = None,
            per_mk_arith: bool = False, overhead_per_block: bool = False,
            on_nonpositive: str = "raise",
            weighting: str = "absolute",
            robust: str | None = None, trim_fraction: float = 0.1,
            extra_provenance: Mapping[str, Any] | None = None,
            ) -> tuple[MachineSpec, FitReport]:
        """One vectorized least-squares solve over all samples.

        Args:
            problems: measured GEMM problems (anything ``GemmProblem``
                coerces); one per entry of ``seconds``.
            seconds: measured wall times, aligned with ``problems``.
            date: calibration date to record in provenance.  Required —
                pass None *explicitly* to record an undated fit; the
                Calibrator never invents timestamps.
            micro_kernels: per-sample micro-kernels (BLIS model).  Pass a
                set spanning several shapes — a single-mk sample set is
                provably rank-deficient (see :meth:`design_matrix`).
                Samples carrying a mixed :class:`PrecisionConfig` (BLIS
                model only) contribute quantize-traffic coefficients to
                the transfer-rate columns and fit one ``arith:<key>``
                column per config, landing in the spec's ``rates_mixed``
                table; ``per_mk_arith`` cannot be combined with them.
            name: name for the fitted spec (default: template name).
            register: land the fitted spec in the registry (source
                ``"calibrated"``).
            manifest_dir: also persist the spec as ``<dir>/<name>.json``.
            per_mk_arith: fit the paper-§4 per-micro-kernel arithmetic
                table instead of one rate per dtype.
            overhead_per_block: also fit a constant cost per innermost
                micro-kernel dispatch (the :data:`OVERHEAD_COL` design
                column).  The solved value is recorded as
                ``FitReport.overhead_per_block_s`` and in the spec's fit
                provenance; the spec's rate tables are unchanged by it (the
                simulators charge rates only), so it is an *attribution*
                refinement: overhead seconds stop polluting the fitted
                rates of small-block samples.
            on_nonpositive: what to do when a column solves non-positive
                (the measurements assign that cost-model term no, or
                negative, cost).  ``"raise"`` refuses to emit a garbage
                spec; ``"drop"`` eliminates offending columns iteratively
                and keeps the template's rates for them (the term is real
                but these samples cannot see it); ``"free"`` likewise
                eliminates them but sets their rates to :data:`FREE_RATE`
                so the term costs ~nothing (the right attribution for
                machines that overlap that traffic with compute).  Either
                way the drop is recorded in provenance.
            weighting: ``"absolute"`` (default) solves plainly — exact on
                synthetic samples; ``"relative"`` solves in units of
                relative error (each row divided by its measured time) so
                a microsecond cell counts as much as a second cell — the
                right loss when the goal is MAPE over a wide-dynamic-range
                workload.
            robust: ``None`` (default) is the plain solve.  ``"huber"``
                down-weights outlier samples smoothly (IRLS, k=1.345, MAD
                scale); ``"trim"`` zeroes the worst ``trim_fraction`` of
                rows (least-trimmed-squares).  Use on field campaigns where
                a slice of the samples is corrupted — thermal throttling,
                a background process — and would otherwise drag every rate:
                the weights are computed once on the full-column system and
                the flagged rows are recorded in ``FitReport.outliers``.
            trim_fraction: fraction of rows ``robust="trim"`` discards
                (default 0.1); must be in [0, 0.5).
            extra_provenance: merged into the fitted spec's provenance.

        Returns:
            ``(fitted_spec, fit_report)`` — the spec with refreshed rate
            tables and the :class:`FitReport` recording columns, inverse
            rates, residual RMS and drops.

        Raises:
            ValueError: mismatched problems/seconds lengths, an
                under-determined or rank-deficient design matrix,
                non-positive rates under ``on_nonpositive="raise"``,
                non-positive measured times under relative weighting, or
                an unknown ``on_nonpositive`` / ``weighting`` value.
        """
        if on_nonpositive not in ("raise", "drop", "free"):
            raise ValueError(f"on_nonpositive must be 'raise', 'drop' or "
                             f"'free', got {on_nonpositive!r}")
        if weighting not in ("absolute", "relative"):
            raise ValueError(f"weighting must be 'absolute' or 'relative', "
                             f"got {weighting!r}")
        if robust not in (None, "huber", "trim"):
            raise ValueError(f"robust must be None, 'huber' or 'trim', "
                             f"got {robust!r}")
        if robust == "trim" and not 0.0 <= trim_fraction < 0.5:
            raise ValueError(f"trim_fraction must be in [0, 0.5), "
                             f"got {trim_fraction!r}")
        t = np.asarray(list(seconds), np.float64)
        A, columns = self.design_matrix(problems, micro_kernels,
                                        per_mk_arith=per_mk_arith,
                                        overhead_per_block=overhead_per_block)
        if A.shape[0] != t.shape[0]:
            raise ValueError(f"{A.shape[0]} problems vs {t.shape[0]} "
                             f"measured times")
        if A.shape[0] < A.shape[1]:
            raise ValueError(
                f"under-determined fit: {A.shape[0]} samples for "
                f"{A.shape[1]} rate columns {columns}")
        if weighting == "relative" and np.any(t <= 0.0):
            raise ValueError("relative weighting needs strictly "
                             "positive measured times")
        Aw = A / t[:, None] if weighting == "relative" else A
        keep = list(range(len(columns)))
        dropped: list[int] = []

        def solve_target() -> np.ndarray:
            # under "drop" the emitted spec keeps charging the template rate
            # for dropped terms, so the kept columns must be solved against
            # the measured times *minus* that charge — otherwise the
            # re-solve absorbs the dropped term's time into the kept rates
            # and the spec double-counts it.  "free" terms charge ~nothing.
            adj = t
            if dropped and on_nonpositive == "drop":
                inv = np.array([1.0 / self._template_rate(columns[i])
                                for i in dropped])
                adj = t - A[:, dropped] @ inv
            return adj / t if weighting == "relative" else adj

        # robust row weights, computed once on the full-column system (the
        # outlier verdict should not depend on which columns later drop);
        # applied as sqrt-row-scaling so the lstsq below minimizes the
        # weighted loss.
        rw = np.ones(len(t))
        if robust is not None:
            rw = _robust_weights(Aw, solve_target(), robust, trim_fraction)
        sw = np.sqrt(rw)

        while True:
            x, _, rank, _ = np.linalg.lstsq(Aw[:, keep] * sw[:, None],
                                            solve_target() * sw,
                                            rcond=None)
            if rank < len(keep):
                kept_cols = [columns[i] for i in keep]
                raise ValueError(
                    f"rank-deficient fit (rank {rank} < {len(keep)} columns "
                    f"{kept_cols}): the samples cannot separate the rates — "
                    f"vary the micro-kernels and problem shapes "
                    f"(see design_matrix)")
            bad = [i for i, xi in zip(keep, x) if xi <= 0.0]
            if not bad:
                break
            if on_nonpositive == "raise":
                raise ValueError(
                    f"fit produced non-positive inverse rates for "
                    f"{[columns[i] for i in bad]}; the measured times are "
                    f"inconsistent with the cost model — not registering a "
                    f"garbage spec (pass on_nonpositive='drop' to keep the "
                    f"template's rates for those columns)")
            # NNLS-style: eliminate only the most-negative column per
            # iteration — a near-collinear partner may solve positive once
            # the worst offender is gone.
            worst = min(zip(keep, x), key=lambda kx: kx[1])[0]
            dropped.append(worst)
            keep.remove(worst)
            if not keep:
                raise ValueError(
                    "every design column solved non-positive — the measured "
                    "times are inconsistent with the cost model")
        # the residual is always reported in absolute seconds for the spec
        # actually emitted: dropped columns still contribute at the rate the
        # spec keeps for them (template rate under "drop", ~0 under "free").
        pred = A[:, keep] @ x
        if dropped:
            fallback = 1.0 / FREE_RATE if on_nonpositive == "free" else None
            inv = np.array([fallback if fallback is not None
                            else 1.0 / self._template_rate(columns[i])
                            for i in dropped])
            pred = pred + A[:, dropped] @ inv
        err = pred - t
        trusted = np.ones(len(t), bool)
        outliers: list[int] = []
        if robust is not None:
            # the residual headline describes the fit actually trusted:
            # RMS over the inlier rows, with the flagged rows reported
            outliers = [int(i) for i in np.flatnonzero(rw < 0.5)]
            inliers = rw >= 0.5
            if np.any(inliers):
                err = err[inliers]
                trusted = inliers
        residual = float(np.sqrt(np.mean(err ** 2)))
        ok = trusted & (t > 0.0)
        mape = float(100.0 * np.mean(np.abs(pred[ok] - t[ok]) / t[ok])) \
            if np.any(ok) else None
        overhead_s = None
        if overhead_per_block and OVERHEAD_COL in columns:
            j = columns.index(OVERHEAD_COL)
            if j in keep:
                overhead_s = float(x[keep.index(j)])
        x_full = np.full(len(columns), np.nan)
        x_full[keep] = x
        report = FitReport(columns=columns, inverse_rates=x_full,
                           residual_rms_s=residual, samples=len(t),
                           date=date,
                           dropped=[columns[i] for i in sorted(dropped)],
                           robust=robust, outliers=outliers,
                           overhead_per_block_s=overhead_s,
                           insample_mape_pct=mape)

        rates = dict(self.template.transfer_rates)
        arith = dict(self.template.arith_rate)
        arith_mk = {dt: dict(tab)
                    for dt, tab in self.template.arith_per_mk.items()}
        rates_mixed = dict(self.template.rates_mixed)

        def assign(col: str, rate: float) -> None:
            if col == OVERHEAD_COL:
                # not a spec rate: the fitted dispatch cost lives in the
                # FitReport / provenance only (simulators charge rates).
                return
            if col.startswith(_RATE):
                o, _, d = col[len(_RATE):].partition("->")
                rates[(o, d)] = rate
            else:
                dt, sep, mk_s = col[len(_ARITH):].partition("@")
                if "->" in dt:      # mixed config key -> rates_mixed
                    rates_mixed[dt] = rate
                elif sep:
                    arith_mk.setdefault(dt, {})[mk_s] = rate
                else:
                    arith[dt] = rate
                    # a refitted shared rate supersedes any per-mk table the
                    # template carried for this dtype — keeping it would make
                    # arith_rate_for shadow the fresh fit with stale rates.
                    arith_mk.pop(dt, None)

        for i, xi in zip(keep, x):
            assign(columns[i], 1.0 / xi)
        if on_nonpositive == "free":
            for col in report.dropped:
                assign(col, FREE_RATE)
        prov: dict[str, Any] = {"base": self.template.name,
                                "fit": report.as_provenance()}
        prov["fit"]["template_geometry"] = \
            self.template.geometry_fingerprint()
        prov["fit"]["weighting"] = weighting
        if report.dropped:
            prov["fit"]["nonpositive_policy"] = on_nonpositive
        if self.model == "blis":
            coerced = self._coerce_mks([None] * len(t), micro_kernels)
            mks = sorted({str(mk) for mk in coerced})
            prov["fit"]["cost_model"] = {
                "model": "blis", "variant": self.variant.value,
                "micro_kernels": mks, "policy": self.policy}
        else:
            prov["fit"]["cost_model"] = {"model": "pallas"}
        if extra_provenance:
            prov.update(extra_provenance)
        spec = dataclasses.replace(
            self.template, name=name or self.template.name,
            transfer_rates=rates, arith_rate=arith, arith_per_mk=arith_mk,
            rates_mixed=rates_mixed, provenance=prov)
        spec.validate()
        if register:
            _registry.register(spec, overwrite=True, source="calibrated")
        if manifest_dir:
            spec.to_manifest(os.path.join(manifest_dir, f"{spec.name}.json"))
        return spec, report

    # -- the paper's micro-experiments ---------------------------------------

    @classmethod
    def measure_host(cls, name: str = "host-cpu", *, date: str | None = None,
                     register: bool = False,
                     manifest_dir: str | None = None) -> MachineSpec:
        """Run the paper's §3.2 micro-experiments on this host and assemble
        a seed :class:`MachineSpec` (the redesigned ``calibrate_host``).

        The spec keeps the host-cpu template's geometry; the measured
        packing / copy / arithmetic rates replace the placeholder rates,
        with calibration provenance attached.
        """
        from repro.core.calibrate import (
            measure_arith_rate,
            measure_copy_rate,
            measure_packing_rate,
        )

        pack4 = measure_packing_rate(4)
        copy = measure_copy_rate()
        arith = measure_arith_rate()
        template = _registry.get("host-cpu")
        spec = dataclasses.replace(
            template,
            name=name,
            # fresh measured rates supersede any per-mk table the template
            # carried — keeping it would shadow the new arith_rate.
            arith_per_mk={},
            transfer_rates={
                ("M", "M"): pack4,
                ("M", "L2"): pack4,
                ("L2", "M"): pack4,
                ("M", "L1"): copy,
                ("M", "R"): copy,
                ("L1", "R"): copy * 4,
                ("L2", "R"): copy * 2,
            },
            arith_rate={"int8": arith, "f32": arith},
            provenance={
                "base": template.name,
                "calibration": {
                    "method": "micro-experiments (paper 3.2)",
                    "date": date,
                    "measured": {"pack_r4_Bps": pack4, "copy_Bps": copy,
                                 "arith_ops": arith},
                },
            })
        spec.validate()
        if register:
            _registry.register(spec, overwrite=True, source="calibrated")
        if manifest_dir:
            spec.to_manifest(os.path.join(manifest_dir, f"{spec.name}.json"))
        return spec
