"""The calibrate→register→plan pipeline (paper §3.2, made first-class).

The paper builds a machine from a handful of micro-experiments; this module
closes the loop so a calibrated spec feeds the planner instead of vanishing:

1. **measure** — :meth:`Calibrator.measure_host` wraps the
   ``repro.core.calibrate`` micro-experiments (packing / copy / arithmetic
   rates) into a seed :class:`MachineSpec`.
2. **fit** — :meth:`Calibrator.fit` refines a spec against measured GEMM
   wall times.  The simulators are *linear in the inverse rates*: a GEMM's
   predicted time is ``sum_r bytes_r / rate_r + flops / arith``, so fitting
   all rates at once is one least-squares solve ``A x = t`` where ``x`` are
   inverse rates and the design matrix ``A`` comes from the **batched**
   engines (``traffic_terms_batch`` for the BLIS-variant model,
   ``estimate_batch`` for the Pallas/TPU model) — no scalar per-sample
   loops.  ``design_matrix_scalar`` replays the same accounting through the
   scalar simulators and is kept as the equivalence oracle for the tests.
3. **register / persist** — the fitted spec lands in the
   :mod:`repro.machines` registry and (optionally) a JSON manifest, carrying
   fit provenance: RMS residual, sample count, and the calibration date
   passed in by the caller.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Mapping, Sequence

import numpy as np

from repro.machines import registry as _registry
from repro.machines.spec import MachineSpec

_RATE = "rate:"
_ARITH = "arith:"


@dataclasses.dataclass(frozen=True)
class FitReport:
    """Provenance of one vectorized rate fit."""

    columns: list[str]          # "rate:M->L2" / "arith:int8" design columns
    inverse_rates: np.ndarray   # the lstsq solution x (seconds per byte/op)
    residual_rms_s: float       # RMS of (A@x - t) over the samples
    samples: int
    date: str | None

    def as_provenance(self) -> dict[str, Any]:
        return {
            "method": "vectorized-lstsq",
            "columns": list(self.columns),
            "residual_rms_s": float(self.residual_rms_s),
            "samples": int(self.samples),
            "date": self.date,
        }


class Calibrator:
    """Fit a machine's rate tables from measured GEMM times.

    ``template`` (name or spec) supplies the geometry — levels, capacities,
    register file — and any rates the fit does not exercise.  ``model``
    picks the cost model the design matrix replays: ``"blis"`` (the paper's
    variant simulator; default for int8-style scratchpad machines) or
    ``"pallas"`` (the TPU tile model; default when the template declares a
    ``bf16`` rate).
    """

    def __init__(self, template, *, model: str | None = None,
                 variant=None, micro_kernel=None, policy: str = "analytic"):
        from repro.core.variants import Variant, feasible_microkernels

        self.template = _registry.resolve(template)
        if model is None:
            model = "pallas" if "bf16" in self.template.arith_rate else "blis"
        if model not in ("blis", "pallas"):
            raise ValueError(f"unknown cost model {model!r}; "
                             f"use 'blis' or 'pallas'")
        self.model = model
        self.policy = policy
        if model == "blis":
            self.variant = variant or Variant.B3A2C0
            cands = feasible_microkernels(self.template, self.variant)
            if micro_kernel is None:
                if not cands:
                    raise ValueError(
                        f"{self.template.name}: no feasible micro-kernel to "
                        f"calibrate with")
                micro_kernel = cands[0]
            self.micro_kernel = micro_kernel
        else:
            self.variant = None
            self.micro_kernel = None

    # -- design matrices ------------------------------------------------------

    def _coerce_problems(self, problems) -> list:
        from repro.gemm.api import GemmProblem
        default = "int8" if self.model == "blis" else "bf16"
        return [GemmProblem.coerce(p, default_dtype=default)
                for p in problems]

    def _coerce_mks(self, probs, micro_kernels) -> list:
        from repro.core.variants import MicroKernel
        if micro_kernels is None:
            return [self.micro_kernel] * len(probs)
        mks = [mk if isinstance(mk, MicroKernel)
               else MicroKernel(int(mk[0]), int(mk[1]))
               for mk in micro_kernels]
        if len(mks) != len(probs):
            raise ValueError(f"{len(probs)} problems vs {len(mks)} "
                             f"micro-kernels")
        return mks

    def design_matrix(self, problems,
                      micro_kernels=None) -> tuple[np.ndarray, list[str]]:
        """(samples x columns) coefficients of the inverse rates, built with
        the batched engines — one vectorized evaluation for all samples.

        For the BLIS model, ``micro_kernels`` optionally gives a per-sample
        micro-kernel.  Calibration samples should span several micro-kernel
        shapes: under a single one every register-streaming term and the
        arithmetic term are exactly proportional to ``m*n*k``, which makes
        the system rank-deficient (the paper's calibration likewise varies
        the micro-kernel across its experiments).
        """
        probs = self._coerce_problems(problems)
        if self.model == "blis":
            return self._design_blis_batch(
                probs, self._coerce_mks(probs, micro_kernels))
        if micro_kernels is not None:
            raise ValueError("micro_kernels only applies to the blis model")
        return self._design_pallas_batch(probs)

    def _design_blis_batch(self, probs, mks):
        from repro.core.variants import (
            derive_blocking_batch,
            traffic_terms_batch,
        )

        mach = self.template
        # per-sample (P,) arrays: micro-kernel dims align elementwise with
        # the problems, so every batched closed form broadcasts to (P,).
        rows = np.array([mk.rows for mk in mks], np.int64)
        cols = np.array([mk.cols for mk in mks], np.int64)
        m = np.array([p.m for p in probs], np.int64)
        n = np.array([p.n for p in probs], np.int64)
        k = np.array([p.k for p in probs], np.int64)
        s = np.array([p.elem_bytes for p in probs], np.int64)
        blk = derive_blocking_batch(self.variant, rows, cols, mach,
                                    m, n, k, s)
        terms = traffic_terms_batch(self.variant, rows, cols, blk,
                                    m, n, k, s, policy=self.policy)
        cols_map: dict[str, np.ndarray] = {}
        for t in terms:
            key = (f"{_RATE}{mach.level(t.origin)}->"
                   f"{mach.level(t.dest)}")
            coeff = np.broadcast_to(t.bytes, (len(probs),)).astype(np.float64)
            if t.chunk is not None:
                # time = bytes / (rate * chunk/ref): fold the chunk scaling
                # into the coefficient of x = 1/rate.
                chunk = np.broadcast_to(np.asarray(t.chunk, np.float64),
                                        (len(probs),))
                coeff = coeff * (mach.reference_chunk / chunk)
            cols_map[key] = cols_map.get(key, 0.0) + coeff
        for dt in sorted({p.dtype for p in probs}):
            sel = np.array([p.dtype == dt for p in probs], np.float64)
            cols_map[f"{_ARITH}{dt}"] = sel * np.array(
                [p.flops for p in probs], np.float64)
        names = list(cols_map)
        return np.stack([cols_map[c] for c in names], axis=1), names

    def _design_pallas_batch(self, probs):
        from repro.core.autotune import tune_batch
        from repro.core.tpu_model import (
            DTYPE_BYTES,
            GridOrder,
            SUBLANE,
            estimate_batch,
            machine_peak,
        )

        mach = self.template
        shapes = [p.as_shape() for p in probs]
        tiles = [d.tile for d in tune_batch(shapes, machine=mach)]
        m = np.array([p.m for p in probs], np.int64)
        n = np.array([p.n for p in probs], np.int64)
        k = np.array([p.k for p in probs], np.int64)
        s = np.array([DTYPE_BYTES[p.dtype] for p in probs], np.int64)
        sub = np.array([SUBLANE[p.dtype] for p in probs], np.int64)
        peak = np.array([machine_peak(mach, p.dtype) for p in probs],
                        np.float64)
        bm = np.array([t.bm for t in tiles], np.int64)
        bn = np.array([t.bn for t in tiles], np.int64)
        bk = np.array([t.bk for t in tiles], np.int64)
        inner = np.array([t.order is GridOrder.K_INNER for t in tiles], bool)
        costs = estimate_batch(m, n, k, s, sub, peak, bm, bn, bk, inner,
                               machine=mach)
        cols_map: dict[str, np.ndarray] = {
            f"{_RATE}{mach.level('M')}->{mach.level('L1')}":
                np.asarray(costs.hbm_bytes, np.float64),
            f"{_RATE}{mach.level('L1')}->{mach.level('R')}":
                np.asarray(costs.vmem_bytes, np.float64),
        }
        # t_compute = flops / (peak * eff) -> coefficient of 1/peak.
        flops = 2.0 * (m * n * k).astype(np.float64)
        for dt in sorted({p.dtype for p in probs}):
            sel = np.array([p.dtype == dt for p in probs], np.float64)
            tag = "bf16" if dt == "f32" else dt
            cols_map[f"{_ARITH}{tag}"] = cols_map.get(
                f"{_ARITH}{tag}", 0.0) + sel * flops / np.asarray(
                    costs.mxu_efficiency, np.float64)
        names = list(cols_map)
        return np.stack([cols_map[c] for c in names], axis=1), names

    def design_matrix_scalar(self, problems,
                             micro_kernels=None
                             ) -> tuple[np.ndarray, list[str]]:
        """The per-sample scalar-loop design matrix, kept as the reference
        oracle the vectorized :meth:`design_matrix` must agree with
        (the tests assert exact equality)."""
        probs = self._coerce_problems(problems)
        mach = self.template
        cols_map: dict[str, list[float]] = {}
        rows_acc: list[dict[str, float]] = []
        if self.model == "blis":
            from repro.core.variants import derive_blocking, traffic_terms
            mks = self._coerce_mks(probs, micro_kernels)
            for p, mk in zip(probs, mks):
                pr = p.as_problem()
                blk = derive_blocking(self.variant, mk, mach, pr)
                row: dict[str, float] = {}
                for t in traffic_terms(self.variant, mk, blk,
                                       pr, policy=self.policy):
                    key = (f"{_RATE}{mach.level(t.origin)}->"
                           f"{mach.level(t.dest)}")
                    coeff = t.bytes
                    if t.chunk is not None:
                        coeff = coeff * (mach.reference_chunk / t.chunk)
                    row[key] = row.get(key, 0.0) + coeff
                row[f"{_ARITH}{p.dtype}"] = pr.flops
                rows_acc.append(row)
        else:
            from repro.core.autotune import tune_batch
            from repro.core.tpu_model import estimate
            for p in probs:
                shape = p.as_shape()
                tile = tune_batch([shape], machine=mach)[0].tile
                c = estimate(shape, tile, mach)
                tag = "bf16" if p.dtype == "f32" else p.dtype
                rows_acc.append({
                    f"{_RATE}{mach.level('M')}->{mach.level('L1')}":
                        c.hbm_bytes,
                    f"{_RATE}{mach.level('L1')}->{mach.level('R')}":
                        c.vmem_bytes,
                    f"{_ARITH}{tag}": shape.flops / c.mxu_efficiency,
                })
        for row in rows_acc:
            for key in row:
                cols_map.setdefault(key, [])
        names = list(cols_map)
        A = np.zeros((len(rows_acc), len(names)))
        for i, row in enumerate(rows_acc):
            for j, key in enumerate(names):
                A[i, j] = row.get(key, 0.0)
        return A, names

    # -- the fit --------------------------------------------------------------

    def fit(self, problems, seconds: Sequence[float], *, date: str | None,
            micro_kernels=None, name: str | None = None,
            register: bool = False, manifest_dir: str | None = None,
            extra_provenance: Mapping[str, Any] | None = None,
            ) -> tuple[MachineSpec, FitReport]:
        """One vectorized least-squares solve over all samples.

        ``date`` is required (pass None explicitly to record an undated
        fit) — the Calibrator never invents timestamps.  For the BLIS
        model pass per-sample ``micro_kernels`` spanning several shapes
        (see :meth:`design_matrix`).  Returns the fitted spec and the
        :class:`FitReport`; with ``register=True`` the spec lands in the
        registry (source ``"calibrated"``), with ``manifest_dir`` it is
        persisted as ``<dir>/<name>.json``.
        """
        t = np.asarray(list(seconds), np.float64)
        A, columns = self.design_matrix(problems, micro_kernels)
        if A.shape[0] != t.shape[0]:
            raise ValueError(f"{A.shape[0]} problems vs {t.shape[0]} "
                             f"measured times")
        if A.shape[0] < A.shape[1]:
            raise ValueError(
                f"under-determined fit: {A.shape[0]} samples for "
                f"{A.shape[1]} rate columns {columns}")
        x, _, rank, _ = np.linalg.lstsq(A, t, rcond=None)
        if rank < len(columns):
            raise ValueError(
                f"rank-deficient fit (rank {rank} < {len(columns)} columns "
                f"{columns}): the samples cannot separate the rates — vary "
                f"the micro-kernels and problem shapes (see design_matrix)")
        if np.any(x <= 0.0):
            bad = [c for c, xi in zip(columns, x) if xi <= 0.0]
            raise ValueError(
                f"fit produced non-positive inverse rates for {bad}; the "
                f"measured times are inconsistent with the cost model — "
                f"not registering a garbage spec")
        residual = float(np.sqrt(np.mean((A @ x - t) ** 2)))
        report = FitReport(columns=columns, inverse_rates=x,
                           residual_rms_s=residual, samples=len(t),
                           date=date)

        rates = dict(self.template.transfer_rates)
        arith = dict(self.template.arith_rate)
        for col, xi in zip(columns, x):
            if col.startswith(_RATE):
                o, _, d = col[len(_RATE):].partition("->")
                rates[(o, d)] = 1.0 / xi
            else:
                arith[col[len(_ARITH):]] = 1.0 / xi
        prov: dict[str, Any] = {"base": self.template.name,
                                "fit": report.as_provenance()}
        if self.model == "blis":
            coerced = self._coerce_mks([None] * len(t), micro_kernels)
            mks = sorted({str(mk) for mk in coerced})
            prov["fit"]["cost_model"] = {
                "model": "blis", "variant": self.variant.value,
                "micro_kernels": mks, "policy": self.policy}
        else:
            prov["fit"]["cost_model"] = {"model": "pallas"}
        if extra_provenance:
            prov.update(extra_provenance)
        spec = dataclasses.replace(
            self.template, name=name or self.template.name,
            transfer_rates=rates, arith_rate=arith, provenance=prov)
        spec.validate()
        if register:
            _registry.register(spec, overwrite=True, source="calibrated")
        if manifest_dir:
            spec.to_manifest(os.path.join(manifest_dir, f"{spec.name}.json"))
        return spec, report

    # -- the paper's micro-experiments ---------------------------------------

    @classmethod
    def measure_host(cls, name: str = "host-cpu", *, date: str | None = None,
                     register: bool = False,
                     manifest_dir: str | None = None) -> MachineSpec:
        """Run the paper's §3.2 micro-experiments on this host and assemble
        a seed :class:`MachineSpec` (the redesigned ``calibrate_host``).

        The spec keeps the host-cpu template's geometry; the measured
        packing / copy / arithmetic rates replace the placeholder rates,
        with calibration provenance attached.
        """
        from repro.core.calibrate import (
            measure_arith_rate,
            measure_copy_rate,
            measure_packing_rate,
        )

        pack4 = measure_packing_rate(4)
        copy = measure_copy_rate()
        arith = measure_arith_rate()
        template = _registry.get("host-cpu")
        spec = dataclasses.replace(
            template,
            name=name,
            transfer_rates={
                ("M", "M"): pack4,
                ("M", "L2"): pack4,
                ("L2", "M"): pack4,
                ("M", "L1"): copy,
                ("M", "R"): copy,
                ("L1", "R"): copy * 4,
                ("L2", "R"): copy * 2,
            },
            arith_rate={"int8": arith, "f32": arith},
            provenance={
                "base": template.name,
                "calibration": {
                    "method": "micro-experiments (paper 3.2)",
                    "date": date,
                    "measured": {"pack_r4_Bps": pack4, "copy_Bps": copy,
                                 "arith_ops": arith},
                },
            })
        spec.validate()
        if register:
            _registry.register(spec, overwrite=True, source="calibrated")
        if manifest_dir:
            spec.to_manifest(os.path.join(manifest_dir, f"{spec.name}.json"))
        return spec
