"""``repro.machines`` — the declarative machine zoo.

The paper models a processor as a handful of calibrated rates (§3.2,
Table 1); this package makes machines first-class API objects on exactly
that premise:

    >>> from repro import machines
    >>> machines.list_machines("zoo/*")
    ['cortex-m7', 'gap8-fc', 'gap9-fc', 'host-cpu', 'tpu-v5e', ...]
    >>> gap8 = machines.get("gap8-fc")          # loaded from its JSON manifest
    >>> fast = gap8.scaled(arith=2.0, name="gap8-fc-2x")   # derived what-if
    >>> machines.register(fast)
    >>> from repro import gemm
    >>> gemm.sweep(problems, backends=["analytic-gap8"],
    ...            machines=["zoo/*"])           # globs expand over the zoo

Calibration feeds the same registry: :class:`Calibrator` wraps the paper's
§3.2 micro-experiments and fits rate tables to measured GEMM times with one
vectorized least-squares solve on the batched simulators, emitting a
registered, manifest-persisted spec with fit provenance.

``python -m repro.machines validate`` schema-checks every zoo manifest
(wired into CI); ``list`` / ``show`` / ``calibrate`` are also available.
"""
from repro.machines.spec import (
    CANONICAL_ROLES,
    MachineSpec,
    SpecValidationError,
)
from repro.machines.registry import (
    alias,
    expand,
    expand_many,
    get,
    list_machines,
    load_zoo,
    register,
    resolve,
    source_of,
    unregister,
    unregister_prefix,
    zoo_dir,
)

__all__ = [
    "CANONICAL_ROLES", "Calibrator", "FitReport", "MachineSpec",
    "SpecValidationError", "alias", "expand", "expand_many", "get",
    "list_machines", "load_zoo", "register", "resolve", "source_of",
    "unregister", "unregister_prefix", "zoo_dir",
]


def __getattr__(name):
    # Calibrator pulls in the core simulators (numpy-heavy); import lazily so
    # `repro.machines` stays dependency-light for core.hardware's shim.
    if name in ("Calibrator", "FitReport"):
        from repro.machines import calibrate
        return getattr(calibrate, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
