"""Machine-zoo command line.

    python -m repro.machines validate [--dir DIR]   # schema-check manifests
    python -m repro.machines list [PATTERN]         # registered machines
    python -m repro.machines show NAME              # one manifest, pretty
    python -m repro.machines calibrate [--name N --date D --out DIR]

``validate`` is wired into CI before pytest: every ``zoo/*.json`` must parse
against the ``repro.machines/v1`` schema (level names, rate keys, dtype
tables) or the build fails.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

from repro import machines
from repro.machines.spec import MachineSpec, SpecValidationError


def cmd_validate(args) -> int:
    directory = args.dir or machines.zoo_dir()
    paths = sorted(glob.glob(os.path.join(directory, "*.json")))
    if not paths:
        print(f"no manifests found under {directory}", file=sys.stderr)
        return 1
    failures = 0
    for path in paths:
        rel = os.path.relpath(path, directory)
        try:
            spec = MachineSpec.from_manifest(path)
            # the manifest must also round-trip losslessly
            if MachineSpec.from_json(spec.to_json()) != spec:
                raise SpecValidationError("to_json/from_json round-trip "
                                          "drift")
            print(f"  OK   {rel:<24} {spec.name} "
                  f"(levels={'/'.join(spec.levels)}, "
                  f"dtypes={','.join(sorted(spec.arith_rate))})")
        except (SpecValidationError, json.JSONDecodeError, OSError) as e:
            failures += 1
            print(f"  FAIL {rel:<24} {e}", file=sys.stderr)
    print(f"{len(paths) - failures}/{len(paths)} manifests valid")
    return 1 if failures else 0


def cmd_list(args) -> int:
    for name in machines.list_machines(args.pattern):
        spec = machines.get(name)
        src = machines.source_of(name) or "?"
        print(f"  {name:<20} [{src}] levels={'/'.join(spec.levels)} "
              f"dtypes={','.join(sorted(spec.arith_rate))}")
    return 0


def cmd_show(args) -> int:
    json.dump(machines.get(args.name).to_json(), sys.stdout, indent=1)
    print()
    return 0


def cmd_calibrate(args) -> int:
    spec = machines.Calibrator.measure_host(
        args.name, date=args.date, register=True, manifest_dir=args.out)
    print(f"calibrated {spec.name}: "
          f"{json.dumps(spec.provenance['calibration']['measured'])}")
    if args.grid or args.store:
        # the full §3.2-and-beyond loop: measure a GEMM campaign against the
        # seed spec's geometry and fit every rate at once (repro.measure).
        import tempfile

        from repro import measure

        store = measure.SampleStore(
            args.store or os.path.join(tempfile.mkdtemp(prefix="calib-"),
                                       "samples.jsonl"))
        if args.grid:
            camp = measure.run_campaign(args.grid, machine=spec,
                                        harness="host-numpy",
                                        dtype=args.dtype, store=store)
            print(f"measured {len(camp.samples)} samples "
                  f"({args.grid}, host-numpy) -> {store.path}")
        spec, fit = measure.fit_from_store(
            store, spec, name=args.name, date=args.date, register=True,
            manifest_dir=args.out, on_nonpositive="free")
        report = measure.validate_spec(spec, store)
        print(f"fitted {spec.name} from {fit.samples} samples "
              f"(residual RMS {fit.residual_rms_s:.3e}s"
              + (f", free columns {fit.dropped}" if fit.dropped else "")
              + f"); validation MAPE {report.mape:.1f}%")
    if args.out:
        print(f"manifest written to "
              f"{os.path.join(args.out, spec.name + '.json')}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.machines")
    sub = ap.add_subparsers(dest="cmd", required=True)
    v = sub.add_parser("validate", help="schema-check every zoo manifest")
    v.add_argument("--dir", default=None)
    v.set_defaults(fn=cmd_validate)
    ls = sub.add_parser("list", help="registered machines")
    ls.add_argument("pattern", nargs="?", default=None)
    ls.set_defaults(fn=cmd_list)
    sh = sub.add_parser("show", help="print one machine's manifest")
    sh.add_argument("name")
    sh.set_defaults(fn=cmd_show)
    ca = sub.add_parser("calibrate",
                        help="run the paper's 3.2 micro-experiments on this "
                             "host and register the spec; with --grid/"
                             "--store, follow with a measured-GEMM campaign "
                             "and a full rate fit (repro.measure)")
    ca.add_argument("--name", default="host-cpu")
    ca.add_argument("--date", default=None,
                    help="calibration date recorded in provenance")
    ca.add_argument("--out", default=None,
                    help="directory to persist the manifest into")
    ca.add_argument("--grid", default=None,
                    help="measurement-campaign grid (smoke|table2|mobilenet)"
                         " to run with the host-numpy harness before fitting")
    ca.add_argument("--store", default=None,
                    help="sample store to measure into / fit from "
                         "(temp file when omitted with --grid)")
    ca.add_argument("--dtype", default="f32",
                    help="campaign dtype (default f32: host BLAS)")
    ca.set_defaults(fn=cmd_calibrate)
    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
