"""The serializable machine schema — a processor as a small set of rates.

The paper's portability claim (§1, §3.2) is that one analytic GEMM simulator
covers a *"highly heterogeneous zoo"* of edge processors because a machine is
nothing but a few calibrated numbers: per-level scratchpad capacities,
point-to-point transfer rates (Table 1), a per-dtype arithmetic-rate table,
and the register-file geometry.  This module makes that literal:
:class:`MachineSpec` is a frozen, JSON-serializable value object with a
validated schema, and every machine the framework knows about is a manifest
under ``repro/machines/zoo/`` — adding a processor is dropping a JSON file,
not editing code.

Level-name indirection: the variant cost models (``core/variants.py``,
``core/simulator.py``) address the canonical role set ``{"M", "L2", "L1",
"R"}``.  A machine whose physical hierarchy differs declares
``level_aliases`` mapping role names onto its real levels (e.g. a two-level
Cortex-M-class part maps the ``"L2"`` role onto ``"L1"``; the TPU maps it
onto VMEM), and :meth:`MachineSpec.capacity` / :meth:`MachineSpec.rate`
resolve through the alias table — the simulators never special-case a
hierarchy again.

Derived machines are first-class: :meth:`scaled`, :meth:`with_capacities`
and :meth:`with_dtype_rates` stamp out hypothetical zoo members (ablations,
what-if parts) with provenance recording the base spec and the transform.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import re
from typing import Any, Mapping

SCHEMA = "repro.machines/v1"

#: canonical memory-level roles addressed by the variant cost models.
CANONICAL_ROLES = ("M", "L2", "L1", "R")

_DTYPE_TAG = re.compile(r"^[a-z][a-z0-9_]*$")
_MK_TAG = re.compile(r"^[1-9][0-9]*x[1-9][0-9]*$")
_RATE_SEP = "->"

#: dtype tags a rate table may be keyed by.  ``validate()`` rejects tables
#: with keys outside this set (a silently-accepted typo like ``"in8"`` used
#: to make every lookup fall through to KeyError at plan time instead).
KNOWN_DTYPES = frozenset(
    {"int4", "int8", "int16", "int32", "f16", "bf16", "f32", "f64"})
# mixed-rate keys are "AxB->ACC" over known dtype tags, e.g. "int4xint8->int32"
_MIXED_KEY = re.compile(r"^([a-z0-9_]+)x([a-z0-9_]+)->([a-z0-9_]+)$")


class SpecValidationError(ValueError):
    """A manifest / MachineSpec that violates the schema."""


@dataclasses.dataclass(frozen=True)
class MachineSpec:
    """A machine for the blocked-GEMM cost model.

    ``transfer_rates`` maps ``(origin, destination)`` level names to bytes/s.
    Level names are free-form but the variant cost models address the
    canonical role set ``{"M", "L2", "L1", "R"}``; machines whose hierarchy
    differs resolve roles through ``level_aliases`` (see module docstring).

    Rates follow the paper's convention: *bytes per second* for transfers and
    *ops per second* for arithmetic (1 MAC = 2 ops), keyed by dtype tag.
    Packing rates are calibrated at ``reference_chunk`` contiguous elements
    and scale linearly with the chunk size (paper §3.2).
    """

    name: str
    # capacities in bytes, by level name (registers expressed in bytes too).
    capacities: Mapping[str, int]
    # (origin, dest) -> bytes/s, calibrated at the reference chunk size.
    transfer_rates: Mapping[tuple[str, str], float]
    # arithmetic throughput, ops/s (1 MAC = 2 ops), by dtype tag.
    arith_rate: Mapping[str, float]
    # optional per-micro-kernel refinement of ``arith_rate`` (paper §4's
    # stated extension of the basic simulator): dtype tag -> {"4x24": ops/s}.
    # Micro-kernels absent from the table fall back to ``arith_rate``.
    arith_per_mk: Mapping[str, Mapping[str, float]] = \
        dataclasses.field(default_factory=dict)
    # chunk size (elements) at which packing rates were calibrated.
    reference_chunk: int = 4
    # element size in bytes for the default dtype.
    elem_bytes: int = 1
    # number of (SIMD) registers and lanes per register, for micro-kernel
    # feasibility checks.
    num_vector_registers: int = 32
    register_lanes: int = 4
    # declared level names, outermost first (derived from capacities when
    # omitted).
    levels: tuple[str, ...] = ()
    # canonical-role -> physical-level indirection (e.g. {"L2": "L1"}).
    level_aliases: Mapping[str, str] = dataclasses.field(default_factory=dict)
    # deployment-memory view (manifest section "memory"): the level whose
    # capacity bounds what a served model may occupy (weights + KV cache +
    # activation workspace) and the fraction of it reserved for the runtime.
    # Empty deployment_level means the canonical "M" role.
    deployment_level: str = ""
    memory_reserved_fraction: float = 0.0
    # where this spec came from: calibration fit, derivation, manifest note.
    provenance: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    # mixed-precision arithmetic rates, ops/s, keyed "AxB->ACC" (e.g.
    # "int4xint8->int32").  Keys absent from the table fall back to the
    # uniform ``arith_rate`` entry of the compute (narrower-operand) dtype —
    # see :meth:`arith_rate_mixed`.
    rates_mixed: Mapping[str, float] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if not self.levels:
            object.__setattr__(self, "levels", tuple(self.capacities))

    # -- level / rate resolution ---------------------------------------------

    def level(self, role: str) -> str:
        """Resolve a canonical role name to this machine's physical level."""
        return self.level_aliases.get(role, role)

    def rate(self, origin: str, dest: str) -> float:
        o, d = self.level(origin), self.level(dest)
        try:
            return self.transfer_rates[(o, d)]
        except KeyError as e:
            raise KeyError(
                f"{self.name}: no calibrated transfer rate {origin}->{dest}"
            ) from e

    def packing_rate(self, origin: str, dest: str, chunk_elems: int) -> float:
        """Packing rate scaled by the contiguous-chunk size (paper §3.2)."""
        scale = chunk_elems / float(self.reference_chunk)
        return self.rate(origin, dest) * scale

    def capacity(self, level: str) -> int:
        return int(self.capacities[self.level(level)])

    def arith_rate_for(self, dtype: str, micro_kernel=None) -> float:
        """Arithmetic rate (ops/s) for a dtype, refined per micro-kernel when
        the spec carries an ``arith_per_mk`` table (paper §4).  With no table
        entry this returns exactly ``arith_rate[dtype]``, so machines without
        the refinement behave bit-identically."""
        if micro_kernel is not None and self.arith_per_mk:
            rate = self.arith_per_mk.get(dtype, {}).get(str(micro_kernel))
            if rate is not None:
                return rate
        return self.arith_rate[dtype]

    def arith_rate_mixed(self, key: str, fallback_dtype: str | None = None,
                         micro_kernel=None) -> float:
        """Arithmetic rate (ops/s) for a mixed-precision configuration.

        ``key`` is the ``"AxB->ACC"`` form of a ``PrecisionConfig``
        (:meth:`PrecisionConfig.key`).  When the spec carries a calibrated
        ``rates_mixed`` entry for the key it wins; otherwise the rate falls
        back to :meth:`arith_rate_for` on ``fallback_dtype`` — the compute
        (narrower-operand) dtype of the config, defaulting to the key's
        first operand — so every machine remains plannable for every mixed
        config its uniform table covers.
        """
        rate = self.rates_mixed.get(key)
        if rate is not None:
            return rate
        dt = fallback_dtype or key.partition("x")[0]
        return self.arith_rate_for(dt, micro_kernel)

    def memory_budget(self, level: str | None = None) -> int:
        """Usable bytes for a served model at the deployment memory level.

        The paper treats every memory level as a hard capacity the blocked
        algorithm must respect; deployment planning extends the same rule to
        the whole model: weights, KV caches and activation workspace all live
        at the deployment level (HBM on the TPU, main memory on the edge
        parts), so a serving configuration is feasible only when its modelled
        footprint (``repro.serving.footprint``) fits this budget.

        Args:
            level: level name or canonical role to budget; defaults to the
                spec's ``deployment_level`` (itself defaulting to the ``"M"``
                role).

        Returns:
            ``capacity(level)`` minus the ``memory_reserved_fraction`` slice
            held back for the runtime (allocator slack, executables,
            non-model buffers), as an int number of bytes.
        """
        lv = level or self.deployment_level or "M"
        return int(self.capacity(lv) * (1.0 - self.memory_reserved_fraction))

    def fingerprint(self) -> str:
        """Content identity for process-level caches.

        Two specs sharing a registry name can carry different rate tables
        (derived transforms, ``register(overwrite=True)``, a Calibrator
        refit), so plan/tune caches key on ``name@fingerprint`` rather than
        the name alone.  Provenance is excluded — it never affects a
        prediction.
        """
        fp = self.__dict__.get("_fingerprint")
        if fp is None:
            payload = {k: v for k, v in self.to_json().items()
                       if k != "provenance"}
            fp = hashlib.sha1(json.dumps(payload, sort_keys=True)
                              .encode()).hexdigest()[:16]
            object.__setattr__(self, "_fingerprint", fp)
        return fp

    @property
    def cache_token(self) -> str:
        """``name@fingerprint`` — the cache-key form of this machine."""
        return f"{self.name}@{self.fingerprint()}"

    #: to_json keys that describe the machine's *geometry* — everything that
    #: shapes a blocked loop nest (blockings, register feasibility) but not
    #: the calibrated rates a fit replaces.
    _GEOMETRY_KEYS = ("levels", "capacities", "level_aliases",
                      "reference_chunk", "elem_bytes",
                      "num_vector_registers", "register_lanes")

    def geometry_fingerprint(self) -> str:
        """Content identity of the geometry alone (capacities, levels,
        aliases, register file — everything except the rate tables, the name
        and provenance).

        Measured GEMM wall times depend on the planned blocking, hence on the
        geometry, but not on a template's placeholder rates; a Calibrator
        refit changes rates only.  ``repro.measure.SampleStore`` keys samples
        on this fingerprint so a campaign survives a refit, while samples
        taken against a spec whose geometry has since changed (or whose name
        now points at a different machine) can never silently calibrate it.
        """
        fp = self.__dict__.get("_geometry_fingerprint")
        if fp is None:
            d = self.to_json()
            payload = {k: d.get(k) for k in self._GEOMETRY_KEYS}
            fp = hashlib.sha1(json.dumps(payload, sort_keys=True)
                              .encode()).hexdigest()[:16]
            object.__setattr__(self, "_geometry_fingerprint", fp)
        return fp

    # -- validation ----------------------------------------------------------

    def validate(self) -> "MachineSpec":
        """Schema-check the spec; raises :class:`SpecValidationError`.

        Checks level-name consistency (every capacity / rate endpoint /
        alias target is a declared level; every canonical role resolves),
        rate-key shape and positivity, and the dtype-rate table.
        """
        err = SpecValidationError
        if not self.name or not isinstance(self.name, str) \
                or self.name != self.name.strip() \
                or self.name.count("/") > 1 \
                or any(not part or part != part.strip()
                       for part in self.name.split("/")):
            raise err(f"bad machine name {self.name!r}")
        levels = tuple(self.levels)
        if not levels or len(set(levels)) != len(levels):
            raise err(f"{self.name}: levels must be non-empty and unique, "
                      f"got {levels!r}")
        if set(self.capacities) != set(levels):
            raise err(f"{self.name}: capacities keys {sorted(self.capacities)}"
                      f" != declared levels {sorted(levels)}")
        for lv, cap in self.capacities.items():
            if int(cap) <= 0:
                raise err(f"{self.name}: capacity[{lv}] must be positive")
        for key, rate in self.transfer_rates.items():
            if not (isinstance(key, tuple) and len(key) == 2):
                raise err(f"{self.name}: transfer-rate key {key!r} is not "
                          f"an (origin, dest) pair")
            o, d = key
            if o not in levels or d not in levels:
                raise err(f"{self.name}: rate key {o}->{d} references an "
                          f"undeclared level (have {levels})")
            if not (isinstance(rate, (int, float)) and math.isfinite(rate)
                    and rate > 0):
                raise err(f"{self.name}: rate {o}->{d} must be a positive "
                          f"finite number, got {rate!r}")
        for role, target in self.level_aliases.items():
            if role in levels:
                raise err(f"{self.name}: alias {role!r} shadows a declared "
                          f"level")
            if target not in levels:
                raise err(f"{self.name}: alias {role}->{target} targets an "
                          f"undeclared level")
        for role in CANONICAL_ROLES:
            if self.level(role) not in levels:
                raise err(f"{self.name}: canonical role {role!r} resolves to "
                          f"no declared level; add it to levels or "
                          f"level_aliases")
        if not self.arith_rate:
            raise err(f"{self.name}: arith_rate table is empty")
        for tag, rate in self.arith_rate.items():
            if not _DTYPE_TAG.match(tag or ""):
                raise err(f"{self.name}: bad dtype tag {tag!r} in arith_rate")
            if tag not in KNOWN_DTYPES:
                raise err(f"{self.name}: unknown dtype tag {tag!r} in "
                          f"arith_rate (known: {sorted(KNOWN_DTYPES)})")
            if not (isinstance(rate, (int, float)) and math.isfinite(rate)
                    and rate > 0):
                raise err(f"{self.name}: arith_rate[{tag}] must be a "
                          f"positive finite number, got {rate!r}")
        for key, rate in self.rates_mixed.items():
            match = _MIXED_KEY.match(key or "")
            if not match:
                raise err(f"{self.name}: bad rates_mixed key {key!r} "
                          f"(expected 'AxB->ACC', e.g. 'int4xint8->int32')")
            for tag in match.groups():
                if tag not in KNOWN_DTYPES:
                    raise err(f"{self.name}: unknown dtype tag {tag!r} in "
                              f"rates_mixed key {key!r} "
                              f"(known: {sorted(KNOWN_DTYPES)})")
            if not (isinstance(rate, (int, float)) and math.isfinite(rate)
                    and rate > 0):
                raise err(f"{self.name}: rates_mixed[{key}] must be a "
                          f"positive finite number, got {rate!r}")
        for tag, table in self.arith_per_mk.items():
            if tag not in self.arith_rate:
                raise err(f"{self.name}: arith_per_mk dtype {tag!r} has no "
                          f"arith_rate fallback entry")
            if not table:
                raise err(f"{self.name}: arith_per_mk[{tag}] is empty")
            for mk, rate in table.items():
                if not _MK_TAG.match(mk or ""):
                    raise err(f"{self.name}: bad micro-kernel key {mk!r} in "
                              f"arith_per_mk[{tag}] (expected 'RxC')")
                if not (isinstance(rate, (int, float))
                        and math.isfinite(rate) and rate > 0):
                    raise err(f"{self.name}: arith_per_mk[{tag}][{mk}] must "
                              f"be a positive finite number, got {rate!r}")
        for field, lo in (("reference_chunk", 1), ("elem_bytes", 1),
                          ("num_vector_registers", 1), ("register_lanes", 1)):
            if int(getattr(self, field)) < lo:
                raise err(f"{self.name}: {field} must be >= {lo}")
        if self.deployment_level and \
                self.level(self.deployment_level) not in levels:
            raise err(f"{self.name}: deployment_level "
                      f"{self.deployment_level!r} resolves to no declared "
                      f"level (have {levels})")
        frac = self.memory_reserved_fraction
        if not (isinstance(frac, (int, float)) and math.isfinite(frac)
                and 0.0 <= frac < 1.0):
            raise err(f"{self.name}: memory_reserved_fraction must be in "
                      f"[0, 1), got {frac!r}")
        return self

    # -- serialization -------------------------------------------------------

    def to_json(self) -> dict:
        """The manifest form; round-trips losslessly through
        :meth:`from_json` (floats serialize at full repr precision)."""
        d: dict[str, Any] = {
            "schema": SCHEMA,
            "name": self.name,
            "levels": list(self.levels),
            "capacities": {k: int(v) for k, v in self.capacities.items()},
            "transfer_rates": {f"{o}{_RATE_SEP}{dst}": float(r)
                               for (o, dst), r in self.transfer_rates.items()},
            "arith_rate": {k: float(v) for k, v in self.arith_rate.items()},
            "reference_chunk": int(self.reference_chunk),
            "elem_bytes": int(self.elem_bytes),
            "num_vector_registers": int(self.num_vector_registers),
            "register_lanes": int(self.register_lanes),
        }
        if self.arith_per_mk:
            d["arith_per_mk"] = {tag: {mk: float(r) for mk, r in tab.items()}
                                 for tag, tab in self.arith_per_mk.items()}
        if self.rates_mixed:
            d["rates_mixed"] = {k: float(v)
                                for k, v in self.rates_mixed.items()}
        if self.level_aliases:
            d["level_aliases"] = dict(self.level_aliases)
        if self.deployment_level or self.memory_reserved_fraction:
            mem: dict[str, Any] = {}
            if self.deployment_level:
                mem["deployment_level"] = self.deployment_level
            if self.memory_reserved_fraction:
                mem["reserved_fraction"] = float(self.memory_reserved_fraction)
            d["memory"] = mem
        if self.provenance:
            d["provenance"] = dict(self.provenance)
        return d

    @classmethod
    def from_json(cls, d: Mapping[str, Any]) -> "MachineSpec":
        schema = d.get("schema", SCHEMA)
        if schema != SCHEMA:
            raise SpecValidationError(
                f"unknown machine-manifest schema {schema!r} "
                f"(expected {SCHEMA!r})")
        try:
            rates = {}
            for key, rate in dict(d["transfer_rates"]).items():
                if _RATE_SEP not in key:
                    raise SpecValidationError(
                        f"bad transfer-rate key {key!r}; expected "
                        f"'ORIGIN{_RATE_SEP}DEST'")
                o, _, dst = key.partition(_RATE_SEP)
                rates[(o, dst)] = float(rate)
            spec = cls(
                name=d["name"],
                capacities={k: int(v) for k, v in d["capacities"].items()},
                transfer_rates=rates,
                arith_rate={k: float(v)
                            for k, v in dict(d["arith_rate"]).items()},
                arith_per_mk={tag: {mk: float(r)
                                    for mk, r in dict(tab).items()}
                              for tag, tab in
                              dict(d.get("arith_per_mk") or {}).items()},
                rates_mixed={k: float(v)
                             for k, v in
                             dict(d.get("rates_mixed") or {}).items()},
                reference_chunk=int(d.get("reference_chunk", 4)),
                elem_bytes=int(d.get("elem_bytes", 1)),
                num_vector_registers=int(d.get("num_vector_registers", 32)),
                register_lanes=int(d.get("register_lanes", 4)),
                levels=tuple(d.get("levels") or ()),
                level_aliases=dict(d.get("level_aliases") or {}),
                deployment_level=str(
                    dict(d.get("memory") or {}).get("deployment_level", "")),
                memory_reserved_fraction=float(
                    dict(d.get("memory") or {}).get("reserved_fraction", 0.0)),
                provenance=dict(d.get("provenance") or {}),
            )
        except (KeyError, TypeError, ValueError) as e:
            if isinstance(e, SpecValidationError):
                raise
            raise SpecValidationError(
                f"malformed machine manifest {d.get('name', '?')!r}: {e}"
            ) from e
        return spec.validate()

    def to_manifest(self, path: str) -> str:
        """Write the manifest JSON; returns the path written."""
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_json(), f, indent=1)
            f.write("\n")
        os.replace(tmp, path)
        return path

    @classmethod
    def from_manifest(cls, path: str) -> "MachineSpec":
        with open(path) as f:
            return cls.from_json(json.load(f))

    # -- derived-machine transforms ------------------------------------------

    def _derive(self, name: str | None, default_suffix: str,
                transform: Mapping[str, Any],
                **changes: Any) -> "MachineSpec":
        prov = {"base": self.name, "transform": dict(transform)}
        return dataclasses.replace(
            self, name=name or f"{self.name}{default_suffix}",
            provenance=prov, **changes)

    def scaled(self, *, arith: float = 1.0, bw: float = 1.0,
               name: str | None = None) -> "MachineSpec":
        """A hypothetical machine with every arithmetic rate scaled by
        ``arith`` and every transfer rate scaled by ``bw``."""
        if arith <= 0 or bw <= 0:
            raise ValueError("scale factors must be positive")
        return self._derive(
            name, f"+arith{arith:g}x+bw{bw:g}x",
            {"scaled": {"arith": arith, "bw": bw}},
            transfer_rates={k: r * bw for k, r in self.transfer_rates.items()},
            arith_rate={k: r * arith for k, r in self.arith_rate.items()},
            arith_per_mk={tag: {mk: r * arith for mk, r in tab.items()}
                          for tag, tab in self.arith_per_mk.items()},
            rates_mixed={k: r * arith for k, r in self.rates_mixed.items()},
        )

    def with_capacities(self, name: str | None = None,
                        **caps: int) -> "MachineSpec":
        """Override per-level capacities (bytes), e.g.
        ``spec.with_capacities(L1=32 * 1024)``."""
        unknown = set(caps) - set(self.levels)
        if unknown:
            raise KeyError(f"{self.name}: no such level(s) {sorted(unknown)}; "
                           f"have {list(self.levels)}")
        merged = dict(self.capacities)
        merged.update({k: int(v) for k, v in caps.items()})
        return self._derive(name, "+caps", {"with_capacities": dict(caps)},
                            capacities=merged)

    def with_dtype_rates(self, name: str | None = None,
                         **rates: float) -> "MachineSpec":
        """Merge entries into the per-dtype arithmetic-rate table, e.g.
        ``spec.with_dtype_rates(int4=2 * spec.arith_rate["int8"])``.
        An overridden dtype also sheds any ``arith_per_mk`` refinement it
        carried — the per-mk table was calibrated against the old rate and
        would otherwise shadow the override."""
        merged = dict(self.arith_rate)
        merged.update({k: float(v) for k, v in rates.items()})
        kept_mk = {dt: tab for dt, tab in self.arith_per_mk.items()
                   if dt not in rates}
        return self._derive(name, "+dtypes", {"with_dtype_rates": dict(rates)},
                            arith_rate=merged, arith_per_mk=kept_mk)

    def with_mixed_rates(self, rates: Mapping[str, float],
                         name: str | None = None) -> "MachineSpec":
        """Merge entries into the mixed-precision rate table, e.g.
        ``spec.with_mixed_rates({"int4xint8->int32": 2.0e10})``.  Keys are
        the ``"AxB->ACC"`` form (they contain ``->``, hence a positional
        mapping rather than keyword arguments)."""
        merged = dict(self.rates_mixed)
        merged.update({k: float(v) for k, v in rates.items()})
        return self._derive(name, "+mixed", {"with_mixed_rates": dict(rates)},
                            rates_mixed=merged).validate()

    def with_memory(self, name: str | None = None, *,
                    deployment_level: str | None = None,
                    reserved_fraction: float | None = None) -> "MachineSpec":
        """Override the deployment-memory view (see :meth:`memory_budget`),
        e.g. ``spec.with_memory(reserved_fraction=0.2)`` for a what-if with a
        fifth of the deployment level held back from serving."""
        changes: dict[str, Any] = {}
        if deployment_level is not None:
            changes["deployment_level"] = deployment_level
        if reserved_fraction is not None:
            changes["memory_reserved_fraction"] = float(reserved_fraction)
        return self._derive(name, "+mem", {"with_memory": dict(changes)},
                            **changes).validate()
