"""Weight-only int8 quantization for serving.

Decode is parameter-read-bound (EXPERIMENTS.md §Roofline: every decode cell's
dominant term is memory, rf ~1e-4), and the paper's whole setting is INT8
GEMM — so the natural beyond-paper optimization is to store serving weights
as int8 with per-output-channel scales and dequantise *inside* the fused
matmul (XLA folds the convert+multiply into the dot's operand), halving the
HBM bytes per decoded token vs bf16.

``quantize_params`` maps every large floating matrix to a ``QuantizedTensor``
(int8 data + f32 scale); ``dequantize_params`` restores a compute-dtype tree
at step entry — inside jit, so consumers fuse the dequant.  Small tensors
(norm scales, biases, embeddings' scale vectors) stay in their origin dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    q: Any            # int8 array
    scale: Any        # f32, broadcastable to q's shape

    def tree_flatten(self):
        return (self.q, self.scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def shape(self):
        return self.q.shape


def _is_qt(x) -> bool:
    return isinstance(x, QuantizedTensor)


def quantize_params(values, min_size: int = 1 << 14):
    """Per-axis0-channel symmetric int8 quantisation of large matrices."""
    def q(x):
        if (hasattr(x, "ndim") and x.ndim >= 2 and x.size >= min_size
                and jnp.issubdtype(x.dtype, jnp.floating)):
            axes = tuple(range(1, x.ndim))
            amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axes,
                           keepdims=True)
            scale = jnp.maximum(amax, 1e-12) / 127.0
            qv = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                          -127, 127).astype(jnp.int8)
            return QuantizedTensor(qv, scale)
        return x
    return jax.tree.map(q, values)


def quantized_specs(values, specs):
    """Spec tree matching ``quantize_params`` output structure."""
    from jax.sharding import PartitionSpec as P

    def q(x, s):
        if (hasattr(x, "ndim") and x.ndim >= 2 and x.size >= (1 << 14)
                and jnp.issubdtype(x.dtype, jnp.floating)):
            scale_spec = P(*( (s[0] if len(s) else None,)
                              + (None,) * (x.ndim - 1)))
            return QuantizedTensor(s, scale_spec)
        return s
    return jax.tree.map(q, values, specs)


def dequantize_params(tree, dtype):
    """QuantizedTensor leaves -> dtype arrays (fused into consumers by XLA)."""
    def d(x):
        if _is_qt(x):
            return (x.q.astype(jnp.float32) * x.scale).astype(dtype)
        return x
    return jax.tree.map(d, tree, is_leaf=_is_qt)


def quantization_error(values, dtype=jnp.bfloat16):
    """Max relative error per quantised leaf (for tests)."""
    qt = quantize_params(values)
    dq = dequantize_params(qt, jnp.float32)
    errs = {}
    flat_v = jax.tree_util.tree_leaves_with_path(values)
    dq_map = dict(jax.tree_util.tree_leaves_with_path(dq))
    for path, v in flat_v:
        if hasattr(v, "ndim") and v.ndim >= 2 and v.size >= (1 << 14):
            w = dq_map[path]
            denom = jnp.max(jnp.abs(v.astype(jnp.float32))) + 1e-12
            errs[jax.tree_util.keystr(path)] = float(
                jnp.max(jnp.abs(v.astype(jnp.float32) - w)) / denom)
    return errs
