"""Serving-step builders: prefill and decode, pjit-able, with sampling."""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.models.common import split_params
from repro.models.model import LM


def make_prefill_step(lm: LM) -> Callable:
    def prefill_step(params, batch):
        logits, caches = lm.prefill(params, batch)
        return logits, caches
    return prefill_step


def make_decode_step(lm: LM, greedy: bool = True) -> Callable:
    """decode_step(params, caches, token, pos) -> (next_token, logits,
    caches).  Sampling masks the padded vocab tail."""
    vocab = lm.cfg.vocab_size

    def decode_step(params, caches, token, pos):
        logits, caches = lm.decode_step(params, caches, token, pos)
        logits = logits.astype(jnp.float32)
        vp = logits.shape[-1]
        if vp > vocab:
            logits = logits.at[..., vocab:].set(-1e9)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_token, logits, caches

    return decode_step


def abstract_cache(lm: LM, batch: int, max_len: int, *, seq_shard=False,
                   batch_shard=True):
    """ShapeDtypeStruct cache + spec trees (dry-run path)."""
    tree = jax.eval_shape(functools.partial(
        lm.init_cache, batch, max_len, seq_shard=seq_shard,
        batch_shard=batch_shard))
    return split_params(tree)


def serve_plan(cfg: ModelConfig, shape: ShapeConfig, minfo):
    """Decide decode-cell sharding: DP over batch when divisible; otherwise
    (long_500k, batch=1) SP over the KV sequence axis."""
    batch_shard = shape.global_batch % minfo.data == 0
    seq_shard = (not batch_shard)
    return {"batch_shard": batch_shard, "seq_shard": seq_shard}
