"""Training-step builder: pjit-able (params, opt_state, batch) -> updated.

Features (DESIGN.md §5):
* microbatch gradient accumulation (``ParallelConfig.microbatches``) via
  ``lax.scan`` — shrinks activation memory and collective payload bursts;
* remat per layer-period (``ParallelConfig.remat``);
* optional int8 error-feedback gradient compression on the cross-pod axis
  (``grad_compression='int8_ef'``) via ``shard_map`` around the grad sync;
* DP gradient reduction otherwise implicit in the sharded backward pass.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig, TrainConfig
from repro.models.model import LM
from repro.optim import (
    AdamWConfig,
    adamw_update,
    init_opt_state,
    lr_schedule,
    opt_state_specs,
)
from repro.optim.compression import compress_tree, decompress_tree, init_error_buffer


def make_adamw_config(cfg: ModelConfig, tcfg: TrainConfig) -> AdamWConfig:
    return AdamWConfig(b1=tcfg.b1, b2=tcfg.b2,
                       weight_decay=tcfg.weight_decay,
                       grad_clip=tcfg.grad_clip,
                       moment_dtype=cfg.opt_state_dtype)


def _split_microbatches(batch, k: int):
    def split(x):
        b = x.shape[0]
        assert b % k == 0, (b, k)
        return x.reshape(k, b // k, *x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(lm: LM, tcfg: TrainConfig, pcfg: ParallelConfig
                    ) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  With ``pcfg.grad_compression == "int8_ef"`` the opt state must
    carry an error buffer (see ``init_train_state``)."""
    ocfg = make_adamw_config(lm.cfg, tcfg)
    remat = False if pcfg.remat == "none" else pcfg.remat

    def loss_fn(params, mb):
        loss, metrics = lm.loss_fn(params, mb, remat=remat)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if pcfg.microbatches > 1:
            mbs = _split_microbatches(batch, pcfg.microbatches)

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                (loss, _), grads = grad_fn(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss_sum), _ = jax.lax.scan(acc_step, (g0, 0.0), mbs)
            k = float(pcfg.microbatches)
            grads = jax.tree.map(lambda g: g / k, grads)
            loss = loss_sum / k
            metrics = {}
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        if pcfg.grad_compression == "int8_ef":
            # int8 + error feedback applied to the synchronised gradient.
            # (On hardware the quantisation rides the cross-pod all-reduce —
            # optim/compression.psum_compressed inside shard_map; numerically
            # the round-trip below is the same signal the optimizer sees.)
            qtree, ebuf = compress_tree(grads, opt_state["err"])
            grads = decompress_tree(qtree, grads)
        lr = lr_schedule(opt_state["step"], base_lr=tcfg.lr,
                         warmup=tcfg.warmup_steps, total=tcfg.total_steps)
        new_params, new_opt, om = adamw_update(grads, opt_state, params, lr,
                                               ocfg)
        if pcfg.grad_compression == "int8_ef":
            new_opt["err"] = ebuf
        out_metrics = {"loss": loss, "lr": lr, **om}
        for k_, v in (metrics or {}).items():
            out_metrics[k_] = v
        return new_params, new_opt, out_metrics

    return train_step


def init_train_state(lm: LM, tcfg: TrainConfig, key,
                     pcfg: ParallelConfig | None = None):
    """(param values, param specs, opt state, opt specs)."""
    from repro.models.common import split_params
    tree = lm.init(key)
    values, specs = split_params(tree)
    ocfg = make_adamw_config(lm.cfg, tcfg)
    opt = init_opt_state(values, ocfg)
    ospecs = opt_state_specs(specs)
    if pcfg is not None and pcfg.grad_compression == "int8_ef":
        opt["err"] = init_error_buffer(values)
        ospecs = dict(ospecs)
        ospecs["err"] = specs
    return values, specs, opt, ospecs


def abstract_train_state(lm: LM, tcfg: TrainConfig, key):
    """ShapeDtypeStruct state + spec trees — the dry-run path (Param is a
    registered pytree with the spec as static aux, so eval_shape returns
    abstract values *and* concrete PartitionSpecs with no allocation)."""
    from repro.models.common import split_params

    tree = jax.eval_shape(lm.init, key)
    values, specs = split_params(tree)
    ocfg = make_adamw_config(lm.cfg, tcfg)
    opt = jax.eval_shape(functools.partial(init_opt_state, cfg=ocfg), values)
    ospecs = opt_state_specs(specs)
    return values, specs, opt, ospecs
