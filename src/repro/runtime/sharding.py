"""Mesh-aware sharding rules: spec trees -> NamedShardings, batch specs,
and per-arch parallelism defaults."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.models.common import MeshInfo


def use_mesh(mesh: Mesh):
    """Ambient-mesh context manager across jax versions: ``jax.set_mesh``
    where it exists, else the ``Mesh`` context manager (jax<0.7), which has
    the same axis-name-resolution effect for pjit/with_sharding_constraint."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def ambient_mesh() -> Mesh | None:
    """The mesh currently installed by :func:`use_mesh`, or None."""
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        m = get_abstract()
        if m is not None and getattr(m, "axis_names", ()):
            return m
    thread_resources = getattr(jax.interpreters.pxla, "thread_resources",
                               None)
    if thread_resources is not None:
        physical = thread_resources.env.physical_mesh
        if not physical.empty:
            return physical
    return None


def mesh_info(mesh: Mesh, fsdp: bool = False) -> MeshInfo:
    names = mesh.axis_names
    sizes = dict(zip(names, mesh.devices.shape))
    data_axes = tuple(a for a in ("pod", "data") if a in names)
    data = 1
    for a in data_axes:
        data *= sizes[a]
    return MeshInfo(data=data, model=sizes.get("model", 1),
                    data_axes=data_axes or ("data",), model_axis="model",
                    fsdp=fsdp)


def shardings_for(mesh: Mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree (same structure)."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, minfo: MeshInfo):
    """PartitionSpecs for the input batch of one cell.

    The batch dim shards over the DP axes when divisible; ``long_500k``'s
    batch of 1 replicates (its parallelism lives in the seq-sharded KV cache
    instead — SP)."""
    dp = minfo.dp() if shape.global_batch % minfo.data == 0 else None
    if shape.kind == "train":
        if cfg.frontend == "audio_stub":
            return {"frames": P(dp, None, None), "labels": P(dp, None)}
        if cfg.frontend == "vision_stub":
            return {"patches": P(dp, None, None), "tokens": P(dp, None),
                    "labels": P(dp, None)}
        return {"tokens": P(dp, None), "labels": P(dp, None)}
    if shape.kind == "prefill":
        if cfg.frontend == "audio_stub":
            return {"frames": P(dp, None, None)}
        if cfg.frontend == "vision_stub":
            return {"patches": P(dp, None, None), "tokens": P(dp, None)}
        return {"tokens": P(dp, None)}
    # decode
    if cfg.frontend == "audio_stub":
        return {"token": P(dp, None, None), "pos": P()}
    return {"token": P(dp, None), "pos": P()}


def default_parallel(arch: str) -> ParallelConfig:
    """Per-arch parallelism defaults (DESIGN.md §5).

    FSDP (param + optimizer sharding over the data axes) for the archs whose
    training state exceeds a model-sharded chip's HBM."""
    fsdp = arch in ("qwen2.5-32b", "kimi-k2-1t-a32b", "stablelm-12b")
    return ParallelConfig(fsdp=fsdp, remat="block")
