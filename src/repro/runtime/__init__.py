"""repro.runtime subpackage."""
