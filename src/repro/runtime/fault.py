"""Fault-tolerance utilities: step watchdog (straggler detection) and the
training-loop guard logic.

At 1000+ nodes the failure model is: (a) preemption signals (handled by
``CheckpointManager.install_preemption_handler`` -> emergency save), (b)
hard node loss (handled by restart-from-latest + elastic resharding, see
``checkpoint.manager`` and tests/test_fault.py), and (c) stragglers — slow
steps that stall the synchronous collective.  The watchdog keeps an EMA of
step wall-time and flags outliers; on a real fleet the launcher would
re-slot the offending host (here we log and count, which is what the
training loop can observe portably).
"""
from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class StepWatchdog:
    threshold: float = 2.0        # x EMA considered a straggler step
    decay: float = 0.9
    ema: float | None = None
    straggler_steps: int = 0
    total_steps: int = 0
    _t0: float | None = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> bool:
        """Returns True if this step was a straggler."""
        assert self._t0 is not None, "start() not called"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self.total_steps += 1
        slow = self.ema is not None and dt > self.threshold * self.ema
        if slow:
            self.straggler_steps += 1
        # EMA excludes straggler samples so one slow host can't mask itself
        if self.ema is None:
            self.ema = dt
        elif not slow:
            self.ema = self.decay * self.ema + (1 - self.decay) * dt
        return slow

    def summary(self) -> dict:
        return {"steps": self.total_steps, "stragglers": self.straggler_steps,
                "ema_step_s": self.ema}
