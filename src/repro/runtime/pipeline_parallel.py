"""GPipe-style pipeline parallelism over a mesh axis.

The layer stack is split into ``S`` equal stages along a mesh axis (the
``pod`` axis at production scale); microbatches stream through with
``collective_permute`` moving activations stage-to-stage.  The schedule is
the classic GPipe fill-drain loop expressed as one ``lax.scan`` over
``n_micro + S - 1`` ticks inside ``shard_map`` — fully differentiable
(collective_permute has a transpose rule: the reverse permute), so
``jax.grad`` through the pipelined forward just works; bubble overhead is
the usual (S-1)/(S-1+n_micro).

This module is deliberately model-agnostic: it pipelines any per-stage
``block_fn(stage_params, x) -> x``.  tests/test_pipeline.py checks exact
equivalence (fwd + grads) with the sequential stack on an 8-device host
mesh.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(block_fn: Callable, stage_params, x_micro, *,
                   mesh: Mesh, axis: str = "pod"):
    """Run microbatches through pipeline stages.

    block_fn: (params_for_one_stage, x) -> x          (pure)
    stage_params: pytree whose leaves have leading dim = n_stages (sharded
        over ``axis`` outside; inside the shard each device sees its own
        stage's slice with leading dim 1)
    x_micro: (n_micro, mb, ...) microbatched activations (replicated)

    Returns (n_micro, mb, ...) outputs (replicated over ``axis``).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1

    def stage_fn(params, xm):
        params = jax.tree.map(lambda v: v[0], params)   # this stage's slice
        idx = jax.lax.axis_index(axis)
        fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(carry, t):
            buf, outs = carry
            # select the incoming microbatch for stage 0 at tick t
            mb_in = jax.lax.dynamic_index_in_dim(
                xm, jnp.clip(t, 0, n_micro - 1), axis=0, keepdims=False)
            x_in = jnp.where(idx == 0, mb_in, buf)
            y = block_fn(params, x_in)
            # last stage emits microbatch t - (S-1) at tick t
            out_t = t - (n_stages - 1)
            outs = jax.lax.cond(
                out_t >= 0,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(out_t, 0, n_micro - 1), axis=0),
                lambda o: o, outs)
            # rotate activations to the next stage
            buf_next = jax.lax.ppermute(y, axis, fwd_perm)
            return (buf_next, outs), None

        buf0 = jnp.zeros_like(xm[0])
        outs0 = jnp.zeros_like(xm)
        (buf, outs), _ = jax.lax.scan(tick, (buf0, outs0),
                                      jnp.arange(ticks))
        # `outs` is valid only on the LAST stage; mask + psum replicates it.
        last = n_stages - 1
        outs = jax.lax.psum(
            jnp.where(idx == last, outs, jnp.zeros_like(outs)), axis)
        return outs

    in_specs = (P(axis), P())        # params sharded by stage; acts replicated
    out_specs = P()
    fn = shard_map(stage_fn, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    return fn(stage_params, x_micro)


def split_stages(stacked_params, n_stages: int):
    """(L, ...) stacked layer params -> (S, L/S, ...) stage-major."""
    def f(v):
        l = v.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return v.reshape(n_stages, l // n_stages, *v.shape[1:])
    return jax.tree.map(f, stacked_params)
