"""SLO-driven deployment selection: pick configs by simulated attainment.

``plan_deployment`` ranks ``(machine, dtype, batch)`` cells by *peak*
decode throughput — a steady-state number that says nothing about
queueing, batch formation, or tails.  This module re-scores the feasible
cells by what actually decides an edge deployment: run each one through
the discrete-event simulator under a concrete traffic scenario and keep
only the cells whose **simulated** p99 latency / TTFT / goodput meet the
:class:`SLO`.  The biggest batch usually wins peak throughput but loses
the tail (every decode step slows down with the pool size); the SLO mode
therefore picks a *smaller* batch whenever the tail demands it — with the
oversized cells recorded as machine-readable rejections
(``slo_p99_latency_exceeded`` et al.) right next to the memory rejections
in the deployment report.

``ServingEngine.autoconfigure(slo=...)`` is the front door; this module
is importable on its own for config-only studies (no params, no jax).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

from repro.simulate.metrics import SimReport
from repro.simulate.server import POLICIES, ServiceModel, simulate_serving
from repro.simulate.traffic import LengthDist, PoissonTraffic, Traffic

#: machine-readable SLO rejection reasons (join the REJECT_* memory codes
#: of ``repro.serving.report`` in ``DeploymentReport.rejected``)
REJECT_SLO_P99 = "slo_p99_latency_exceeded"
REJECT_SLO_TTFT = "slo_p95_ttft_exceeded"
REJECT_SLO_GOODPUT = "slo_goodput_below_min"
REJECT_SLO_UNFINISHED = "slo_unfinished_requests"
REJECT_SLO_SHED = "slo_shed_above_max"

#: rejections recorded under a fault scenario carry this prefix, so a
#: fair-weather-feasible cell that dies under throttle is distinguishable
#: (``fault_slo_p99_latency_exceeded`` vs ``slo_p99_latency_exceeded``)
FAULT_REJECT_PREFIX = "fault_"


@dataclasses.dataclass(frozen=True)
class SLO:
    """A serving service-level objective, checkable against a sim report.

    Unset fields are unconstrained.  ``p99_latency_s`` bounds end-to-end
    request latency at the 99th percentile; ``p95_ttft_s`` bounds time to
    first token at the 95th; ``min_goodput_tps`` floors completed
    tokens/second; ``require_finished`` rejects runs that left requests
    in flight (an unstable queue never meets any tail bound honestly);
    ``max_shed_fraction`` caps load shedding — without it, a deadline-
    shedding run could trivially "attain" any latency bound by serving
    almost nothing.
    """

    p99_latency_s: float | None = None
    p95_ttft_s: float | None = None
    min_goodput_tps: float | None = None
    require_finished: bool = True
    max_shed_fraction: float | None = None

    @classmethod
    def coerce(cls, spec: Any) -> "SLO":
        if isinstance(spec, cls):
            return spec
        if isinstance(spec, Mapping):
            return cls(**spec)
        if isinstance(spec, (int, float)):
            return cls(p99_latency_s=float(spec))
        raise TypeError(f"cannot interpret {spec!r} as an SLO (pass an "
                        "SLO, a kwargs dict, or a bare p99 latency bound)")

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def check(self, report: SimReport) -> list[dict]:
        """Machine-readable violations of this SLO in one sim report
        (empty list == attained)."""
        out = []

        def add(reason: str, observed: float, limit: float) -> None:
            out.append({"reason": reason, "observed": observed,
                        "limit": limit})

        if self.require_finished and report.requests["unfinished"]:
            add(REJECT_SLO_UNFINISHED, report.requests["unfinished"], 0)
        if self.max_shed_fraction is not None \
                and report.shed_fraction > self.max_shed_fraction:
            add(REJECT_SLO_SHED, report.shed_fraction,
                self.max_shed_fraction)
        if not report.requests["finished"]:
            return out
        if self.p99_latency_s is not None \
                and report.latency["p99"] > self.p99_latency_s:
            add(REJECT_SLO_P99, report.latency["p99"], self.p99_latency_s)
        if self.p95_ttft_s is not None \
                and report.ttft["p95"] > self.p95_ttft_s:
            add(REJECT_SLO_TTFT, report.ttft["p95"], self.p95_ttft_s)
        if self.min_goodput_tps is not None \
                and report.goodput_tps < self.min_goodput_tps:
            add(REJECT_SLO_GOODPUT, report.goodput_tps,
                self.min_goodput_tps)
        return out


def default_traffic(report, *, utilization: float = 0.6,
                    prompt_len: Any = 32, decode_len: Any = 16,
                    seed: int = 0) -> Traffic:
    """A Poisson scenario pinned to the deployment report: arrivals at
    ``utilization`` x the *peak* cell's request throughput (peak tokens/s
    divided by the mean decode length).  Deterministic given the report,
    so ``autoconfigure(slo=...)`` without an explicit traffic argument is
    reproducible."""
    if not report.options:
        raise ValueError("deployment report has no feasible options to "
                         "derive a traffic rate from")
    decode = LengthDist.coerce(decode_len)
    mean_decode = max(1.0, decode.mean_value(report.max_len))
    peak_rps = max(o.tokens_per_second for o in report.options) / mean_decode
    return PoissonTraffic(rate=utilization * peak_rps,
                          prompt_len=prompt_len, decode_len=decode,
                          seed=seed)


@dataclasses.dataclass
class SloSelection:
    """The sim-backed pick plus everything it was picked from."""

    option: Any                         # DeploymentOption
    policy: str
    sim: SimReport
    traffic_name: str
    slo: SLO
    results: list[dict]                 # one summary per (option, policy)
    rejections: list                    # CellRejection, SLO-reason coded
    faults: str | None = None           # fault scenario the cells ran under

    def as_dict(self) -> dict:
        return {
            "machine": self.option.machine, "dtype": self.option.dtype,
            "batch": self.option.batch, "policy": self.policy,
            "traffic": self.traffic_name, "slo": self.slo.as_dict(),
            "faults": self.faults,
            "sim": self.sim.summary(),
            "results": list(self.results),
            "rejected": [r.as_dict() for r in self.rejections],
        }


def evaluate_deployment(cfg, report, *, slo, traffic: Traffic | None = None,
                        policies: Sequence[str] = ("greedy",),
                        requests: int = 200, seed: int = 0,
                        machines: Mapping[str, Any] | None = None,
                        faults=None, deadline_s: float | None = None,
                        queue_limit: int | None = None,
                        attach: bool = True) -> SloSelection:
    """Simulate every feasible option of a deployment report under one
    traffic scenario and select by SLO attainment.

    Args:
        cfg: the model config the report was planned for.
        report: a :class:`repro.serving.report.DeploymentReport`.
        slo: an :class:`SLO` (or anything :meth:`SLO.coerce` takes).
        traffic: the scenario; ``None`` uses :func:`default_traffic`.
        policies: admission policies to cross with the options (see
            ``repro.simulate.server.POLICIES``; the real engine admits
            greedily).
        requests: simulated stream length per cell.
        seed: seeds the default traffic (an explicit ``traffic`` keeps
            its own seed).
        machines: optional ``name -> MachineSpec`` overrides for options
            planned on unregistered (derived) specs.
        faults: a :class:`~repro.simulate.faults.FaultScenario` (or
            registry name / dict): every cell is simulated *under the
            perturbation* — the robust mode.  Cells that only fail under
            the faults are rejected with ``fault_``-prefixed reasons
            (``fault_slo_p99_latency_exceeded`` ...), so the report
            distinguishes fair-weather losers from fault casualties.
        deadline_s / queue_limit: optional shedding knobs forwarded to the
            simulated server (pair ``deadline_s`` with
            ``slo.max_shed_fraction`` so shedding cannot trivially attain
            the tail bound).
        attach: annotate the report in place — sim summaries onto the
            options, SLO rejections into ``report.rejected``, and the
            whole evaluation under ``report.slo``.

    Returns:
        A :class:`SloSelection`.  The winner is the SLO-attaining
        ``(option, policy)`` cell with the best simulated goodput,
        native-dtype cells preferred (mirroring ``report.select()``);
        ties break toward the smaller batch.

    Raises:
        ValueError: when no cell attains the SLO — the error carries every
            per-cell violation, machine-readably mirrored in
            ``report.rejected`` when ``attach`` is set.
    """
    from repro.serving.report import CellRejection
    from repro.simulate.faults import FaultScenario

    slo = SLO.coerce(slo)
    for p in policies:
        if p not in POLICIES:
            raise ValueError(f"unknown admission policy {p!r}; "
                             f"have {POLICIES}")
    if traffic is None:
        traffic = default_traffic(report, seed=seed)
    machines = dict(machines or {})
    scenario = FaultScenario.coerce(faults) if faults is not None else None
    prefix = FAULT_REJECT_PREFIX if scenario is not None else ""

    services: dict[tuple, ServiceModel] = {}
    results: list[dict] = []
    candidates: list[tuple] = []
    rejections: list = []
    sims: dict[int, dict] = {}          # option index -> policy -> summary
    for i, o in enumerate(report.options):
        key = (o.machine, o.dtype, o.batch)
        if key not in services:
            # a mixed-precision what-if cell's dtype is its "AxB->ACC"
            # label; plan its prefill ladder under the PrecisionConfig
            # with the compute dtype as the plannable base tag
            pc = o.precision
            plan_dtype = o.dtype
            if pc is not None:
                from repro.core.precision import PrecisionConfig
                plan_dtype = PrecisionConfig.parse(pc).compute_dtype
            services[key] = ServiceModel.from_plans(
                cfg, batch=o.batch, machine=machines.get(o.machine,
                                                         o.machine),
                dtype=plan_dtype, precision=pc, backend=report.backend,
                max_len=report.max_len, decode_step_s=o.seconds_per_step)
        for policy in policies:
            rep = simulate_serving(
                services[key], traffic, max_batch=o.batch,
                max_len=report.max_len, policy=policy, requests=requests,
                deadline_s=deadline_s, queue_limit=queue_limit,
                faults=scenario,
                config={"machine": o.machine, "dtype": o.dtype})
            violations = slo.check(rep)
            row = {"machine": o.machine, "dtype": o.dtype,
                   "batch": o.batch, "policy": policy,
                   "peak_tokens_per_second": o.tokens_per_second,
                   "goodput_tps": rep.goodput_tps,
                   "p99_latency_s": rep.latency.get("p99"),
                   "p95_ttft_s": rep.ttft.get("p95"),
                   "slo_attained": not violations,
                   "violations": violations}
            if scenario is not None:
                row["faults"] = scenario.name
                row["shed_fraction"] = rep.shed_fraction
            results.append(row)
            sims.setdefault(i, {})[policy] = {
                "goodput_tps": rep.goodput_tps,
                "latency": rep.latency, "ttft": rep.ttft,
                "slo_attained": not violations}
            if violations:
                rejections.append(CellRejection(
                    machine=o.machine, dtype=o.dtype, batch=o.batch,
                    reason=prefix + violations[0]["reason"],
                    footprint_bytes=o.footprint.total_bytes,
                    budget_bytes=o.budget_bytes,
                    detail={"policy": policy, "traffic": traffic.name,
                            **({"faults": scenario.name}
                               if scenario is not None else {}),
                            "violations": violations}))
            elif o.precision is None:
                candidates.append((o, policy, rep))
            # mixed-precision what-if cells are simulated for the results
            # table but never deployed (mirroring report.select(): the
            # engine has no kernels to freeze for them)

    if attach:
        report.options = [
            dataclasses.replace(o, sim=sims.get(i)) if i in sims else o
            for i, o in enumerate(report.options)]
        report.rejected.extend(rejections)

    if not candidates:
        under = traffic.name + (f" + faults {scenario.name}"
                                if scenario is not None else "")
        why = "; ".join(sorted({
            f"{r['machine']}/{r['dtype']}/b{r['batch']}/{r['policy']}: "
            + ",".join(v["reason"] for v in r["violations"])
            for r in results if r["violations"]})) or "no options simulated"
        raise ValueError(
            f"no (machine, dtype, batch, policy) cell attains the SLO "
            f"{slo.as_dict()} under {under}: {why}")

    native = [c for c in candidates if c[0].dtype == report.native_dtype]
    pool = native or candidates
    option, policy, rep = min(
        pool, key=lambda c: (-c[2].goodput_tps, c[0].batch, c[0].machine,
                             c[0].dtype, c[1]))
    selection = SloSelection(
        option=option, policy=policy, sim=rep, traffic_name=traffic.name,
        slo=slo, results=results, rejections=rejections,
        faults=scenario.name if scenario is not None else None)
    if attach:
        report.slo = {
            "slo": slo.as_dict(), "traffic": traffic.name,
            "faults": scenario.name if scenario is not None else None,
            "requests": requests, "policies": list(policies),
            "selected": {"machine": option.machine, "dtype": option.dtype,
                         "batch": option.batch, "policy": policy,
                         "goodput_tps": rep.goodput_tps,
                         "p99_latency_s": rep.latency.get("p99")},
            "results": results,
        }
    return selection
