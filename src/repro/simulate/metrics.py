"""Simulation metrics: per-request records -> tail-latency report.

The collector receives lifecycle callbacks from the slot server (arrival,
admission, first token, finish) plus one sample per decode step (duration,
active slots, queue depth), and reduces them to the numbers an SLO is
written against: latency / TTFT / queue-wait percentiles, goodput, slot
utilization and queue depth.  The report persists as JSON
(``repro.simulate/report-v1``) exactly like ``repro.measure``'s validation
reports, so simulated and measured artifacts live side by side.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Any, Mapping

REPORT_SCHEMA = "repro.simulate/report-v1"


def percentile(xs, q: float) -> float:
    """Linear-interpolation percentile (``q`` in [0, 100]) of a sequence;
    NaN on empty input."""
    xs = sorted(xs)
    if not xs:
        return float("nan")
    if len(xs) == 1:
        return float(xs[0])
    pos = (q / 100.0) * (len(xs) - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(xs) - 1)
    frac = pos - lo
    return float(xs[lo] * (1.0 - frac) + xs[hi] * frac)


def _dist(xs) -> dict:
    xs = list(xs)
    if not xs:
        return {"count": 0}
    return {
        "count": len(xs),
        "mean": sum(xs) / len(xs),
        "p50": percentile(xs, 50), "p95": percentile(xs, 95),
        "p99": percentile(xs, 99), "max": max(xs),
    }


@dataclasses.dataclass
class RequestRecord:
    """Lifecycle timestamps of one simulated request (sim seconds)."""

    rid: int
    arrival_s: float
    prompt_len: int
    decode_len: int
    admit_s: float | None = None
    first_token_s: float | None = None
    finish_s: float | None = None
    tokens: int = 0
    deadline_s: float | None = None
    shed_s: float | None = None
    shed_cause: str | None = None

    @property
    def done(self) -> bool:
        return self.finish_s is not None

    @property
    def shed(self) -> bool:
        return self.shed_cause is not None

    @property
    def deadline_met(self) -> bool | None:
        """Whether the finish beat the deadline; ``None`` without one (or
        without a finish — a shed request never met its deadline)."""
        if self.deadline_s is None:
            return None
        if self.finish_s is None:
            return False if self.shed else None
        return (self.finish_s - self.arrival_s) <= self.deadline_s

    @property
    def wait_s(self) -> float:
        """Queue time: arrival -> admission."""
        return self.admit_s - self.arrival_s

    @property
    def ttft_s(self) -> float:
        """Time to first token: arrival -> first decode token."""
        return self.first_token_s - self.arrival_s

    @property
    def latency_s(self) -> float:
        """End-to-end: arrival -> last token."""
        return self.finish_s - self.arrival_s

    @property
    def service_s(self) -> float:
        """Admission -> finish (time actually holding a slot)."""
        return self.finish_s - self.admit_s


@dataclasses.dataclass(frozen=True)
class StepSample:
    """One decode step: when it started, how long it took, and occupancy."""

    t: float
    dt: float
    active: int
    admitted: int
    queue_depth: int


class Metrics:
    """Collector wired into the slot server's lifecycle hooks."""

    def __init__(self):
        self.records: dict[int, RequestRecord] = {}
        self.steps: list[StepSample] = []
        self.finish_order: list[int] = []

    # -- lifecycle hooks ----------------------------------------------------
    def on_arrival(self, rid: int, t: float, prompt_len: int,
                   decode_len: int, deadline_s: float | None = None) -> None:
        self.records[rid] = RequestRecord(
            rid=rid, arrival_s=t, prompt_len=prompt_len,
            decode_len=decode_len, deadline_s=deadline_s)

    def on_shed(self, rid: int, t: float, cause: str) -> None:
        r = self.records[rid]
        r.shed_s = t
        r.shed_cause = cause

    def on_requeue(self, rid: int, t: float) -> None:
        """A slot failure evicted this request: its generated prefix is
        lost (never delivered), so the token/TTFT bookkeeping restarts."""
        r = self.records[rid]
        r.tokens = 0
        r.first_token_s = None
        r.admit_s = None

    def on_admit(self, rid: int, t: float) -> None:
        self.records[rid].admit_s = t

    def on_token(self, rid: int, t: float) -> None:
        r = self.records[rid]
        r.tokens += 1
        if r.first_token_s is None:
            r.first_token_s = t

    def on_finish(self, rid: int, t: float) -> None:
        self.records[rid].finish_s = t
        self.finish_order.append(rid)

    def on_step(self, sample: StepSample) -> None:
        self.steps.append(sample)

    # -- reduction ----------------------------------------------------------
    def report(self, *, config: Mapping[str, Any] | None = None,
               max_batch: int | None = None,
               faults: Mapping[str, Any] | None = None,
               drift: Mapping[str, Any] | None = None) -> "SimReport":
        done = [r for r in self.records.values() if r.done]
        shed = [r for r in self.records.values() if r.shed]
        busy = sum(s.dt for s in self.steps)
        span = max((r.finish_s for r in done), default=0.0)
        util = (sum(s.active * s.dt for s in self.steps)
                / (busy * max_batch)) if busy and max_batch else 0.0
        tokens = sum(r.tokens for r in done)
        causes: dict[str, int] = {}
        for r in shed:
            causes[r.shed_cause] = causes.get(r.shed_cause, 0) + 1
        with_deadline = [r for r in self.records.values()
                         if r.deadline_s is not None]
        deadline = {}
        if with_deadline:
            met = sum(1 for r in with_deadline if r.deadline_met)
            deadline = {"requests": len(with_deadline), "met": met,
                        "violated": len(with_deadline) - met}
        return SimReport(
            config=dict(config or {}),
            requests={"submitted": len(self.records), "finished": len(done),
                      "shed": len(shed),
                      "unfinished":
                          len(self.records) - len(done) - len(shed)},
            shed={"count": len(shed), "causes": causes} if shed else {},
            deadline=deadline,
            faults=dict(faults or {}),
            drift=dict(drift or {}),
            latency=_dist(r.latency_s for r in done),
            ttft=_dist(r.ttft_s for r in done),
            wait=_dist(r.wait_s for r in done),
            goodput_tps=(tokens / span) if span > 0 else 0.0,
            requests_per_s=(len(done) / span) if span > 0 else 0.0,
            queue={"mean_depth": (sum(s.queue_depth * s.dt for s in
                                      self.steps) / busy) if busy else 0.0,
                   "max_depth": max((s.queue_depth for s in self.steps),
                                    default=0)},
            slot_utilization=util,
            steps=len(self.steps),
            busy_s=busy,
            span_s=span,
            finish_order=list(self.finish_order),
            per_request=[dataclasses.asdict(r) for r in
                         sorted(self.records.values(), key=lambda r: r.rid)],
        )


@dataclasses.dataclass
class SimReport:
    """One simulation run, reduced.  ``config`` carries the cell identity
    (machine, dtype, batch, policy, traffic, seed) the run was scored at."""

    config: dict
    requests: dict
    latency: dict
    ttft: dict
    wait: dict
    goodput_tps: float
    requests_per_s: float
    queue: dict
    slot_utilization: float
    steps: int
    busy_s: float
    span_s: float
    shed: dict = dataclasses.field(default_factory=dict)
    deadline: dict = dataclasses.field(default_factory=dict)
    faults: dict = dataclasses.field(default_factory=dict)
    # online prediction-drift verdict (repro.obs DriftMonitor.report()):
    # {} when the run carried no monitor, so older saved reports round-trip.
    drift: dict = dataclasses.field(default_factory=dict)
    finish_order: list[int] = dataclasses.field(default_factory=list)
    per_request: list[dict] = dataclasses.field(default_factory=list)

    @property
    def p99_latency_s(self) -> float:
        return self.latency.get("p99", float("nan"))

    @property
    def shed_count(self) -> int:
        return self.requests.get("shed", self.shed.get("count", 0))

    @property
    def shed_fraction(self) -> float:
        """Shed requests as a fraction of everything submitted."""
        n = self.requests.get("submitted", 0)
        return (self.shed_count / n) if n else 0.0

    @property
    def finite(self) -> bool:
        keys = ("mean", "p50", "p95", "p99", "max")
        return self.requests["finished"] > 0 and all(
            math.isfinite(self.latency[k]) for k in keys)

    def summary(self) -> dict:
        return {
            "config": self.config,
            "requests": self.requests,
            "latency": self.latency, "ttft": self.ttft, "wait": self.wait,
            "goodput_tps": self.goodput_tps,
            "requests_per_s": self.requests_per_s,
            "queue": self.queue,
            "slot_utilization": self.slot_utilization,
            "steps": self.steps, "busy_s": self.busy_s, "span_s": self.span_s,
            "shed": self.shed, "deadline": self.deadline,
            "faults": self.faults, "drift": self.drift,
        }

    def table(self) -> str:
        c = self.config
        lines = [
            f"sim {c.get('machine', '?')} dtype={c.get('dtype', '?')} "
            f"batch={c.get('batch', '?')} policy={c.get('policy', '?')} "
            f"traffic={c.get('traffic', '?')}",
            f"  requests   {self.requests['finished']}/"
            f"{self.requests['submitted']} finished "
            f"({self.requests['unfinished']} unfinished), "
            f"{self.steps} steps over {self.span_s:.4g}s",
            f"  goodput    {self.goodput_tps:.4g} tok/s "
            f"({self.requests_per_s:.4g} req/s), slot utilization "
            f"{self.slot_utilization:.1%}",
        ]
        for label, d in (("latency", self.latency), ("ttft", self.ttft),
                         ("wait", self.wait)):
            if d.get("count"):
                lines.append(
                    f"  {label:<9}  p50 {d['p50']:.4g}s  p95 {d['p95']:.4g}s"
                    f"  p99 {d['p99']:.4g}s  max {d['max']:.4g}s")
        lines.append(f"  queue      mean depth {self.queue['mean_depth']:.2f}"
                     f", max {self.queue['max_depth']}")
        if self.shed.get("count"):
            causes = ", ".join(f"{k}={v}" for k, v in
                               sorted(self.shed["causes"].items()))
            lines.append(f"  shed       {self.shed['count']} "
                         f"({self.shed_fraction:.1%}): {causes}")
        if self.deadline:
            lines.append(f"  deadline   {self.deadline['met']}/"
                         f"{self.deadline['requests']} met "
                         f"({self.deadline['violated']} violated)")
        if self.faults:
            bits = ", ".join(f"{k}={v}" for k, v in
                             sorted(self.faults.items())
                             if not isinstance(v, (dict, list)))
            lines.append(f"  faults     {bits}" if bits else
                         f"  faults     {self.faults.get('scenario', '?')}")
        return "\n".join(lines)

    def to_json(self) -> dict:
        return {"schema": REPORT_SCHEMA, **self.summary(),
                "finish_order": self.finish_order,
                "per_request": self.per_request}

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)
        return path

    @classmethod
    def from_json(cls, d: Mapping[str, Any]) -> "SimReport":
        if d.get("schema") != REPORT_SCHEMA:
            raise ValueError(f"unknown sim-report schema {d.get('schema')!r}")
        kw = {f.name: d[f.name] for f in dataclasses.fields(cls)
              if f.name in d}
        return cls(**kw)

    @classmethod
    def load(cls, path: str) -> "SimReport":
        with open(path) as f:
            return cls.from_json(json.load(f))
