"""Fault-injection scenarios for the serving simulator.

The paper's premise is that edge processors *diverge* from their nominal
rates — DVFS, thermal throttling, co-tenant contention — yet a plain
simulation run executes every step at the calibrated price and delivers
every arrival on schedule.  A :class:`FaultScenario` perturbs one run
three ways, all reproducible from the scenario's own seed:

* **Thermal throttle windows** (:class:`ThrottleWindow`): between
  ``start_s`` and ``start_s + duration_s`` every service time is scaled
  by ``factor`` (1.25 = 25% slower, the classic DVFS step-down).  With
  ``period_s`` set the windows repeat, modelling a duty-cycled thermal
  limit.
* **Transient slot failures** (``slot_mtbf_s``): a slot dies mid-step at
  exponentially-distributed intervals; its request loses that step's
  token, is reset, and re-queued at the *front* (it keeps its arrival
  time, so the latency hit is visible in the tail).
* **Arrival surges** (:class:`ArrivalSurge`): a burst of extra requests
  injected on top of the nominal traffic at a fixed time — the flash
  crowd the admission/shedding policy must survive.

Scenarios serialise (``as_dict`` / ``coerce`` round-trip) so a CLI flag,
a CI smoke, and an autoconfiguration sweep all name the same perturbation;
the named registry (:data:`SCENARIOS`) carries the canonical ones,
``"throttle20"`` being the 20%-duty throttle window the robust
autoconfiguration defaults to.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Any, Iterator, Mapping

FAULTS_SCHEMA = "repro.simulate/faults-v1"


@dataclasses.dataclass(frozen=True)
class ThrottleWindow:
    """One service-time scaling window: ``[start_s, start_s + duration_s)``
    costs ``factor``× the calibrated price."""

    start_s: float
    duration_s: float
    factor: float

    def __post_init__(self):
        if self.duration_s <= 0:
            raise ValueError(f"throttle duration must be positive, "
                             f"got {self.duration_s}")
        if self.factor <= 0:
            raise ValueError(f"throttle factor must be positive, "
                             f"got {self.factor}")

    def active(self, t: float) -> bool:
        return self.start_s <= t < self.start_s + self.duration_s

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class ArrivalSurge:
    """A burst of ``requests`` extra arrivals injected at ``at_s`` (on top
    of the nominal traffic)."""

    at_s: float
    requests: int
    prompt_len: int = 32
    decode_len: int = 16

    def __post_init__(self):
        if self.requests < 1:
            raise ValueError(f"surge needs >= 1 request, got {self.requests}")

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


# rids of surge-injected requests start here, far above any traffic stream
SURGE_RID_BASE = 1_000_000


@dataclasses.dataclass(frozen=True)
class FaultScenario:
    """A named, seeded perturbation schedule for one simulation run.

    All randomness (slot-failure times and victims) comes from the
    scenario's own ``random.Random(seed)`` drawn in schedule order, so the
    same scenario perturbs the same run identically every time.
    """

    name: str
    throttles: tuple = ()
    period_s: float | None = None       # repeat throttle windows every period
    slot_mtbf_s: float | None = None    # mean time between slot failures
    surges: tuple = ()
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "throttles", tuple(
            t if isinstance(t, ThrottleWindow) else ThrottleWindow(**t)
            for t in self.throttles))
        object.__setattr__(self, "surges", tuple(
            s if isinstance(s, ArrivalSurge) else ArrivalSurge(**s)
            for s in self.surges))
        if self.period_s is not None and self.period_s <= 0:
            raise ValueError(f"period must be positive, got {self.period_s}")
        if self.slot_mtbf_s is not None and self.slot_mtbf_s <= 0:
            raise ValueError(f"slot MTBF must be positive, "
                             f"got {self.slot_mtbf_s}")

    # -- service-time perturbation -------------------------------------------
    def service_scale(self, t: float) -> float:
        """Multiplier on service times at sim time ``t`` (overlapping
        windows compound)."""
        if self.period_s is not None:
            t = t % self.period_s
        scale = 1.0
        for w in self.throttles:
            if w.active(t):
                scale *= w.factor
        return scale

    # -- slot failures -------------------------------------------------------
    def failures(self) -> Iterator[tuple[float, float]]:
        """Infinite stream of ``(gap_s, victim_u)`` pairs: exponential
        inter-failure gaps at the configured MTBF plus a uniform [0,1)
        draw the server maps onto a victim slot.  Empty when no MTBF is
        set.  A fresh, identically-seeded stream per call."""
        if self.slot_mtbf_s is None:
            return
        rng = random.Random(self.seed)
        while True:
            yield rng.expovariate(1.0 / self.slot_mtbf_s), rng.random()

    def surge_requests(self) -> list:
        """The extra arrivals of every surge, as ``SimRequest`` records
        with rids from :data:`SURGE_RID_BASE` up."""
        from repro.simulate.traffic import SimRequest
        out, rid = [], SURGE_RID_BASE
        for s in self.surges:
            for _ in range(s.requests):
                out.append(SimRequest(rid=rid, arrival_s=s.at_s,
                                      prompt_len=s.prompt_len,
                                      decode_len=s.decode_len))
                rid += 1
        return out

    # -- serialisation -------------------------------------------------------
    def as_dict(self) -> dict:
        return {"schema": FAULTS_SCHEMA, "name": self.name,
                "throttles": [w.as_dict() for w in self.throttles],
                "period_s": self.period_s,
                "slot_mtbf_s": self.slot_mtbf_s,
                "surges": [s.as_dict() for s in self.surges],
                "seed": self.seed}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "FaultScenario":
        schema = d.get("schema", FAULTS_SCHEMA)
        if schema != FAULTS_SCHEMA:
            raise ValueError(f"unknown fault-scenario schema {schema!r} "
                             f"(want {FAULTS_SCHEMA})")
        kw = {k: d[k] for k in ("name", "throttles", "period_s",
                                "slot_mtbf_s", "surges", "seed") if k in d}
        return cls(**kw)

    @classmethod
    def coerce(cls, spec: Any) -> "FaultScenario":
        """Registry name -> scenario, dict -> :meth:`from_dict`,
        pass-through for instances."""
        if isinstance(spec, cls):
            return spec
        if isinstance(spec, str):
            try:
                return SCENARIOS[spec]
            except KeyError:
                raise ValueError(
                    f"unknown fault scenario {spec!r}; "
                    f"have {sorted(SCENARIOS)}") from None
        if isinstance(spec, Mapping):
            return cls.from_dict(spec)
        raise TypeError(f"cannot interpret {spec!r} as a fault scenario "
                        "(name, dict, or FaultScenario)")


def throttle_scenario(*, factor: float = 2.0, duty: float = 0.2,
                      period_s: float = 10.0, name: str | None = None,
                      seed: int = 0) -> FaultScenario:
    """A duty-cycled thermal throttle: ``duty`` of every ``period_s``
    window runs ``factor``× slower.  The robust-autoconfiguration default
    (``"throttle20"``) is ``factor=2, duty=0.2, period_s=10``."""
    if not 0.0 < duty < 1.0:
        raise ValueError(f"duty must be in (0, 1), got {duty}")
    return FaultScenario(
        name=name or f"throttle{int(round(duty * 100))}",
        throttles=(ThrottleWindow(start_s=0.0, duration_s=duty * period_s,
                                  factor=factor),),
        period_s=period_s, seed=seed)


SCENARIOS: dict[str, FaultScenario] = {
    # the canonical robust-autoconfiguration perturbation: 20% of every
    # 10 s window runs at half speed (one DVFS step down)
    "throttle20": throttle_scenario(factor=2.0, duty=0.2, period_s=10.0),
    # a harsher sustained brown-out: half of every window at half speed
    "throttle50": throttle_scenario(factor=2.0, duty=0.5, period_s=10.0),
    # transient slot failures, one per ~5 s of sim time on average
    "flaky-slots": FaultScenario(name="flaky-slots", slot_mtbf_s=5.0),
    # a flash crowd 2 s in, on top of whatever the nominal traffic sends
    "flash-crowd": FaultScenario(
        name="flash-crowd",
        surges=(ArrivalSurge(at_s=2.0, requests=32),)),
    # everything at once — the CI overload smoke uses this family
    "storm": FaultScenario(
        name="storm",
        throttles=(ThrottleWindow(start_s=0.0, duration_s=2.0, factor=2.0),),
        period_s=10.0, slot_mtbf_s=8.0,
        surges=(ArrivalSurge(at_s=1.0, requests=24),)),
}
