"""Replay a real ``ServingEngine`` trace through the simulator.

The real engine emits an event trace (``repro.serving/trace-v1``: submits,
admissions, steps with wall-clock durations, finishes).  Replaying it here
closes the loop in the direction the ``repro.measure`` subsystem closes it
for single GEMMs: the simulator re-enacts the recorded arrival stream
through its own queue/slot/step logic and the result is a validation
report — did the sim admit and finish requests in the same order, and how
far off are its latencies?

Two service modes:

* **measured** (``service=None``, the default): step ``k`` costs the real
  trace's ``k``-th recorded step duration.  This validates the *dynamics*
  (queueing, admission, batch formation) in isolation — with correct
  semantics the replayed latencies match the recorded ones almost exactly
  (same step count, same completion order; timestamps agree to the
  sub-step bookkeeping the engine does after stamping, documented at
  <2%).
* **model** (pass a :class:`~repro.simulate.server.ServiceModel`): steps
  cost the analytic price.  Order should still match; the latency MAPE is
  then a statement about the calibrated cost model, directly comparable
  to ``repro.measure``'s per-GEMM MAPE reports.
"""
from __future__ import annotations

import dataclasses
import json
import os
import statistics
from typing import Any, Mapping

from repro.simulate.engine import Simulator
from repro.simulate.metrics import Metrics
from repro.simulate.server import ServiceModel, SlotServer
from repro.simulate.traffic import SimRequest, TraceTraffic

TRACE_SCHEMA = "repro.serving/trace-v1"
REPLAY_SCHEMA = "repro.simulate/replay-v1"


def load_trace(path: str) -> dict:
    with open(path) as f:
        trace = json.load(f)
    return check_trace(trace)


def check_trace(trace: Mapping[str, Any]) -> dict:
    if trace.get("schema") != TRACE_SCHEMA:
        raise ValueError(f"unknown trace schema {trace.get('schema')!r} "
                         f"(want {TRACE_SCHEMA})")
    return dict(trace)


def _events(trace: Mapping[str, Any], kind: str) -> list[dict]:
    return [e for e in trace["events"] if e["type"] == kind]


def trace_requests(trace: Mapping[str, Any]) -> list[SimRequest]:
    """The recorded arrival stream as :class:`SimRequest` records.

    Arrival times are rebased so the first submit lands at t=0.  The
    decode length is the *actual* generated token count from the finish
    event (EOS and cache-limit stops included); a request the trace never
    finishes falls back to its ``max_new_tokens``.
    """
    submits = _events(trace, "submit")
    if not submits:
        raise ValueError("trace contains no submit events")
    t0 = min(e["t"] for e in submits)
    generated = {e["rid"]: e["tokens"] for e in _events(trace, "finish")}
    # a shed request has no finish event; its decode_len stays at
    # max_new_tokens — exactly what the engine's shedding decision priced
    return [SimRequest(
        rid=e["rid"], arrival_s=e["t"] - t0, prompt_len=e["prompt_len"],
        decode_len=generated.get(e["rid"], e["max_new_tokens"]),
        deadline_s=e.get("deadline_s"),
    ) for e in sorted(submits, key=lambda e: (e["t"], e["rid"]))]


def trace_traffic(trace: Mapping[str, Any]) -> TraceTraffic:
    """The recorded stream as a :class:`TraceTraffic` generator — feed it
    back to :func:`repro.simulate.server.simulate_serving` (round-trips
    the request list bit-exactly)."""
    return TraceTraffic(trace_requests(trace))


def _fallback_service(trace: Mapping[str, Any]) -> ServiceModel:
    """A service model for measured replay's overflow: pure-decode steps
    (no admissions) price the decode step; prefill is unpriced (the
    measured durations normally cover every step, this is a backstop)."""
    steps = _events(trace, "step")
    decode = [e["dt"] for e in steps if not e.get("admitted")]
    dt = statistics.median(decode or [e["dt"] for e in steps] or [0.0])
    return ServiceModel(decode_step_s=dt, prefill_s={})


@dataclasses.dataclass(frozen=True)
class ReplayRow:
    """One request, real vs simulated."""

    rid: int
    real_latency_s: float
    sim_latency_s: float
    real_ttft_s: float | None = None
    sim_ttft_s: float | None = None

    @property
    def rel_err(self) -> float:
        return self.sim_latency_s / self.real_latency_s - 1.0

    @property
    def ape(self) -> float:
        return abs(self.sim_latency_s - self.real_latency_s) \
            / self.real_latency_s

    def as_dict(self) -> dict:
        return {"rid": self.rid, "real_latency_s": self.real_latency_s,
                "sim_latency_s": self.sim_latency_s,
                "real_ttft_s": self.real_ttft_s,
                "sim_ttft_s": self.sim_ttft_s,
                "rel_err": self.rel_err, "ape": self.ape}


@dataclasses.dataclass
class ReplayReport:
    """Sim-vs-real verdict for one trace."""

    mode: str                       # "measured" | "model"
    rows: list[ReplayRow]
    real_order: list[int]
    sim_order: list[int]
    steps_real: int
    steps_sim: int
    config: dict = dataclasses.field(default_factory=dict)
    # rid -> shed cause, both sides (empty when the trace has no deadlines)
    real_shed: dict = dataclasses.field(default_factory=dict)
    sim_shed: dict = dataclasses.field(default_factory=dict)

    @property
    def order_match(self) -> bool:
        return self.real_order == self.sim_order

    @property
    def shed_match(self) -> bool:
        """Did the simulator shed exactly the requests the real engine
        shed (by rid)?  The headline of resilience replay validation."""
        return set(self.real_shed) == set(self.sim_shed)

    @property
    def steps_match(self) -> bool:
        return self.steps_real == self.steps_sim

    @property
    def mape(self) -> float:
        """Mean absolute percentage latency error, in percent."""
        if not self.rows:
            return float("nan")
        return 100.0 * statistics.fmean(r.ape for r in self.rows)

    @property
    def worst(self) -> ReplayRow:
        return max(self.rows, key=lambda r: r.ape)

    def summary(self) -> dict:
        out = {
            "mode": self.mode, "requests": len(self.rows),
            "order_match": self.order_match,
            "steps_real": self.steps_real, "steps_sim": self.steps_sim,
            "mape_pct": self.mape, "config": self.config,
        }
        if self.real_shed or self.sim_shed:
            out["shed"] = {"match": self.shed_match,
                           "real": dict(self.real_shed),
                           "sim": dict(self.sim_shed)}
        if self.rows:
            w = self.worst
            out["worst"] = {"rid": w.rid, "ape_pct": 100.0 * w.ape,
                            "real_latency_s": w.real_latency_s,
                            "sim_latency_s": w.sim_latency_s}
        return out

    def table(self, limit: int | None = None) -> str:
        lines = [f"replay ({self.mode} service): "
                 f"{len(self.rows)} requests, steps real/sim "
                 f"{self.steps_real}/{self.steps_sim}, completion order "
                 + ("MATCH" if self.order_match else
                    f"MISMATCH {self.real_order} vs {self.sim_order}"),
                 "rid   real latency   sim latency     rel err"]
        for r in self.rows[:limit]:
            lines.append(f"{r.rid:<6}{r.real_latency_s:>11.4e} "
                         f"{r.sim_latency_s:>13.4e}{r.rel_err:>+11.2%}")
        if limit is not None and len(self.rows) > limit:
            lines.append(f"... ({len(self.rows) - limit} more rows)")
        lines.append(f"latency MAPE {self.mape:.2f}%")
        return "\n".join(lines)

    def to_json(self) -> dict:
        return {"schema": REPLAY_SCHEMA, **self.summary(),
                "real_order": self.real_order, "sim_order": self.sim_order,
                "rows": [r.as_dict() for r in self.rows]}

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)
        return path


def replay(trace: Mapping[str, Any], service: ServiceModel | None = None, *,
           policy: str = "greedy") -> ReplayReport:
    """Re-enact a recorded engine trace and compare.

    Args:
        trace: a ``repro.serving/trace-v1`` dict (``ServingEngine.trace_
            json()``) or anything :func:`load_trace` read.
        service: ``None`` replays with the *measured* per-step durations
            (validating the dynamics); a :class:`ServiceModel` prices
            steps analytically (validating the cost model).
        policy: admission policy for the sim side (the real engine is
            ``greedy``).

    Returns:
        A :class:`ReplayReport`; ``order_match`` / ``mape`` are the
        headline verdicts.
    """
    trace = check_trace(trace)
    reqs = trace_requests(trace)
    t0 = min(e["t"] for e in _events(trace, "submit"))
    steps = _events(trace, "step")
    mode = "measured" if service is None else "model"
    step_times = [e["dt"] for e in steps] if service is None else None
    svc = service if service is not None else _fallback_service(trace)
    # the real drain loop starts after every submit; hold the sim's first
    # step to the recorded start so clocks stay aligned
    start_at = (min(e["t"] for e in steps) - t0) if steps else 0.0

    sim = Simulator(seed=0)
    server = SlotServer(sim, svc, max_batch=trace["max_batch"],
                        max_len=trace["max_len"], policy=policy,
                        start_at=start_at, step_times=step_times,
                        decision_step_s=trace.get("predicted_step_s"))
    server.drive(reqs)
    sim.run()

    finishes = {e["rid"]: e for e in _events(trace, "finish")}
    submits = {e["rid"]: e for e in _events(trace, "submit")}
    firsts = {e["rid"]: e["t"] for e in trace["events"]
              if e["type"] == "first_token"}
    rows = []
    for rec in server.metrics.records.values():
        fin = finishes.get(rec.rid)
        if fin is None or not rec.done:
            continue
        real_lat = fin["t"] - submits[rec.rid]["t"]
        real_ttft = (firsts[rec.rid] - submits[rec.rid]["t"]) \
            if rec.rid in firsts else None
        rows.append(ReplayRow(rid=rec.rid, real_latency_s=real_lat,
                              sim_latency_s=rec.latency_s,
                              real_ttft_s=real_ttft,
                              sim_ttft_s=rec.ttft_s))
    rows.sort(key=lambda r: r.rid)
    # the event list is chronological; same-step finishes keep slot order
    # on both sides, so the raw sequence IS the completion order
    real_order = [e["rid"] for e in _events(trace, "finish")]
    real_shed = {e["rid"]: e["cause"] for e in _events(trace, "shed")}
    sim_shed = {r.rid: r.shed_cause
                for r in server.metrics.records.values() if r.shed}
    return ReplayReport(
        mode=mode, rows=rows, real_order=real_order,
        sim_order=list(server.metrics.finish_order),
        steps_real=len(steps), steps_sim=server.steps_run,
        real_shed=real_shed, sim_shed=sim_shed,
        config={"max_batch": trace["max_batch"],
                "max_len": trace["max_len"], "policy": policy})
