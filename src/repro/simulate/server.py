"""Slot-server simulation: ``ServingEngine`` semantics on the event queue.

:class:`SlotServer` mirrors the real engine step for step — a fixed pool
of ``max_batch`` decode slots, FIFO admission from an unbounded queue,
per-admission bucketed prefill, then one batched decode step for every
active slot (inactive slots decode harmlessly in the real engine, so the
decode step costs the same regardless of occupancy — the simulator charges
the same constant).  What the real engine gets from jit-compiled kernels,
the simulator gets from a :class:`ServiceModel`: calibrated
``GemmPlan.estimate()`` costs for the decode-step and prefill-bucket
workloads, so a simulated deployment is priced by exactly the analytic
models the planner ranks with.

Admission policies (the ``policy`` axis of the SLO sweep):

* ``greedy`` — fill every free slot each step (the real engine's rule).
* ``one-per-step`` — admit at most one request per step, bounding the
  prefill work (and hence the stall) any single step can add.
* ``drain-first`` — admit only when the whole pool is idle (batch-
  synchronous serving, the anti-pattern continuous batching replaced;
  kept as the baseline it is).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Any, Iterable, Iterator, Mapping

from repro import obs
from repro.obs import DriftMonitor
from repro.serving.buckets import PREFILL_BUCKETS, bucket_cover, bucket_len
from repro.serving.resilience import (SHED_DEADLINE_EXPIRED,
                                      SHED_DEADLINE_UNMEETABLE,
                                      SHED_QUEUE_FULL)
from repro.simulate.engine import Simulator
from repro.simulate.faults import FaultScenario
from repro.simulate.metrics import Metrics, SimReport, StepSample
from repro.simulate.traffic import SimRequest, Traffic

POLICIES = ("greedy", "one-per-step", "drain-first")


def _workload_seconds(plans) -> float:
    return sum(p.predicted_seconds for p in plans)


@dataclasses.dataclass(frozen=True)
class ServiceModel:
    """Analytic service times for one ``(machine, dtype, batch)`` cell.

    ``decode_step_s`` prices one decode step of the full slot pool;
    ``prefill_s`` maps each jit bucket to the seconds a single-sequence
    prefill of that length costs.  Both come from the same calibrated
    plans the deployment report ranks with (:meth:`from_plans`), or from
    any explicit numbers (tests, what-ifs).
    """

    decode_step_s: float
    prefill_s: Mapping[int, float]
    buckets: tuple = PREFILL_BUCKETS

    def prefill_seconds(self, prefix_len: int) -> float:
        """Cost of prefilling ``prefix_len`` prompt tokens (0 tokens cost
        nothing — the engine skips the prefill call entirely)."""
        if prefix_len <= 0 or not self.prefill_s:
            return 0.0
        b = bucket_len(prefix_len, self.buckets)
        if b in self.prefill_s:
            return self.prefill_s[b]
        # beyond the priced ladder: charge pro rata against the largest
        # priced bucket (prefill cost is ~linear in tokens at these sizes)
        top = max(self.prefill_s)
        return self.prefill_s[top] * (b / top)

    @classmethod
    def from_plans(cls, cfg, *, batch: int, machine=None, dtype: str = "bf16",
                   backend: str = "analytic-tpu", max_len: int = 512,
                   buckets=PREFILL_BUCKETS,
                   decode_step_s: float | None = None,
                   precision=None) -> "ServiceModel":
        """Price the cell from the analytic planner.

        Decode: the ``model_gemm_shapes(cfg, tokens=batch)`` workload (the
        exact plans ``ServingEngine`` freezes).  Prefill: the same workload
        at ``tokens=bucket`` for every bucket a ``max_len`` prompt can
        land in.  ``decode_step_s`` overrides the decode price when the
        caller already planned it (e.g. a ``DeploymentOption``'s
        ``seconds_per_step``), skipping the duplicate sweep.  ``precision``
        (a ``PrecisionConfig`` or its key) prices a mixed-precision cell —
        ``dtype`` must then be a plannable operand dtype (the config's
        compute dtype), not the cell's ``AxB->ACC`` label.
        """
        from repro import gemm
        from repro.core.autotune import model_gemm_shapes

        if decode_step_s is None:
            decode_step_s = _workload_seconds(gemm.plan_many(
                model_gemm_shapes(cfg, tokens=batch), backend=backend,
                machine=machine, dtype=dtype, precision=precision))
        prefill: dict[int, float] = {}
        for b in bucket_cover(max_len, buckets):
            prefill[b] = _workload_seconds(gemm.plan_many(
                model_gemm_shapes(cfg, tokens=b), backend=backend,
                machine=machine, dtype=dtype, precision=precision))
        return cls(decode_step_s=float(decode_step_s), prefill_s=prefill,
                   buckets=tuple(buckets))


@dataclasses.dataclass
class _Live:
    """A request occupying a slot (or waiting in the queue)."""

    req: SimRequest
    tokens: int = 0


class SlotServer:
    """The simulated engine: schedule with :meth:`offer`, step on events.

    Args:
        sim: the event loop this server schedules on.
        service: per-step / per-prefill costs.
        max_batch: decode-slot pool size.
        max_len: per-slot cache length — long prompts are trimmed exactly
            as the real engine trims them (``prompt[-max_len + new:]``).
        policy: admission policy, one of :data:`POLICIES`.
        metrics: collector (a fresh one by default).
        start_at: hold the first step until this sim time (replay aligns
            this with the real engine's drain-loop start).
        step_times: optional iterable of *measured* step durations; when
            given, step ``k`` costs the ``k``-th entry instead of the
            analytic price (measured-service replay).  Falls back to the
            model if the iterator runs dry.
        deadline_s: default end-to-end latency budget applied to requests
            that carry none; ``None`` disables deadline shedding.
        queue_limit: bounded-queue depth; an arrival that finds the queue
            full is *dropped* and recorded as a ``queue_full`` shed (open
            loop: arrivals cannot be asked to wait, unlike the real
            engine's ``QueueFullError`` backpressure).
        decision_step_s: the per-step cost the *shedding decision* uses
            when modeling whether a deadline is meetable (defaults to the
            service model's decode step).  Replay passes the real
            engine's recorded planning estimate so both sides decide on
            identical inputs.
        faults: a :class:`~repro.simulate.faults.FaultScenario` (or name /
            dict) perturbing this run: throttle windows scale step costs,
            slot failures evict and re-queue a victim at step boundaries,
            surges are extra arrivals the *caller* drives (see
            :func:`simulate_serving`).
    """

    def __init__(self, sim: Simulator, service: ServiceModel, *,
                 max_batch: int, max_len: int = 512,
                 policy: str = "greedy", metrics: Metrics | None = None,
                 start_at: float | None = None,
                 step_times: Iterable[float] | None = None,
                 deadline_s: float | None = None,
                 queue_limit: int | None = None,
                 decision_step_s: float | None = None,
                 faults: FaultScenario | str | dict | None = None,
                 drift: DriftMonitor | None = None,
                 drift_key: str = "sim"):
        if policy not in POLICIES:
            raise ValueError(f"unknown admission policy {policy!r}; "
                             f"have {POLICIES}")
        if queue_limit is not None and queue_limit < 1:
            raise ValueError(f"queue limit must be >= 1, got {queue_limit}")
        self.sim = sim
        self.service = service
        self.max_batch = int(max_batch)
        self.max_len = int(max_len)
        self.policy = policy
        self.metrics = metrics if metrics is not None else Metrics()
        self.queue: collections.deque[_Live] = collections.deque()
        self.slots: list[_Live | None] = [None] * self.max_batch
        self.steps_run = 0
        self.deadline_s = deadline_s
        self.queue_limit = queue_limit
        self.decision_step_s = float(
            service.decode_step_s if decision_step_s is None
            else decision_step_s)
        self.faults = FaultScenario.coerce(faults) if faults is not None \
            else None
        self.slot_failures = 0
        self.throttled_steps = 0
        # online drift: the un-perturbed model price vs what the step
        # actually cost (measured replay times, fault-scaled costs) — the
        # simulated analogue of the real engine's step-time monitoring.
        self.drift = drift if drift is not None else DriftMonitor()
        self.drift_key = drift_key
        self._stepping = False
        self._started = start_at is None
        self._step_times: Iterator[float] | None = \
            iter(step_times) if step_times is not None else None
        # slot failures materialise at step boundaries: track the next
        # scheduled failure and process every one that fell inside a step
        # when the step completes (the victim loses that step's work)
        self._failures = self.faults.failures() if self.faults else iter(())
        nxt = next(self._failures, None)
        self._next_fail: tuple[float, float] | None = \
            (nxt[0], nxt[1]) if nxt else None
        if start_at is not None:
            sim.schedule_at(start_at, self._start)

    # -- driving ------------------------------------------------------------
    def _deadline_for(self, req: SimRequest) -> float | None:
        return req.deadline_s if req.deadline_s is not None \
            else self.deadline_s

    def offer(self, req: SimRequest) -> None:
        """Accept one request (call at its arrival time)."""
        self.metrics.on_arrival(req.rid, self.sim.now, req.prompt_len,
                                req.decode_len,
                                deadline_s=self._deadline_for(req))
        if self.queue_limit is not None \
                and len(self.queue) >= self.queue_limit:
            self.metrics.on_shed(req.rid, self.sim.now, SHED_QUEUE_FULL)
            obs.metrics.counter("sim.shed")
            return
        self.queue.append(_Live(req=req))
        self._kick()

    def drive(self, requests: Iterable[SimRequest]) -> None:
        """Schedule a whole traffic stream's arrivals."""
        for req in requests:
            self.sim.schedule_at(req.arrival_s,
                                 functools.partial(self.offer, req))

    def _start(self) -> None:
        self._started = True
        self._kick()

    def _kick(self) -> None:
        if self._started and not self._stepping and (
                self.queue or any(self.slots)):
            self._stepping = True
            self.sim.schedule(0.0, self._step)

    # -- one engine step ----------------------------------------------------
    def _free(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _shed_cause(self, req: SimRequest) -> str | None:
        """Why this queued request should be shed instead of admitted
        right now; ``None`` when it is admissible.  The decision uses the
        same two inputs the real engine uses: time already waited and the
        modeled decode time at ``decision_step_s`` (prefill excluded —
        both sides must exclude it identically)."""
        dl = self._deadline_for(req)
        if dl is None:
            return None
        waited = self.sim.now - req.arrival_s
        if waited >= dl:
            return SHED_DEADLINE_EXPIRED
        if waited + self.decision_step_s * req.decode_len > dl:
            return SHED_DEADLINE_UNMEETABLE
        return None

    def _next_admissible(self) -> _Live | None:
        """Pop the queue until an admissible request surfaces, shedding
        the hopeless ones along the way (a shed never consumes a slot)."""
        while self.queue:
            live = self.queue.popleft()
            cause = self._shed_cause(live.req)
            if cause is None:
                return live
            self.metrics.on_shed(live.req.rid, self.sim.now, cause)
            obs.metrics.counter("sim.shed")
        return None

    def _admit(self) -> list[_Live]:
        free = self._free()
        if self.policy == "one-per-step":
            free = free[:1]
        elif self.policy == "drain-first" and len(free) < self.max_batch:
            free = []
        admitted = []
        for slot in free:
            live = self._next_admissible()
            if live is None:
                break
            self.slots[slot] = live
            self.metrics.on_admit(live.req.rid, self.sim.now)
            admitted.append(live)
        return admitted

    def _prefix_len(self, req: SimRequest) -> int:
        # mirror the engine: prompt trimmed to the cache window, last
        # prompt token fed to the first decode step rather than prefilled
        kept = min(req.prompt_len, max(1, self.max_len - req.decode_len))
        return max(kept - 1, 0)

    def _step(self) -> None:
        t0 = self.sim.now
        admitted = self._admit()
        active = [s for s in self.slots if s is not None]
        if not active:
            self._stepping = False
            return
        nominal = self.service.decode_step_s + sum(
            self.service.prefill_seconds(self._prefix_len(a.req))
            for a in admitted)
        cost = None
        if self._step_times is not None:
            cost = next(self._step_times, None)
        if cost is None:
            cost = nominal
        # thermal-throttle windows scale whatever this step costs,
        # sampled at step start (DVFS changes between steps, not within)
        if self.faults is not None:
            scale = self.faults.service_scale(t0)
            if scale != 1.0:
                cost *= scale
                self.throttled_steps += 1
                obs.metrics.counter("sim.faults.throttled_steps")
        # what the calibration predicted vs what the step will really
        # cost in sim time — throttles and measured replays drift, the
        # un-faulted analytic path stays at ratio 1.0 exactly
        self.drift.observe(nominal, cost, key=self.drift_key)
        sample = StepSample(t=t0, dt=cost, active=len(active),
                            admitted=len(admitted),
                            queue_depth=len(self.queue))
        self.sim.schedule(cost, functools.partial(self._finish_step, sample))

    def _process_failures(self, now: float) -> None:
        """Evict the victim of every slot failure that fell inside the
        step that just completed.  The victim loses the step's work
        entirely — tokens reset (its KV cache is gone, re-admission pays
        prefill again) — and re-queues at the *front*, keeping its
        original arrival time so the latency hit lands in the tail."""
        while self._next_fail is not None and self._next_fail[0] <= now:
            u = self._next_fail[1]
            occupied = [i for i, s in enumerate(self.slots) if s is not None]
            if occupied:
                victim_slot = occupied[min(int(u * len(occupied)),
                                           len(occupied) - 1)]
                live = self.slots[victim_slot]
                self.slots[victim_slot] = None
                live.tokens = 0
                self.queue.appendleft(live)
                self.metrics.on_requeue(live.req.rid, now)
                self.slot_failures += 1
                obs.metrics.counter("sim.faults.slot_failures")
            # advance to the next scheduled failure (an idle-slot failure
            # is a no-op but still consumes its schedule entry)
            nxt = next(self._failures, None)
            self._next_fail = (self._next_fail[0] + nxt[0], nxt[1]) \
                if nxt else None

    def _finish_step(self, sample: StepSample) -> None:
        now = self.sim.now
        self._process_failures(now)
        for i, live in enumerate(self.slots):
            if live is None:
                continue
            live.tokens += 1
            self.metrics.on_token(live.req.rid, now)
            if live.tokens >= live.req.decode_len:
                self.metrics.on_finish(live.req.rid, now)
                self.slots[i] = None
        self.steps_run += 1
        self.metrics.on_step(sample)
        self._stepping = False
        self._kick()


def simulate_serving(service: ServiceModel, traffic: Traffic, *,
                     max_batch: int, max_len: int = 512,
                     policy: str = "greedy", requests: int = 100,
                     seed: int | None = None, horizon: float | None = None,
                     deadline_s: float | None = None,
                     queue_limit: int | None = None,
                     decision_step_s: float | None = None,
                     faults: FaultScenario | str | dict | None = None,
                     config: Mapping[str, Any] | None = None) -> SimReport:
    """One full run: traffic -> slot server -> metrics report.

    Args:
        service / traffic: who prices the work and who sends it.
        max_batch / max_len / policy: the serving configuration under test.
        requests: stream length drawn from ``traffic``.
        seed: simulator RNG seed (defaults to the traffic's own seed; the
            generators pre-draw their randomness, so this only matters for
            future stochastic modules).
        horizon: optional sim-time cutoff — requests still in flight are
            reported as ``unfinished``.
        deadline_s / queue_limit / decision_step_s: resilience knobs, see
            :class:`SlotServer`.
        faults: a :class:`~repro.simulate.faults.FaultScenario` (or
            registry name / dict) perturbing the run; its surges are
            driven on top of the nominal traffic and the report's
            ``faults`` block records what fired.
        config: extra identity keys merged into the report's ``config``.

    Returns:
        A :class:`~repro.simulate.metrics.SimReport` for the run.
    """
    scenario = FaultScenario.coerce(faults) if faults is not None else None
    sim = Simulator(seed=traffic.seed if seed is None else seed,
                    horizon=horizon)
    drift_key = str((config or {}).get("machine", "sim"))
    server = SlotServer(sim, service, max_batch=max_batch, max_len=max_len,
                        policy=policy, deadline_s=deadline_s,
                        queue_limit=queue_limit,
                        decision_step_s=decision_step_s, faults=scenario,
                        drift_key=drift_key)
    server.drive(traffic.requests(requests))
    surge = scenario.surge_requests() if scenario is not None else []
    if surge:
        server.drive(surge)
    sim.run()
    full = {"traffic": traffic.name, "batch": max_batch, "policy": policy,
            "max_len": max_len, "requests": requests,
            "seed": traffic.seed if seed is None else seed,
            **({"deadline_s": deadline_s} if deadline_s is not None else {}),
            **({"queue_limit": queue_limit} if queue_limit is not None
               else {}),
            **({"faults": scenario.name} if scenario is not None else {}),
            **dict(config or {})}
    fault_info = {}
    if scenario is not None:
        fault_info = {"scenario": scenario.name,
                      "slot_failures": server.slot_failures,
                      "throttled_steps": server.throttled_steps,
                      "surge_requests": len(surge)}
    report = server.metrics.report(config=full, max_batch=max_batch,
                                   faults=fault_info,
                                   drift=server.drift.report())
    return report
