"""Discrete-event core: a monotonic event queue with a seeded RNG.

The engine is deliberately tiny — a heap of ``(time, seq, Event)`` entries
popped in order, a ``now`` clock that only moves forward, and a
``random.Random`` seeded at construction so every run is reproducible.
Everything domain-specific (arrival processes, the slot server) is a
module scheduling callbacks on this queue; the engine knows nothing about
serving.

    sim = Simulator(seed=0)
    sim.schedule(1.5, lambda: print(sim.now))
    sim.run()                      # -> 1.5

Ties break by schedule order (``seq``), so same-time events run in a
deterministic, insertion-ordered sequence — the property the replay
validation relies on.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import random
from typing import Any, Callable

from repro import obs


@dataclasses.dataclass
class Event:
    """One scheduled callback.  ``cancel()`` marks it dead in place (lazy
    deletion; the heap drops it when popped)."""

    time: float
    seq: int
    fn: Callable[[], Any]
    cancelled: bool = False

    def cancel(self) -> None:
        self.cancelled = True


class Simulator:
    """Monotonic event loop.

    Args:
        seed: seeds ``self.rng`` (a ``random.Random``); modules draw all
            their randomness from it (or from their own seeded streams)
            so runs are bit-reproducible.
        horizon: optional hard stop — events scheduled past it are kept
            but never executed by :meth:`run`.
    """

    def __init__(self, *, seed: int = 0, horizon: float | None = None):
        self.now = 0.0
        self.rng = random.Random(seed)
        self.horizon = horizon
        self.events_processed = 0
        self._heap: list[tuple[float, int, Event]] = []
        self._seq = itertools.count()

    def schedule(self, delay: float, fn: Callable[[], Any]) -> Event:
        """Run ``fn`` ``delay`` seconds from now (``delay >= 0``)."""
        return self.schedule_at(self.now + delay, fn)

    def schedule_at(self, time: float, fn: Callable[[], Any]) -> Event:
        """Run ``fn`` at absolute sim time ``time`` (not in the past)."""
        if time < self.now:
            raise ValueError(
                f"cannot schedule at t={time:g} before now={self.now:g}")
        ev = Event(time=time, seq=next(self._seq), fn=fn)
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        return ev

    def pending(self) -> int:
        """Live (non-cancelled) events still queued."""
        return sum(1 for _, _, e in self._heap if not e.cancelled)

    def run(self, until: float | None = None) -> float:
        """Pop events in time order until the queue drains (or ``until`` /
        the horizon is reached).  Returns the final clock."""
        stop = until if until is not None else self.horizon
        before = self.events_processed
        with obs.span("sim.run", until=stop) as sp:
            while self._heap:
                t, _, ev = self._heap[0]
                if stop is not None and t > stop:
                    self.now = stop
                    break
                heapq.heappop(self._heap)
                if ev.cancelled:
                    continue
                self.now = t
                self.events_processed += 1
                ev.fn()
            sp.set(events_processed=self.events_processed - before,
                   sim_time=self.now)
        obs.metrics.counter("sim.events_processed",
                            self.events_processed - before)
        return self.now
