"""``repro.simulate`` — discrete-event serving simulation + traffic.

The planner's numbers are steady-state; this subsystem adds *dynamics*:
request arrivals, queueing, batch formation, and tail latency, priced by
the same calibrated analytic cost models the planner ranks with.

* :class:`Simulator` — monotonic event queue with a seeded RNG
  (``engine.py``).
* :class:`PoissonTraffic` / :class:`UniformTraffic` /
  :class:`BurstyTraffic` / :class:`TraceTraffic` + :func:`make_traffic` —
  open-loop arrival processes with prompt/decode length distributions
  (``traffic.py``).
* :class:`SlotServer` / :class:`ServiceModel` /
  :func:`simulate_serving` — ``ServingEngine`` semantics on the event
  queue, service times from ``GemmPlan.estimate()`` (``server.py``).
* :class:`Metrics` / :class:`SimReport` — p50/p95/p99 latency, TTFT,
  goodput, queue depth, slot utilization, persisted JSON
  (``metrics.py``).
* :func:`replay` / :class:`ReplayReport` — re-enact a real
  ``ServingEngine`` trace, measured- or model-priced, sim-vs-real
  validation (``replay.py``).
* :class:`SLO` / :func:`evaluate_deployment` — SLO-driven
  autoconfiguration over a deployment report (``autoconf.py``); pass
  ``faults=`` for the perturbation-robust mode.
* :class:`FaultScenario` / :data:`SCENARIOS` — seeded fault injection:
  thermal-throttle windows, transient slot failures, arrival surges
  (``faults.py``; see ``docs/RESILIENCE.md``).

Everything here is config-only (no jax): full-size architectures simulate
in milliseconds, so the CLI (``python -m repro.simulate run|replay|sweep``)
is cheap enough for CI.
"""
from repro.simulate.autoconf import (
    FAULT_REJECT_PREFIX,
    REJECT_SLO_GOODPUT,
    REJECT_SLO_P99,
    REJECT_SLO_SHED,
    REJECT_SLO_TTFT,
    REJECT_SLO_UNFINISHED,
    SLO,
    SloSelection,
    default_traffic,
    evaluate_deployment,
)
from repro.simulate.engine import Event, Simulator
from repro.simulate.faults import (
    SCENARIOS,
    ArrivalSurge,
    FaultScenario,
    ThrottleWindow,
    throttle_scenario,
)
from repro.simulate.metrics import Metrics, SimReport, StepSample, percentile
from repro.simulate.replay import (
    REPLAY_SCHEMA,
    TRACE_SCHEMA,
    ReplayReport,
    load_trace,
    replay,
    trace_requests,
    trace_traffic,
)
from repro.simulate.server import (
    POLICIES,
    ServiceModel,
    SlotServer,
    simulate_serving,
)
from repro.simulate.traffic import (
    BurstyTraffic,
    LengthDist,
    PoissonTraffic,
    SimRequest,
    TraceTraffic,
    Traffic,
    TrafficScenario,
    UniformTraffic,
    make_traffic,
)

__all__ = [
    "SLO", "ArrivalSurge", "BurstyTraffic", "Event", "FAULT_REJECT_PREFIX",
    "FaultScenario", "LengthDist", "Metrics",
    "POLICIES", "PoissonTraffic", "REJECT_SLO_GOODPUT", "REJECT_SLO_P99",
    "REJECT_SLO_SHED", "REJECT_SLO_TTFT", "REJECT_SLO_UNFINISHED",
    "REPLAY_SCHEMA",
    "ReplayReport", "SCENARIOS", "ServiceModel", "SimReport", "SimRequest",
    "Simulator",
    "SloSelection", "SlotServer", "StepSample", "TRACE_SCHEMA",
    "ThrottleWindow",
    "TraceTraffic", "Traffic", "TrafficScenario", "UniformTraffic",
    "default_traffic", "evaluate_deployment", "load_trace", "make_traffic",
    "percentile", "replay", "simulate_serving", "throttle_scenario",
    "trace_requests", "trace_traffic",
]
