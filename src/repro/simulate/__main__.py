"""Serving-simulation command line.

    python -m repro.simulate run --arch qwen2-1.5b --machine tpu-v5e \\
        --batch 8 --traffic poisson --rate 200 --requests 500
    python -m repro.simulate replay --trace trace.json
    python -m repro.simulate sweep --arch qwen2-1.5b --machine gap9-fc \\
        --smoke --batches 1 2 4 8 16 --rate 5 --slo-p99 0.35

``run`` simulates one serving cell — service times priced by the analytic
planner for the given ``(machine, dtype, batch)`` — under an open-loop
traffic scenario and prints the latency/goodput report.  ``replay``
re-enacts a recorded ``ServingEngine`` trace (measured step durations by
default; ``--model`` prices steps analytically instead) and reports the
sim-vs-real verdict.  ``sweep`` crosses a deployment report's feasible
cells with admission policies under one scenario and selects by SLO
attainment.  Everything is config-only — no parameters, no jax — so
full-size architectures simulate in milliseconds.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.configs import ARCH_IDS, get_config


def _length(spec: str):
    """``16`` -> fixed, ``8:100`` -> uniform, ``geo:64`` -> geometric."""
    if spec.startswith("geo:"):
        return {"kind": "geometric", "lo": 1, "mean": float(spec[4:])}
    if ":" in spec:
        lo, hi = spec.split(":", 1)
        return (int(lo), int(hi))
    return int(spec)


def _traffic(args):
    from repro.simulate.traffic import make_traffic

    kw = dict(rate=args.rate, prompt_len=_length(args.prompt_len),
              decode_len=_length(args.decode_len), seed=args.seed)
    if args.traffic == "bursty":
        kw["burst"] = args.burst
    return make_traffic(args.traffic, **kw)


def cmd_run(args) -> int:
    from repro import obs
    from repro.simulate.server import ServiceModel, simulate_serving

    if args.trace_out:
        obs.enable()
    cfg = get_config(args.arch, smoke=args.smoke)
    service = ServiceModel.from_plans(
        cfg, batch=args.batch, machine=args.machine, dtype=args.dtype,
        backend=args.backend, max_len=args.max_len)
    traffic = _traffic(args)
    report = simulate_serving(
        service, traffic, max_batch=args.batch, max_len=args.max_len,
        policy=args.policy, requests=args.requests, horizon=args.horizon,
        deadline_s=args.deadline, queue_limit=args.queue_limit,
        faults=args.faults,
        config={"arch": cfg.name, "machine": args.machine,
                "dtype": args.dtype})
    print(f"simulated {cfg.name} on {args.machine or 'native'} "
          f"dtype={args.dtype} batch={args.batch} policy={args.policy} "
          f"under {traffic.name}"
          + (f" faults={args.faults}" if args.faults else ""))
    print(report.table())
    if args.json:
        report.save(args.json)
        print(f"wrote {args.json}")
    if args.trace_out:
        doc = obs.save_chrome_trace(args.trace_out)
        print(f"wrote Chrome trace to {args.trace_out} "
              f"({doc['metadata']['spans']} spans; open in "
              f"chrome://tracing or ui.perfetto.dev)")
    return 0 if report.finite else 1


def cmd_replay(args) -> int:
    from repro.simulate.replay import load_trace, replay
    from repro.simulate.server import ServiceModel

    trace = load_trace(args.trace)
    service = None
    if args.model:
        cfg = get_config(args.arch, smoke=args.smoke)
        service = ServiceModel.from_plans(
            cfg, batch=trace["max_batch"], machine=args.machine,
            dtype=args.dtype, backend=args.backend,
            max_len=trace["max_len"])
    report = replay(trace, service, policy=args.policy)
    print(report.table(limit=args.limit))
    if args.json:
        report.save(args.json)
        print(f"wrote {args.json}")
    return 0 if report.order_match else 1


def cmd_sweep(args) -> int:
    from repro.serving.report import plan_deployment
    from repro.simulate.autoconf import SLO, evaluate_deployment

    cfg = get_config(args.arch, smoke=args.smoke)
    report = plan_deployment(
        cfg, machines=args.machine, dtypes=args.dtypes,
        batches=args.batches, max_len=args.max_len, backend=args.backend)
    if not report.options:
        print("no memory-feasible cells to simulate", file=sys.stderr)
        return 1
    slo = SLO(p99_latency_s=args.slo_p99, p95_ttft_s=args.slo_ttft,
              min_goodput_tps=args.slo_goodput,
              max_shed_fraction=args.slo_shed)
    traffic = _traffic(args) if args.rate is not None else None
    try:
        sel = evaluate_deployment(
            cfg, report, slo=slo, traffic=traffic, policies=args.policies,
            requests=args.requests, seed=args.seed, faults=args.faults,
            deadline_s=args.deadline, queue_limit=args.queue_limit)
    except ValueError as e:
        print(e, file=sys.stderr)
        return 1
    print(f"SLO sweep for {cfg.name} under {sel.traffic_name} "
          + (f"with faults={sel.faults} " if sel.faults else "")
          + f"({len(sel.results)} cells, {len(sel.rejections)} rejected)")
    hdr = (f"{'machine':<18}{'dtype':<7}{'batch':>6}  {'policy':<13}"
           f"{'p99 lat':>10}{'p95 ttft':>10}{'goodput':>10}  slo")
    print(hdr)
    for r in sorted(sel.results,
                    key=lambda r: (r["machine"], r["dtype"], r["batch"])):
        print(f"{r['machine']:<18}{r['dtype']:<7}{r['batch']:>6}  "
              f"{r['policy']:<13}{r['p99_latency_s']:>10.4f}"
              f"{r['p95_ttft_s']:>10.4f}{r['goodput_tps']:>10.1f}  "
              + ("ok" if r["slo_attained"]
                 else ",".join(v["reason"] for v in r["violations"])))
    o = sel.option
    print(f"selected: {o.machine} dtype={o.dtype} max_batch={o.batch} "
          f"policy={sel.policy} (sim p99 "
          f"{sel.sim.latency['p99']:.4f}s, goodput "
          f"{sel.sim.goodput_tps:.1f} tok/s)")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(sel.as_dict(), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")
    return 0


def _traffic_args(p, rate_default):
    p.add_argument("--traffic", choices=["poisson", "uniform", "bursty"],
                   default="poisson")
    p.add_argument("--rate", type=float, default=rate_default,
                   help="arrival rate, requests/second")
    p.add_argument("--burst", type=int, default=8,
                   help="burst size for --traffic bursty")
    p.add_argument("--prompt-len", default="32",
                   help="int | lo:hi | geo:MEAN prompt-length distribution")
    p.add_argument("--decode-len", default="16",
                   help="int | lo:hi | geo:MEAN decode-length distribution")
    p.add_argument("--seed", type=int, default=0)


def _resilience_args(p):
    from repro.simulate.faults import SCENARIOS
    p.add_argument("--faults", default=None,
                   help="named fault scenario to inject: "
                        + "|".join(sorted(SCENARIOS)))
    p.add_argument("--deadline", type=float, default=None, dest="deadline",
                   help="per-request latency deadline, seconds "
                        "(arms deadline-aware shedding)")
    p.add_argument("--queue-limit", type=int, default=None,
                   help="bounded queue depth (overflow is shed)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.simulate")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("run", help="simulate one serving cell")
    p.add_argument("--arch", choices=ARCH_IDS, default="qwen2-1.5b")
    p.add_argument("--machine", default="tpu-v5e")
    p.add_argument("--dtype", default="bf16")
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--policy", default="greedy")
    p.add_argument("--max-len", type=int, default=512)
    p.add_argument("--backend", default="analytic-tpu")
    p.add_argument("--requests", type=int, default=200)
    p.add_argument("--horizon", type=float, default=None,
                   help="sim-time cutoff in seconds")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--json", default=None)
    p.add_argument("--trace-out", default=None,
                   help="write a Chrome-trace/Perfetto JSON of the "
                        "simulated timeline (repro.obs spans)")
    _traffic_args(p, rate_default=100.0)
    _resilience_args(p)
    p.set_defaults(fn=cmd_run)

    p = sub.add_parser("replay", help="re-enact a recorded engine trace")
    p.add_argument("--trace", required=True, help="trace JSON path "
                   "(ServingEngine.trace_json())")
    p.add_argument("--model", action="store_true",
                   help="price steps with the analytic model instead of "
                        "the measured durations")
    p.add_argument("--arch", choices=ARCH_IDS, default="qwen2-1.5b")
    p.add_argument("--machine", default=None)
    p.add_argument("--dtype", default="bf16")
    p.add_argument("--backend", default="analytic-tpu")
    p.add_argument("--policy", default="greedy")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--limit", type=int, default=12)
    p.add_argument("--json", default=None)
    p.set_defaults(fn=cmd_replay)

    p = sub.add_parser("sweep", help="SLO sweep over deployment cells")
    p.add_argument("--arch", choices=ARCH_IDS, default="qwen2-1.5b")
    p.add_argument("--machine", nargs="*", default=None)
    p.add_argument("--dtypes", nargs="+", default=["bf16"])
    p.add_argument("--batches", nargs="+", type=int,
                   default=[1, 2, 4, 8, 16])
    p.add_argument("--max-len", type=int, default=512)
    p.add_argument("--backend", default="analytic-tpu")
    p.add_argument("--policies", nargs="+", default=["greedy"])
    p.add_argument("--requests", type=int, default=200)
    p.add_argument("--slo-p99", type=float, default=None,
                   help="p99 end-to-end latency bound, seconds")
    p.add_argument("--slo-ttft", type=float, default=None,
                   help="p95 time-to-first-token bound, seconds")
    p.add_argument("--slo-goodput", type=float, default=None,
                   help="minimum completed tokens/second")
    p.add_argument("--slo-shed", type=float, default=None,
                   help="maximum tolerated shed fraction (0..1)")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--json", default=None)
    _traffic_args(p, rate_default=None)
    _resilience_args(p)
    p.set_defaults(fn=cmd_sweep)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
