"""Open-loop traffic: arrival processes + request-length distributions.

A traffic generator materialises a deterministic stream of
:class:`SimRequest` records — arrival time, prompt length, decode length —
from its own ``random.Random(seed)``, independent of the server it will
drive (open loop: arrivals do not slow down when the server saturates,
which is exactly how tail latency blows up in production).

Generators:

* :class:`PoissonTraffic` — exponential inter-arrival gaps at ``rate``
  requests/second (the memoryless default).
* :class:`UniformTraffic` — a constant ``1/rate`` gap (the arrival process
  with zero burstiness, the lower bound on queueing).
* :class:`BurstyTraffic` — Poisson-arriving *bursts* of ``burst`` back-to-
  back requests; the mean rate still equals ``rate``, but queue depth
  spikes (the adversarial end of the same axis).
* :class:`TraceTraffic` — replays an explicit request list, e.g. one
  recorded from a real :class:`~repro.serving.engine.ServingEngine` trace
  (see :func:`repro.simulate.replay.trace_requests`); round-trips
  bit-exactly.

Lengths are drawn per request from a :class:`LengthDist` — ``fixed``,
``uniform`` over ``[lo, hi]``, or ``geometric`` with a mean (the classic
decode-length model).  A bare int coerces to ``fixed``, a ``(lo, hi)``
tuple to ``uniform``.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Any, Iterable, Sequence

from repro.serving.buckets import PREFILL_BUCKETS, bucket_len


@dataclasses.dataclass(frozen=True)
class SimRequest:
    """One request of the open-loop stream.  ``deadline_s`` is a relative
    end-to-end latency budget (seconds from arrival); ``None`` means no
    deadline (the server may still impose a default)."""

    rid: int
    arrival_s: float
    prompt_len: int
    decode_len: int
    deadline_s: float | None = None

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class LengthDist:
    """A token-length distribution: ``fixed`` | ``uniform`` | ``geometric``.

    ``lo`` is the minimum (and the fixed value); ``hi`` bounds ``uniform``
    draws and clips ``geometric`` ones; ``mean`` parameterises
    ``geometric``.
    """

    kind: str = "fixed"
    lo: int = 8
    hi: int | None = None
    mean: float | None = None

    def __post_init__(self):
        if self.kind not in ("fixed", "uniform", "geometric"):
            raise ValueError(f"unknown length distribution {self.kind!r}")
        if self.kind == "uniform" and (self.hi is None or self.hi < self.lo):
            raise ValueError(f"uniform length needs lo <= hi, got {self}")
        if self.kind == "geometric" and not (self.mean or 0) > 0:
            raise ValueError(f"geometric length needs a positive mean")

    @classmethod
    def coerce(cls, spec: Any) -> "LengthDist":
        """int -> fixed, (lo, hi) -> uniform, dict -> kwargs, pass-through."""
        if isinstance(spec, cls):
            return spec
        if isinstance(spec, int):
            return cls(kind="fixed", lo=spec)
        if isinstance(spec, (tuple, list)) and len(spec) == 2:
            return cls(kind="uniform", lo=int(spec[0]), hi=int(spec[1]))
        if isinstance(spec, dict):
            return cls(**spec)
        raise TypeError(f"cannot interpret {spec!r} as a length "
                        "distribution (int, (lo, hi), dict, or LengthDist)")

    def sample(self, rng: random.Random) -> int:
        if self.kind == "fixed":
            return self.lo
        if self.kind == "uniform":
            return rng.randint(self.lo, self.hi)
        # geometric with the given mean above lo, via inverse transform
        u = 1.0 - rng.random()                       # (0, 1]
        extra = int(-math.log(u) * (self.mean - self.lo)) \
            if self.mean > self.lo else 0
        n = self.lo + extra
        return min(n, self.hi) if self.hi is not None else n

    def mean_value(self, cap: int) -> float:
        """Expected draw (capped support for geometric tails)."""
        if self.kind == "fixed":
            return float(min(self.lo, cap))
        if self.kind == "uniform":
            lo, hi = self.bounds(cap)
            return (lo + hi) / 2.0
        return float(min(self.mean, cap))

    def bounds(self, cap: int) -> tuple[int, int]:
        """Smallest and largest value a draw can take, capped at ``cap``
        (geometric tails are open-ended; the cap is the serving
        ``max_len``)."""
        if self.kind == "fixed":
            return (min(self.lo, cap),) * 2
        hi = self.hi if self.hi is not None else cap
        return min(self.lo, cap), min(hi, cap)

    def prefill_buckets(self, cap: int,
                        buckets=PREFILL_BUCKETS) -> list[int]:
        """Every prefill bucket a prompt drawn from this distribution can
        land in (lengths capped at ``cap``) — what a service model must
        price."""
        lo, hi = self.bounds(cap)
        lob, hib = bucket_len(lo, buckets), bucket_len(hi, buckets)
        hit = {lob, hib}
        hit.update(b for b in buckets if lob <= b <= hib)
        return sorted(hit)


class Traffic:
    """Base class: subclasses implement ``_gaps(rng)`` yielding successive
    inter-arrival gaps; lengths are drawn per request."""

    kind = "traffic"

    def __init__(self, *, rate: float, prompt_len: Any = 8,
                 decode_len: Any = 16, seed: int = 0):
        if rate <= 0:
            raise ValueError(f"arrival rate must be positive, got {rate}")
        self.rate = float(rate)
        self.prompt_len = LengthDist.coerce(prompt_len)
        self.decode_len = LengthDist.coerce(decode_len)
        self.seed = int(seed)

    @property
    def name(self) -> str:
        return f"{self.kind}@{self.rate:g}rps"

    def _gaps(self, rng: random.Random) -> Iterable[float]:
        raise NotImplementedError

    def requests(self, n: int) -> list[SimRequest]:
        """The first ``n`` requests of the stream.  Deterministic: the
        same ``(generator config, seed, n)`` always yields the same list,
        and a longer stream is a prefix-extension of a shorter one."""
        rng = random.Random(self.seed)
        out, t = [], 0.0
        gaps = iter(self._gaps(rng))
        for rid in range(n):
            t += next(gaps)
            out.append(SimRequest(
                rid=rid, arrival_s=t,
                prompt_len=max(1, self.prompt_len.sample(rng)),
                decode_len=max(1, self.decode_len.sample(rng))))
        return out


class PoissonTraffic(Traffic):
    kind = "poisson"

    def _gaps(self, rng: random.Random) -> Iterable[float]:
        while True:
            yield rng.expovariate(self.rate)


class UniformTraffic(Traffic):
    kind = "uniform"

    def _gaps(self, rng: random.Random) -> Iterable[float]:
        while True:
            yield 1.0 / self.rate


class BurstyTraffic(Traffic):
    """Poisson bursts: every burst brings ``burst`` requests separated by
    ``intra_gap`` seconds; burst starts arrive at ``rate / burst`` so the
    long-run request rate matches ``rate``."""

    kind = "bursty"

    def __init__(self, *, rate: float, burst: int = 8,
                 intra_gap: float = 1e-3, **kw):
        super().__init__(rate=rate, **kw)
        if burst < 1:
            raise ValueError(f"burst size must be >= 1, got {burst}")
        self.burst = int(burst)
        self.intra_gap = float(intra_gap)

    @property
    def name(self) -> str:
        return f"bursty{self.burst}@{self.rate:g}rps"

    def _gaps(self, rng: random.Random) -> Iterable[float]:
        burst_rate = self.rate / self.burst
        while True:
            yield rng.expovariate(burst_rate)
            for _ in range(self.burst - 1):
                yield self.intra_gap


class TraceTraffic(Traffic):
    """Replays an explicit request list (e.g. a recorded engine trace)."""

    kind = "trace"

    def __init__(self, requests: Sequence[SimRequest]):
        self._requests = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        n = len(self._requests)
        span = self._requests[-1].arrival_s if self._requests else 0.0
        # nominal rate for reporting only; arrivals come from the trace
        self.rate = (n / span) if span > 0 else float(n or 1)
        self.seed = 0

    @property
    def name(self) -> str:
        return f"trace[{len(self._requests)}]"

    def requests(self, n: int | None = None) -> list[SimRequest]:
        if n is not None and n < len(self._requests):
            return list(self._requests[:n])
        return list(self._requests)


TRAFFIC_KINDS = {"poisson": PoissonTraffic, "uniform": UniformTraffic,
                 "bursty": BurstyTraffic}


def make_traffic(kind: str, **kw) -> Traffic:
    """CLI-friendly factory: ``make_traffic("poisson", rate=32, ...)``."""
    try:
        cls = TRAFFIC_KINDS[kind]
    except KeyError:
        raise ValueError(f"unknown traffic kind {kind!r}; "
                         f"have {sorted(TRAFFIC_KINDS)}") from None
    return cls(**kw)


@dataclasses.dataclass(frozen=True)
class TrafficScenario:
    """A named traffic configuration — the unit the sweep axes cross.

    ``bind(cfg, max_len)`` turns it into a ``repro.gemm.sweep`` scenario
    axis entry: the bound scenario's ``problems`` hook extends the decode
    workload with the prefill-bucket GEMMs its prompt-length distribution
    can hit, so one sweep call plans every shape the simulation will
    price under this scenario.
    """

    name: str
    traffic: Traffic
    description: str = ""

    def bind(self, cfg, max_len: int = 512) -> "BoundScenario":
        from repro.core.autotune import model_gemm_shapes

        extra = []
        for b in self.traffic.prompt_len.prefill_buckets(max_len):
            extra.extend(model_gemm_shapes(cfg, tokens=b))
        return BoundScenario(name=self.name, extra_problems=tuple(extra))


@dataclasses.dataclass(frozen=True)
class BoundScenario:
    """A scenario bound to one model config: a valid ``gemm.sweep``
    ``scenarios=`` entry (``name`` + ``problems`` transform)."""

    name: str
    extra_problems: tuple = ()

    def problems(self, base: Sequence) -> list:
        out = list(base)
        out.extend(self.extra_problems)
        return out
