"""Architecture registry: ``--arch <id>`` ids from the assignment map to one
config module each; ``input_specs`` builds the ShapeDtypeStruct stand-ins the
dry-run lowers against (weak-type-correct, shardable, no device allocation).
"""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

_MODULES = {
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "paligemma-3b": "repro.configs.paligemma_3b",
    "stablelm-12b": "repro.configs.stablelm_12b",
    "qwen2-1.5b": "repro.configs.qwen2_1p5b",
    "qwen2.5-32b": "repro.configs.qwen2p5_32b",
    "qwen2-7b": "repro.configs.qwen2_7b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b",
}

ARCH_IDS = tuple(_MODULES)

# archs with sub-quadratic token mixing run the long_500k cell; pure
# full-attention archs skip it (assignment rule; DESIGN.md §8).
SUBQUADRATIC = ("zamba2-1.2b", "xlstm-125m")


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(_MODULES[arch])
    return mod.smoke_config() if smoke else mod.get_config()


def shape_cells(arch: str) -> list[ShapeConfig]:
    """The assigned (arch x shape) cells, with the long_500k rule applied."""
    cells = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if arch in SUBQUADRATIC:
        cells.append(SHAPES["long_500k"])
    return cells


def skipped_cells(arch: str) -> list[str]:
    return [] if arch in SUBQUADRATIC else ["long_500k"]


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of one cell.

    train:    token/label batches (frontends: embeddings + labels)
    prefill:  the request batch (tokens / frame embeddings / patches+text)
    decode:   one new token per sequence (+ ``pos``); the KV/state caches are
              built separately by ``LM.init_cache`` (they are carried state,
              not inputs, but the dry-run passes them as arguments too).
    """
    S = jax.ShapeDtypeStruct
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    emb = jnp.dtype(cfg.compute_dtype)
    d = cfg.d_model

    if shape.kind == "train":
        if cfg.frontend == "audio_stub":
            return {"frames": S((b, s, d), emb), "labels": S((b, s), i32)}
        if cfg.frontend == "vision_stub":
            st = s - cfg.num_prefix_tokens
            return {"patches": S((b, cfg.num_prefix_tokens, d), emb),
                    "tokens": S((b, st), i32), "labels": S((b, st), i32)}
        return {"tokens": S((b, s), i32), "labels": S((b, s), i32)}

    if shape.kind == "prefill":
        if cfg.frontend == "audio_stub":
            return {"frames": S((b, s, d), emb)}
        if cfg.frontend == "vision_stub":
            st = s - cfg.num_prefix_tokens
            return {"patches": S((b, cfg.num_prefix_tokens, d), emb),
                    "tokens": S((b, st), i32)}
        return {"tokens": S((b, s), i32)}

    # decode: one token (audio: one frame embedding)
    if cfg.frontend == "audio_stub":
        return {"token": S((b, 1, d), emb), "pos": S((), i32)}
    return {"token": S((b, 1), i32), "pos": S((), i32)}
