"""qwen2-7b — dense GQA with QKV bias.  [arXiv:2407.10671]

28L, d_model=3584, 28H (kv=4), d_ff=18944, vocab=152064.  28 heads don't
divide a 16-way model axis: the runtime pads query heads to 32 (exact
results, zero wo rows; DESIGN.md §5).  Full attention -> ``long_500k``
skipped.
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b",
        family="dense",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        d_ff=18944,
        vocab_size=152064,
        head_dim=128,
        qkv_bias=True,
        rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b-smoke",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=6,   # deliberately not a power of two (head padding path)
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        qkv_bias=True,
    )
