"""xlstm-125m — alternating sLSTM + mLSTM blocks.  [arXiv:2405.04517]

12L, d_model=768, 4 heads, d_ff=0 (blocks carry their own up/down
projections), vocab=50304.  Runs ``long_500k`` (recurrent decode).
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        family="ssm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        head_dim=192,
        lstm_heads=4,
        block_pattern=("mlstm", "slstm") * 6,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke",
        family="ssm",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=512,
        head_dim=16,
        lstm_heads=4,
        xlstm_chunk=16,
        block_pattern=("mlstm", "slstm") * 2,
        tie_embeddings=True,
    )
