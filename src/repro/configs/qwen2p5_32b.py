"""qwen2.5-32b — dense GQA with QKV bias.  [Qwen2.5 family]

64L, d_model=5120, 40H (kv=8), d_ff=27648, vocab=152064.  FSDP (parameter +
optimizer-state sharding over the data axis) is required at this size.
Full attention -> ``long_500k`` skipped.
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=27648,
        vocab_size=152064,
        head_dim=128,
        qkv_bias=True,
        rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b-smoke",
        family="dense",
        n_layers=4,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=192,
        vocab_size=512,
        head_dim=16,
        qkv_bias=True,
    )
