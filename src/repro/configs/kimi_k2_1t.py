"""kimi-k2-1t-a32b — trillion-parameter MoE (384 experts, top-8).

[Kimi K2 paper table]  61L, d_model=7168, 64H (kv=8), expert d_ff=2048,
vocab=163840.  Per the assignment the attention is GQA (not MLA).  Optimizer
moments are kept in bf16 — f32 moments for 1T params (8 TB) would not fit
512 x 16 GB HBM (DESIGN.md §4).  Full attention -> ``long_500k`` skipped.
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        d_ff=0,
        vocab_size=163840,
        head_dim=128,
        n_experts=384,
        experts_per_token=8,
        moe_d_ff=2048,
        block_pattern=("moe",) * 61,
        param_dtype="bfloat16",
        opt_state_dtype="bfloat16",
        rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="kimi-smoke",
        family="moe",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=0,
        vocab_size=512,
        head_dim=16,
        n_experts=8,
        experts_per_token=2,
        moe_d_ff=32,
        block_pattern=("moe",) * 3,
    )
