"""granite-moe-3b-a800m — IBM Granite MoE (40 experts, top-8).

[hf:ibm-granite]  32L, d_model=1536, 24H (kv=8), expert d_ff=512,
vocab=49155.  40 experts don't divide a 16-way model axis, so expert FFN
dims shard instead (TP-inside-expert); the 49155 vocab is padded to a
256-multiple for the vocab-sharded embedding (DESIGN.md §5).
Full attention -> ``long_500k`` skipped.
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        d_ff=0,
        vocab_size=49155,
        head_dim=64,
        n_experts=40,
        experts_per_token=8,
        moe_d_ff=512,
        block_pattern=("moe",) * 32,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-smoke",
        family="moe",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=0,
        vocab_size=300,   # deliberately not 256-divisible (padding path)
        head_dim=16,
        n_experts=5,
        experts_per_token=2,
        moe_d_ff=32,
        block_pattern=("moe",) * 3,
        tie_embeddings=True,
    )
