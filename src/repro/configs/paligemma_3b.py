"""paligemma-3b — SigLIP vision tower (STUB) + Gemma decoder backbone.

[arXiv:2407.07726]  18L, d_model=2048, 8H (kv=1, MQA), d_ff=16384,
vocab=257216.  ``input_specs`` provides 256 precomputed patch embeddings as a
bidirectional prefix (prefix-LM mask); GeGLU MLP, tied embeddings, MQA's
single KV head replicates across TP (DESIGN.md §5).  Full attention ->
``long_500k`` skipped.
"""
from repro.configs.base import ModelConfig

NUM_PATCHES = 256


def get_config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b",
        family="vlm",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,
        d_ff=16384,
        vocab_size=257216,
        head_dim=256,
        act="geglu",
        tie_embeddings=True,
        frontend="vision_stub",
        num_prefix_tokens=NUM_PATCHES,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-smoke",
        family="vlm",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        act="geglu",
        tie_embeddings=True,
        frontend="vision_stub",
        num_prefix_tokens=8,
    )
