"""stablelm-12b — dense GQA decoder.  [hf:stabilityai/stablelm-2-12b]

40L, d_model=5120, 32H (kv=8), d_ff=13824, vocab=100352.  LayerNorm +
SwiGLU.  Full attention -> ``long_500k`` skipped.
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        d_ff=13824,
        vocab_size=100352,
        head_dim=160,
        norm_type="layernorm",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-smoke",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        norm_type="layernorm",
    )
