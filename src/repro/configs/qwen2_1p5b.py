"""qwen2-1.5b — dense GQA with QKV bias.  [arXiv:2407.10671]

28L, d_model=1536, 12H (kv=2), d_ff=8960, vocab=151936, tied embeddings.
Full attention -> ``long_500k`` skipped.
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b",
        family="dense",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab_size=151936,
        head_dim=128,
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b-smoke",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        qkv_bias=True,
        tie_embeddings=True,
    )
