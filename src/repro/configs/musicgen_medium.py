"""musicgen-medium — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284]  48L, d_model=1536, 24H (kv=24), d_ff=6144, vocab=2048.
The EnCodec frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings; the backbone is a standard LayerNorm+GeLU
decoder.  Full attention -> ``long_500k`` skipped.
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        family="audio",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,
        d_ff=6144,
        vocab_size=2048,
        head_dim=64,
        norm_type="layernorm",
        act="gelu",
        frontend="audio_stub",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke",
        family="audio",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=128,
        head_dim=16,
        norm_type="layernorm",
        act="gelu",
        frontend="audio_stub",
    )
