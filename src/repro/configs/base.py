"""Config schema for architectures, parallelism, training and serving.

Every assigned architecture gets a ``ModelConfig`` with its exact published
hyper-parameters plus a ``smoke()`` reduction of the same family used by the
CPU tests.  Parallelism knobs live in ``ParallelConfig`` and are resolved
against a concrete mesh at sharding-rule construction time
(``runtime/sharding.py``).
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Sequence


def _ceil_to(x: int, mult: int) -> int:
    return mult * int(math.ceil(x / mult))


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0               # 0 -> d_model // n_heads
    qkv_bias: bool = False
    norm_type: str = "rmsnorm"      # rmsnorm | layernorm
    act: str = "swiglu"             # swiglu | gelu
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- SSM (Mamba2) -------------------------------------------------------
    ssm_state: int = 0              # N
    ssm_head_dim: int = 64          # P
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # --- xLSTM ---------------------------------------------------------------
    lstm_heads: int = 4
    mlstm_expand: int = 2
    xlstm_chunk: int = 128

    # --- block layout --------------------------------------------------------
    # per-layer block kinds; empty -> ["attn"] * n_layers.
    # kinds: attn | moe | mamba2 | mlstm | slstm | shared_attn
    block_pattern: tuple = ()
    # zamba2: one set of tied attn+mlp weights used at every shared_attn site.
    shared_block: bool = False

    # --- modality frontend (stub per assignment) -----------------------------
    frontend: str = "none"          # none | audio_stub | vision_stub
    num_prefix_tokens: int = 0      # vision: patch count (prefix-LM mask)

    # --- numerics ------------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"   # kimi-k2 uses bfloat16 (DESIGN.md §4)
    # int8 KV cache (per-entry scales): halves the decode cache-read traffic
    # — the dominant real decode cost (EXPERIMENTS.md §Perf iteration D2).
    kv_cache_dtype: str = "bfloat16"   # bfloat16 | int8

    # --- attention scalability ----------------------------------------------
    attn_chunk: int = 1024          # KV-chunk for the blockwise reference path

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if not self.block_pattern:
            kind = "moe" if self.n_experts else "attn"
            object.__setattr__(self, "block_pattern", tuple([kind] * self.n_layers))
        assert len(self.block_pattern) == self.n_layers, (
            f"{self.name}: pattern len {len(self.block_pattern)} != {self.n_layers}")

    # vocab padded for TP-divisibility (granite's 49155 is not 16-divisible).
    @property
    def padded_vocab(self) -> int:
        return _ceil_to(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:       # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def mlstm_inner(self) -> int:
        return self.mlstm_expand * self.d_model

    def block_counts(self) -> dict[str, int]:
        """Occurrences of each block kind in ``block_pattern`` — the layer
        census ``param_count`` sums over and the serving footprint model
        (``repro.serving.footprint``) charges per-kind decode state
        against."""
        return dict(collections.Counter(self.block_pattern))

    def param_count(self) -> int:
        """Approximate parameter count (used for MODEL_FLOPS = 6 N D)."""
        d, hd = self.d_model, self.head_dim
        n = self.padded_vocab * d  # embedding
        if not self.tie_embeddings:
            n += self.padded_vocab * d
        shared = 0
        for kind, cnt in self.block_counts().items():
            if kind in ("attn", "shared_attn"):
                per = (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                       + self.n_heads * hd * d)
                if self.d_ff:
                    per += 3 * d * self.d_ff if self.act == "swiglu" else 2 * d * self.d_ff
                if kind == "shared_attn" and self.shared_block:
                    shared = per
                    continue
                n += cnt * per
            elif kind == "moe":
                per = (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                       + self.n_heads * hd * d)
                per += self.n_experts * 3 * d * self.moe_d_ff
                per += d * self.n_experts  # router
                n += cnt * per
            elif kind == "mamba2":
                di = self.d_inner
                per = d * (2 * di + 2 * self.ssm_heads * self.ssm_state
                           + self.ssm_heads) + di * d
                n += cnt * per
            elif kind == "mlstm":
                di = self.mlstm_inner
                per = d * 3 * di + 2 * di + di * d + 2 * d * di
                n += cnt * per
            elif kind == "slstm":
                per = 4 * d * d + 4 * d * d // self.lstm_heads + 2 * d * d
                n += cnt * per
        n += shared
        return int(n)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.n_experts:
            return self.param_count()
        dead = (self.n_experts - self.experts_per_token) * 3 * self.d_model * self.moe_d_ff
        moe_layers = sum(1 for k in self.block_pattern if k == "moe")
        return int(self.param_count() - moe_layers * dead)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell: training or serving geometry."""
    name: str                       # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                       # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """Parallelism & distributed-optimization knobs."""
    fsdp: bool = False              # shard params/opt-state over the data axis
    remat: str = "block"            # none | block
    microbatches: int = 1           # gradient-accumulation steps
    pipeline_stages: int = 1        # >1 -> GPipe over the pod axis
    grad_compression: str = "none"  # none | int8_ef (cross-pod int8 + error feedback)
    scan_layers: bool = True
    # beyond-paper hillclimb knobs (EXPERIMENTS.md §Perf)
    seq_shard_long_kv: bool = True  # SP: shard long decode KV over data axis
    chunked_logits: int = 0         # >0: compute CE loss in vocab-chunks


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    seed: int = 0
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
