"""zamba2-1.2b — hybrid Mamba2 backbone + shared (tied) attention blocks.

[arXiv:2411.15242]  38L, d_model=2048, 32H (kv=32), d_ff=8192, vocab=32000,
ssm_state=64.  Shared transformer block applied every 6th slot with tied
weights (Zamba-style); remaining slots are Mamba2 SSD blocks.
Runs ``long_500k`` (sub-quadratic backbone).
"""
from repro.configs.base import ModelConfig

# 38 slots: shared-attention sites at 5, 11, 17, 23, 29, 35; tail of 2 mamba.
_PERIOD = ("mamba2",) * 5 + ("shared_attn",)
_PATTERN = _PERIOD * 6 + ("mamba2", "mamba2")


def get_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=32000,
        head_dim=64,
        ssm_state=64,
        ssm_head_dim=64,
        block_pattern=_PATTERN,
        shared_block=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke",
        family="hybrid",
        n_layers=7,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_chunk=16,
        block_pattern=("mamba2", "mamba2", "shared_attn") * 2 + ("mamba2",),
        shared_block=True,
    )
