"""Measured-sample records and their append-only JSONL store.

A :class:`Sample` is one measured GEMM wall time together with everything
needed to re-predict it: the problem, the pinned selection (variant +
micro-kernel for the BLIS-variant model, tile for the TPU model), the
partial-tile policy, the harness that produced it, and the *geometry
fingerprint* of the machine spec it was planned against.

The fingerprint is the staleness guard: blockings — and therefore measured
times — depend on a spec's geometry (capacities, levels, register file), not
on its placeholder rates, so a Calibrator refit keeps old samples valid
while any geometry change (or a name that now points at a different machine)
invalidates them.  :meth:`SampleStore.for_machine` refuses to return
mismatching samples rather than silently calibrating a renamed spec.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Iterator, Mapping

SAMPLE_SCHEMA = "repro.measure/sample-v1"


class StaleSampleError(ValueError):
    """Samples whose machine geometry no longer matches the spec."""


@dataclasses.dataclass(frozen=True)
class Sample:
    """One measured (problem, selection) -> seconds data point."""

    m: int
    n: int
    k: int
    dtype: str
    seconds: float
    harness: str                    # timing backend that measured it
    machine: str                    # spec name the plan was made against
    machine_fingerprint: str        # MachineSpec.geometry_fingerprint()
    backend: str = "analytic-gap8"  # planning backend
    variant: str | None = None      # BLIS-model selection ...
    micro_kernel: str | None = None  # ... e.g. "4x24"
    tile: str | None = None         # TPU-model selection, TileConfig str
    policy: str = "analytic"
    rounds: int = 1
    calls: int = 1
    spread: float = 0.0
    meta: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def problem(self):
        from repro.gemm.api import GemmProblem
        return GemmProblem(self.m, self.n, self.k, dtype=self.dtype)

    @property
    def cell(self) -> str:
        """Human-readable grid-cell tag for reports."""
        sel = self.micro_kernel or self.tile or "-"
        return f"{self.m}x{self.n}x{self.k}:{self.dtype}/{sel}"

    @classmethod
    def from_measurement(cls, plan, result, harness: str, machine_spec,
                         meta: Mapping[str, Any] | None = None) -> "Sample":
        """Build the record for one plan measured by one harness."""
        from repro.gemm.api import VariantChoice

        sel = plan.selection
        variant = micro_kernel = tile = None
        if isinstance(sel, VariantChoice):
            variant = sel.variant.value
            micro_kernel = str(sel.micro_kernel)
        elif sel is not None:
            tile = str(sel)
        p = plan.problem
        return cls(
            m=p.m, n=p.n, k=p.k, dtype=p.dtype,
            seconds=float(result.seconds), harness=harness,
            machine=machine_spec.name,
            machine_fingerprint=machine_spec.geometry_fingerprint(),
            backend=plan.backend, variant=variant,
            micro_kernel=micro_kernel, tile=tile,
            policy=str(plan.provenance.get("policy", "analytic")),
            rounds=int(result.rounds), calls=int(result.calls),
            spread=float(result.spread), meta=dict(meta or {}))

    def to_json(self) -> dict:
        d = {"schema": SAMPLE_SCHEMA}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if f.name == "meta":
                if v:
                    d["meta"] = dict(v)
            elif v is not None:
                d[f.name] = v
        return d

    @classmethod
    def from_json(cls, d: Mapping[str, Any]) -> "Sample":
        schema = d.get("schema", SAMPLE_SCHEMA)
        if schema != SAMPLE_SCHEMA:
            raise ValueError(f"unknown sample schema {schema!r} "
                             f"(expected {SAMPLE_SCHEMA!r})")
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})


class SampleStore:
    """Append-only JSONL store of :class:`Sample` records.

    One sample per line; ``append`` opens in append mode and flushes, so
    campaigns can crash mid-run without corrupting earlier samples and
    concurrent readers always see whole records.
    """

    def __init__(self, path: str):
        self.path = str(path)

    def append(self, sample: Sample) -> Sample:
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        with open(self.path, "a") as f:
            json.dump(sample.to_json(), f, sort_keys=True)
            f.write("\n")
        return sample

    def extend(self, samples) -> int:
        n = 0
        for s in samples:
            self.append(s)
            n += 1
        return n

    def __iter__(self) -> Iterator[Sample]:
        if not os.path.exists(self.path):
            return
        with open(self.path) as f:
            for lineno, line in enumerate(f, 1):
                line = line.strip()
                if not line:
                    continue
                try:
                    yield Sample.from_json(json.loads(line))
                except (ValueError, TypeError) as e:
                    raise ValueError(
                        f"{self.path}:{lineno}: bad sample record: {e}"
                    ) from e

    def __len__(self) -> int:
        return sum(1 for _ in self)

    def samples(self, **filters) -> list[Sample]:
        """All samples matching the given field values, e.g.
        ``samples(dtype="int8", harness="host-numpy")``."""
        out = list(self)
        for name, want in filters.items():
            out = [s for s in out if getattr(s, name) == want]
        return out

    @staticmethod
    def _lineage_names(spec) -> set[str]:
        """The machine names whose samples legitimately describe ``spec``:
        its own name, plus — for calibrated specs only — the template it
        was measured/fitted from (``provenance["base"]``).  Transform-derived
        ablations (``scaled`` etc.) do NOT inherit their base's samples: a
        what-if machine must never be calibrated from the real one's data.
        """
        names = {spec.name}
        prov = dict(spec.provenance or {})
        if ("fit" in prov or "calibration" in prov) and prov.get("base"):
            names.add(str(prov["base"]))
        return names

    def for_machine(self, spec, *, allow_stale: bool = False) -> list[Sample]:
        """Samples measured for ``spec``: the recorded machine name must be
        in the spec's calibration lineage (its own name, or the template a
        fit was solved from) AND the recorded geometry fingerprint must
        match.

        Lineage samples whose geometry no longer matches are stale — the
        spec changed since the campaign — and raise
        :class:`StaleSampleError` unless ``allow_stale=True`` skips them.
        Samples of other machines are ignored, even when their geometry
        coincides (a rates-only ablation shares its base's geometry but
        must not silently calibrate from its measurements).
        """
        fp = spec.geometry_fingerprint()
        names = self._lineage_names(spec)
        match, stale = [], []
        for s in self:
            if s.machine not in names:
                continue
            if s.machine_fingerprint == fp:
                match.append(s)
            else:
                stale.append(s)
        if stale and not allow_stale:
            raise StaleSampleError(
                f"{self.path}: {len(stale)} sample(s) named "
                f"{sorted({s.machine for s in stale})} were measured "
                f"against a different geometry (fingerprint != {fp}); "
                f"re-run the campaign into a fresh store path (this one is "
                f"append-only, the stale lines stay) or pass "
                f"allow_stale=True to skip them")
        return match
