"""Measurement & validation command line.

    python -m repro.measure run --grid smoke --backend host-numpy \\
        --machine host-cpu --store measurements/host.jsonl
    python -m repro.measure fit --store measurements/host.jsonl \\
        --template host-cpu --name host-cpu-fit --out measurements/
    python -m repro.measure validate --store measurements/host.jsonl \\
        --machine measurements/host-cpu-fit.json --json report.json
    python -m repro.measure report --json report.json

``run`` measures a named grid with one timing backend (``--backend
simulated --truth NAME`` replays the closed-loop oracle), ``fit`` solves the
vectorized least-squares rate fit from the stored samples and persists the
spec, ``validate`` re-predicts every sample and reports per-cell error +
MAPE (exit 1 if the report is not finite), ``report`` renders a persisted
report.  CI runs a host smoke campaign through run→fit→validate before
pytest.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro import measure


def _load_machine(tag: str):
    """A registry name, or a manifest path (anything ending in .json)."""
    if tag.endswith(".json"):
        from repro.machines import MachineSpec
        return MachineSpec.from_manifest(tag)
    from repro.machines import resolve
    return resolve(tag)


def cmd_run(args) -> int:
    timing = {"warmup": args.warmup, "rounds": args.rounds}
    mks = measure.DEFAULT_FIT_MKS
    if args.mks:
        mks = [tuple(int(x) for x in mk.split("x"))
               for mk in args.mks.split(",")]
    res = measure.run_campaign(
        args.grid, machine=_load_machine(args.machine),
        harness=args.backend, store=args.store, dtype=args.dtype,
        variant=args.variant, micro_kernels=mks, policy=args.policy,
        timing=timing, truth=args.truth, interpret=args.interpret,
        progress=(lambda s: print(f"  {s.cell:<35} {s.seconds:.3e}s "
                                  f"({s.rounds} rounds)"))
        if args.verbose else None)
    print(f"{args.grid}: {len(res.samples)} samples via {res.harness} on "
          f"{res.machine} ({res.measured_seconds:.3g}s measured) -> "
          f"{args.store}")
    return 0


def cmd_fit(args) -> int:
    try:
        spec, report = measure.fit_from_store(
            args.store, _load_machine(args.template), name=args.name,
            date=args.date, per_mk_arith=args.per_mk_arith,
            register=args.register, manifest_dir=args.out,
            on_nonpositive=args.on_nonpositive,
            weighting=args.weighting, robust=args.robust,
            trim_fraction=args.trim_fraction, max_drift=args.max_drift,
            allow_stale=args.allow_stale)
    except measure.CalibrationDriftError as e:
        print(json.dumps(e.as_dict(), indent=1, sort_keys=True))
        print(str(e), file=sys.stderr)
        return 1
    print(f"fitted {spec.name} from {report.samples} samples "
          f"(residual RMS {report.residual_rms_s:.3e}s)")
    if report.robust:
        print(f"  robust={report.robust}: {len(report.outliers)} sample(s) "
              f"down-weighted {report.outliers}")
    import math as _math
    for col, x in zip(report.columns, report.inverse_rates):
        if _math.isnan(x):
            tag = (f"dropped -> "
                   f"{'free' if args.on_nonpositive == 'free' else 'template rate'}")
        else:
            unit = "B/s" if col.startswith("rate:") else "ops/s"
            tag = f"{1.0 / x:.4g} {unit}"
        print(f"  {col:<28} {tag}")
    if args.out:
        print(f"manifest written to {args.out}/{spec.name}.json")
    return 0


def cmd_validate(args) -> int:
    report = measure.validate_spec(_load_machine(args.machine), args.store,
                                   allow_stale=args.allow_stale)
    print(report.table(limit=args.limit))
    for field in ("dtype", "micro_kernel"):
        groups = report.breakdown(field)
        if len(groups) > 1:
            print(f"by {field}:")
            for key, g in groups.items():
                print(f"  {key:<12} {g['cells']:>3} cells  "
                      f"MAPE {g['mape_pct']:6.2f}%  "
                      f"bias {g['bias_pct']:+6.2f}%")
    if args.json:
        report.save(args.json)
        print(f"report written to {args.json}")
    if not report.finite:
        print("validation MAPE is not finite", file=sys.stderr)
        return 1
    return 0


def cmd_report(args) -> int:
    report = measure.ValidationReport.load(args.json)
    print(report.table(limit=args.limit))
    print(json.dumps(report.summary(), indent=1, sort_keys=True))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.measure")
    sub = ap.add_subparsers(dest="cmd", required=True)

    r = sub.add_parser("run", help="measure a campaign grid into a store")
    r.add_argument("--grid", default="smoke",
                   choices=measure.grid_names())
    r.add_argument("--backend", default="host-numpy",
                   choices=measure.harness_names(),
                   help="timing backend (harness)")
    r.add_argument("--machine", default="host-cpu",
                   help="registry name or manifest path to plan against")
    r.add_argument("--store", required=True, help="JSONL sample store path")
    r.add_argument("--dtype", default=None)
    r.add_argument("--variant", default=None,
                   help="BLIS loop-order variant (default B3A2C0)")
    r.add_argument("--mks", default=None,
                   help="comma-separated micro-kernels, e.g. 4x24,8x12")
    r.add_argument("--policy", default="analytic",
                   choices=["analytic", "padded"])
    r.add_argument("--truth", default=None,
                   help="ground-truth machine for --backend simulated")
    r.add_argument("--interpret", action="store_true",
                   help="interpret-mode Pallas for --backend pallas")
    r.add_argument("--rounds", type=int, default=3)
    r.add_argument("--warmup", type=int, default=1)
    r.add_argument("--verbose", action="store_true")
    r.set_defaults(fn=cmd_run)

    f = sub.add_parser("fit", help="least-squares rate fit from a store")
    f.add_argument("--store", required=True)
    f.add_argument("--template", required=True,
                   help="geometry template: registry name or manifest path")
    f.add_argument("--name", default=None)
    f.add_argument("--date", default=None,
                   help="calibration date recorded in provenance")
    f.add_argument("--per-mk-arith", action="store_true",
                   help="fit a per-micro-kernel arithmetic-rate table "
                        "(paper 4's refinement)")
    f.add_argument("--register", action="store_true")
    f.add_argument("--out", default=None,
                   help="directory to persist the fitted manifest into")
    f.add_argument("--weighting", default="relative",
                   choices=["relative", "absolute"],
                   help="solve in relative-error or absolute-seconds space")
    f.add_argument("--on-nonpositive", default="raise",
                   choices=["raise", "drop", "free"],
                   help="columns the measurements assign no cost: fail, "
                        "keep template rates, or mark the term free")
    f.add_argument("--robust", default=None, choices=["huber", "trim"],
                   help="outlier-resistant solve (corrupted field samples)")
    f.add_argument("--trim-fraction", type=float, default=0.1,
                   help="fraction --robust trim discards (default 0.1)")
    f.add_argument("--max-drift", type=float, default=None,
                   help="refuse to fit when the median measured/predicted "
                        "ratio vs the template deviates from 1 by more "
                        "than this (e.g. 0.25)")
    f.add_argument("--allow-stale", action="store_true")
    f.set_defaults(fn=cmd_fit)

    v = sub.add_parser("validate",
                       help="predicted-vs-measured accuracy report")
    v.add_argument("--store", required=True)
    v.add_argument("--machine", required=True,
                   help="registry name or fitted manifest path")
    v.add_argument("--json", default=None, help="persist the report here")
    v.add_argument("--limit", type=int, default=None)
    v.add_argument("--allow-stale", action="store_true")
    v.set_defaults(fn=cmd_validate)

    p = sub.add_parser("report", help="render a persisted validation report")
    p.add_argument("--json", required=True)
    p.add_argument("--limit", type=int, default=20)
    p.set_defaults(fn=cmd_report)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
