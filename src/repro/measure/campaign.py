"""Measurement campaigns: sweep-planned grids, measured and fit-ready.

A campaign crosses a named problem grid with a pinned (variant x
micro-kernel) axis through :func:`repro.gemm.sweep` — the same bulk planner
the design-space studies use — and measures every planned grid point with
one timing harness, appending :class:`Sample` records to a
:class:`SampleStore`.  Pinning the selection matters: with an explicit
variant + micro-kernel the derived blocking depends only on the spec's
*geometry*, so the samples stay valid across rate refits (see
``store.py``).

``fit_from_store`` then closes the loop: it pulls a store's samples for a
template spec and hands them to :class:`repro.machines.Calibrator` — exactly
the ``(problem, micro-kernel, seconds)`` triples its vectorized
least-squares fit consumes — making ``python -m repro.measure run`` +
``fit`` the paper's "small collection of experiments" end to end.

Grids:

* ``table2`` / ``mobilenet`` — the 19 MobileNetV1 im2col GEMMs of Table 2
  (``mobilenet`` is the alias; the dims are the paper's workload).
* ``smoke``  — six small shapes that measure in ~2 s on a laptop; used by CI
  and the planner benchmark.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

from repro.measure.harness import Harness, get_harness
from repro.measure.store import Sample, SampleStore

#: the default micro-kernel axis for calibration campaigns.  Spanning several
#: shapes is load-bearing: under a single micro-kernel the streaming and
#: arithmetic design columns are all proportional to m*n*k and the fit is
#: provably rank-deficient (see Calibrator.design_matrix).
DEFAULT_FIT_MKS = ((4, 24), (8, 12), (12, 8), (16, 4))

_SMOKE_SHAPES = [(48, 96, 64), (96, 48, 80), (64, 160, 32),
                 (128, 64, 96), (32, 32, 256), (80, 112, 48)]


def grid_names() -> list[str]:
    return ["mobilenet", "smoke", "table2"]


def grid_problems(grid: str, dtype: str | None = None) -> list:
    """The problems of a named grid, with an optional dtype override
    (``smoke`` defaults to f32 so the host replay hits BLAS; the Table-2
    grids default to the paper's int8)."""
    from repro.gemm.api import GemmProblem

    if grid in ("table2", "mobilenet"):
        from repro.core.mobilenet import TABLE2
        return [GemmProblem.coerce(row.problem, dtype=dtype)
                for row in TABLE2]
    if grid == "smoke":
        return [GemmProblem.coerce(s, dtype=dtype, default_dtype="f32")
                for s in _SMOKE_SHAPES]
    raise KeyError(f"unknown campaign grid {grid!r}; have {grid_names()}")


@dataclasses.dataclass
class CampaignResult:
    """The measured grid plus bookkeeping."""

    grid: str
    machine: str
    harness: str
    samples: list[Sample]
    sweep_stats: dict = dataclasses.field(default_factory=dict)

    @property
    def measured_seconds(self) -> float:
        return float(sum(s.seconds for s in self.samples))


def run_campaign(grid: str, *, machine="host-cpu", harness="host-numpy",
                 store: SampleStore | str | None = None,
                 dtype: str | None = None, backend: str | None = None,
                 variant=None, micro_kernels=DEFAULT_FIT_MKS,
                 policy: str = "analytic",
                 timing: Mapping[str, Any] | None = None,
                 truth=None, interpret: bool = False, seed: int = 0,
                 problems: Sequence | None = None,
                 progress=None) -> CampaignResult:
    """Plan, measure and (optionally) store one campaign.

    ``backend`` defaults per harness: the host replay and the simulated
    oracle measure BLIS-variant plans (``analytic-gap8``); the execute
    harnesses measure their own plans (``pallas`` / ``reference``).  For
    backends with a micro-kernel sweep axis the grid is problems x
    ``micro_kernels`` under one ``variant`` (default B3A2C0); other backends
    get one searched plan per problem.  ``truth`` feeds the simulated
    harness; ``problems`` overrides the named grid's problem list.
    """
    from repro import gemm
    from repro.core.variants import Variant
    from repro.machines import resolve

    spec = resolve(machine)
    if problems is not None:
        probs = list(problems)
        grid = "custom"          # don't stamp samples with a grid they
        # don't belong to — provenance must claim only measured workloads.
    else:
        probs = grid_problems(grid, dtype)
    from repro.gemm.api import GemmProblem
    missing = sorted({p.dtype for p in probs if isinstance(p, GemmProblem)
                      and p.dtype not in spec.arith_rate})
    if missing:
        raise ValueError(
            f"{spec.name} has no arith_rate entry for dtype(s) {missing} "
            f"(have {sorted(spec.arith_rate)}); pass dtype= to the "
            f"campaign (e.g. --dtype {sorted(spec.arith_rate)[0]})")
    if isinstance(store, str):
        store = SampleStore(store)
    if not isinstance(harness, Harness):
        kwargs: dict[str, Any] = {}
        if harness == "simulated":
            if truth is None:
                raise ValueError("the simulated harness needs truth=<the "
                                 "ground-truth machine>")
            kwargs["truth"] = truth
        elif harness in ("pallas", "reference"):
            kwargs["interpret"] = interpret
        harness = get_harness(harness, **kwargs)
    if harness.supported_dtypes is not None:
        unsup = sorted({p.dtype for p in probs
                        if isinstance(p, GemmProblem)
                        and p.dtype not in harness.supported_dtypes})
        if unsup:
            raise ValueError(
                f"the {harness.name} harness cannot materialise operands "
                f"for dtype(s) {unsup}; it supports "
                f"{sorted(harness.supported_dtypes)}")
    if backend is None:
        backend = {"pallas": "pallas", "reference": "reference"}.get(
            harness.name, "analytic-gap8")
    variant = variant or Variant.B3A2C0

    res = gemm.sweep(probs, backends=[backend], machines=[spec],
                     dtypes=[dtype] if dtype else None,
                     policies=[policy], variants=[variant],
                     micro_kernels=list(micro_kernels), cache=False)
    samples: list[Sample] = []
    for i, row in enumerate(res.rows):
        t = harness.measure(row.plan, timing=timing, seed=seed + i)
        s = Sample.from_measurement(row.plan, t, harness.name, spec,
                                    meta={"grid": grid})
        if store is not None:
            store.append(s)
        samples.append(s)
        if progress is not None:
            progress(s)
    return CampaignResult(grid=grid, machine=spec.name, harness=harness.name,
                          samples=samples, sweep_stats=dict(res.stats))


class CalibrationDriftError(RuntimeError):
    """The store's measurements disagree with the baseline spec beyond the
    drift threshold — the machine (or the store) is not what the spec says
    it is, and fitting would silently bake the disagreement into fresh
    rates.  Machine-readable via :meth:`as_dict`."""

    def __init__(self, *, baseline: str, store: str, samples: int,
                 median_ratio: float, drift: float, max_drift: float):
        self.baseline = baseline
        self.store = store
        self.samples = samples
        self.median_ratio = median_ratio
        self.drift = drift
        self.max_drift = max_drift
        super().__init__(
            f"{store}: measured times disagree with spec {baseline!r} — "
            f"median measured/predicted = {median_ratio:.3f} "
            f"(drift {drift:.1%} > max_drift {max_drift:.1%}).  Either the "
            f"machine has drifted since the baseline was calibrated or the "
            f"store holds someone else's samples; inspect with "
            f"`python -m repro.measure validate`, then refit against a "
            f"trusted baseline or raise max_drift to accept the shift")

    def as_dict(self) -> dict:
        return {"error": "calibration_drift", "baseline": self.baseline,
                "store": self.store, "samples": self.samples,
                "median_ratio": self.median_ratio, "drift": self.drift,
                "max_drift": self.max_drift}


def fit_from_store(store: SampleStore | str, template, *,
                   name: str | None = None, date: str | None = None,
                   policy: str | None = None, per_mk_arith: bool = False,
                   overhead_per_block: bool = False,
                   register: bool = False, manifest_dir: str | None = None,
                   on_nonpositive: str = "raise",
                   weighting: str = "relative",
                   robust: str | None = None, trim_fraction: float = 0.1,
                   max_drift: float | None = None,
                   drift_baseline=None,
                   allow_stale: bool = False):
    """Fit ``template``'s rates from a store's measured samples.

    Pulls the samples whose geometry fingerprint matches the template
    (stale ones raise, see :meth:`SampleStore.for_machine`), groups them
    into the ``(problem, micro-kernel, seconds)`` triples
    :meth:`Calibrator.fit` consumes, and runs the vectorized least-squares
    fit.  Real measurements default to the relative-error solve
    (``weighting="relative"``) so MAPE over a wide-dynamic-range grid is
    what gets minimised; pass ``"absolute"`` for the plain solve.
    Returns ``(spec, FitReport)``.

    ``overhead_per_block=True`` additionally fits a constant cost per
    innermost micro-kernel dispatch (recorded in fit provenance, not in the
    rate tables) so loop overhead on small blocks stops polluting the rates.

    ``robust``/``trim_fraction`` pass through to
    :meth:`repro.machines.Calibrator.fit` — use ``robust="huber"`` (or
    ``"trim"``) on field campaigns where a slice of the samples is
    corrupted (thermal throttling, background load) so the outliers don't
    drag every fitted rate.

    ``max_drift`` arms the drift gate: before fitting, every sample is
    priced by ``drift_baseline`` (default: the template itself) via
    :func:`repro.measure.validate.predict_samples`, and if the *median*
    measured/predicted ratio deviates from 1 by more than ``max_drift``
    the fit refuses with :class:`CalibrationDriftError` — a systematic
    disagreement with the registered spec means the samples describe a
    different machine (or a drifted one) and should be inspected, not
    silently absorbed.  The median is robust to the same outliers
    ``robust=`` handles, so the two compose: outliers don't trip the gate,
    wholesale drift does.
    """
    from repro.core.variants import MicroKernel, Variant
    from repro.machines import resolve
    from repro.machines.calibrate import Calibrator

    if isinstance(store, str):
        store = SampleStore(store)
    spec = resolve(template)
    samples = [s for s in store.for_machine(spec, allow_stale=allow_stale)
               if s.micro_kernel is not None]
    if not samples:
        raise ValueError(
            f"{store.path}: no BLIS-model samples for machine {spec.name!r} "
            f"(geometry {spec.geometry_fingerprint()}) — run a campaign "
            f"first (python -m repro.measure run)")
    variants = sorted({s.variant for s in samples})
    if len(variants) > 1:
        raise ValueError(
            f"samples span variants {variants}; fit one variant at a time "
            f"(filter the store or run separate campaigns)")
    if policy is None:
        policies = sorted({s.policy for s in samples})
        if len(policies) > 1:
            raise ValueError(f"samples span policies {policies}; pass "
                             f"policy= explicitly")
        policy = policies[0]
    cal = Calibrator(spec, model="blis", variant=Variant(variants[0]),
                     policy=policy)
    if max_drift is not None:
        import statistics

        from repro.measure.validate import predict_samples
        base = resolve(drift_baseline) if drift_baseline is not None \
            else spec
        predicted = predict_samples(base, samples)
        ratios = [s.seconds / p for s, p in zip(samples, predicted)
                  if p > 0.0]
        median_ratio = statistics.median(ratios)
        drift = abs(median_ratio - 1.0)
        if drift > max_drift:
            raise CalibrationDriftError(
                baseline=base.name, store=store.path, samples=len(ratios),
                median_ratio=median_ratio, drift=drift,
                max_drift=max_drift)
    probs = [s.problem for s in samples]
    mks = [MicroKernel(*map(int, s.micro_kernel.split("x")))
           for s in samples]
    seconds = [s.seconds for s in samples]
    harnesses = sorted({s.harness for s in samples})
    return cal.fit(
        probs, seconds, micro_kernels=mks, date=date, name=name,
        register=register, manifest_dir=manifest_dir,
        per_mk_arith=per_mk_arith, overhead_per_block=overhead_per_block,
        on_nonpositive=on_nonpositive,
        weighting=weighting, robust=robust, trim_fraction=trim_fraction,
        extra_provenance={"measure": {
            "store": store.path, "harnesses": harnesses,
            "grids": sorted({s.meta.get("grid", "?") for s in samples}),
        }})
