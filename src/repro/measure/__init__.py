"""``repro.measure`` — measurement, sample storage and model validation.

The fourth subsystem next to ``core`` / ``gemm`` / ``machines``: it closes
the paper's measure→fit→validate loop that the analytic side only predicts.

    >>> from repro import measure
    >>> store = measure.SampleStore("measurements/host.jsonl")
    >>> measure.run_campaign("table2", machine="host-cpu", dtype="f32",
    ...                      harness="host-numpy", store=store)
    >>> spec, fit = measure.fit_from_store(store, "host-cpu",
    ...                                    name="host-cpu-fit", date=None)
    >>> report = measure.validate_spec(spec, store)
    >>> print(report.table())           # per-cell errors + MAPE

Layers: ``harness`` (timing backends behind one protocol — host loop-nest
replay, plan.execute under block_until_ready, the simulated closed-loop
oracle), ``store`` (append-only JSONL samples keyed by the machine's
geometry fingerprint), ``campaign`` (sweep-driven measurement grids feeding
``Calibrator.fit``), ``validate`` (predicted-vs-measured accuracy reports).

``python -m repro.measure run|fit|validate|report`` drives the same loop
from the shell; CI runs a host smoke campaign + validation every build.
"""
from repro.measure.harness import (
    Harness,
    TimingResult,
    blocked_loop_nest,
    clock_overhead,
    get_harness,
    harness_names,
    plan_loop_order,
    time_callable,
)
from repro.measure.store import (
    SAMPLE_SCHEMA,
    Sample,
    SampleStore,
    StaleSampleError,
)
from repro.measure.campaign import (
    CalibrationDriftError,
    CampaignResult,
    DEFAULT_FIT_MKS,
    fit_from_store,
    grid_names,
    grid_problems,
    run_campaign,
)
from repro.measure.validate import (
    REPORT_SCHEMA,
    ValidationReport,
    ValidationRow,
    predict_plan,
    predict_sample,
    predict_samples,
    validate_spec,
)

__all__ = [
    "CalibrationDriftError", "CampaignResult", "DEFAULT_FIT_MKS",
    "Harness", "REPORT_SCHEMA",
    "SAMPLE_SCHEMA", "Sample", "SampleStore", "StaleSampleError",
    "TimingResult", "ValidationReport", "ValidationRow",
    "blocked_loop_nest", "clock_overhead", "fit_from_store", "get_harness",
    "grid_names", "grid_problems", "harness_names", "plan_loop_order",
    "predict_plan", "predict_sample", "predict_samples", "run_campaign",
    "time_callable", "validate_spec",
]
