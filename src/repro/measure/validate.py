"""Predicted-vs-measured accuracy reports — the paper's claim, runnable.

The paper's headline (§3.2/§4) is that a handful of calibration experiments
make the analytic simulator "deliver highly accurate estimations of the
execution time".  This module turns that into an artifact: re-predict every
measured :class:`Sample` under a spec (same pinned selection, same policy),
and report per-cell relative error, MAPE, the worst cell, and per-dtype /
per-micro-kernel breakdowns, as a table and as persisted JSON.

The per-micro-kernel breakdown is also where the ``arith_per_mk``
refinement (paper §4) shows up: a spec carrying per-mk arithmetic rates is
predicted through them, so fitting the table should flatten the per-mk
error profile.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import statistics
from typing import Any, Mapping

from repro.measure.store import Sample, SampleStore

REPORT_SCHEMA = "repro.measure/validation-v1"


def _parse_tile(tag: str):
    from repro.core.tpu_model import GridOrder, TileConfig
    dims, _, order = tag.partition(":")
    bm, bn, bk = (int(x) for x in dims.split("x"))
    return TileConfig(bm, bn, bk, GridOrder(order or "k_inner"))


def predict_plan(plan, machine) -> float:
    """Re-predict a plan's time under another machine, keeping the pinned
    selection and policy (shared by the simulated harness and the
    validator)."""
    from repro import gemm
    from repro.gemm.api import VariantChoice

    sel = plan.selection
    opts: dict[str, Any] = {}
    if isinstance(sel, VariantChoice):
        opts = {"variant": sel.variant, "micro_kernel": sel.micro_kernel}
    elif sel is not None:
        opts = {"tile": sel}
    p = gemm.plan(plan.problem, backend=plan.backend, machine=machine,
                  policy=str(plan.provenance.get("policy", "analytic")),
                  cache=False, **opts)
    return p.predicted_seconds


def _sample_plan_opts(sample: Sample) -> dict[str, Any]:
    if sample.micro_kernel is not None:
        return {"variant": sample.variant,
                "micro_kernel": tuple(int(x) for x in
                                      sample.micro_kernel.split("x"))}
    if sample.tile is not None:
        return {"tile": _parse_tile(sample.tile)}
    return {}


def predict_sample(spec, sample: Sample) -> float:
    """The spec's predicted seconds for one sample's recorded grid cell."""
    return predict_samples(spec, [sample])[0]


def predict_samples(spec, samples) -> list[float]:
    """Predicted seconds for many samples, grouped by (backend, selection,
    policy) so each group is one bulk :func:`repro.gemm.plan_many` call
    through the batched engines rather than a scalar planning loop."""
    from repro import gemm

    samples = list(samples)
    groups: dict[tuple, list[int]] = {}
    for i, s in enumerate(samples):
        key = (s.backend, s.variant, s.micro_kernel, s.tile, s.policy)
        groups.setdefault(key, []).append(i)
    out: list[float] = [0.0] * len(samples)
    for idxs in groups.values():
        first = samples[idxs[0]]
        plans = gemm.plan_many([samples[i].problem for i in idxs],
                               backend=first.backend, machine=spec,
                               policy=first.policy, cache=False,
                               **_sample_plan_opts(first))
        for i, p in zip(idxs, plans):
            out[i] = p.predicted_seconds
    return out


@dataclasses.dataclass(frozen=True)
class ValidationRow:
    """One grid cell: measured vs predicted."""

    sample: Sample
    predicted_s: float

    @property
    def measured_s(self) -> float:
        return self.sample.seconds

    @property
    def rel_err(self) -> float:
        """Signed relative error: predicted/measured - 1."""
        return self.predicted_s / self.measured_s - 1.0

    @property
    def ape(self) -> float:
        """Absolute percentage error of this cell."""
        return abs(self.predicted_s - self.measured_s) / self.measured_s


@dataclasses.dataclass
class ValidationReport:
    """Accuracy of one spec against one sample set."""

    machine: str
    fingerprint: str
    rows: list[ValidationRow]

    def __post_init__(self):
        if not self.rows:
            raise ValueError("validation needs at least one sample")

    @property
    def mape(self) -> float:
        """Mean absolute percentage error over all cells, in percent."""
        return 100.0 * statistics.fmean(r.ape for r in self.rows)

    @property
    def median_ape(self) -> float:
        return 100.0 * statistics.median(r.ape for r in self.rows)

    @property
    def worst(self) -> ValidationRow:
        return max(self.rows, key=lambda r: r.ape)

    def breakdown(self, field: str) -> dict[str, dict]:
        """Per-group accuracy, grouped by a sample field (``"dtype"``,
        ``"micro_kernel"``, ``"harness"``, ...)."""
        groups: dict[str, list[ValidationRow]] = {}
        for r in self.rows:
            key = str(getattr(r.sample, field))
            groups.setdefault(key, []).append(r)
        return {key: {
            "cells": len(rs),
            "mape_pct": 100.0 * statistics.fmean(r.ape for r in rs),
            "bias_pct": 100.0 * statistics.fmean(r.rel_err for r in rs),
        } for key, rs in sorted(groups.items())}

    def per_dtype(self) -> dict[str, dict]:
        return self.breakdown("dtype")

    def per_micro_kernel(self) -> dict[str, dict]:
        return self.breakdown("micro_kernel")

    def summary(self) -> dict:
        w = self.worst
        return {
            "machine": self.machine,
            "fingerprint": self.fingerprint,
            "cells": len(self.rows),
            "mape_pct": self.mape,
            "median_ape_pct": self.median_ape,
            "worst": {"cell": w.sample.cell, "ape_pct": 100.0 * w.ape,
                      "measured_s": w.measured_s,
                      "predicted_s": w.predicted_s},
        }

    def table(self, limit: int | None = None) -> str:
        lines = ["cell                               measured s   "
                 "predicted s   rel err"]
        for r in self.rows[:limit]:
            lines.append(f"{r.sample.cell:<35}{r.measured_s:>10.3e}"
                         f"{r.predicted_s:>14.3e}{r.rel_err:>+9.2%}")
        if limit is not None and len(self.rows) > limit:
            lines.append(f"... ({len(self.rows) - limit} more cells)")
        lines.append(f"MAPE {self.mape:.2f}% over {len(self.rows)} cells "
                     f"(median {self.median_ape:.2f}%, worst "
                     f"{100.0 * self.worst.ape:.2f}% on "
                     f"{self.worst.sample.cell})")
        return "\n".join(lines)

    def to_json(self) -> dict:
        return {
            "schema": REPORT_SCHEMA,
            "summary": self.summary(),
            "per_dtype": self.per_dtype(),
            "per_micro_kernel": self.per_micro_kernel(),
            "rows": [{**r.sample.to_json(),
                      "predicted_s": r.predicted_s,
                      "rel_err": r.rel_err} for r in self.rows],
        }

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)
        return path

    @classmethod
    def from_json(cls, d: Mapping[str, Any]) -> "ValidationReport":
        if d.get("schema") != REPORT_SCHEMA:
            raise ValueError(f"unknown validation-report schema "
                             f"{d.get('schema')!r}")
        rows = [ValidationRow(sample=Sample.from_json(r),
                              predicted_s=float(r["predicted_s"]))
                for r in d["rows"]]
        s = d["summary"]
        return cls(machine=s["machine"], fingerprint=s["fingerprint"],
                   rows=rows)

    @classmethod
    def load(cls, path: str) -> "ValidationReport":
        with open(path) as f:
            return cls.from_json(json.load(f))

    @property
    def finite(self) -> bool:
        return math.isfinite(self.mape)


def validate_spec(spec, samples, *,
                  allow_stale: bool = False) -> ValidationReport:
    """Predicted-vs-measured report for ``spec`` over ``samples`` (a
    :class:`SampleStore`, a path, or an explicit sample list).

    Store lookups go through the geometry-fingerprint guard, so a report can
    never silently score a spec against another machine's measurements.
    """
    from repro.machines import resolve

    mspec = resolve(spec)
    if isinstance(samples, str):
        samples = SampleStore(samples)
    if isinstance(samples, SampleStore):
        samples = samples.for_machine(mspec, allow_stale=allow_stale)
    samples = list(samples)
    rows = [ValidationRow(sample=s, predicted_s=p)
            for s, p in zip(samples, predict_samples(mspec, samples))]
    return ValidationReport(machine=mspec.name,
                            fingerprint=mspec.geometry_fingerprint(),
                            rows=rows)
