"""Hardware descriptions for the GEMM performance simulator.

The paper models an IoT processor as a set of software-managed scratchpad
memory levels (R, L1, L2, M) with measured point-to-point transfer rates
(Table 1) plus a flat arithmetic rate.  We keep that structure parametric so
the same simulator drives both the paper's GAP8 fabric-controller instance
(4 levels, INT8) and the TPU-v5e adaptation (R / VMEM / HBM, bf16+int8).

Rates follow the paper's convention: *bytes per second* for transfers and
*ops per second* for arithmetic.  The packing/unpacking rates were calibrated
with chunks of ``r = 4`` contiguous elements and scale linearly with the
chunk size (paper §3.2: ``n_r=4 → 1.62 MB/s``, ``n_r=8 → 3.24 MB/s``); the
simulator applies that scaling via :meth:`MachineSpec.packing_rate`.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

MB = 1.0e6          # the paper reports MBytes/s (decimal)
KiB = 1024
MiB = 1024 * 1024
GB = 1.0e9


@dataclasses.dataclass(frozen=True)
class MachineSpec:
    """A machine for the blocked-GEMM cost model.

    ``transfer_rates`` maps ``(origin, destination)`` level names to bytes/s.
    Level names are free-form but the variant cost models use the canonical
    set ``{"M", "L2", "L1", "R"}`` (TPU: ``{"M", "L1", "R"}`` where ``L1`` is
    VMEM and ``M`` is HBM; the "L2" role collapses onto VMEM).
    """

    name: str
    # capacities in bytes, by level name (registers expressed in bytes too).
    capacities: Mapping[str, int]
    # (origin, dest) -> bytes/s, calibrated at the reference chunk size.
    transfer_rates: Mapping[tuple[str, str], float]
    # arithmetic throughput, ops/s (1 MAC = 2 ops), by dtype tag.
    arith_rate: Mapping[str, float]
    # chunk size (elements) at which packing rates were calibrated.
    reference_chunk: int = 4
    # element size in bytes for the default dtype.
    elem_bytes: int = 1
    # number of (SIMD) registers and lanes per register, for micro-kernel
    # feasibility checks.
    num_vector_registers: int = 32
    register_lanes: int = 4

    def rate(self, origin: str, dest: str) -> float:
        try:
            return self.transfer_rates[(origin, dest)]
        except KeyError as e:
            raise KeyError(
                f"{self.name}: no calibrated transfer rate {origin}->{dest}"
            ) from e

    def packing_rate(self, origin: str, dest: str, chunk_elems: int) -> float:
        """Packing rate scaled by the contiguous-chunk size (paper §3.2)."""
        scale = chunk_elems / float(self.reference_chunk)
        return self.rate(origin, dest) * scale

    def capacity(self, level: str) -> int:
        return int(self.capacities[level])


# ---------------------------------------------------------------------------
# GAP8 fabric controller — the paper's calibrated instance (Table 1).
# ---------------------------------------------------------------------------
# Levels: M  = the off-FC memory the paper calls "L3"/main,
#         L2 = 512 KiB shared memory area,
#         L1 = 16 KiB FC L1 memory area,
#         R  = 32 SIMD registers of 32 bits (4 INT8 lanes each).
GAP8_FC = MachineSpec(
    name="gap8-fc",
    capacities={
        "M": 8 * MiB,          # external; effectively unbounded for the model
        "L2": 512 * KiB,
        "L1": 16 * KiB,
        "R": 32 * 4,           # 32 regs x 4 INT8 lanes
    },
    transfer_rates={
        # -- packing / unpacking (measured with r = 4 element chunks) -------
        ("M", "M"): 1.62e0 * MB,    # e.g. B -> B_c with the buffer in M
        ("M", "L2"): 5.30e-1 * MB,  # e.g. A -> A_c
        ("L2", "M"): 6.54e-1 * MB,  # unpack C_c -> C (B3C2A0)
        # -- L3->L1 panel copy (contiguous; not chunk-scaled) ----------------
        ("M", "L1"): 8.81e0 * MB,
        # -- micro-kernel streaming ------------------------------------------
        ("M", "R"): 4.87e-1 * MB,
        ("L1", "R"): 1.78e2 * MB,
        ("L2", "R"): 7.18e0 * MB,
    },
    arith_rate={"int8": 5.64e9},    # 5.64 INT8 GOPS (paper §3.2)
    reference_chunk=4,
    elem_bytes=1,
    num_vector_registers=32,
    register_lanes=4,
)

# ---------------------------------------------------------------------------
# TPU v5e — the adaptation target (roofline constants from the assignment).
# ---------------------------------------------------------------------------
V5E_PEAK_BF16 = 197e12            # FLOP/s per chip
V5E_PEAK_INT8 = 394e12            # OP/s per chip
V5E_HBM_BW = 819e9                # bytes/s
V5E_HBM_BYTES = 16 * GB
V5E_VMEM_BYTES = 128 * MiB
V5E_ICI_BW = 50e9                 # bytes/s per link
V5E_VMEM_BW = 22e12               # bytes/s VMEM<->VREG (approximate)
V5E_MXU = 128                     # systolic array dimension

TPU_V5E = MachineSpec(
    name="tpu-v5e",
    capacities={
        "M": int(V5E_HBM_BYTES),   # HBM
        "L1": int(V5E_VMEM_BYTES), # VMEM (software-managed scratchpad)
        "R": 64 * KiB,             # VREG file (nominal)
    },
    transfer_rates={
        ("M", "L1"): V5E_HBM_BW,   # HBM -> VMEM (DMA)
        ("L1", "M"): V5E_HBM_BW,
        ("M", "M"): V5E_HBM_BW,    # HBM-resident reshuffle ~ HBM bw bound
        ("L1", "R"): V5E_VMEM_BW,
        ("M", "R"): V5E_HBM_BW,    # streaming HBM operand
    },
    arith_rate={"bf16": V5E_PEAK_BF16, "int8": V5E_PEAK_INT8,
                "f32": V5E_PEAK_BF16 / 2},
    reference_chunk=4,
    elem_bytes=2,                  # bf16 default
    num_vector_registers=64,
    register_lanes=1024,           # 8 sublanes x 128 lanes (f32 lanes)
)


MACHINES = {"gap8-fc": GAP8_FC, "tpu-v5e": TPU_V5E}


def get_machine(name: str) -> MachineSpec:
    try:
        return MACHINES[name]
    except KeyError as e:
        raise KeyError(f"unknown machine {name!r}; have {sorted(MACHINES)}") from e
