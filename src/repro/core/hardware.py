"""Hardware descriptions — now a compatibility shim over ``repro.machines``.

Machine specs used to be hard-coded constants here; they are now JSON
manifests in the declarative machine zoo (``repro/machines/zoo/*.json``)
loaded through the :mod:`repro.machines` registry.  Adding a processor is
dropping a manifest file (or calling ``repro.machines.register``), not
editing code — see the "Machine zoo & calibration" section of the README.

This module keeps the legacy surface importable:

* ``MachineSpec`` — re-exported from :mod:`repro.machines.spec` (the
  canonical home; it gained ``to_json``/``from_json``, validation,
  level-role aliasing and derived-machine transforms).
* ``GAP8_FC`` / ``TPU_V5E`` / ``MACHINES`` — deprecated module attributes
  resolved from the registry on first access.
* ``get_machine`` — deprecated; call ``repro.machines.get`` instead.

The roofline scalars (``V5E_*``) remain plain constants: they parameterize
the TPU cost model's geometry (MXU dimension, VMEM budget), not a machine's
calibrated rates.
"""
from __future__ import annotations

import warnings

from repro.machines import registry as _machines
from repro.machines.spec import MachineSpec

__all__ = [
    "MB", "KiB", "MiB", "GB", "MachineSpec", "get_machine",
    "V5E_PEAK_BF16", "V5E_PEAK_INT8", "V5E_HBM_BW", "V5E_HBM_BYTES",
    "V5E_VMEM_BYTES", "V5E_ICI_BW", "V5E_VMEM_BW", "V5E_MXU",
]

MB = 1.0e6          # the paper reports MBytes/s (decimal)
KiB = 1024
MiB = 1024 * 1024
GB = 1.0e9

# ---------------------------------------------------------------------------
# TPU v5e roofline constants (cost-model geometry; the calibrated machine
# spec itself lives in repro/machines/zoo/tpu-v5e.json).
# ---------------------------------------------------------------------------
V5E_PEAK_BF16 = 197e12            # FLOP/s per chip
V5E_PEAK_INT8 = 394e12            # OP/s per chip
V5E_HBM_BW = 819e9                # bytes/s
V5E_HBM_BYTES = 16 * GB
V5E_VMEM_BYTES = 128 * MiB
V5E_ICI_BW = 50e9                 # bytes/s per link
V5E_VMEM_BW = 22e12               # bytes/s VMEM<->VREG (approximate)
V5E_MXU = 128                     # systolic array dimension

_DEPRECATED = {"GAP8_FC": "gap8-fc", "TPU_V5E": "tpu-v5e"}


def __getattr__(name: str):
    if name in _DEPRECATED:
        warnings.warn(
            f"repro.core.hardware.{name} is deprecated; use "
            f"repro.machines.get({_DEPRECATED[name]!r}) — the spec now "
            f"lives in the machine zoo manifest",
            DeprecationWarning, stacklevel=2)
        return _machines.get(_DEPRECATED[name])
    if name == "MACHINES":
        warnings.warn(
            "repro.core.hardware.MACHINES is deprecated; use "
            "repro.machines.list_machines() / repro.machines.get(name)",
            DeprecationWarning, stacklevel=2)
        return {n: _machines.get(n) for n in _machines.list_machines()}
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def get_machine(name: str) -> MachineSpec:
    """Deprecated alias of :func:`repro.machines.get`."""
    warnings.warn(
        "repro.core.hardware.get_machine is deprecated; use "
        "repro.machines.get", DeprecationWarning, stacklevel=2)
    try:
        return _machines.get(name)
    except KeyError as e:
        raise KeyError(f"unknown machine {name!r}; have "
                       f"{_machines.list_machines()}") from e
