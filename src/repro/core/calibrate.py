"""Calibration micro-experiments (paper §3.2).

The paper calibrates its simulator with a handful of micro-experiments:
packing rates at a reference chunk size (r = 4), straight panel-copy rates,
micro-kernel streaming rates, and one arithmetic-rate measurement.  The GAP8
numbers are published (Table 1) and live in the machine-zoo manifest
``repro/machines/zoo/gap8-fc.json``; this module provides the raw
*measurements* for re-running the methodology on the host we are on.

The pipeline around them — assembling a :class:`MachineSpec`, least-squares
rate fitting on the batched simulators, registering the result and
persisting a manifest — is :class:`repro.machines.Calibrator`;
:func:`calibrate_host` below is a thin wrapper over
``Calibrator.measure_host`` kept for compatibility.  On a real TPU the same
harness would time HBM<->VMEM DMAs via Pallas kernels.
"""
from __future__ import annotations

import numpy as np

from repro.machines.spec import MachineSpec


def _time(fn, *args, reps: int = 5) -> float:
    """Timing via the shared ``repro.measure.harness`` protocol.

    The old inline loop took a bare best-of-5 with no warmup, which billed
    first-touch page faults of the freshly allocated buffers to the packing
    rates; the harness warms up once and aggregates median-of-min with the
    clock overhead subtracted.
    """
    from repro.measure.harness import time_callable

    return time_callable(lambda: fn(*args), warmup=1, rounds=reps).seconds


def measure_copy_rate(nbytes: int = 1 << 24) -> float:
    """Contiguous copy bandwidth (bytes/s) — the analogue of T_{M,L1}."""
    src = np.ones(nbytes, dtype=np.uint8)
    dst = np.empty_like(src)
    t = _time(lambda: np.copyto(dst, src))
    return nbytes / t


def measure_packing_rate(chunk: int, rows: int = 4096, cols: int = 4096
                         ) -> float:
    """Strided packing bandwidth (bytes/s) for a given contiguous-chunk size.

    Mirrors the paper's packing experiment: reorganise a matrix into
    micro-panels of ``chunk`` leading elements.  The paper observed the rate
    scaling linearly with the chunk size; ``tests/test_calibrate.py`` checks
    the same trend holds for the host.
    """
    a = np.arange(rows * cols, dtype=np.uint8).reshape(rows, cols)
    panels = cols // chunk

    def pack():
        # (rows, panels, chunk) -> (panels, rows, chunk): same data movement
        # pattern as Fig. 2 (chunks of `chunk` consecutive elements).
        return np.ascontiguousarray(
            a.reshape(rows, panels, chunk).transpose(1, 0, 2))

    t = _time(pack)
    return a.nbytes / t


def measure_arith_rate(n: int = 1024) -> float:
    """Matmul throughput (ops/s) — the analogue of the 5.64 INT8 GOPS
    micro-kernel experiment."""
    a = np.random.rand(n, n).astype(np.float32)
    b = np.random.rand(n, n).astype(np.float32)
    t = _time(lambda: a @ b)
    return 2.0 * n ** 3 / t


def calibrate_host(name: str = "host-cpu", *, date: str | None = None,
                   register: bool = False) -> MachineSpec:
    """Run the full calibration suite and assemble a MachineSpec.

    Thin wrapper over :meth:`repro.machines.Calibrator.measure_host`, which
    owns the measure→register→persist pipeline; with ``register=True`` the
    spec replaces the zoo's ``host-cpu`` template in the registry so the
    planner sweeps against measured host rates.
    """
    from repro.machines.calibrate import Calibrator

    return Calibrator.measure_host(name, date=date, register=register)
