"""Calibration harness (paper §3.2).

The paper calibrates its simulator with a handful of micro-experiments:
packing rates at a reference chunk size (r = 4), straight panel-copy rates,
micro-kernel streaming rates, and one arithmetic-rate measurement.  The GAP8
numbers are published (Table 1) and encoded in ``hardware.GAP8_FC``; this
module re-runs the *methodology* on the host we are on, producing a
``MachineSpec`` for it — demonstrating the portability claim (§1: "a few
experimental data ... collected via simple calibration experiments").

On the CPU container this yields a host-CPU spec (useful for the unit tests
that check chunk-rate linearity); on a real TPU the same harness would time
HBM<->VMEM DMAs via Pallas kernels.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.hardware import MachineSpec


def _time(fn, *args, reps: int = 5) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best


def measure_copy_rate(nbytes: int = 1 << 24) -> float:
    """Contiguous copy bandwidth (bytes/s) — the analogue of T_{M,L1}."""
    src = np.ones(nbytes, dtype=np.uint8)
    dst = np.empty_like(src)
    t = _time(lambda: np.copyto(dst, src))
    return nbytes / t


def measure_packing_rate(chunk: int, rows: int = 4096, cols: int = 4096
                         ) -> float:
    """Strided packing bandwidth (bytes/s) for a given contiguous-chunk size.

    Mirrors the paper's packing experiment: reorganise a matrix into
    micro-panels of ``chunk`` leading elements.  The paper observed the rate
    scaling linearly with the chunk size; ``tests/test_calibrate.py`` checks
    the same trend holds for the host.
    """
    a = np.arange(rows * cols, dtype=np.uint8).reshape(rows, cols)
    panels = cols // chunk

    def pack():
        # (rows, panels, chunk) -> (panels, rows, chunk): same data movement
        # pattern as Fig. 2 (chunks of `chunk` consecutive elements).
        return np.ascontiguousarray(
            a.reshape(rows, panels, chunk).transpose(1, 0, 2))

    t = _time(pack)
    return a.nbytes / t


def measure_arith_rate(n: int = 1024) -> float:
    """Matmul throughput (ops/s) — the analogue of the 5.64 INT8 GOPS
    micro-kernel experiment."""
    a = np.random.rand(n, n).astype(np.float32)
    b = np.random.rand(n, n).astype(np.float32)
    t = _time(lambda: a @ b)
    return 2.0 * n ** 3 / t


def calibrate_host(name: str = "host-cpu") -> MachineSpec:
    """Run the full calibration suite and assemble a MachineSpec."""
    pack4 = measure_packing_rate(4)
    copy = measure_copy_rate()
    arith = measure_arith_rate()
    return MachineSpec(
        name=name,
        capacities={"M": 1 << 34, "L2": 1 << 21, "L1": 1 << 15, "R": 1 << 10},
        transfer_rates={
            ("M", "M"): pack4,
            ("M", "L2"): pack4,
            ("L2", "M"): pack4,
            ("M", "L1"): copy,
            ("M", "R"): copy,
            ("L1", "R"): copy * 4,
            ("L2", "R"): copy * 2,
        },
        arith_rate={"int8": arith, "f32": arith},
        reference_chunk=4,
        elem_bytes=1,
    )
