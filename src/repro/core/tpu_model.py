"""TPU adaptation of the paper's simulator: a cost model for Pallas GEMM.

The paper's memory model (software-managed scratchpads, programmed DMA, no
caches) *is* the TPU memory model: HBM -> VMEM -> VREG with Pallas
``BlockSpec`` controlling every transfer.  The paper's algorithmic family
(loop orders deciding which operand is resident vs. streamed) maps onto the
**grid iteration order** of a Pallas kernel:

* ``k`` innermost (grid ``(i, j, k)``)  — the C block stays in a VMEM
  accumulator while A/B blocks stream: the **B3A2C0 analogue**
  (output-stationary; C written once).
* ``k`` outermost (grid ``(k, i, j)``) — the C block is revisited (read +
  written) on every k step: the **C3B2A0/B3C2A0 analogue** (C streamed).

The cost model mirrors the paper's: traffic per level x calibrated rate plus
a flat arithmetic term, with *two* composition rules — the paper's
no-overlap sum (§3.1 assumption) and the double-buffered ``max`` that Pallas'
pipeline actually achieves (the paper's future-work item).
"""
from __future__ import annotations

import dataclasses
import enum
import math

import numpy as np

from repro.core.hardware import MachineSpec, V5E_MXU  # noqa: F401
from repro.core.precision import PrecisionConfig
from repro.machines import registry as _machines

# int4 is modelled at one byte (unpacked panels — see core/precision.py);
# its advantage over int8 is purely the arithmetic rate.
DTYPE_BYTES = {"int4": 1, "int8": 1, "bf16": 2, "f32": 4}
# minimal TPU tile (sublane, lane) per dtype — misaligned blocks get padded.
SUBLANE = {"int4": 32, "int8": 32, "bf16": 16, "f32": 8}
LANE = 128


class GridOrder(str, enum.Enum):
    """Pallas grid iteration order == the paper's loop-order variant."""
    K_INNER = "k_inner"     # B3A2C0 analogue: C resident, written once
    K_OUTER = "k_outer"     # C3B2A0/B3C2A0 analogue: C revisited every k step


@dataclasses.dataclass(frozen=True)
class TileConfig:
    bm: int
    bn: int
    bk: int
    order: GridOrder = GridOrder.K_INNER

    def __str__(self) -> str:
        return f"{self.bm}x{self.bn}x{self.bk}:{self.order.value}"


@dataclasses.dataclass(frozen=True)
class GemmShape:
    m: int
    n: int
    k: int
    dtype: str = "bf16"
    accumulate: bool = False   # C += A.B (paper semantics) vs C = A.B
    # per-operand dtypes for mixed-precision GEMM; None (or a uniform
    # config) is the plain single-dtype path.  ``dtype`` stays the compute
    # (narrower-operand) dtype — the MXU path the arithmetic runs on.
    precision: PrecisionConfig | None = None

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.n * self.k

    @property
    def mixed_precision(self) -> PrecisionConfig | None:
        """The shape's precision config when it is genuinely mixed (uniform
        configs are the plain dtype path and return None)."""
        pc = self.precision
        return pc if pc is not None and not pc.is_uniform else None


@dataclasses.dataclass(frozen=True)
class TpuCost:
    """Cost estimate for one Pallas GEMM tile configuration."""
    shape: GemmShape
    tile: TileConfig
    hbm_bytes: float          # HBM <-> VMEM traffic
    vmem_bytes: float         # VMEM <-> VREG traffic (usually negligible)
    vmem_peak: int            # peak VMEM working set (double-buffered)
    t_compute: float
    t_hbm: float
    t_vmem: float
    mxu_efficiency: float     # useful fraction of MXU-padded FLOPs
    # quantize/dequantize HBM traffic of a mixed-precision shape (already
    # included in hbm_bytes; kept separate for attribution/explain).
    quant_bytes: float = 0.0

    @property
    def total_no_overlap(self) -> float:
        """Paper-faithful composition: transfers are not overlapped (§3.1)."""
        return self.t_compute + self.t_hbm + self.t_vmem

    @property
    def total_overlapped(self) -> float:
        """Double-buffered Pallas pipeline: bound by the slowest resource,
        plus one pipeline fill of the first block pair."""
        startup = self.t_hbm / max(1.0, self._grid_steps())
        return max(self.t_compute, self.t_hbm, self.t_vmem) + startup

    def _grid_steps(self) -> float:
        s, t = self.shape, self.tile
        return (math.ceil(s.m / t.bm) * math.ceil(s.n / t.bn)
                * math.ceil(s.k / t.bk))

    def total(self, overlap: bool = True) -> float:
        return self.total_overlapped if overlap else self.total_no_overlap

    def roofline_fraction(self, overlap: bool = True) -> float:
        """Fraction of the pure-compute roofline this config achieves."""
        ideal = self.shape.flops / _peak(self.shape.dtype)
        return ideal / self.total(overlap)


def _default_machine() -> MachineSpec:
    return _machines.get("tpu-v5e")


def machine_peak(machine: MachineSpec, dtype: str) -> float:
    """Per-dtype arithmetic peak of a machine's rate table.

    ``f32`` computes through the bf16 MXU path (same convention the model
    has always used); machines whose table lacks the requested tag fall
    back to their fastest declared rate, so analytic what-ifs on foreign
    machines (e.g. the GAP8 spec through the TPU model) stay well-defined.
    """
    tag = "bf16" if dtype == "f32" else dtype
    rate = machine.arith_rate.get(tag)
    return rate if rate is not None else max(machine.arith_rate.values())


def machine_peak_mixed(machine: MachineSpec,
                       precision: PrecisionConfig) -> float:
    """Arithmetic peak for a mixed-precision config: the spec's
    ``rates_mixed`` entry for the config key when calibrated, else
    :func:`machine_peak` of the compute (narrower-operand) dtype."""
    rate = machine.rates_mixed.get(precision.key())
    return rate if rate is not None \
        else machine_peak(machine, precision.compute_dtype)


def shape_peak(machine: MachineSpec, shape: GemmShape) -> float:
    """Per-shape arithmetic peak honouring an attached mixed precision."""
    pc = shape.mixed_precision
    return machine_peak_mixed(machine, pc) if pc is not None \
        else machine_peak(machine, shape.dtype)


def _peak(dtype: str) -> float:
    return machine_peak(_default_machine(), dtype)


def _pad(x: int, mult: int) -> int:
    return mult * math.ceil(x / mult)


def vmem_required(shape: GemmShape, tile: TileConfig,
                  double_buffer: bool = True) -> int:
    """Peak VMEM bytes: A and B blocks (x2 when double-buffered by the
    pipeline) plus the f32 accumulator and the output block."""
    s = DTYPE_BYTES[shape.dtype]
    buf = 2 if double_buffer else 1
    a = tile.bm * tile.bk * s
    b = tile.bk * tile.bn * s
    acc = tile.bm * tile.bn * 4              # f32 accumulator
    out = tile.bm * tile.bn * s
    return buf * (a + b) + acc + buf * out


def mxu_efficiency(shape: GemmShape, tile: TileConfig) -> float:
    """Useful-FLOP fraction after padding block dims to hardware tiles.

    The paper's basic simulator assumes arithmetic rate independent of the
    micro-kernel; its §4 discussion flags per-micro-kernel rates as needed
    refinement — on TPU the MXU gives a crisp version of that refinement:
    blocks pay padding to (sublane, lane) tiles and the 128x128 systolic
    array.
    """
    sub = SUBLANE[shape.dtype]
    bm_eff = min(tile.bm, shape.m)
    bn_eff = min(tile.bn, shape.n)
    bk_eff = min(tile.bk, shape.k)
    pm = _pad(bm_eff, sub)
    pn = _pad(bn_eff, LANE)
    pk = _pad(bk_eff, LANE)
    return (bm_eff * bn_eff * bk_eff) / float(pm * pn * pk)


def estimate(shape: GemmShape, tile: TileConfig,
             machine: MachineSpec | None = None) -> TpuCost:
    """Traffic-based cost estimate of a tiled Pallas GEMM (one chip).

    ``machine`` is any registry spec (default: ``tpu-v5e`` from the zoo);
    rates resolve through the spec's level aliases, so every transfer/peak
    term is machine-parametric.

    HBM->VMEM traffic follows the paper's revisit accounting:
      A block (bm x bk): fetched once per (i, k) per j-sweep  -> M.K.(N/bn)
      B block (bk x bn): fetched once per (k, j) per i-sweep  -> K.N.(M/bm)
      C block (bm x bn): K_INNER  -> written once (+read if accumulate);
                         K_OUTER  -> read+written every k step (K/bk).
    """
    machine = machine or _default_machine()
    s = DTYPE_BYTES[shape.dtype]
    m, n, k = shape.m, shape.n, shape.k
    gm, gn, gk = (math.ceil(m / tile.bm), math.ceil(n / tile.bn),
                  math.ceil(k / tile.bk))
    a_bytes = s * m * k * gn
    b_bytes = s * k * n * gm
    if tile.order is GridOrder.K_INNER:
        c_writes = s * m * n
        c_reads = s * m * n if shape.accumulate else 0.0
    else:
        c_writes = s * m * n * gk
        c_reads = s * m * n * gk
    hbm = a_bytes + b_bytes + c_writes + c_reads

    # Mixed-precision shapes pay quantize/dequantize traffic at the HBM
    # boundary: wider-than-compute operands move extra bytes proportional
    # to their width ratio (core/precision.py).  Uniform shapes take the
    # pre-existing path untouched.
    pc = shape.mixed_precision
    quant_bytes = 0.0
    if pc is not None:
        ra, rb, rc = pc.quant_ratios(s)
        quant_bytes = (a_bytes * ra + b_bytes * rb
                       + (c_writes + c_reads) * rc)
        hbm = hbm + quant_bytes

    # VMEM->VREG streaming inside the kernel: each resident A/B block is read
    # once per block-matmul, plus the f32 accumulator read+written per k step.
    vmem_stream = a_bytes + b_bytes + 8.0 * m * n * gk

    eff = mxu_efficiency(shape, tile)
    t_compute = shape.flops / (shape_peak(machine, shape) * eff)
    t_hbm = hbm / machine.rate("M", "L1")
    t_vmem = vmem_stream / machine.rate("L1", "R")
    return TpuCost(
        shape=shape, tile=tile, hbm_bytes=hbm, vmem_bytes=vmem_stream,
        vmem_peak=vmem_required(shape, tile),
        t_compute=t_compute, t_hbm=t_hbm, t_vmem=t_vmem, mxu_efficiency=eff,
        quant_bytes=quant_bytes,
    )


def arithmetic_intensity(shape: GemmShape, tile: TileConfig) -> float:
    c = estimate(shape, tile)
    return shape.flops / max(c.hbm_bytes, 1.0)


# ---------------------------------------------------------------------------
# Batched evaluation engine: ``estimate`` as a NumPy array program.
#
# The design-space sweep (autotune over ~810 candidate tiles x many shapes)
# is the framework's hottest non-JAX path; scoring candidates one Python call
# at a time makes planning O(shapes x tiles) interpreter work.  The batch
# engine scores the whole (problem x candidate) lattice in a handful of
# vectorized operations.  Every formula replays ``estimate`` elementwise with
# the same operations in the same order, so totals are bit-identical with the
# scalar simulator and argmin tile selections agree exactly (all integer
# intermediates stay below 2^53 and convert to float64 without rounding).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TpuCostBatch:
    """Structure-of-arrays :class:`TpuCost` over a (problem x candidate)
    lattice.  Fields broadcast to a common ``(P, C)`` shape."""

    hbm_bytes: np.ndarray
    vmem_bytes: np.ndarray
    vmem_peak: np.ndarray
    t_compute: np.ndarray
    t_hbm: np.ndarray
    t_vmem: np.ndarray
    mxu_efficiency: np.ndarray
    grid_steps: np.ndarray

    @property
    def total_no_overlap(self) -> np.ndarray:
        return self.t_compute + self.t_hbm + self.t_vmem

    @property
    def total_overlapped(self) -> np.ndarray:
        startup = self.t_hbm / np.maximum(1.0, self.grid_steps)
        return (np.maximum(np.maximum(self.t_compute, self.t_hbm),
                           self.t_vmem) + startup)

    def total(self, overlap: bool = True) -> np.ndarray:
        return self.total_overlapped if overlap else self.total_no_overlap


def peak_rate(dtype: str) -> float:
    """Public alias of the per-dtype peak used by the cost model."""
    return _peak(dtype)


def vmem_required_batch(bm, bn, bk, elem_bytes) -> np.ndarray:
    """Vectorized :func:`vmem_required` (double-buffered) over tile arrays."""
    bm, bn, bk = (np.asarray(x, np.int64) for x in (bm, bn, bk))
    s = np.asarray(elem_bytes, np.int64)
    a = bm * bk * s
    b = bk * bn * s
    acc = bm * bn * 4
    out = bm * bn * s
    return 2 * (a + b) + acc + 2 * out


def estimate_batch(m, n, k, elem_bytes, sublane, peak, bm, bn, bk, k_inner,
                   accumulate=False,
                   machine: MachineSpec | None = None,
                   quant=None) -> TpuCostBatch:
    """Vectorized :func:`estimate` over problem arrays x tile arrays.

    Problem-side arrays (``m``, ``n``, ``k``, ``elem_bytes``, ``sublane``,
    ``peak``, ``accumulate``) and tile-side arrays (``bm``, ``bn``, ``bk``,
    ``k_inner``) must broadcast against each other — the canonical layout is
    problems as ``(P, 1)`` columns against flat ``(C,)`` candidate rows.
    ``peak`` is the per-problem arithmetic rate (use :func:`machine_peak` /
    :func:`shape_peak` so non-default machines keep their own dtype tables).
    ``quant`` is an optional ``(ra, rb, rc)`` triple of per-problem
    quantize-ratio arrays (see ``PrecisionConfig.quant_ratios``); None is
    exactly the pre-mixed-precision path.
    """
    machine = machine or _default_machine()
    m, n, k = (np.asarray(x, np.int64) for x in (m, n, k))
    s = np.asarray(elem_bytes, np.int64)
    sub = np.asarray(sublane, np.int64)
    peak = np.asarray(peak, np.float64)
    bm, bn, bk = (np.asarray(x, np.int64) for x in (bm, bn, bk))
    k_inner = np.asarray(k_inner, bool)
    accumulate = np.asarray(accumulate, bool)

    gm = -(-m // bm)
    gn = -(-n // bn)
    gk = -(-k // bk)
    a_bytes = (s * m * k * gn).astype(np.float64)
    b_bytes = (s * k * n * gm).astype(np.float64)
    c_once = (s * m * n).astype(np.float64)
    c_revisit = (s * m * n * gk).astype(np.float64)
    c_writes = np.where(k_inner, c_once, c_revisit)
    c_reads = np.where(k_inner, np.where(accumulate, c_once, 0.0), c_revisit)
    hbm = a_bytes + b_bytes + c_writes + c_reads

    if quant is not None:
        ra, rb, rc = (np.asarray(q, np.float64) for q in quant)
        quant_bytes = (a_bytes * ra + b_bytes * rb
                       + (c_writes + c_reads) * rc)
        hbm = hbm + quant_bytes

    vmem_stream = a_bytes + b_bytes + 8.0 * m * n * gk

    bm_eff = np.minimum(bm, m)
    bn_eff = np.minimum(bn, n)
    bk_eff = np.minimum(bk, k)
    pm = sub * -(-bm_eff // sub)
    pn = LANE * -(-bn_eff // LANE)
    pk = LANE * -(-bk_eff // LANE)
    eff = (bm_eff * bn_eff * bk_eff) / (pm * pn * pk).astype(np.float64)

    flops = 2.0 * m * n * k
    t_compute = flops / (peak * eff)
    t_hbm = hbm / machine.rate("M", "L1")
    t_vmem = vmem_stream / machine.rate("L1", "R")
    return TpuCostBatch(
        hbm_bytes=hbm, vmem_bytes=vmem_stream,
        vmem_peak=vmem_required_batch(bm, bn, bk, s),
        t_compute=t_compute, t_hbm=t_hbm, t_vmem=t_vmem,
        mxu_efficiency=eff,
        grid_steps=(gm * gn * gk).astype(np.float64),
    )
