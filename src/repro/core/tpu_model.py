"""TPU adaptation of the paper's simulator: a cost model for Pallas GEMM.

The paper's memory model (software-managed scratchpads, programmed DMA, no
caches) *is* the TPU memory model: HBM -> VMEM -> VREG with Pallas
``BlockSpec`` controlling every transfer.  The paper's algorithmic family
(loop orders deciding which operand is resident vs. streamed) maps onto the
**grid iteration order** of a Pallas kernel:

* ``k`` innermost (grid ``(i, j, k)``)  — the C block stays in a VMEM
  accumulator while A/B blocks stream: the **B3A2C0 analogue**
  (output-stationary; C written once).
* ``k`` outermost (grid ``(k, i, j)``) — the C block is revisited (read +
  written) on every k step: the **C3B2A0/B3C2A0 analogue** (C streamed).

The cost model mirrors the paper's: traffic per level x calibrated rate plus
a flat arithmetic term, with *two* composition rules — the paper's
no-overlap sum (§3.1 assumption) and the double-buffered ``max`` that Pallas'
pipeline actually achieves (the paper's future-work item).
"""
from __future__ import annotations

import dataclasses
import enum
import math

from repro.core.hardware import (
    MachineSpec,
    TPU_V5E,
    V5E_MXU,
)

DTYPE_BYTES = {"int8": 1, "bf16": 2, "f32": 4}
# minimal TPU tile (sublane, lane) per dtype — misaligned blocks get padded.
SUBLANE = {"int8": 32, "bf16": 16, "f32": 8}
LANE = 128


class GridOrder(str, enum.Enum):
    """Pallas grid iteration order == the paper's loop-order variant."""
    K_INNER = "k_inner"     # B3A2C0 analogue: C resident, written once
    K_OUTER = "k_outer"     # C3B2A0/B3C2A0 analogue: C revisited every k step


@dataclasses.dataclass(frozen=True)
class TileConfig:
    bm: int
    bn: int
    bk: int
    order: GridOrder = GridOrder.K_INNER

    def __str__(self) -> str:
        return f"{self.bm}x{self.bn}x{self.bk}:{self.order.value}"


@dataclasses.dataclass(frozen=True)
class GemmShape:
    m: int
    n: int
    k: int
    dtype: str = "bf16"
    accumulate: bool = False   # C += A.B (paper semantics) vs C = A.B

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.n * self.k


@dataclasses.dataclass(frozen=True)
class TpuCost:
    """Cost estimate for one Pallas GEMM tile configuration."""
    shape: GemmShape
    tile: TileConfig
    hbm_bytes: float          # HBM <-> VMEM traffic
    vmem_bytes: float         # VMEM <-> VREG traffic (usually negligible)
    vmem_peak: int            # peak VMEM working set (double-buffered)
    t_compute: float
    t_hbm: float
    t_vmem: float
    mxu_efficiency: float     # useful fraction of MXU-padded FLOPs

    @property
    def total_no_overlap(self) -> float:
        """Paper-faithful composition: transfers are not overlapped (§3.1)."""
        return self.t_compute + self.t_hbm + self.t_vmem

    @property
    def total_overlapped(self) -> float:
        """Double-buffered Pallas pipeline: bound by the slowest resource,
        plus one pipeline fill of the first block pair."""
        startup = self.t_hbm / max(1.0, self._grid_steps())
        return max(self.t_compute, self.t_hbm, self.t_vmem) + startup

    def _grid_steps(self) -> float:
        s, t = self.shape, self.tile
        return (math.ceil(s.m / t.bm) * math.ceil(s.n / t.bn)
                * math.ceil(s.k / t.bk))

    def total(self, overlap: bool = True) -> float:
        return self.total_overlapped if overlap else self.total_no_overlap

    def roofline_fraction(self, overlap: bool = True) -> float:
        """Fraction of the pure-compute roofline this config achieves."""
        ideal = self.shape.flops / _peak(self.shape.dtype)
        return ideal / self.total(overlap)


def _peak(dtype: str) -> float:
    return TPU_V5E.arith_rate["bf16" if dtype == "f32" else dtype]


def _pad(x: int, mult: int) -> int:
    return mult * math.ceil(x / mult)


def vmem_required(shape: GemmShape, tile: TileConfig,
                  double_buffer: bool = True) -> int:
    """Peak VMEM bytes: A and B blocks (x2 when double-buffered by the
    pipeline) plus the f32 accumulator and the output block."""
    s = DTYPE_BYTES[shape.dtype]
    buf = 2 if double_buffer else 1
    a = tile.bm * tile.bk * s
    b = tile.bk * tile.bn * s
    acc = tile.bm * tile.bn * 4              # f32 accumulator
    out = tile.bm * tile.bn * s
    return buf * (a + b) + acc + buf * out


def mxu_efficiency(shape: GemmShape, tile: TileConfig) -> float:
    """Useful-FLOP fraction after padding block dims to hardware tiles.

    The paper's basic simulator assumes arithmetic rate independent of the
    micro-kernel; its §4 discussion flags per-micro-kernel rates as needed
    refinement — on TPU the MXU gives a crisp version of that refinement:
    blocks pay padding to (sublane, lane) tiles and the 128x128 systolic
    array.
    """
    sub = SUBLANE[shape.dtype]
    bm_eff = min(tile.bm, shape.m)
    bn_eff = min(tile.bn, shape.n)
    bk_eff = min(tile.bk, shape.k)
    pm = _pad(bm_eff, sub)
    pn = _pad(bn_eff, LANE)
    pk = _pad(bk_eff, LANE)
    return (bm_eff * bn_eff * bk_eff) / float(pm * pn * pk)


def estimate(shape: GemmShape, tile: TileConfig,
             machine: MachineSpec = TPU_V5E) -> TpuCost:
    """Traffic-based cost estimate of a tiled Pallas GEMM (one chip).

    HBM->VMEM traffic follows the paper's revisit accounting:
      A block (bm x bk): fetched once per (i, k) per j-sweep  -> M.K.(N/bn)
      B block (bk x bn): fetched once per (k, j) per i-sweep  -> K.N.(M/bm)
      C block (bm x bn): K_INNER  -> written once (+read if accumulate);
                         K_OUTER  -> read+written every k step (K/bk).
    """
    s = DTYPE_BYTES[shape.dtype]
    m, n, k = shape.m, shape.n, shape.k
    gm, gn, gk = (math.ceil(m / tile.bm), math.ceil(n / tile.bn),
                  math.ceil(k / tile.bk))
    a_bytes = s * m * k * gn
    b_bytes = s * k * n * gm
    if tile.order is GridOrder.K_INNER:
        c_writes = s * m * n
        c_reads = s * m * n if shape.accumulate else 0.0
    else:
        c_writes = s * m * n * gk
        c_reads = s * m * n * gk
    hbm = a_bytes + b_bytes + c_writes + c_reads

    # VMEM->VREG streaming inside the kernel: each resident A/B block is read
    # once per block-matmul, plus the f32 accumulator read+written per k step.
    vmem_stream = a_bytes + b_bytes + 8.0 * m * n * gk

    eff = mxu_efficiency(shape, tile)
    t_compute = shape.flops / (_peak(shape.dtype) * eff)
    t_hbm = hbm / machine.rate("M", "L1")
    t_vmem = vmem_stream / machine.rate("L1", "R")
    return TpuCost(
        shape=shape, tile=tile, hbm_bytes=hbm, vmem_bytes=vmem_stream,
        vmem_peak=vmem_required(shape, tile),
        t_compute=t_compute, t_hbm=t_hbm, t_vmem=t_vmem, mxu_efficiency=eff,
    )


def arithmetic_intensity(shape: GemmShape, tile: TileConfig) -> float:
    c = estimate(shape, tile)
    return shape.flops / max(c.hbm_bytes, 1.0)
