"""The GotoBLAS/BLIS family of blocked GEMM algorithms modelled by the paper.

Notation (paper §2, ref. [9]): ``X3Y2Z0`` means operand ``X``'s packed buffer
lives at the L3 level of the model, ``Y``'s at L2, and ``Z`` is resident in
the processor registers inside the micro-kernel.

Modelled variants (paper §2.2 — the A/B-swapped mirrors are performance
equivalent and not modelled):

* ``B3A2C0`` — the GotoBLAS2/BLIS/OpenBLAS baseline.  Micro-kernel is an
  ``m_r x n_r`` outer-product update of a C micro-tile held in registers.
* ``C3B2A0`` — C packed at L3, B at L2, A streamed into registers; the
  micro-kernel performs ``m_r x k_r`` matrix-vector products.
* ``B3C2A0`` — B packed at L3, C at L2 (requires an explicit *unpack* of
  C_c back to C), A in registers.

Each variant carries its loop nest (trip counts), the packing/copy/stream
traffic terms, and the scratchpad-occupancy rule used to derive
``(m_c, n_c, k_c)`` from the micro-kernel dimensions (paper §3.2: "set the
configuration parameters so that the buffers maximise the occupancy of the
L1/L2 memory areas").

Level names here are canonical *roles* (``M``/``L2``/``L1``/``R``), not
physical levels: ``machine.capacity("L2")`` and the traffic terms' rate
lookups resolve through the spec's ``level_aliases`` (see
``repro.machines.spec``), so a machine without a distinct L2 area simply
aliases the role onto another level and the same occupancy rules apply.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Iterable

import numpy as np

from repro.core.hardware import MachineSpec
from repro.core.precision import PrecisionConfig


class Variant(str, enum.Enum):
    B3A2C0 = "B3A2C0"
    C3B2A0 = "C3B2A0"
    B3C2A0 = "B3C2A0"

    @property
    def register_operand(self) -> str:
        return {"B3A2C0": "C", "C3B2A0": "A", "B3C2A0": "A"}[self.value]

    @property
    def micro_dims(self) -> tuple[str, str]:
        """Names of the two micro-kernel dimensions (paper: m_r x n_r for the
        baseline, m_r x k_r for the A-resident variants)."""
        return ("m_r", "n_r") if self is Variant.B3A2C0 else ("m_r", "k_r")


@dataclasses.dataclass(frozen=True)
class Problem:
    """A GEMM ``C (m x n) += A (m x k) . B (k x n)``."""
    m: int
    n: int
    k: int
    elem_bytes: int = 1       # INT8 on the GAP8
    dtype: str = "int8"
    # per-operand dtypes for mixed-precision GEMM; None (or a uniform
    # config) is the plain single-dtype path with zero extra terms.
    # ``dtype``/``elem_bytes`` stay the *compute* dtype — the narrower
    # input operand the micro-kernel arithmetic runs at.
    precision: PrecisionConfig | None = None

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.n * self.k

    @property
    def abytes(self) -> float:
        return float(self.m * self.k * self.elem_bytes)

    @property
    def bbytes(self) -> float:
        return float(self.k * self.n * self.elem_bytes)

    @property
    def cbytes(self) -> float:
        return float(self.m * self.n * self.elem_bytes)


@dataclasses.dataclass(frozen=True)
class MicroKernel:
    """Micro-kernel dimensions.  ``rows`` is always m_r; ``cols`` is n_r for
    B3A2C0 and k_r for the A-resident variants."""
    rows: int
    cols: int

    def __str__(self) -> str:  # e.g. "4x24"
        return f"{self.rows}x{self.cols}"


@dataclasses.dataclass(frozen=True)
class Blocking:
    m_c: int
    n_c: int
    k_c: int


def registers_needed(variant: Variant, mk: MicroKernel, lanes: int) -> float:
    """Vector registers needed by the micro-kernel (paper §3.1/§4).

    B3A2C0 holds the ``m_r x n_r`` C micro-tile plus one column of A and one
    row of B; the A-resident variants hold the ``m_r x k_r`` A micro-tile
    plus one column of C and one column of B.  Register width = ``lanes``
    elements (GAP8: 4 INT8 lanes per 32-bit register).
    """
    r, c = mk.rows, mk.cols
    return (r * c) / lanes + r / lanes + c / lanes


def feasible_microkernels(
    machine: MachineSpec,
    variant: Variant,
    step: int | None = None,
    max_dim: int | None = None,
) -> list[MicroKernel]:
    """Enumerate register-feasible micro-kernels.

    The paper's search space (§4): dimensions that are multiples of the SIMD
    width (4 for the GAP8) such that the register working set fits the 32
    vector registers.  This yields exactly the set seen in Figs. 4-6 /
    Table 2: 4x{4..24}, 8x{4..12}, 12x{4,8}, {16,20,24}x4.
    """
    lanes = machine.register_lanes
    step = step or lanes
    max_dim = max_dim or (machine.num_vector_registers * lanes)
    out = []
    for r in range(step, max_dim + 1, step):
        for c in range(step, max_dim + 1, step):
            if registers_needed(variant, MicroKernel(r, c), lanes) <= machine.num_vector_registers:
                out.append(MicroKernel(r, c))
    return out


def _align_down(x: int, a: int) -> int:
    return max(a, (x // a) * a)


def derive_blocking(
    variant: Variant, mk: MicroKernel, machine: MachineSpec, prob: Problem
) -> Blocking:
    """Derive (m_c, n_c, k_c) maximising L1/L2 occupancy (paper §3.2).

    * B3A2C0: B_r (k_c x n_r) fills L1  ->  k_c = C_L1 / n_r;
              A_c (m_c x k_c) fills L2  ->  m_c = C_L2 / k_c;
              B_c lives at the model's L3 (= M on the GAP8) -> n_c = n.
    * C3B2A0: C_r (m_r x n_c) fills L1  ->  n_c = C_L1 / m_r;
              B_c (k_c x n_c) fills L2  ->  k_c = C_L2 / n_c;
              C_c at L3 -> m_c = m.
    * B3C2A0: B_r (k_r x n_c) fills L1  ->  n_c = C_L1 / k_r;
              C_c (m_c x n_c) fills L2  ->  m_c = C_L2 / n_c;
              B_c at L3 -> k_c = k.

    All block dims are capped by the problem dims and aligned down to the
    micro-kernel multiple where the loop structure requires it.
    """
    s = prob.elem_bytes
    l1 = machine.capacity("L1") // s
    l2 = machine.capacity("L2") // s
    if variant is Variant.B3A2C0:
        n_r, m_r = mk.cols, mk.rows
        k_c = min(max(1, l1 // n_r), prob.k)
        m_c = min(_align_down(max(m_r, l2 // max(1, k_c)), m_r), max(m_r, _align_down(prob.m, 1)))
        m_c = min(m_c, prob.m) if prob.m >= m_r else prob.m
        n_c = prob.n
        return Blocking(m_c=max(1, m_c), n_c=n_c, k_c=k_c)
    if variant is Variant.C3B2A0:
        m_r, k_r = mk.rows, mk.cols
        n_c = min(max(1, l1 // m_r), prob.n)
        k_c = min(max(1, l2 // max(1, n_c)), prob.k)
        m_c = prob.m
        return Blocking(m_c=m_c, n_c=n_c, k_c=k_c)
    if variant is Variant.B3C2A0:
        m_r, k_r = mk.rows, mk.cols
        n_c = min(max(1, l1 // k_r), prob.n)
        m_c = min(_align_down(max(m_r, l2 // max(1, n_c)), m_r), prob.m) if prob.m >= m_r else prob.m
        k_c = prob.k
        return Blocking(m_c=max(1, m_c), n_c=n_c, k_c=k_c)
    raise ValueError(variant)


# ---------------------------------------------------------------------------
# Traffic terms.  Each term is (bytes, origin, dest, chunk_elems_or_None);
# chunk=None means the calibrated rate applies unscaled (streaming / straight
# panel copies); chunk=r means the packing rate scales by r/reference_chunk
# (paper §3.2).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrafficTerm:
    name: str         # e.g. "pack_B", "stream_C"
    bytes: float
    origin: str
    dest: str
    chunk: int | None  # packing chunk size in elements, or None
    note: str = ""


# Which traffic terms touch an *original* operand array (A, B or the C
# accumulator in external memory), per variant.  Mixed-precision configs
# charge quantize/dequantize traffic exactly at these boundaries: a
# wider-than-compute operand is converted while being packed/streamed, so
# the term moves extra bytes proportional to the width ratio.  Inner packed
# buffers (A_c, B_c, C_c, B_r, C_r) already hold compute-width panels and
# carry no extra charge.
_QUANT_OPERANDS = {
    Variant.B3A2C0: {"pack_A": "A", "pack_B": "B", "stream_C": "C"},
    Variant.C3B2A0: {"stream_A": "A", "pack_B": "B",
                     "pack_C": "C", "unpack_C": "C"},
    Variant.B3C2A0: {"stream_A": "A", "pack_B": "B",
                     "pack_C": "C", "unpack_C": "C"},
}


def quant_ratio_map(prob: Problem) -> dict[str, float] | None:
    """Per-operand quantize-traffic ratios of one problem, or None when the
    problem is single-dtype / uniform / all-zero (no extra terms)."""
    pc = prob.precision
    if pc is None or pc.is_uniform:
        return None
    ra, rb, rc = pc.quant_ratios(prob.elem_bytes)
    ratios = {"A": ra, "B": rb, "C": rc}
    return ratios if any(r > 0.0 for r in ratios.values()) else None


def _with_quant(variant: Variant, terms: list[TrafficTerm],
                prob: Problem) -> list[TrafficTerm]:
    """Append ``quant_<term>`` charges for wider-than-compute operands.

    Each charge replays its base term's route and chunk, scaled by the
    operand's width ratio, so the extra time is exactly ``ratio x`` the
    base term's time — the property the mixed-precision tests assert."""
    ratios = quant_ratio_map(prob)
    if not ratios:
        return terms
    ops = _QUANT_OPERANDS[variant]
    extra = []
    for t in terms:
        op = ops.get(t.name)
        if op is None:
            continue
        r = ratios[op]
        if r <= 0.0:
            continue
        extra.append(TrafficTerm(
            f"quant_{t.name}", t.bytes * r, t.origin, t.dest, t.chunk,
            note=f"{op} requantize ({r:g}x {t.name})"))
    return terms + extra


def _trips(x: int, b: int, policy: str) -> float:
    """Trip count of a blocked loop: exact ratio ("analytic", the paper's
    closed-form accounting) or ceil ("padded", mimicking edge tiles at full
    cost)."""
    if policy == "analytic":
        return x / b
    if policy == "padded":
        return float(math.ceil(x / b))
    raise ValueError(policy)


def traffic_terms(
    variant: Variant,
    mk: MicroKernel,
    blk: Blocking,
    prob: Problem,
    policy: str = "analytic",
) -> list[TrafficTerm]:
    """All data-movement terms of one GEMM under the given variant.

    Derived by walking the loop nests of Fig. 1 / Fig. 3 and counting, for
    every packed buffer / panel copy / micro-kernel stream, how many times
    each byte crosses each level boundary.  See DESIGN.md §1 for the
    derivation; tests/test_simulator.py checks the closed forms against a
    literal loop-nest walker.
    """
    m, n, k, s = prob.m, prob.n, prob.k, prob.elem_bytes
    t = lambda x, b: _trips(x, b, policy)  # noqa: E731
    terms: list[TrafficTerm] = []
    add = lambda *a, **kw: terms.append(TrafficTerm(*a, **kw))  # noqa: E731

    if variant is Variant.B3A2C0:
        m_r, n_r = mk.rows, mk.cols
        # L1 jc / L2 pc: pack B(k_c x n_c) -> B_c once per (jc,pc): covers B once.
        add("pack_B", s * k * n, "M", "M", n_r, note="B->B_c (L3 buffer)")
        # L3 ic: pack A(m_c x k_c) -> A_c once per (jc,pc,ic).
        add("pack_A", s * m * k * t(n, blk.n_c), "M", "L2", m_r, note="A->A_c")
        # L4 jr: copy B_r (k_c x n_r) panel into L1 once per (jc,pc,ic,jr).
        add("copy_Br", s * k * n * t(m, blk.m_c), "M", "L1", None, note="B_c->B_r")
        # micro-kernel: C micro-tile loaded+stored once per call (k/k_c passes
        # over the full C).
        add("stream_C", 2.0 * s * m * n * t(k, blk.k_c), "M", "R", None,
            note="C<->regs, amortised over k_c")
        # micro-kernel: A_c micro-panel (m_r x k_c) read once per jr iter.
        add("stream_A", s * m * k * t(n, n_r), "L2", "R", None, note="A_c->regs")
        # micro-kernel: B_r (k_c x n_r) read once per ir iter.
        add("stream_B", s * k * n * t(m, m_r), "L1", "R", None, note="B_r->regs")
        return _with_quant(variant, terms, prob)

    if variant is Variant.C3B2A0:
        m_r, k_r = mk.rows, mk.cols
        # L2 ic: pack C -> C_c (L3 buffer) once per (jc,ic); unpack at end.
        add("pack_C", s * m * n, "M", "M", m_r, note="C->C_c (L3 buffer)")
        add("unpack_C", s * m * n, "M", "M", m_r, note="C_c->C")
        # L3 pc: pack B(k_c x n_c) -> B_c once per (jc,ic,pc).
        add("pack_B", s * k * n * t(m, blk.m_c), "M", "L2", k_r, note="B->B_c")
        # C_r (m_r x n_c) copied L1-ward and back once per (jc,ic,pc,ir).
        add("copy_Cr", 2.0 * s * m * n * t(k, blk.k_c), "M", "L1", None,
            note="C_c<->C_r")
        # micro-kernel: A micro-tile (m_r x k_r) streamed from memory.
        add("stream_A", s * m * k * t(n, blk.n_c), "M", "R", None, note="A->regs")
        # micro-kernel: B_c column (k_r) per jr iteration.
        add("stream_B", s * k * n * t(m, m_r), "L2", "R", None, note="B_c->regs")
        # micro-kernel: C_r column (m_r) loaded+stored per jr iteration.
        add("stream_C", 2.0 * s * m * n * t(k, k_r), "L1", "R", None,
            note="C_r<->regs")
        return _with_quant(variant, terms, prob)

    if variant is Variant.B3C2A0:
        m_r, k_r = mk.rows, mk.cols
        # L2 pc: pack B(k_c x n_c) -> B_c (L3 buffer) once per (jc,pc).
        add("pack_B", s * k * n, "M", "M", k_r, note="B->B_c (L3 buffer)")
        # L3 ic: pack C(m_c x n_c) -> C_c (L2) once per (jc,pc,ic); unpack too.
        add("pack_C", s * m * n * t(k, blk.k_c), "M", "L2", m_r, note="C->C_c")
        add("unpack_C", s * m * n * t(k, blk.k_c), "L2", "M", m_r, note="C_c->C")
        # L4 pr: copy B_r (k_r x n_c) into L1 once per (jc,pc,ic,pr).
        add("copy_Br", s * k * n * t(m, blk.m_c), "M", "L1", None, note="B_c->B_r")
        # micro-kernel: A micro-tile streamed from memory.
        add("stream_A", s * m * k * t(n, blk.n_c), "M", "R", None, note="A->regs")
        # micro-kernel: C_c column (m_r) loaded+stored per jr iteration.
        add("stream_C", 2.0 * s * m * n * t(k, k_r), "L2", "R", None,
            note="C_c<->regs")
        # micro-kernel: B_r column (k_r) per jr iteration.
        add("stream_B", s * k * n * t(m, m_r), "L1", "R", None, note="B_r->regs")
        return _with_quant(variant, terms, prob)

    raise ValueError(variant)


# ---------------------------------------------------------------------------
# Batched closed forms.  The same §3.2 occupancy rules and Fig. 1/Fig. 3
# traffic terms as above, expressed as NumPy array programs over a
# (problems x micro-kernels) lattice: problem dims arrive as (P, 1) columns,
# micro-kernel dims as flat (C,) rows.  Every expression replays the scalar
# functions' integer/float operations in the same order, so the batched
# simulator's totals are bit-identical with ``simulate`` and argmin
# selections agree exactly.
# ---------------------------------------------------------------------------


def derive_blocking_batch(
    variant: Variant, rows: np.ndarray, cols: np.ndarray,
    machine: MachineSpec, m: np.ndarray, n: np.ndarray, k: np.ndarray,
    elem_bytes: np.ndarray,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized :func:`derive_blocking`: (m_c, n_c, k_c) arrays broadcast
    to the full (P, C) lattice."""
    l1 = machine.capacity("L1") // elem_bytes
    l2 = machine.capacity("L2") // elem_bytes
    if variant is Variant.B3A2C0:
        m_r, n_r = rows, cols
        k_c = np.minimum(np.maximum(1, l1 // n_r), k)
        grown = np.maximum(m_r, l2 // np.maximum(1, k_c))
        aligned = np.maximum(m_r, (grown // m_r) * m_r)
        m_c = np.minimum(aligned, np.maximum(m_r, m))
        m_c = np.where(m >= m_r, np.minimum(m_c, m), m + 0 * m_r)
        m_c = np.maximum(1, m_c)
        n_c = n + 0 * cols
    elif variant is Variant.C3B2A0:
        m_r = rows
        n_c = np.minimum(np.maximum(1, l1 // m_r), n)
        k_c = np.minimum(np.maximum(1, l2 // np.maximum(1, n_c)), k)
        m_c = m + 0 * rows
    elif variant is Variant.B3C2A0:
        m_r, k_r = rows, cols
        n_c = np.minimum(np.maximum(1, l1 // k_r), n)
        grown = np.maximum(m_r, l2 // np.maximum(1, n_c))
        aligned = np.maximum(m_r, (grown // m_r) * m_r)
        m_c = np.where(m >= m_r, np.minimum(aligned, m), m + 0 * m_r)
        m_c = np.maximum(1, m_c)
        k_c = k + 0 * cols
    else:
        raise ValueError(variant)
    return np.broadcast_arrays(m_c, n_c, k_c)


@dataclasses.dataclass(frozen=True)
class TrafficTermBatch:
    """One traffic term over the whole lattice: ``bytes`` broadcasts to
    (P, C); ``chunk`` is the per-candidate packing chunk array or None."""
    name: str
    bytes: np.ndarray
    origin: str
    dest: str
    chunk: np.ndarray | None


def _trips_batch(x, b, policy: str) -> np.ndarray:
    if policy == "analytic":
        return x / b
    if policy == "padded":
        return np.ceil(x / b)
    raise ValueError(policy)


def quant_ratio_arrays(probs) -> dict[str, np.ndarray] | None:
    """(P, 1) quantize-ratio columns per operand for a problem batch, or
    None when no problem carries a mixed precision (the plain path).

    The arrays feed :func:`traffic_terms_batch`: uniform problems get 0.0
    rows, whose term contributions are exactly 0.0 — adding them preserves
    bit-identity with the scalar path, which skips zero-ratio terms."""
    rows = []
    mixed = False
    for p in probs:
        ratios = quant_ratio_map(p)
        if ratios is None:
            rows.append((0.0, 0.0, 0.0))
        else:
            mixed = True
            rows.append((ratios["A"], ratios["B"], ratios["C"]))
    if not mixed:
        return None
    arr = np.array(rows, np.float64)
    return {"A": arr[:, 0:1], "B": arr[:, 1:2], "C": arr[:, 2:3]}


def _with_quant_batch(variant: Variant, terms: list[TrafficTermBatch],
                      quant: dict[str, np.ndarray] | None
                      ) -> list[TrafficTermBatch]:
    """Vectorized :func:`_with_quant` over the (P, C) lattice."""
    if quant is None:
        return terms
    ops = _QUANT_OPERANDS[variant]
    extra = []
    for t in terms:
        op = ops.get(t.name)
        if op is None:
            continue
        extra.append(TrafficTermBatch(
            f"quant_{t.name}", t.bytes * quant[op], t.origin, t.dest,
            t.chunk))
    return terms + extra


def traffic_terms_batch(
    variant: Variant, rows: np.ndarray, cols: np.ndarray,
    blocking: tuple[np.ndarray, np.ndarray, np.ndarray],
    m: np.ndarray, n: np.ndarray, k: np.ndarray, elem_bytes: np.ndarray,
    policy: str = "analytic",
    quant: dict[str, np.ndarray] | None = None,
) -> list[TrafficTermBatch]:
    """Vectorized :func:`traffic_terms`, in the scalar term order.

    ``quant`` is the optional per-operand quantize-ratio column dict from
    :func:`quant_ratio_arrays`; when given, ``quant_*`` terms are appended
    in the scalar order (zero rows for uniform problems)."""
    m_c, n_c, k_c = blocking
    s = elem_bytes
    smn = (s * m * n).astype(np.float64)
    smk = (s * m * k).astype(np.float64)
    skn = (s * k * n).astype(np.float64)
    t = lambda x, b: _trips_batch(x, b, policy)  # noqa: E731
    T = TrafficTermBatch

    if variant is Variant.B3A2C0:
        m_r, n_r = rows, cols
        return _with_quant_batch(variant, [
            T("pack_B", skn, "M", "M", n_r),
            T("pack_A", smk * t(n, n_c), "M", "L2", m_r),
            T("copy_Br", skn * t(m, m_c), "M", "L1", None),
            T("stream_C", 2.0 * smn * t(k, k_c), "M", "R", None),
            T("stream_A", smk * t(n, n_r), "L2", "R", None),
            T("stream_B", skn * t(m, m_r), "L1", "R", None),
        ], quant)
    if variant is Variant.C3B2A0:
        m_r, k_r = rows, cols
        return _with_quant_batch(variant, [
            T("pack_C", smn, "M", "M", m_r),
            T("unpack_C", smn, "M", "M", m_r),
            T("pack_B", skn * t(m, m_c), "M", "L2", k_r),
            T("copy_Cr", 2.0 * smn * t(k, k_c), "M", "L1", None),
            T("stream_A", smk * t(n, n_c), "M", "R", None),
            T("stream_B", skn * t(m, m_r), "L2", "R", None),
            T("stream_C", 2.0 * smn * t(k, k_r), "L1", "R", None),
        ], quant)
    if variant is Variant.B3C2A0:
        m_r, k_r = rows, cols
        return _with_quant_batch(variant, [
            T("pack_B", skn, "M", "M", k_r),
            T("pack_C", smn * t(k, k_c), "M", "L2", m_r),
            T("unpack_C", smn * t(k, k_c), "L2", "M", m_r),
            T("copy_Br", skn * t(m, m_c), "M", "L1", None),
            T("stream_A", smk * t(n, n_c), "M", "R", None),
            T("stream_C", 2.0 * smn * t(k, k_r), "L2", "R", None),
            T("stream_B", skn * t(m, m_r), "L1", "R", None),
        ], quant)
    raise ValueError(variant)


def loop_trip_counts(
    variant: Variant, mk: MicroKernel, blk: Blocking, prob: Problem
) -> dict[str, int]:
    """Integer trip counts of the 5 outer loops (for the literal walker and
    for sanity display)."""
    m, n, k = prob.m, prob.n, prob.k
    c = lambda x, b: int(math.ceil(x / b))  # noqa: E731
    if variant is Variant.B3A2C0:
        return {"jc": c(n, blk.n_c), "pc": c(k, blk.k_c), "ic": c(m, blk.m_c),
                "jr": c(blk.n_c, mk.cols), "ir": c(blk.m_c, mk.rows)}
    if variant is Variant.C3B2A0:
        return {"jc": c(n, blk.n_c), "ic": c(m, blk.m_c), "pc": c(k, blk.k_c),
                "ir": c(blk.m_c, mk.rows), "pr": c(blk.k_c, mk.cols)}
    return {"jc": c(n, blk.n_c), "pc": c(k, blk.k_c), "ic": c(m, blk.m_c),
            "pr": c(blk.k_c, mk.cols), "ir": c(blk.m_c, mk.rows)}


def microkernel_invocations(
    variant: Variant, mk: MicroKernel, blk: Blocking, prob: Problem,
    policy: str = "analytic",
) -> float:
    """Number of innermost micro-kernel calls: the product of all 5 outer
    loop trips under the given edge policy ("analytic" keeps the paper's
    fractional accounting; "padded" matches :func:`loop_trip_counts`).

    This is the coefficient of the Calibrator's opt-in per-block overhead
    column (``overhead_per_block=True``): each micro-kernel dispatch carries
    a constant cost (loop bookkeeping, address setup, function-call
    overhead) that the pure rate model cannot express for small blocks.
    """
    m, n, k = prob.m, prob.n, prob.k
    t = lambda x, b: _trips(x, b, policy)  # noqa: E731
    if variant is Variant.B3A2C0:
        return (t(n, blk.n_c) * t(k, blk.k_c) * t(m, blk.m_c)
                * t(blk.n_c, mk.cols) * t(blk.m_c, mk.rows))
    if variant is Variant.C3B2A0:
        return (t(n, blk.n_c) * t(m, blk.m_c) * t(k, blk.k_c)
                * t(blk.m_c, mk.rows) * t(blk.k_c, mk.cols))
    if variant is Variant.B3C2A0:
        return (t(n, blk.n_c) * t(k, blk.k_c) * t(m, blk.m_c)
                * t(blk.k_c, mk.cols) * t(blk.m_c, mk.rows))
    raise ValueError(variant)


def microkernel_invocations_batch(
    variant: Variant, rows: np.ndarray, cols: np.ndarray,
    blocking: tuple[np.ndarray, np.ndarray, np.ndarray],
    m: np.ndarray, n: np.ndarray, k: np.ndarray,
    policy: str = "analytic",
) -> np.ndarray:
    """Vectorized :func:`microkernel_invocations` over the (P, C) lattice,
    replaying the scalar multiplication order so totals are bit-identical."""
    m_c, n_c, k_c = blocking
    t = lambda x, b: _trips_batch(x, b, policy)  # noqa: E731
    if variant is Variant.B3A2C0:
        return (t(n, n_c) * t(k, k_c) * t(m, m_c)
                * t(n_c, cols) * t(m_c, rows))
    if variant is Variant.C3B2A0:
        return (t(n, n_c) * t(m, m_c) * t(k, k_c)
                * t(m_c, rows) * t(k_c, cols))
    if variant is Variant.B3C2A0:
        return (t(n, n_c) * t(k, k_c) * t(m, m_c)
                * t(k_c, cols) * t(m_c, rows))
    raise ValueError(variant)
