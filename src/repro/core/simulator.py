"""The paper's performance simulator (§3): traffic terms x calibrated rates.

``simulate`` produces a :class:`CostBreakdown` whose components mirror the
stacked bars of Figs. 4-5: packing, unpacking, L1 copies, per-level
micro-kernel streaming, and arithmetic.  The basic model assumes *no overlap*
between data transfers and compute (paper §3.1), so the total is the plain
sum of all components; the arithmetic rate is independent of the micro-kernel
shape (paper §4, a stated simplification of the basic simulator).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

from repro.core.hardware import MachineSpec
from repro.core.variants import (
    Blocking,
    MicroKernel,
    Problem,
    TrafficTerm,
    Variant,
    derive_blocking,
    traffic_terms,
)


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    """Execution-time decomposition (seconds) of one GEMM."""

    variant: Variant
    micro_kernel: MicroKernel
    blocking: Blocking
    problem: Problem
    # name -> seconds for every traffic term, plus "arith".
    components: Mapping[str, float]
    # name -> bytes moved, for roofline-style reporting.
    traffic_bytes: Mapping[str, float]
    # name -> origin memory level (for grouping like the paper's figures).
    origins: Mapping[str, str]

    @property
    def total(self) -> float:
        return float(sum(self.components.values()))

    @property
    def arith(self) -> float:
        return self.components["arith"]

    @property
    def transfer(self) -> float:
        return self.total - self.arith

    def grouped(self) -> dict[str, float]:
        """Group components the way the paper's figures do."""
        g = {"packing": 0.0, "unpacking": 0.0, "copy": 0.0,
             "stream_M": 0.0, "stream_L1": 0.0, "stream_L2": 0.0, "arith": 0.0}
        for name, secs in self.components.items():
            if name.startswith("pack"):
                g["packing"] += secs
            elif name.startswith("unpack"):
                g["unpacking"] += secs
            elif name.startswith("copy"):
                g["copy"] += secs
            elif name == "arith":
                g["arith"] += secs
            else:  # stream_X
                g[f"stream_{self.origins[name]}"] += secs
        return g


def simulate(
    machine: MachineSpec,
    variant: Variant,
    mk: MicroKernel,
    prob: Problem,
    blocking: Blocking | None = None,
    policy: str = "analytic",
) -> CostBreakdown:
    """Estimate the execution time of ``C += A.B`` on ``machine``.

    ``policy`` selects the partial-tile accounting: "analytic" uses exact
    byte ratios (closed-form; the paper's 2%-accurate regime), "padded"
    charges edge tiles at full-tile cost (a real implementation's upper
    bound).  EXPERIMENTS.md reports Table-2 agreement for both.
    """
    blk = blocking or derive_blocking(variant, mk, machine, prob)
    terms = traffic_terms(variant, mk, blk, prob, policy=policy)

    components: dict[str, float] = {}
    traffic: dict[str, float] = {}
    origins: dict[str, str] = {}
    for t in terms:
        if t.chunk is None:
            rate = machine.rate(t.origin, t.dest)
        else:
            rate = machine.packing_rate(t.origin, t.dest, t.chunk)
        components[t.name] = t.bytes / rate
        traffic[t.name] = t.bytes
        origins[t.name] = t.origin

    arith_rate = machine.arith_rate[prob.dtype]
    components["arith"] = prob.flops / arith_rate

    return CostBreakdown(
        variant=variant, micro_kernel=mk, blocking=blk, problem=prob,
        components=components, traffic_bytes=traffic, origins=origins,
    )


def best_microkernel(
    machine: MachineSpec,
    variant: Variant,
    prob: Problem,
    candidates: list[MicroKernel] | None = None,
    policy: str = "analytic",
) -> CostBreakdown:
    """Exhaustive search over the register-feasible micro-kernel set —
    the paper's Table-2 procedure."""
    from repro.core.variants import feasible_microkernels

    cands = candidates or feasible_microkernels(machine, variant)
    best: CostBreakdown | None = None
    for mk in cands:
        cb = simulate(machine, variant, mk, prob, policy=policy)
        if best is None or cb.total < best.total:
            best = cb
    assert best is not None, "no feasible micro-kernel"
    return best
