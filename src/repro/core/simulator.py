"""The paper's performance simulator (§3): traffic terms x calibrated rates.

``simulate`` produces a :class:`CostBreakdown` whose components mirror the
stacked bars of Figs. 4-5: packing, unpacking, L1 copies, per-level
micro-kernel streaming, and arithmetic.  The basic model assumes *no overlap*
between data transfers and compute (paper §3.1), so the total is the plain
sum of all components; the arithmetic rate is independent of the micro-kernel
shape (paper §4, a stated simplification of the basic simulator).

Machines come from the ``repro.machines`` zoo.  The simulator addresses the
canonical level roles ``{"M", "L2", "L1", "R"}``; a spec whose physical
hierarchy differs (a two-level Cortex-M-class part, the TPU's HBM/VMEM pair)
declares ``level_aliases`` and every ``machine.rate`` / ``machine.capacity``
call here resolves through them — no per-machine special cases.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from repro.core.hardware import MachineSpec
from repro.core.variants import (
    Blocking,
    MicroKernel,
    Problem,
    TrafficTerm,
    Variant,
    derive_blocking,
    derive_blocking_batch,
    feasible_microkernels,
    quant_ratio_arrays,
    traffic_terms,
    traffic_terms_batch,
)


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    """Execution-time decomposition (seconds) of one GEMM."""

    variant: Variant
    micro_kernel: MicroKernel
    blocking: Blocking
    problem: Problem
    # name -> seconds for every traffic term, plus "arith".
    components: Mapping[str, float]
    # name -> bytes moved, for roofline-style reporting.
    traffic_bytes: Mapping[str, float]
    # name -> origin memory level (for grouping like the paper's figures).
    origins: Mapping[str, str]

    @property
    def total(self) -> float:
        return float(sum(self.components.values()))

    @property
    def arith(self) -> float:
        return self.components["arith"]

    @property
    def transfer(self) -> float:
        return self.total - self.arith

    def grouped(self) -> dict[str, float]:
        """Group components the way the paper's figures do."""
        g = {"packing": 0.0, "unpacking": 0.0, "copy": 0.0,
             "stream_M": 0.0, "stream_L1": 0.0, "stream_L2": 0.0,
             "arith": 0.0, "quantize": 0.0}
        for name, secs in self.components.items():
            if name.startswith("quant_"):
                g["quantize"] += secs
            elif name.startswith("pack"):
                g["packing"] += secs
            elif name.startswith("unpack"):
                g["unpacking"] += secs
            elif name.startswith("copy"):
                g["copy"] += secs
            elif name == "arith":
                g["arith"] += secs
            else:  # stream_X
                g[f"stream_{self.origins[name]}"] += secs
        return g


def simulate(
    machine: MachineSpec,
    variant: Variant,
    mk: MicroKernel,
    prob: Problem,
    blocking: Blocking | None = None,
    policy: str = "analytic",
) -> CostBreakdown:
    """Estimate the execution time of ``C += A.B`` on ``machine``.

    ``policy`` selects the partial-tile accounting: "analytic" uses exact
    byte ratios (closed-form; the paper's 2%-accurate regime), "padded"
    charges edge tiles at full-tile cost (a real implementation's upper
    bound).  EXPERIMENTS.md reports Table-2 agreement for both.
    """
    blk = blocking or derive_blocking(variant, mk, machine, prob)
    terms = traffic_terms(variant, mk, blk, prob, policy=policy)

    components: dict[str, float] = {}
    traffic: dict[str, float] = {}
    origins: dict[str, str] = {}
    for t in terms:
        if t.chunk is None:
            rate = machine.rate(t.origin, t.dest)
        else:
            rate = machine.packing_rate(t.origin, t.dest, t.chunk)
        components[t.name] = t.bytes / rate
        traffic[t.name] = t.bytes
        origins[t.name] = t.origin

    # per-micro-kernel refinement (paper §4) when the spec carries a table;
    # otherwise exactly arith_rate[dtype].  Mixed-precision problems look
    # up the machine's rates_mixed table by config key, falling back to the
    # uniform rate of the compute dtype.
    pc = prob.precision
    if pc is not None and not pc.is_uniform:
        arith_rate = machine.arith_rate_mixed(pc.key(), prob.dtype, mk)
    else:
        arith_rate = machine.arith_rate_for(prob.dtype, mk)
    components["arith"] = prob.flops / arith_rate

    return CostBreakdown(
        variant=variant, micro_kernel=mk, blocking=blk, problem=prob,
        components=components, traffic_bytes=traffic, origins=origins,
    )


def best_microkernel(
    machine: MachineSpec,
    variant: Variant,
    prob: Problem,
    candidates: list[MicroKernel] | None = None,
    policy: str = "analytic",
) -> CostBreakdown:
    """Exhaustive search over the register-feasible micro-kernel set —
    the paper's Table-2 procedure (thin wrapper over the batched engine)."""
    return best_microkernel_batch(machine, variant, [prob],
                                  candidates=candidates, policy=policy)[0]


def best_microkernel_scalar(
    machine: MachineSpec,
    variant: Variant,
    prob: Problem,
    candidates: list[MicroKernel] | None = None,
    policy: str = "analytic",
) -> CostBreakdown:
    """The pre-batching scalar search loop, preserved verbatim as the
    reference oracle for the equivalence tests and the planner benchmark.
    Do not optimise or route through the batch engine — its whole value is
    being an independent implementation the batch path must agree with."""
    cands = candidates or feasible_microkernels(machine, variant)
    best: CostBreakdown | None = None
    for mk in cands:
        cb = simulate(machine, variant, mk, prob, policy=policy)
        if best is None or cb.total < best.total:
            best = cb
    assert best is not None, "no feasible micro-kernel"
    return best


# ---------------------------------------------------------------------------
# Batched evaluation engine: score every (micro-kernel, problem) pair of a
# variant in a handful of vectorized operations.  The per-candidate totals
# replay ``simulate``'s arithmetic elementwise in the same order (see
# core/variants.py), so they are bit-identical with the scalar simulator and
# argmin micro-kernel selections agree exactly; winners are rehydrated into
# full :class:`CostBreakdown` objects by one scalar ``simulate`` call each.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CostBatch:
    """Structure-of-arrays cost lattice for one variant: ``total`` has shape
    (problems, micro-kernels), in the candidate order of ``micro_kernels``."""

    variant: Variant
    micro_kernels: list[MicroKernel]
    total: np.ndarray
    arith: np.ndarray
    blocking: tuple[np.ndarray, np.ndarray, np.ndarray]


def _problem_arrays(probs: Sequence[Problem]):
    m = np.array([p.m for p in probs], np.int64)[:, None]
    n = np.array([p.n for p in probs], np.int64)[:, None]
    k = np.array([p.k for p in probs], np.int64)[:, None]
    s = np.array([p.elem_bytes for p in probs], np.int64)[:, None]
    return m, n, k, s


def simulate_batch(
    machine: MachineSpec,
    probs: Sequence[Problem],
    variant: Variant,
    candidates: Sequence[MicroKernel] | None = None,
    policy: str = "analytic",
) -> CostBatch:
    """Vectorized ``simulate`` over problems x micro-kernels (one variant).

    Blockings are derived per lattice point with the closed-form occupancy
    rules; the traffic terms come from ``traffic_terms_batch`` and are
    divided by the calibrated rates exactly like the scalar path.
    """
    probs = list(probs)
    cands = list(candidates or feasible_microkernels(machine, variant))
    rows = np.array([mk.rows for mk in cands], np.int64)
    cols = np.array([mk.cols for mk in cands], np.int64)
    m, n, k, s = _problem_arrays(probs)
    blk = derive_blocking_batch(variant, rows, cols, machine, m, n, k, s)
    terms = traffic_terms_batch(variant, rows, cols, blk, m, n, k, s,
                                policy=policy,
                                quant=quant_ratio_arrays(probs))
    total = None
    for t in terms:
        base = machine.rate(t.origin, t.dest)
        if t.chunk is None:
            rate = base
        else:
            rate = base * (t.chunk / float(machine.reference_chunk))
        comp = t.bytes / rate
        total = comp if total is None else total + comp
    dtypes = [p.dtype for p in probs]
    # arithmetic rates mirror the scalar lookup chain per problem: mixed
    # configs via rates_mixed (constant across candidates on a table hit,
    # per-mk refined through the uniform fallback otherwise), uniform
    # problems exactly as before.
    def _mixed_of(p):
        pc = p.precision
        return pc if pc is not None and not pc.is_uniform else None
    if machine.arith_per_mk and any(dt in machine.arith_per_mk
                                    for dt in dtypes):
        # per-candidate rates: (P, C) lattice of the paper-§4 refinement,
        # one lookup row per (precision, dtype) pair, broadcast over
        # problems.
        rows_by_key: dict[tuple, np.ndarray] = {}
        rate_rows = []
        for p in probs:
            pc = _mixed_of(p)
            key = (pc.key() if pc else None, p.dtype)
            row = rows_by_key.get(key)
            if row is None:
                if pc is not None:
                    row = np.array(
                        [machine.arith_rate_mixed(pc.key(), p.dtype, mk)
                         for mk in cands], np.float64)
                else:
                    row = np.array([machine.arith_rate_for(p.dtype, mk)
                                    for mk in cands], np.float64)
                rows_by_key[key] = row
            rate_rows.append(row)
        arith_rate = np.stack(rate_rows, axis=0)
    else:
        arith_rate = np.array(
            [machine.arith_rate_mixed(pc.key(), p.dtype)
             if (pc := _mixed_of(p)) is not None
             else machine.arith_rate[p.dtype]
             for p in probs], np.float64)[:, None]
    arith = 2.0 * (m * n * k).astype(np.float64) / arith_rate
    total = np.broadcast_to(total + arith, (len(probs), len(cands)))
    return CostBatch(variant=variant, micro_kernels=cands, total=total,
                     arith=arith, blocking=blk)


def best_microkernel_batch(
    machine: MachineSpec,
    variant: Variant,
    probs: Sequence[Problem],
    candidates: Sequence[MicroKernel] | None = None,
    policy: str = "analytic",
) -> list[CostBreakdown]:
    """Batched Table-2 procedure: one argmin row per problem."""
    probs = list(probs)
    if not probs:
        return []
    batch = simulate_batch(machine, probs, variant, candidates, policy)
    assert batch.micro_kernels, "no feasible micro-kernel"
    idx = np.argmin(batch.total, axis=1)
    return [simulate(machine, variant, batch.micro_kernels[int(i)], p,
                     policy=policy)
            for i, p in zip(idx, probs)]


def search_batch(
    machine: MachineSpec,
    probs: Sequence[Problem],
    variants: Sequence[Variant] | None = None,
    policy: str = "analytic",
) -> list[CostBreakdown]:
    """Full design-space argmin over variant x micro-kernel for many
    problems at once — equivalent to (but much faster than) taking the
    cheapest ``best_microkernel`` across variants per problem."""
    probs = list(probs)
    if not probs:
        return []
    variants = list(variants or Variant)
    batches = [simulate_batch(machine, probs, v, policy=policy)
               for v in variants]
    totals = np.concatenate([b.total for b in batches], axis=1)
    if totals.shape[1] == 0:
        raise ValueError(
            f"{machine.name}: no register-feasible micro-kernel for any of "
            f"{[v.value for v in variants]} ({machine.num_vector_registers} "
            f"regs x {machine.register_lanes} lanes)")
    idx = np.argmin(totals, axis=1)
    offsets = np.cumsum([0] + [len(b.micro_kernels) for b in batches])
    out = []
    for p, i in zip(probs, idx):
        b = int(np.searchsorted(offsets, i, side="right") - 1)
        mk = batches[b].micro_kernels[int(i - offsets[b])]
        out.append(simulate(machine, batches[b].variant, mk, p,
                            policy=policy))
    return out
