"""Per-operand dtype configurations for mixed-precision GEMM planning.

The source paper prices a GEMM for a single dtype per plan; its sequel —
"The Cambrian Explosion of Mixed-Precision Matrix Multiplication for
Quantized Deep Learning Inference" (arXiv 2506.11728) — shows edge inference
kernels take *per-operand* dtypes: int8/int4 inputs accumulated in int32,
or a wide activation operand quantized on the fly into a narrow micro-kernel
panel.  :class:`PrecisionConfig` is that triple, plus an optional KV-cache
dtype for the serving layer.

Modelling conventions (shared by both cost models):

* The **compute dtype** is the narrower of the two input operands — the
  micro-kernel / MXU path the arithmetic runs on.  Storage widths come from
  :data:`DTYPE_WIDTH`; ``int4`` is modelled at 1 byte (unpacked panels), so
  its advantage is purely the arithmetic rate, never phantom half-bytes.
* A **uniform** config (``a == b`` with the default accumulator) is, by
  definition, the existing single-dtype path: planners normalize it away
  (``GemmProblem`` drops it and keeps the plain dtype), so uniform configs
  are bit-identical to pre-mixed-precision plans.
* A *wider-than-compute* operand pays quantize/dequantize traffic: the
  ratio of extra bytes moved per compute-width byte,
  ``(width(op) - width(compute)) / width(compute)``, clamped at zero.
  The same ratios feed ``core/variants.traffic_terms[_batch]`` (per-term
  ``quant_*`` charges at the level the operand is packed/streamed) and
  ``core/tpu_model.estimate[_batch]`` (extra HBM bytes).
* The machine-side arithmetic rate resolves through the spec's
  ``rates_mixed`` table keyed by :meth:`PrecisionConfig.key` (e.g.
  ``"int4xint8->int32"``), falling back to the uniform ``arith_rate`` entry
  of the compute dtype when the mixed key is absent.
"""
from __future__ import annotations

import dataclasses

#: storage width (bytes) of each supported tag.  int4 panels are modelled
#: unpacked at one byte — see module docstring.
DTYPE_WIDTH = {"int4": 1.0, "int8": 1.0, "bf16": 2.0, "f32": 4.0,
               "int32": 4.0}
#: nominal bit width, used for the accuracy proxy and narrowness ordering.
DTYPE_BITS = {"int4": 4, "int8": 8, "bf16": 16, "f32": 32, "int32": 32}
#: tags allowed as A/B input operands.
OPERAND_DTYPES = ("int4", "int8", "bf16", "f32")
#: default accumulator per compute dtype (the sequel paper's convention:
#: integer inputs accumulate in int32, floating inputs in f32).
DEFAULT_ACC = {"int4": "int32", "int8": "int32", "bf16": "f32", "f32": "f32"}


def _narrower(a: str, b: str) -> str:
    """The narrower of two operand tags (ties broken by name for
    determinism — irrelevant in practice since equal-width tags tie only
    when identical or int4/int8, where bits still differ)."""
    return min((a, b), key=lambda t: (DTYPE_BITS[t], t))


@dataclasses.dataclass(frozen=True)
class PrecisionConfig:
    """A per-operand dtype assignment ``C[acc] (+)= A[a] . B[b]``.

    ``kv_dtype`` rides along for the serving layer (KV-cache storage dtype);
    it never affects GEMM cost, only the deployment footprint.
    """

    a_dtype: str
    b_dtype: str
    acc_dtype: str = ""
    kv_dtype: str | None = None

    def __post_init__(self):
        for role, tag in (("a_dtype", self.a_dtype),
                          ("b_dtype", self.b_dtype)):
            if tag not in OPERAND_DTYPES:
                raise ValueError(
                    f"PrecisionConfig.{role}={tag!r} is not an operand "
                    f"dtype; have {list(OPERAND_DTYPES)}")
        if not self.acc_dtype:
            object.__setattr__(self, "acc_dtype",
                               DEFAULT_ACC[self.compute_dtype])
        if self.acc_dtype not in DTYPE_WIDTH:
            raise ValueError(
                f"PrecisionConfig.acc_dtype={self.acc_dtype!r} is not a "
                f"known dtype; have {sorted(DTYPE_WIDTH)}")
        if self.kv_dtype is not None and self.kv_dtype not in OPERAND_DTYPES:
            raise ValueError(
                f"PrecisionConfig.kv_dtype={self.kv_dtype!r} is not an "
                f"operand dtype; have {list(OPERAND_DTYPES)}")

    # -- identity ------------------------------------------------------------

    @property
    def compute_dtype(self) -> str:
        """The dtype the arithmetic runs at: the narrower input operand."""
        return _narrower(self.a_dtype, self.b_dtype)

    @property
    def is_uniform(self) -> bool:
        """True when this config *is* the existing single-dtype path:
        identical operands with the default accumulator.  Uniform configs
        are normalized away by the planners and never consult
        ``rates_mixed`` or emit quantize traffic."""
        return (self.a_dtype == self.b_dtype
                and self.acc_dtype == DEFAULT_ACC[self.a_dtype])

    def key(self) -> str:
        """The machine-table / sweep-row key, e.g. ``"int4xint8->int32"``."""
        return f"{self.a_dtype}x{self.b_dtype}->{self.acc_dtype}"

    def __str__(self) -> str:
        base = self.key()
        return base if self.kv_dtype is None else f"{base}@kv={self.kv_dtype}"

    # -- cost-model inputs ---------------------------------------------------

    def widths(self) -> tuple[float, float, float]:
        """Storage widths (bytes) of (A, B, accumulator)."""
        return (DTYPE_WIDTH[self.a_dtype], DTYPE_WIDTH[self.b_dtype],
                DTYPE_WIDTH[self.acc_dtype])

    def quant_ratios(self, compute_bytes: float) -> tuple[float, float, float]:
        """Quantize/dequantize traffic ratios for (A, B, C).

        Each is the *extra* bytes moved per byte of the operand's
        compute-width traffic term: ``(width(op) - compute) / compute``,
        clamped at zero (an operand narrower than the compute width is not
        credited — the calibrated uniform rates already absorb the native
        accumulator traffic, see docs/COST_MODELS.md).
        """
        s = float(compute_bytes)
        wa, wb, wc = self.widths()
        return (max(0.0, (wa - s) / s), max(0.0, (wb - s) / s),
                max(0.0, (wc - s) / s))

    @property
    def accuracy_proxy(self) -> float:
        """Crude monotone accuracy stand-in for deployment ranking:
        narrowest input bits over 16, capped at 1.0 (bf16 is the reference
        inference precision) — int4 -> 0.25, int8 -> 0.5, bf16/f32 -> 1.0.
        A proxy for *relative ordering only*, not a quality prediction."""
        bits = min(DTYPE_BITS[self.a_dtype], DTYPE_BITS[self.b_dtype])
        return min(1.0, bits / 16.0)

    # -- construction --------------------------------------------------------

    @classmethod
    def uniform(cls, dtype: str, kv_dtype: str | None = None
                ) -> "PrecisionConfig":
        """The config equivalent to the plain single-dtype path."""
        return cls(dtype, dtype, kv_dtype=kv_dtype)

    @classmethod
    def parse(cls, text: str) -> "PrecisionConfig":
        """Parse ``"AxB"``, ``"AxB->ACC"`` or ``"AxB->ACC@kv=KV"`` (the
        :meth:`key` / CLI form); the accumulator defaults per
        :data:`DEFAULT_ACC` when omitted."""
        body, _, kv = text.partition("@kv=")
        left, _, acc = body.partition("->")
        a, sep, b = left.partition("x")
        if not sep or not a or not b:
            raise ValueError(
                f"cannot parse precision {text!r}; expected 'AxB' or "
                f"'AxB->ACC', e.g. 'int8xint8' or 'f32xint8->int32'")
        return cls(a, b, acc_dtype=acc, kv_dtype=kv or None)

    @classmethod
    def coerce(cls, obj) -> "PrecisionConfig | None":
        """None passes through; strings parse; configs are returned as-is."""
        if obj is None or isinstance(obj, cls):
            return obj
        if isinstance(obj, str):
            return cls.parse(obj)
        raise TypeError(
            f"cannot interpret {obj!r} as a PrecisionConfig; pass a "
            f"PrecisionConfig, a key string like 'int8xint8->int32', or None")
