"""TileTuner — the paper's design-space exploration as a framework service.

The paper's stated goal is to *experiment with algorithmic alternatives prior
to implementing them* (§1, §4).  TileTuner does exactly that for every
GEMM-shaped operation in the framework: given a :class:`GemmShape` it ranks
Pallas ``(bm, bn, bk, grid-order)`` candidates with the analytical TPU model
(``core.tpu_model``) and returns the winner; decisions are memoised in a
JSON manifest so kernels, benchmarks and the perf log all agree on the tiles
used.

For the GAP8 instance the equivalent entry point is
:func:`repro.core.simulator.best_microkernel` (Table 2's procedure).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import math
import os
from typing import Iterable, Sequence

import numpy as np

from repro.core.hardware import MachineSpec, V5E_VMEM_BYTES
from repro.core.tpu_model import (
    DTYPE_BYTES,
    SUBLANE,
    GemmShape,
    GridOrder,
    TileConfig,
    TpuCost,
    estimate,
    estimate_batch,
    machine_peak,  # noqa: F401  (re-exported; shape_peak supersedes it here)
    shape_peak,
    vmem_required,
    vmem_required_batch,
)
from repro.machines import registry as _machines

# Candidate block dims: MXU-aligned multiples of 128 plus small sublane
# multiples for skinny shapes.
_CAND_MN = (8, 16, 32, 64, 128, 256, 512, 1024, 2048)
_CAND_K = (128, 256, 512, 1024, 2048)
# Fraction of VMEM the kernel may claim (leave headroom for Mosaic spills,
# semaphores and the scalar prefetch working set).
VMEM_BUDGET_FRACTION = 0.75


def candidate_tiles(
    shape: GemmShape,
    orders: Sequence[GridOrder] = (GridOrder.K_INNER, GridOrder.K_OUTER),
    vmem_bytes: int = int(V5E_VMEM_BYTES),
) -> list[TileConfig]:
    budget = int(vmem_bytes * VMEM_BUDGET_FRACTION)
    out = []
    for bm in _CAND_MN:
        if bm > shape.m and bm > 8:
            # allow one size past the dim for padding, then stop
            if bm // 2 >= shape.m:
                continue
        for bn in _CAND_MN:
            if bn > shape.n and bn > 128 and bn // 2 >= shape.n:
                continue
            for bk in _CAND_K:
                if bk > shape.k and bk > 128 and bk // 2 >= shape.k:
                    continue
                for order in orders:
                    t = TileConfig(bm, bn, bk, order)
                    if vmem_required(shape, t) <= budget:
                        out.append(t)
    return out


@dataclasses.dataclass(frozen=True)
class TileDecision:
    shape: GemmShape
    tile: TileConfig
    cost: TpuCost
    overlap: bool

    @property
    def seconds(self) -> float:
        return self.cost.total(self.overlap)

    def to_json(self) -> dict:
        return {
            "m": self.shape.m, "n": self.shape.n, "k": self.shape.k,
            "dtype": self.shape.dtype,
            "bm": self.tile.bm, "bn": self.tile.bn, "bk": self.tile.bk,
            "order": self.tile.order.value,
            "seconds": self.seconds,
            "roofline_fraction": self.cost.roofline_fraction(self.overlap),
            "hbm_bytes": self.cost.hbm_bytes,
            "vmem_peak": self.cost.vmem_peak,
        }


# ---------------------------------------------------------------------------
# Batched engine.  The full candidate lattice (every (bm, bn, bk, order)
# cross product, feasibility expressed as a mask) is materialized once as
# flat arrays; scoring many shapes is then a single ``estimate_batch`` call
# over a (P, C) broadcast plus one argmin per row.  Selections are
# bit-identical with the scalar loop: the lattice preserves
# ``candidate_tiles``'s enumeration order and ``np.argmin`` keeps the first
# minimum, exactly like the loop's strict ``<`` update.
# ---------------------------------------------------------------------------

_FALLBACK_TILE = TileConfig(8, 128, 128, GridOrder.K_INNER)


@functools.lru_cache(maxsize=None)
def _lattice() -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Flat (bm, bn, bk, k_inner) arrays in ``candidate_tiles`` order."""
    bms, bns, bks, inner = [], [], [], []
    for bm in _CAND_MN:
        for bn in _CAND_MN:
            for bk in _CAND_K:
                for order in (GridOrder.K_INNER, GridOrder.K_OUTER):
                    bms.append(bm)
                    bns.append(bn)
                    bks.append(bk)
                    inner.append(order is GridOrder.K_INNER)
    return (np.array(bms, np.int64), np.array(bns, np.int64),
            np.array(bks, np.int64), np.array(inner, bool))


def _feasible_mask(m, n, k, elem_bytes, vmem_bytes: int) -> np.ndarray:
    """(P, C) candidate-feasibility mask replaying ``candidate_tiles``'s
    skip rules: one size past a short dim is allowed for padding, and the
    double-buffered working set must fit the VMEM budget."""
    bm, bn, bk, _ = _lattice()
    budget = int(vmem_bytes * VMEM_BUDGET_FRACTION)
    skip_m = (bm > m) & (bm > 8) & (bm // 2 >= m)
    skip_n = (bn > n) & (bn > 128) & (bn // 2 >= n)
    skip_k = (bk > k) & (bk > 128) & (bk // 2 >= k)
    fits = vmem_required_batch(bm, bn, bk, elem_bytes) <= budget
    return ~skip_m & ~skip_n & ~skip_k & fits


def _solve_batch(shapes: Sequence[GemmShape], overlap: bool,
                 machine: MachineSpec) -> list[TileDecision]:
    """Score the whole lattice for every shape at once; argmin per shape."""
    m = np.array([s.m for s in shapes], np.int64)[:, None]
    n = np.array([s.n for s in shapes], np.int64)[:, None]
    k = np.array([s.k for s in shapes], np.int64)[:, None]
    s_bytes = np.array([DTYPE_BYTES[s.dtype] for s in shapes],
                       np.int64)[:, None]
    sub = np.array([SUBLANE[s.dtype] for s in shapes], np.int64)[:, None]
    peak = np.array([shape_peak(machine, s) for s in shapes],
                    np.float64)[:, None]
    acc = np.array([s.accumulate for s in shapes], bool)[:, None]
    bm, bn, bk, inner = _lattice()

    # per-shape quantize ratios; None (no mixed shape) keeps the plain path.
    ratios = [s.mixed_precision.quant_ratios(DTYPE_BYTES[s.dtype])
              if s.mixed_precision is not None else (0.0, 0.0, 0.0)
              for s in shapes]
    quant = None
    if any(any(r > 0.0 for r in t) for t in ratios):
        qr = np.array(ratios, np.float64)
        quant = (qr[:, 0:1], qr[:, 1:2], qr[:, 2:3])

    mask = _feasible_mask(m, n, k, s_bytes, machine.capacity("L1"))
    costs = estimate_batch(m, n, k, s_bytes, sub, peak, bm, bn, bk, inner,
                           accumulate=acc, machine=machine, quant=quant)
    totals = np.where(mask, costs.total(overlap), np.inf)
    idx = np.argmin(totals, axis=1)
    feasible = mask.any(axis=1)

    out = []
    for p, shape in enumerate(shapes):
        if feasible[p]:
            i = int(idx[p])
            tile = TileConfig(int(bm[i]), int(bn[i]), int(bk[i]),
                              GridOrder.K_INNER if inner[i]
                              else GridOrder.K_OUTER)
        else:  # degenerate tiny shape: single-block fallback
            tile = _FALLBACK_TILE
        # The winner's TpuCost is rebuilt by the scalar model: one call per
        # shape, and the resulting TileDecision is exactly the scalar one.
        out.append(TileDecision(shape=shape, tile=tile,
                                cost=estimate(shape, tile, machine),
                                overlap=overlap))
    return out


# FIFO-bounded decision memo (same memory bound the old lru_cache enforced).
_TUNE_CACHE: dict[tuple, TileDecision] = {}
_TUNE_CACHE_MAX = 4096


def _cache_key(shape: GemmShape, overlap: bool,
               machine: MachineSpec) -> tuple:
    # cache_token (name@content-fingerprint), not the bare name: same-named
    # machines with different rate tables must not share tile decisions.
    pc = shape.precision
    return (shape.m, shape.n, shape.k, shape.dtype, shape.accumulate,
            None if pc is None else pc.key(), overlap, machine.cache_token)


def clear_tune_cache() -> None:
    _TUNE_CACHE.clear()


def tune_batch(shapes: Iterable[GemmShape], overlap: bool = True,
               machine: MachineSpec | None = None,
               cache: bool = True) -> list[TileDecision]:
    """Batched TileTuner: one vectorized lattice evaluation for all shapes.

    Duplicate shapes are deduped before evaluation and decisions are memoised
    process-wide, so repeated QKV/logits shapes across arch configs cost one
    lattice row total.  Returns decisions in input order.  ``machine`` is
    any registry spec (default ``tpu-v5e``).
    """
    machine = machine or _machines.get("tpu-v5e")
    shapes = list(shapes)
    out: list[TileDecision | None] = [None] * len(shapes)
    missing: dict[GemmShape, list[int]] = {}
    for i, s in enumerate(shapes):
        hit = _TUNE_CACHE.get(_cache_key(s, overlap, machine)) if cache \
            else None
        if hit is not None:
            out[i] = hit
        else:
            missing.setdefault(s, []).append(i)
    if missing:
        for s, d in zip(missing, _solve_batch(list(missing), overlap,
                                              machine)):
            if cache:
                if len(_TUNE_CACHE) >= _TUNE_CACHE_MAX:
                    _TUNE_CACHE.pop(next(iter(_TUNE_CACHE)))
                _TUNE_CACHE[_cache_key(s, overlap, machine)] = d
            for i in missing[s]:
                out[i] = d
    return out  # type: ignore[return-value]


def tune(shape: GemmShape, overlap: bool = True) -> TileDecision:
    """Pick the best (bm, bn, bk, order) for one GEMM shape (thin wrapper
    over the batched engine)."""
    return tune_batch([shape], overlap)[0]


def tune_many(shapes: Iterable[GemmShape], overlap: bool = True
              ) -> list[TileDecision]:
    """Batch-tune many shapes (deduped before evaluation)."""
    return tune_batch(shapes, overlap)


def tune_scalar(shape: GemmShape, overlap: bool = True,
                machine: MachineSpec | None = None) -> TileDecision:
    """The pre-batching scalar search loop, preserved verbatim as the
    reference oracle for the equivalence tests and the planner benchmark.
    Do not optimise or route through the batch engine — its whole value is
    being an independent implementation ``tune_batch`` must agree with."""
    machine = machine or _machines.get("tpu-v5e")
    best: TileDecision | None = None
    for t in candidate_tiles(shape, vmem_bytes=machine.capacity("L1")):
        d = TileDecision(shape=shape, tile=t,
                         cost=estimate(shape, t, machine), overlap=overlap)
        if best is None or d.seconds < best.seconds:
            best = d
    if best is None:  # degenerate tiny shape: single-block fallback
        best = TileDecision(shape, _FALLBACK_TILE,
                            estimate(shape, _FALLBACK_TILE, machine), overlap)
    return best


class Manifest:
    """Persisted tile decisions, keyed by (m, n, k, dtype)."""

    def __init__(self, path: str):
        self.path = path
        self._entries: dict[str, dict] = {}
        if os.path.exists(path):
            with open(path) as f:
                self._entries = json.load(f)

    @staticmethod
    def key(shape: GemmShape) -> str:
        base = f"{shape.m}x{shape.n}x{shape.k}:{shape.dtype}"
        # mixed-precision decisions get their own manifest namespace; plain
        # shapes keep the historical key so existing manifests stay valid.
        pc = shape.precision
        return base if pc is None else f"{base}|{pc.key()}"

    def lookup(self, shape: GemmShape) -> TileConfig | None:
        e = self._entries.get(self.key(shape))
        if e is None:
            return None
        return TileConfig(e["bm"], e["bn"], e["bk"], GridOrder(e["order"]))

    def record(self, decision: TileDecision) -> None:
        self._entries[self.key(decision.shape)] = decision.to_json()

    def save(self) -> None:
        tmp = self.path + ".tmp"
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        with open(tmp, "w") as f:
            json.dump(self._entries, f, indent=1, sort_keys=True)
        os.replace(tmp, self.path)

    def __len__(self) -> int:
        return len(self._entries)


def model_gemm_shapes(cfg, tokens: int = 4096) -> list[GemmShape]:
    """Enumerate the GEMM shapes of one transformer architecture config —
    the per-arch workload TileTuner optimises (the MobileNetV1-Table-2
    analogue for our assigned architectures).  ``tokens`` is the per-chip
    token tile (a representative M; serving passes its decode batch)."""
    d = cfg.d_model
    shapes = []
    q = cfg.n_heads * cfg.head_dim
    kv = cfg.n_kv_heads * cfg.head_dim
    shapes.append(GemmShape(tokens, q + 2 * kv, d, dtype="bf16"))   # QKV
    shapes.append(GemmShape(tokens, d, q, dtype="bf16"))            # O proj
    if cfg.d_ff:
        shapes.append(GemmShape(tokens, 2 * cfg.d_ff, d, dtype="bf16"))  # gate+up
        shapes.append(GemmShape(tokens, d, cfg.d_ff, dtype="bf16"))      # down
    if getattr(cfg, "n_experts", 0):
        per_e = max(1, tokens * cfg.experts_per_token // cfg.n_experts)
        shapes.append(GemmShape(per_e, 2 * cfg.moe_d_ff, d, dtype="bf16"))
        shapes.append(GemmShape(per_e, d, cfg.moe_d_ff, dtype="bf16"))
    shapes.append(GemmShape(tokens, cfg.vocab_size, d, dtype="bf16"))    # logits
    return shapes
