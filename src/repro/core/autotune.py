"""TileTuner — the paper's design-space exploration as a framework service.

The paper's stated goal is to *experiment with algorithmic alternatives prior
to implementing them* (§1, §4).  TileTuner does exactly that for every
GEMM-shaped operation in the framework: given a :class:`GemmShape` it ranks
Pallas ``(bm, bn, bk, grid-order)`` candidates with the analytical TPU model
(``core.tpu_model``) and returns the winner; decisions are memoised in a
JSON manifest so kernels, benchmarks and the perf log all agree on the tiles
used.

For the GAP8 instance the equivalent entry point is
:func:`repro.core.simulator.best_microkernel` (Table 2's procedure).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import math
import os
from typing import Iterable, Sequence

from repro.core.hardware import MachineSpec, TPU_V5E, V5E_VMEM_BYTES
from repro.core.tpu_model import (
    DTYPE_BYTES,
    GemmShape,
    GridOrder,
    TileConfig,
    TpuCost,
    estimate,
    vmem_required,
)

# Candidate block dims: MXU-aligned multiples of 128 plus small sublane
# multiples for skinny shapes.
_CAND_MN = (8, 16, 32, 64, 128, 256, 512, 1024, 2048)
_CAND_K = (128, 256, 512, 1024, 2048)
# Fraction of VMEM the kernel may claim (leave headroom for Mosaic spills,
# semaphores and the scalar prefetch working set).
VMEM_BUDGET_FRACTION = 0.75


def candidate_tiles(
    shape: GemmShape,
    orders: Sequence[GridOrder] = (GridOrder.K_INNER, GridOrder.K_OUTER),
    vmem_bytes: int = int(V5E_VMEM_BYTES),
) -> list[TileConfig]:
    budget = int(vmem_bytes * VMEM_BUDGET_FRACTION)
    out = []
    for bm in _CAND_MN:
        if bm > shape.m and bm > 8:
            # allow one size past the dim for padding, then stop
            if bm // 2 >= shape.m:
                continue
        for bn in _CAND_MN:
            if bn > shape.n and bn > 128 and bn // 2 >= shape.n:
                continue
            for bk in _CAND_K:
                if bk > shape.k and bk > 128 and bk // 2 >= shape.k:
                    continue
                for order in orders:
                    t = TileConfig(bm, bn, bk, order)
                    if vmem_required(shape, t) <= budget:
                        out.append(t)
    return out


@dataclasses.dataclass(frozen=True)
class TileDecision:
    shape: GemmShape
    tile: TileConfig
    cost: TpuCost
    overlap: bool

    @property
    def seconds(self) -> float:
        return self.cost.total(self.overlap)

    def to_json(self) -> dict:
        return {
            "m": self.shape.m, "n": self.shape.n, "k": self.shape.k,
            "dtype": self.shape.dtype,
            "bm": self.tile.bm, "bn": self.tile.bn, "bk": self.tile.bk,
            "order": self.tile.order.value,
            "seconds": self.seconds,
            "roofline_fraction": self.cost.roofline_fraction(self.overlap),
            "hbm_bytes": self.cost.hbm_bytes,
            "vmem_peak": self.cost.vmem_peak,
        }


@functools.lru_cache(maxsize=4096)
def _tune_cached(m: int, n: int, k: int, dtype: str, accumulate: bool,
                 overlap: bool) -> TileDecision:
    shape = GemmShape(m=m, n=n, k=k, dtype=dtype, accumulate=accumulate)
    best: TileDecision | None = None
    for t in candidate_tiles(shape):
        c = estimate(shape, t)
        d = TileDecision(shape=shape, tile=t, cost=c, overlap=overlap)
        if best is None or d.seconds < best.seconds:
            best = d
    if best is None:  # degenerate tiny shape: single-block fallback
        t = TileConfig(8, 128, 128, GridOrder.K_INNER)
        best = TileDecision(shape, t, estimate(shape, t), overlap)
    return best


def tune(shape: GemmShape, overlap: bool = True) -> TileDecision:
    """Pick the best (bm, bn, bk, order) for one GEMM shape."""
    return _tune_cached(shape.m, shape.n, shape.k, shape.dtype,
                        shape.accumulate, overlap)


def tune_many(shapes: Iterable[GemmShape], overlap: bool = True
              ) -> list[TileDecision]:
    return [tune(s, overlap) for s in shapes]


class Manifest:
    """Persisted tile decisions, keyed by (m, n, k, dtype)."""

    def __init__(self, path: str):
        self.path = path
        self._entries: dict[str, dict] = {}
        if os.path.exists(path):
            with open(path) as f:
                self._entries = json.load(f)

    @staticmethod
    def key(shape: GemmShape) -> str:
        return f"{shape.m}x{shape.n}x{shape.k}:{shape.dtype}"

    def lookup(self, shape: GemmShape) -> TileConfig | None:
        e = self._entries.get(self.key(shape))
        if e is None:
            return None
        return TileConfig(e["bm"], e["bn"], e["bk"], GridOrder(e["order"]))

    def record(self, decision: TileDecision) -> None:
        self._entries[self.key(decision.shape)] = decision.to_json()

    def save(self) -> None:
        tmp = self.path + ".tmp"
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        with open(tmp, "w") as f:
            json.dump(self._entries, f, indent=1, sort_keys=True)
        os.replace(tmp, self.path)

    def __len__(self) -> int:
        return len(self._entries)


def model_gemm_shapes(cfg, tokens: int = 4096) -> list[GemmShape]:
    """Enumerate the GEMM shapes of one transformer architecture config —
    the per-arch workload TileTuner optimises (the MobileNetV1-Table-2
    analogue for our assigned architectures).  ``tokens`` is the per-chip
    token tile (a representative M; serving passes its decode batch)."""
    d = cfg.d_model
    shapes = []
    q = cfg.n_heads * cfg.head_dim
    kv = cfg.n_kv_heads * cfg.head_dim
    shapes.append(GemmShape(tokens, q + 2 * kv, d, dtype="bf16"))   # QKV
    shapes.append(GemmShape(tokens, d, q, dtype="bf16"))            # O proj
    if cfg.d_ff:
        shapes.append(GemmShape(tokens, 2 * cfg.d_ff, d, dtype="bf16"))  # gate+up
        shapes.append(GemmShape(tokens, d, cfg.d_ff, dtype="bf16"))      # down
    if getattr(cfg, "n_experts", 0):
        per_e = max(1, tokens * cfg.experts_per_token // cfg.n_experts)
        shapes.append(GemmShape(per_e, 2 * cfg.moe_d_ff, d, dtype="bf16"))
        shapes.append(GemmShape(per_e, d, cfg.moe_d_ff, dtype="bf16"))
    shapes.append(GemmShape(tokens, cfg.vocab_size, d, dtype="bf16"))    # logits
    return shapes
