"""The paper's primary contribution: an analytical performance simulator for
blocked GEMM (GotoBLAS/BLIS family), plus its TPU adaptation (TileTuner) and
the roofline machinery built on it.

Public surface:
  hardware   — machine specs (GAP8_FC calibration Table 1, TPU_V5E roofline)
  variants   — B3A2C0 / C3B2A0 / B3C2A0 loop nests + blocking derivation
  simulator  — the faithful cost model (paper §3) and Table-2 search
  tpu_model  — Pallas-grid cost model (HBM/VMEM/MXU, ±overlap)
  autotune   — TileTuner: analytical BlockSpec selection + manifest
  roofline   — 3-term roofline from compiled HLO
  calibrate  — the paper's calibration methodology, runnable on any host

NOTE: consumers should plan GEMMs through the unified façade
``repro.gemm.plan(...)`` rather than calling ``best_microkernel`` / ``tune``
directly; these remain public as the implementation layer the registered
backends dispatch to.
"""
from repro.core.hardware import GAP8_FC, TPU_V5E, MachineSpec, get_machine
from repro.core.simulator import CostBreakdown, best_microkernel, simulate
from repro.core.tpu_model import GemmShape, GridOrder, TileConfig, estimate
from repro.core.autotune import Manifest, TileDecision, tune
from repro.core.variants import (
    Blocking,
    MicroKernel,
    Problem,
    Variant,
    derive_blocking,
    feasible_microkernels,
)

__all__ = [
    "GAP8_FC", "TPU_V5E", "MachineSpec", "get_machine",
    "CostBreakdown", "best_microkernel", "simulate",
    "GemmShape", "GridOrder", "TileConfig", "estimate",
    "Manifest", "TileDecision", "tune",
    "Blocking", "MicroKernel", "Problem", "Variant",
    "derive_blocking", "feasible_microkernels",
]
