"""The paper's primary contribution: an analytical performance simulator for
blocked GEMM (GotoBLAS/BLIS family), plus its TPU adaptation (TileTuner) and
the roofline machinery built on it.

Public surface:
  hardware   — machine specs (GAP8_FC calibration Table 1, TPU_V5E roofline)
  variants   — B3A2C0 / C3B2A0 / B3C2A0 loop nests + blocking derivation
  simulator  — the faithful cost model (paper §3) and Table-2 search
  tpu_model  — Pallas-grid cost model (HBM/VMEM/MXU, ±overlap)
  autotune   — TileTuner: analytical BlockSpec selection + manifest
  roofline   — 3-term roofline from compiled HLO
  calibrate  — the paper's calibration methodology, runnable on any host

NOTE: consumers should plan GEMMs through the unified façade
``repro.gemm.plan(...)`` rather than calling ``best_microkernel`` / ``tune``
directly; these remain public as the implementation layer the registered
backends dispatch to.
"""
from repro.core.hardware import GAP8_FC, TPU_V5E, MachineSpec, get_machine
from repro.core.simulator import (
    CostBatch,
    CostBreakdown,
    best_microkernel,
    best_microkernel_batch,
    search_batch,
    simulate,
    simulate_batch,
)
from repro.core.tpu_model import (
    GemmShape,
    GridOrder,
    TileConfig,
    TpuCostBatch,
    estimate,
    estimate_batch,
)
from repro.core.autotune import Manifest, TileDecision, tune, tune_batch
from repro.core.variants import (
    Blocking,
    MicroKernel,
    Problem,
    Variant,
    derive_blocking,
    derive_blocking_batch,
    feasible_microkernels,
)

__all__ = [
    "GAP8_FC", "TPU_V5E", "MachineSpec", "get_machine",
    "CostBatch", "CostBreakdown", "best_microkernel",
    "best_microkernel_batch", "search_batch", "simulate", "simulate_batch",
    "GemmShape", "GridOrder", "TileConfig", "TpuCostBatch", "estimate",
    "estimate_batch",
    "Manifest", "TileDecision", "tune", "tune_batch",
    "Blocking", "MicroKernel", "Problem", "Variant",
    "derive_blocking", "derive_blocking_batch", "feasible_microkernels",
]
