"""The paper's primary contribution: an analytical performance simulator for
blocked GEMM (GotoBLAS/BLIS family), plus its TPU adaptation (TileTuner) and
the roofline machinery built on it.

Public surface:
  hardware   — legacy shim over repro.machines (the declarative machine zoo)
  variants   — B3A2C0 / C3B2A0 / B3C2A0 loop nests + blocking derivation
  simulator  — the faithful cost model (paper §3) and Table-2 search
  tpu_model  — Pallas-grid cost model (HBM/VMEM/MXU, ±overlap)
  autotune   — TileTuner: analytical BlockSpec selection + manifest
  roofline   — 3-term roofline from compiled HLO
  calibrate  — the paper's calibration methodology, runnable on any host

NOTE: consumers should plan GEMMs through the unified façade
``repro.gemm.plan(...)`` rather than calling ``best_microkernel`` / ``tune``
directly; these remain public as the implementation layer the registered
backends dispatch to.  Machine specs live in ``repro.machines`` (the
declarative zoo); ``GAP8_FC`` / ``TPU_V5E`` / ``get_machine`` are kept as
legacy re-exports resolved from the registry.
"""
from repro.core.hardware import MachineSpec, get_machine
from repro.core.simulator import (
    CostBatch,
    CostBreakdown,
    best_microkernel,
    best_microkernel_batch,
    search_batch,
    simulate,
    simulate_batch,
)
from repro.core.tpu_model import (
    GemmShape,
    GridOrder,
    TileConfig,
    TpuCostBatch,
    estimate,
    estimate_batch,
)
from repro.core.autotune import Manifest, TileDecision, tune, tune_batch
from repro.core.variants import (
    Blocking,
    MicroKernel,
    Problem,
    Variant,
    derive_blocking,
    derive_blocking_batch,
    feasible_microkernels,
)

# Legacy constant names resolve lazily from the zoo registry on every
# access (no import-time snapshot to go stale after a re-registration, and
# no deprecation noise on `import repro.core`; attribute access on
# repro.core.hardware is the surface that warns).
_LAZY_MACHINES = {"GAP8_FC": "gap8-fc", "TPU_V5E": "tpu-v5e"}


def __getattr__(name):
    if name in _LAZY_MACHINES:
        from repro.machines import get as _get_machine
        return _get_machine(_LAZY_MACHINES[name])
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "GAP8_FC", "TPU_V5E", "MachineSpec", "get_machine",
    "CostBatch", "CostBreakdown", "best_microkernel",
    "best_microkernel_batch", "search_batch", "simulate", "simulate_batch",
    "GemmShape", "GridOrder", "TileConfig", "TpuCostBatch", "estimate",
    "estimate_batch",
    "Manifest", "TileDecision", "tune", "tune_batch",
    "Blocking", "MicroKernel", "Problem", "Variant",
    "derive_blocking", "derive_blocking_batch", "feasible_microkernels",
]
