"""Three-term roofline analysis from compiled XLA artifacts.

For each (architecture x input shape x mesh) dry-run cell we derive:

    compute term    = HLO_FLOPs   / (chips x peak_FLOP/s)
    memory term     = HLO_bytes   / (chips x HBM_bw)
    collective term = coll_bytes  / (chips x link_bw)

``cost_analysis()`` supplies FLOPs and bytes; collective bytes are *not* in
cost_analysis, so we parse the optimized HLO text and sum operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op (prompt-specified methodology).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Mapping

from repro.core.hardware import V5E_HBM_BW, V5E_ICI_BW, V5E_PEAK_BF16

def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalised across jax versions: older
    releases return a single-element list of dicts (one per partition),
    newer ones a plain dict.  Returns ``{}`` when analysis is unavailable."""
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        return dict(ca[0]) if ca else {}
    return dict(ca)


COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "e4m3": 1, "e5m2": 1,
}

# shape literal, e.g. "bf16[256,4096,512]{2,1,0}" or "f32[]"
_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([0-9,]*)\]")


def shape_bytes(dtype: str, dims_str: str) -> int:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0  # token/opaque types
    n = 1
    if dims_str:
        for d in dims_str.split(","):
            n *= int(d)
    return n * nb


_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:                         # iota form: [n_groups, group_size]<=[...]
        return max(int(m.group(2)), 1)
    m = _GROUPS_BRACE_RE.search(line)
    if m:                         # explicit form: {{0,1,...},{...}}
        return max(len(m.group(1).split(",")), 1)
    return 1


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum *operand* bytes of every collective in an (optimized) HLO dump.

    XLA's text dumps print operands as bare names (no types), so operand
    sizes are derived from the RESULT shape left of ``=`` and each op's
    semantics (group size G parsed from ``replica_groups``):

        all-reduce / all-to-all / collective-permute: operand == result
        all-gather:      operand = result / G
        reduce-scatter:  operand = result * G

    ``fusion`` bodies can't contain collectives, so a line-wise scan is safe.
    """
    totals: dict[str, float] = {op: 0.0 for op in COLLECTIVE_OPS}
    counts: dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$", ls)
        if not m:
            continue
        rhs = m.group(1)
        opm = re.match(r"^(?:\([^)]*\)|[a-z0-9\[\],{}\s]*?)\s*"
                       r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                       r"collective-permute)(-start|-done)?\(", rhs)
        if not opm:
            continue
        op = opm.group(1)
        suffix = opm.group(2) or ""
        if suffix == "-done":
            continue  # the -start line already carries the result shape
        # result type(s): everything before the op name
        head = rhs[:rhs.index(op + suffix + "(")]
        b = 0
        for dm in _SHAPE_RE.finditer(head):
            b += shape_bytes(dm.group(1), dm.group(2))
        if suffix == "-start" and head.lstrip().startswith("("):
            b //= 2               # async start returns (operand, result)
        g = _group_size(line)
        if op == "all-gather":
            b = b / g
        elif op == "reduce-scatter":
            b = b * g
        totals[op] += b
        counts[op] += 1
    totals["_total"] = sum(totals[o] for o in COLLECTIVE_OPS)
    totals["_count"] = float(sum(counts.values()))
    return totals


@dataclasses.dataclass(frozen=True)
class RooflineReport:
    """Roofline terms from a compiled SPMD artifact.

    IMPORTANT: ``compiled.cost_analysis()`` on a partitioned module reports
    the *per-device* program (verified in tests/test_roofline.py), so the
    assignment's ``X / (chips x rate)`` is realised as ``X_perdev / rate`` —
    numerically identical for perfectly-sharded ops and *more honest* for
    replicated ones (replicated compute costs every chip its full time).
    ``model_flops`` stays global and is divided by chips for the ideal.
    """
    arch: str
    shape_name: str
    mesh: str
    chips: int
    hlo_flops: float              # per-device
    hlo_bytes: float              # per-device
    coll_bytes: float             # per-device
    model_flops: float            # GLOBAL: 6 N D (dense) / 6 N_active D (MoE)
    coll_detail: Mapping[str, float]

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / V5E_PEAK_BF16

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / V5E_HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / V5E_ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Lower-bound step time: overlapped resources -> max of the terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the step at the dominant bottleneck:
        MODEL_FLOPs-at-peak over the bound step time."""
        ideal = self.model_flops / (self.chips * V5E_PEAK_BF16)
        return ideal / self.step_time if self.step_time > 0 else 0.0

    @property
    def useful_flop_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS — catches remat/redundant compute."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape_name, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "hlo_gflops": self.hlo_flops / 1e9,
            "hlo_gbytes": self.hlo_bytes / 1e9,
            "coll_gbytes": self.coll_bytes / 1e9,
            "model_gflops": self.model_flops / 1e9,
            "useful_flop_ratio": self.useful_flop_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def from_compiled(arch: str, shape_name: str, mesh_name: str, chips: int,
                  cost: dict, hlo_text: str, model_flops: float
                  ) -> RooflineReport:
    """Build a report from ``compiled.cost_analysis()`` + HLO text.

    cost_analysis flops/bytes are per-device on SPMD modules; the term
    properties use per-chip rates accordingly (see class docstring).
    """
    coll = collective_bytes(hlo_text)
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    return RooflineReport(
        arch=arch, shape_name=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=nbytes, coll_bytes=coll["_total"],
        model_flops=model_flops, coll_detail=coll,
    )
