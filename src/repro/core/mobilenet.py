"""MobileNetV1 GEMM workload (paper Table 2).

Applying the lowering (im2col) approach to MobileNetV1's convolutions yields
one GEMM per layer; the paper evaluates the three algorithmic variants on all
of them and reports the optimal micro-kernel per (layer, variant).  We encode
the table verbatim as the reproduction oracle; ``benchmarks/bench_table2.py``
re-derives the optima with our simulator and reports the agreement matrix.

Layer #28 is skipped by the paper (not a convolution).  Rows that the paper
groups ("5,7", "14,16,18,20,22", ...) are expanded to the first layer id of
the group (the GEMM dims are identical).
"""
from __future__ import annotations

import dataclasses

from repro.core.variants import MicroKernel, Problem


@dataclasses.dataclass(frozen=True)
class Table2Row:
    layer: str
    m: int
    n: int
    k: int
    best: dict  # variant name -> paper's optimal micro-kernel

    @property
    def problem(self) -> Problem:
        return Problem(m=self.m, n=self.n, k=self.k, elem_bytes=1, dtype="int8")


def _mk(s: str) -> MicroKernel:
    r, c = s.split("x")
    return MicroKernel(int(r), int(c))


TABLE2: list[Table2Row] = [
    Table2Row("1", 32, 12544, 27,
              {"B3A2C0": _mk("4x24"), "C3B2A0": _mk("24x4"), "B3C2A0": _mk("8x12")}),
    Table2Row("2", 32, 12544, 288,
              {"B3A2C0": _mk("4x24"), "C3B2A0": _mk("8x12"), "B3C2A0": _mk("4x24")}),
    Table2Row("3", 64, 12544, 32,
              {"B3A2C0": _mk("4x24"), "C3B2A0": _mk("24x4"), "B3C2A0": _mk("12x8")}),
    Table2Row("4", 64, 3136, 576,
              {"B3A2C0": _mk("4x24"), "C3B2A0": _mk("12x8"), "B3C2A0": _mk("4x24")}),
    Table2Row("5,7", 128, 3136, 128,
              {"B3A2C0": _mk("4x24"), "C3B2A0": _mk("24x4"), "B3C2A0": _mk("4x24")}),
    Table2Row("6", 128, 3136, 1152,
              {"B3A2C0": _mk("4x24"), "C3B2A0": _mk("12x8"), "B3C2A0": _mk("4x24")}),
    Table2Row("8", 128, 784, 1152,
              {"B3A2C0": _mk("4x24"), "C3B2A0": _mk("12x8"), "B3C2A0": _mk("4x24")}),
    Table2Row("9", 256, 784, 128,
              {"B3A2C0": _mk("4x24"), "C3B2A0": _mk("24x4"), "B3C2A0": _mk("8x12")}),
    Table2Row("10", 256, 784, 2304,
              {"B3A2C0": _mk("4x24"), "C3B2A0": _mk("12x8"), "B3C2A0": _mk("4x24")}),
    Table2Row("11", 256, 784, 256,
              {"B3A2C0": _mk("4x24"), "C3B2A0": _mk("12x8"), "B3C2A0": _mk("4x20")}),
    Table2Row("12", 256, 196, 2304,
              {"B3A2C0": _mk("4x24"), "C3B2A0": _mk("12x8"), "B3C2A0": _mk("4x24")}),
    Table2Row("13", 512, 196, 256,
              {"B3A2C0": _mk("4x24"), "C3B2A0": _mk("24x4"), "B3C2A0": _mk("4x24")}),
    Table2Row("14,16,18,20,22", 512, 196, 4608,
              {"B3A2C0": _mk("4x24"), "C3B2A0": _mk("12x8"), "B3C2A0": _mk("4x24")}),
    Table2Row("15,17,19,21,23", 512, 196, 512,
              {"B3A2C0": _mk("4x24"), "C3B2A0": _mk("12x8"), "B3C2A0": _mk("4x24")}),
    Table2Row("24", 512, 49, 4608,
              {"B3A2C0": _mk("8x12"), "C3B2A0": _mk("12x8"), "B3C2A0": _mk("4x24")}),
    Table2Row("25", 1024, 49, 512,
              {"B3A2C0": _mk("8x12"), "C3B2A0": _mk("12x8"), "B3C2A0": _mk("4x24")}),
    Table2Row("26", 1024, 49, 9216,
              {"B3A2C0": _mk("8x12"), "C3B2A0": _mk("12x8"), "B3C2A0": _mk("4x24")}),
    Table2Row("27", 1024, 49, 1024,
              {"B3A2C0": _mk("8x12"), "C3B2A0": _mk("12x8"), "B3C2A0": _mk("4x24")}),
    Table2Row("29", 1024, 1000, 1,
              {"B3A2C0": _mk("4x24"), "C3B2A0": _mk("24x4"), "B3C2A0": _mk("24x4")}),
]

# The validation GEMM of §3.2 / Fig. 4-5 (MobileNetV1 layer #10).
LAYER10 = TABLE2[8].problem
assert (LAYER10.m, LAYER10.n, LAYER10.k) == (256, 784, 2304)
