"""repro.launch subpackage."""
