import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# Roofline probes: exact HLO cost extrapolation around XLA's while-loop
# accounting.
#
# ``compiled.cost_analysis()`` counts a scan body ONCE regardless of trip
# count, so the scanned full model under-reports.  Each cell therefore
# compiles two *unrolled* probe models — 1 period and 2 periods of the layer
# pattern (tail attached to both, so it cancels), with ``attn_chunk = S`` so
# the attention KV scan has trip count 1 — and extrapolates exactly:
#
#     F_cell = F(1) + (k_full - 1) * (F(2) - F(1))
#
# Per-period costs are identical by construction (same shapes per period),
# so the extrapolation is exact for FLOPs, bytes and collective bytes; the
# only residual undercount is sLSTM's time-step scan (~2% of that block's
# FLOPs, noted in EXPERIMENTS.md).  Memory figures come from the *scanned*
# production compile (launch/dryrun.py), which is what would execute.
import argparse
import dataclasses
import json

from repro.configs import ARCH_IDS, get_config, shape_cells, skipped_cells
from repro.configs.base import SHAPES
from repro.core.hardware import V5E_HBM_BW, V5E_ICI_BW, V5E_PEAK_BF16
from repro.launch.dryrun import run_cell
from repro.models.model import factor_pattern


def probe_config(cfg, n_periods: int, seq_len: int):
    period, k, tail = factor_pattern(cfg.block_pattern)
    pattern = tuple(period) * n_periods + tuple(tail)
    return dataclasses.replace(
        cfg, n_layers=len(pattern), block_pattern=pattern,
        attn_chunk=max(seq_len, cfg.attn_chunk))


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6 N D (train) / 2 N_active per generated token (decode),
    N = active params."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens
    return 2.0 * n_active * shape.global_batch      # one token per sequence


def probe_cell(arch: str, shape_name: str, multi_pod: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    period, k_full, tail = factor_pattern(cfg.block_pattern)

    f1 = run_cell(arch, shape_name, multi_pod,
                  cfg=probe_config(cfg, 1, shape.seq_len), unroll=True,
                  donate=False)
    f2 = run_cell(arch, shape_name, multi_pod,
                  cfg=probe_config(cfg, 2, shape.seq_len), unroll=True,
                  donate=False)

    def extrap(key):
        d = f2[key] - f1[key]
        return f1[key] + (k_full - 1) * d

    chips = f1["chips"]
    # cost_analysis is PER-DEVICE on SPMD modules (core/roofline.py): terms
    # divide by per-chip rates directly.
    flops = extrap("flops")
    nbytes = extrap("bytes_accessed")
    coll = extrap("collective_bytes")
    mf = model_flops(cfg, shape)
    t_comp = flops / V5E_PEAK_BF16
    t_mem = nbytes / V5E_HBM_BW
    t_coll = coll / V5E_ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    step = max(terms.values())
    ideal = mf / (chips * V5E_PEAK_BF16)
    return {
        "arch": arch, "shape": shape_name,
        "mesh": f2["mesh"], "chips": chips,
        "hlo_flops": flops, "hlo_bytes": nbytes, "collective_bytes": coll,
        "per_period_flops": f2["flops"] - f1["flops"],
        "n_periods": k_full,
        "model_flops": mf,
        "useful_flop_ratio": mf / (flops * chips) if flops else 0.0,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "step_time_bound_s": step,
        "roofline_fraction": ideal / step if step else 0.0,
        "probe_compile_s": f1["compile_seconds"] + f2["compile_seconds"],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true",
                    help="probe the 512-chip mesh (default: single pod)")
    ap.add_argument("--out", default="experiments/roofline")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        cells = [(a, s.name) for a in ARCH_IDS for s in shape_cells(a)]
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape_name in cells:
        if shape_name in skipped_cells(arch):
            continue
        tag = f"{arch}__{shape_name}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path) and not args.force:
            print(f"CACHED {tag}")
            continue
        print(f"PROBE {tag} ...", flush=True)
        try:
            rec = probe_cell(arch, shape_name, args.multipod)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"  {rec['dominant']:<10} comp={rec['t_compute_s']*1e3:.2f}ms "
                  f"mem={rec['t_memory_s']*1e3:.2f}ms "
                  f"coll={rec['t_collective_s']*1e3:.2f}ms "
                  f"rf={rec['roofline_fraction']:.3f}")
        except Exception as e:  # noqa: BLE001
            failures.append((tag, repr(e)))
            print(f"  FAIL {tag}: {e}")
    if failures:
        for t, e in failures:
            print("FAILED:", t, e)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
