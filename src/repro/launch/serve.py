"""Serving driver: continuous-batching engine over a trained/initialised
model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \\
        --requests 8 --max-new 12
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \\
        --autoconfigure --machine gap9-fc --slo-p99 0.35 --rate 5 \\
        --trace /tmp/trace.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro import obs
from repro.configs import ARCH_IDS, get_config
from repro.checkpoint.manager import CheckpointManager
from repro.models.common import HOST_MESH, split_params
from repro.models.model import LM
from repro.serving.engine import Request, ServingEngine
from repro.serving.resilience import retry_with_backoff


def serve_demo(arch: str, *, smoke: bool = True, n_requests: int = 8,
               max_new: int = 12, max_batch: int = 4, max_len: int = 256,
               ckpt_dir: str | None = None, seed: int = 0,
               autoconfigure: bool = False, machine: str | None = None,
               memory: bool = True, precisions=(), slo=None, traffic=None,
               deadline_s: float | None = None, queue_limit: int | None = None,
               faults=None, on_truncate: str = "raise",
               trace_path: str | None = None,
               trace_out: str | None = None) -> dict:
    if trace_out:
        # span tracing costs nothing until enabled; a Chrome-trace export
        # without spans would be instants-only, so asking for one opts in
        obs.enable()
    cfg = get_config(arch, smoke=smoke)
    lm = LM(cfg, HOST_MESH)
    values, _ = split_params(lm.init(jax.random.key(seed)))
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir)
        step, state, _ = mgr.restore_latest({"params": values})
        if state is not None:
            values = state["params"]
            print(f"serving checkpoint step {step}")

    if autoconfigure:
        # rank the (machine x dtype x batch) deployment grid — memory-
        # infeasible cells pruned against each machine's budget — and let
        # the analytic model pick machine/max_batch/plans.  With an SLO,
        # the surviving cells are additionally run through the discrete-
        # event simulator (repro.simulate) and the pick is by *simulated*
        # SLO attainment rather than peak throughput.
        eng = ServingEngine.autoconfigure(lm, values, machine=machine,
                                          dtypes=("bf16", "int8"),
                                          batches=(1, 2, 4, 8, 16),
                                          max_len=max_len, memory=memory,
                                          precisions=precisions,
                                          slo=slo, traffic=traffic,
                                          faults=faults,
                                          deadline_s=deadline_s,
                                          queue_limit=queue_limit)
        ac = eng.autoconfig
        print(eng.deployment_report.table(limit=8))
        print(f"autoconfigured: max_batch={ac['max_batch']} "
              f"dtype={ac['dtype']} machine={ac['machine']} "
              f"({ac['predicted_tokens_per_second']:.0f} pred tok/s, "
              f"{ac['memory_headroom_bytes'] / 2**30:.2f} GiB headroom)")
        if "slo" in ac:
            sim = ac["slo"]["sim"]
            mode = "robust SLO" if ac["slo"].get("faults") else "SLO"
            under = ac["slo"]["traffic"] + (
                f" + faults={ac['slo']['faults']}"
                if ac["slo"].get("faults") else "")
            print(f"  {mode} mode ({under}): simulated p99 "
                  f"latency {sim['latency']['p99']:.4g}s, goodput "
                  f"{sim['goodput_tps']:.4g} tok/s, "
                  f"{len(ac['slo']['rejected'])} cell(s) rejected")
    else:
        eng = ServingEngine(lm, values, max_batch=max_batch, max_len=max_len,
                            deadline_s=deadline_s, queue_limit=queue_limit)
    rng = np.random.default_rng(seed)
    t0 = time.perf_counter()
    for i in range(n_requests):
        plen = int(rng.integers(3, 12))
        prompt = rng.integers(0, cfg.vocab_size, size=plen).tolist()
        req = Request(rid=i, prompt=prompt, max_new_tokens=max_new)
        if queue_limit is None:
            eng.submit(req)
        else:
            # bounded queue: on QueueFullError the retry's backpressure is
            # "let the server catch up" — step the engine until a queue
            # slot frees instead of sleeping wall-clock
            def _catch_up(_dt):
                for _ in range(64):
                    eng.step()
                    if len(eng.queue) < queue_limit:
                        return
            retry_with_backoff(lambda: eng.submit(req), sleep=_catch_up)
    done = eng.run_until_drained(on_truncate=on_truncate)
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s)")
    perf = eng.perf_report()
    if "measured_requests" in perf:
        m = perf["measured_requests"]
        print(f"  measured: mean latency {m['latency_s']['mean']:.3f}s, "
              f"p95 {m['latency_s']['p95']:.3f}s, mean wait "
              f"{m['wait_s']['mean']:.3f}s")
    res = perf.get("resilience")
    if res:
        deg = res["degraded"]
        print(f"  resilience: shed {res['shed']['count']} "
              f"({res['shed']['causes'] or 'none'}), expired "
              f"{res['expired']}, rejected submits "
              f"{res['rejected_submits']}, rung "
              f"{deg['rung'] or 'nominal'} "
              f"({len(deg['events'])} ladder event(s))")
        if res.get("truncated"):
            print(f"  WARNING: drain truncated with "
                  f"{res['truncated']['active']} active / "
                  f"{res['truncated']['queued']} queued after "
                  f"{res['truncated']['max_steps']} steps")
    for r in sorted(done, key=lambda r: r.rid)[:4]:
        print(f"  req{r.rid}: prompt[:6]={r.prompt[:6]} -> {r.generated}")
    if trace_path:
        with open(trace_path, "w") as f:
            json.dump(eng.trace_json(), f, indent=1, sort_keys=True)
        print(f"wrote event trace to {trace_path} "
              f"(replay: python -m repro.simulate replay --trace "
              f"{trace_path})")
    print(f"  drift: {perf['drift_status']} "
          f"(predicted step {perf['predicted_gemm_seconds_per_step']:.3g}s "
          f"vs measured — see perf_report()['drift'])")
    if trace_out:
        doc = obs.save_chrome_trace(trace_out)
        print(f"wrote Chrome trace to {trace_out} "
              f"({doc['metadata']['spans']} spans, "
              f"{doc['metadata']['events']} events; open in "
              f"chrome://tracing or ui.perfetto.dev)")
    return {"requests": len(done), "tokens": toks, "seconds": dt}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--autoconfigure", action="store_true",
                    help="pick machine/max_batch/plans by ranking the "
                         "memory-feasible (machine x dtype x batch) grid "
                         "instead of using --max-batch")
    ap.add_argument("--machine", default=None,
                    help="machine name/glob for --autoconfigure "
                         "(e.g. tpu-v5e, 'tpu-v5e*', 'zoo/*')")
    ap.add_argument("--precision", nargs="*", default=None,
                    metavar="AxB[->ACC][@kv=KV]",
                    help="mixed-precision what-if cells for "
                         "--autoconfigure's ranking table, e.g. "
                         "int4xint8->int32")
    ap.add_argument("--no-memory", action="store_true",
                    help="autoconfigure on throughput alone, ignoring the "
                         "deployment-memory budget")
    ap.add_argument("--slo-p99", type=float, default=None,
                    help="with --autoconfigure: pick by simulated SLO "
                         "attainment under Poisson traffic instead of "
                         "peak throughput (p99 latency bound, seconds)")
    ap.add_argument("--rate", type=float, default=None,
                    help="arrival rate (req/s) for the --slo-p99 traffic "
                         "scenario; default derives one from the report")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request latency deadline, seconds — arms "
                         "deadline-aware admission/shedding")
    ap.add_argument("--queue-limit", type=int, default=None,
                    help="bounded submit queue; overflow raises "
                         "QueueFullError and the driver retries with "
                         "backpressure (engine steps)")
    ap.add_argument("--faults", default=None,
                    help="fault scenario name for robust --autoconfigure "
                         "(e.g. throttle20; implies robust SLO mode)")
    ap.add_argument("--on-truncate", choices=["raise", "report"],
                    default="raise",
                    help="partial-drain policy: raise (default) or record "
                         "the truncation in perf_report and keep going")
    ap.add_argument("--trace", default=None,
                    help="write the engine's event trace JSON here "
                         "(consumed by python -m repro.simulate replay)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace/Perfetto JSON of the run "
                         "(spans + events; enables span tracing)")
    a = ap.parse_args()
    slo = traffic = None
    if a.slo_p99 is not None:
        from repro.simulate import SLO, PoissonTraffic
        slo = SLO(p99_latency_s=a.slo_p99)
        if a.rate is not None:
            traffic = PoissonTraffic(rate=a.rate, prompt_len=16,
                                     decode_len=a.max_new)
    elif a.faults is not None:
        ap.error("--faults needs --slo-p99 (robust autoconfiguration is "
                 "SLO attainment under perturbation)")
    serve_demo(a.arch, n_requests=a.requests, max_new=a.max_new,
               max_batch=a.max_batch, max_len=a.max_len, ckpt_dir=a.ckpt_dir,
               autoconfigure=a.autoconfigure, machine=a.machine,
               memory=not a.no_memory, precisions=a.precision or (),
               slo=slo, traffic=traffic,
               deadline_s=a.deadline, queue_limit=a.queue_limit,
               faults=a.faults, on_truncate=a.on_truncate,
               trace_path=a.trace, trace_out=a.trace_out)


if __name__ == "__main__":
    main()
