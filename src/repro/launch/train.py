"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \\
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Real-hardware runs use the production mesh; on the CPU container the driver
runs smoke-scale models end-to-end (the quickstart example trains one to
visibly decreasing loss).  The loop wires together every fault-tolerance
feature: periodic atomic checkpoints, preemption handler, deterministic
resume of the data stream, straggler watchdog.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ParallelConfig, ShapeConfig, TrainConfig
from repro.data import DataIterator
from repro.models.common import HOST_MESH, split_params
from repro.models.model import LM
from repro.runtime.fault import StepWatchdog
from repro.runtime.train_lib import init_train_state, make_train_step


def train(arch: str, *, smoke: bool = True, steps: int = 100, batch: int = 8,
          seq: int = 128, ckpt_dir: str | None = None, ckpt_every: int = 50,
          lr: float = 3e-3, microbatches: int = 1, log_every: int = 10,
          seed: int = 0):
    cfg = get_config(arch, smoke=smoke)
    shape = ShapeConfig("custom", "train", seq, batch)
    tcfg = TrainConfig(lr=lr, warmup_steps=max(steps // 20, 5),
                       total_steps=steps, checkpoint_every=ckpt_every)
    pcfg = ParallelConfig(microbatches=microbatches)
    lm = LM(cfg, HOST_MESH)

    params, pspecs, opt, ospecs = init_train_state(lm, tcfg,
                                                   jax.random.key(seed))
    data = DataIterator(cfg, shape, seed=seed)
    step = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, keep=3)
        mgr.install_preemption_handler()
        latest = mgr.latest_step()
        if latest is not None:
            step, state, extra = mgr.restore_latest({"params": params,
                                                     "opt": opt})
            params, opt = state["params"], state["opt"]
            data.load_state_dict(extra["data"])
            print(f"resumed from step {step}")

    train_step = jax.jit(make_train_step(lm, tcfg, pcfg),
                         donate_argnums=(0, 1))
    wd = StepWatchdog()
    losses = []
    while step < steps:
        batch_data = next(data)
        wd.start()
        params, opt, metrics = train_step(params, opt, batch_data)
        loss = float(metrics["loss"])
        wd.stop()
        losses.append(loss)
        step += 1
        if step % log_every == 0 or step == steps:
            print(f"step {step:5d} loss {loss:.4f} lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f}")
        if mgr and (step % ckpt_every == 0 or mgr.preempted):
            mgr.save(step, {"params": params, "opt": opt},
                     extra={"data": data.state_dict(),
                            "watchdog": wd.summary()})
            if mgr.preempted:
                print(f"preempted: emergency checkpoint at step {step}")
                return {"step": step, "losses": losses, "preempted": True}
    if mgr:
        mgr.save(step, {"params": params, "opt": opt},
                 extra={"data": data.state_dict(),
                        "watchdog": wd.summary()})
    print("watchdog:", wd.summary())
    return {"step": step, "losses": losses, "preempted": False,
            "params": params}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    out = train(a.arch, smoke=a.smoke, steps=a.steps, batch=a.batch,
                seq=a.seq, ckpt_dir=a.ckpt_dir, ckpt_every=a.ckpt_every,
                lr=a.lr, microbatches=a.microbatches, seed=a.seed)
    first, last = np.mean(out["losses"][:5]), np.mean(out["losses"][-5:])
    print(f"loss: first5={first:.4f} last5={last:.4f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
