import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST precede every other import (including
# ``from repro...``) — jax locks the device count on first init.

# Multi-pod dry-run docstring follows (kept as module comment because the
# XLA_FLAGS lines must be the first statements).
_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we build the jitted step (train / prefill / decode) with full
in/out shardings, ``.lower()`` it against ShapeDtypeStruct inputs, and
``.compile()`` on the 512-placeholder-device CPU backend — proving the
distribution config is coherent (sharding divisibility, collective layouts,
SPMD partitioning) without hardware.  ``memory_analysis`` and
``cost_analysis`` plus the HLO collective bytes feed EXPERIMENTS.md
§Dry-run / §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all [--mesh both] [--out experiments/dryrun]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (
    ARCH_IDS,
    SUBQUADRATIC,
    get_config,
    input_specs,
    shape_cells,
    skipped_cells,
)
from repro.configs.base import SHAPES, TrainConfig
from repro.core.roofline import collective_bytes, cost_analysis_dict
from repro.launch.mesh import make_production_mesh
from repro.models.model import LM
from repro.runtime.serve_lib import (
    abstract_cache,
    make_decode_step,
    serve_plan,
)
from repro.runtime.sharding import (
    batch_specs,
    default_parallel,
    mesh_info,
    shardings_for,
    use_mesh,
)
from repro.runtime.train_lib import abstract_train_state, make_train_step


def _sds_with_sharding(tree_sds, tree_spec, mesh):
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        tree_sds, tree_spec)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             donate: bool = True, cfg=None, unroll: bool = False,
             pcfg=None) -> dict:
    """Lower + compile one cell; returns the record for EXPERIMENTS.md.

    ``cfg``/``unroll``/``pcfg`` overrides serve the roofline probes
    (launch/roofline_probe.py)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    pcfg = pcfg or default_parallel(arch)
    minfo = mesh_info(mesh, fsdp=pcfg.fsdp)
    lm = LM(cfg, minfo, unroll=unroll)
    tcfg = TrainConfig()
    key = jax.random.key(0)
    t0 = time.time()

    with use_mesh(mesh):
        if shape.kind == "train":
            params, pspecs, opt, ospecs = abstract_train_state(lm, tcfg, key)
            bspecs = batch_specs(cfg, shape, minfo)
            batch_sds = {
                k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                        sharding=NamedSharding(mesh, bspecs[k]))
                for k, v in input_specs(cfg, shape).items()}
            params_sds = _sds_with_sharding(params, pspecs, mesh)
            opt_sds = _sds_with_sharding(opt, ospecs, mesh)
            step_fn = make_train_step(lm, tcfg, pcfg)
            jitted = jax.jit(
                step_fn,
                in_shardings=(shardings_for(mesh, pspecs),
                              shardings_for(mesh, ospecs),
                              shardings_for(mesh, bspecs)),
                donate_argnums=(0, 1) if donate else (),
            )
            lowered = jitted.lower(params_sds, opt_sds, batch_sds)
        elif shape.kind == "prefill":
            params, pspecs, _, _ = abstract_train_state(lm, tcfg, key)
            bspecs = batch_specs(cfg, shape, minfo)
            batch_sds = {
                k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                        sharding=NamedSharding(mesh, bspecs[k]))
                for k, v in input_specs(cfg, shape).items()}
            params_sds = _sds_with_sharding(params, pspecs, mesh)
            jitted = jax.jit(lm.prefill,
                             in_shardings=(shardings_for(mesh, pspecs),
                                           shardings_for(mesh, bspecs)))
            lowered = jitted.lower(params_sds, batch_sds)
        else:  # decode
            params, pspecs, _, _ = abstract_train_state(lm, tcfg, key)
            plan = serve_plan(cfg, shape, minfo)
            caches, cspecs = abstract_cache(
                lm, shape.global_batch, shape.seq_len,
                seq_shard=plan["seq_shard"] and pcfg.seq_shard_long_kv,
                batch_shard=plan["batch_shard"])
            bspecs = batch_specs(cfg, shape, minfo)
            ins = input_specs(cfg, shape)
            batch_sds = {
                k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                        sharding=NamedSharding(mesh, bspecs[k]))
                for k, v in ins.items()}
            params_sds = _sds_with_sharding(params, pspecs, mesh)
            cache_sds = _sds_with_sharding(caches, cspecs, mesh)
            step_fn = make_decode_step(lm)
            jitted = jax.jit(
                step_fn,
                in_shardings=(shardings_for(mesh, pspecs),
                              shardings_for(mesh, cspecs),
                              NamedSharding(mesh, bspecs["token"]),
                              NamedSharding(mesh, P())),
                donate_argnums=(1,) if donate else (),
            )
            lowered = jitted.lower(params_sds, cache_sds,
                                   batch_sds["token"],
                                   jax.ShapeDtypeStruct((), jnp.int32))

        compiled = lowered.compile()

    cost = cost_analysis_dict(compiled)
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "ok": True,
        "compile_seconds": round(time.time() - t0, 1),
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll["_total"],
        "collective_count": coll["_count"],
        "collective_detail": {k: v for k, v in coll.items()
                              if not k.startswith("_") and v},
        "model_params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "n_layers": cfg.n_layers,
        "unrolled": unroll,
        "fsdp": pcfg.fsdp,
    }
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                record[attr] = int(v)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true",
                    help="recompute cached cells")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    if args.all:
        cells = [(a, s.name) for a in ARCH_IDS for s in shape_cells(a)]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape_name in cells:
        if shape_name in skipped_cells(arch):
            print(f"SKIP {arch} x {shape_name} (full attention; DESIGN.md §8)")
            continue
        for multi_pod in meshes:
            tag = f"{arch}__{shape_name}__{'multipod' if multi_pod else 'pod'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path) and not args.force:
                print(f"CACHED {tag}")
                continue
            print(f"RUN {tag} ...", flush=True)
            try:
                rec = run_cell(arch, shape_name, multi_pod)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"  OK flops={rec['flops']:.3e} "
                      f"coll={rec['collective_bytes']/1e9:.2f}GB "
                      f"({rec['compile_seconds']}s)")
            except Exception as e:  # noqa: BLE001 — record and continue
                failures.append((tag, repr(e)))
                print(f"  FAIL {tag}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nall requested cells compiled OK")


if __name__ == "__main__":
    main()
