"""Batched-vs-scalar equivalence for the design-space sweep engine.

The batch engine (core.tpu_model.estimate_batch / core.simulator
.simulate_batch and the bulk planning built on them) claims *bit-identical*
totals and *exactly equal* argmin selections vs the scalar simulators.
These tests pin that claim: property tests on randomized problems, the
full all-arch + Table-2 acceptance grids, and the bulk façade
(plan_many / sweep / plan_model_gemms).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import gemm
from repro.configs import ARCH_IDS, get_config
from repro.core.autotune import (
    candidate_tiles,
    model_gemm_shapes,
    tune_batch,
    tune_scalar,
)
from repro.core.hardware import GAP8_FC, TPU_V5E
from repro.core.mobilenet import TABLE2
from repro.core.simulator import (
    best_microkernel_batch,
    best_microkernel_scalar,
    search_batch,
    simulate,
    simulate_batch,
)
from repro.core.tpu_model import (
    GemmShape,
    GridOrder,
    TileConfig,
    estimate,
    estimate_batch,
    peak_rate,
)
from repro.core.tpu_model import DTYPE_BYTES, SUBLANE
from repro.core.variants import Problem, Variant


@pytest.fixture(autouse=True)
def _fresh_cache():
    gemm.clear_plan_cache()
    yield
    gemm.clear_plan_cache()


# The scalar reference loops (the pre-PR algorithms) live next to the batch
# engines as `tune_scalar` / `best_microkernel_scalar` — one shared oracle
# for these tests and benchmarks/bench_planner.py.


def _scalar_tune(shape, overlap=True, machine=TPU_V5E):
    d = tune_scalar(shape, overlap, machine)
    return d.seconds, d.tile


def _scalar_best_mk(machine, variant, prob, policy="analytic"):
    return best_microkernel_scalar(machine, variant, prob, policy=policy)


# ---------------------------------------------------------------------------
# TPU engine: estimate_batch / tune_batch == the scalar loop
# ---------------------------------------------------------------------------

dims = st.integers(min_value=1, max_value=4096)
dtypes = st.sampled_from(["bf16", "f32", "int8"])


@settings(max_examples=25, deadline=None)
@given(m=dims, n=dims, k=dims, dtype=dtypes,
       overlap=st.sampled_from([True, False]))
def test_tune_batch_matches_scalar_loop(m, n, k, dtype, overlap):
    shape = GemmShape(m, n, k, dtype)
    sec, tile = _scalar_tune(shape, overlap)
    d = tune_batch([shape], overlap, cache=False)[0]
    assert d.tile == tile
    assert d.seconds == sec          # bit-identical, not just approx


def test_estimate_batch_fields_bit_identical():
    shapes = [GemmShape(100, 60, 250), GemmShape(8, 8, 8),
              GemmShape(4096, 4096, 4096), GemmShape(333, 4097, 129, "f32"),
              GemmShape(64, 128, 8192, "int8"),
              GemmShape(4096, 152064, 8192)]
    for shape in shapes:
        tiles = candidate_tiles(shape)[:80]
        if not tiles:
            tiles = [TileConfig(8, 128, 128)]
        bm = np.array([t.bm for t in tiles], np.int64)
        bn = np.array([t.bn for t in tiles], np.int64)
        bk = np.array([t.bk for t in tiles], np.int64)
        inner = np.array([t.order is GridOrder.K_INNER for t in tiles])
        batch = estimate_batch(
            np.array([[shape.m]]), np.array([[shape.n]]),
            np.array([[shape.k]]), np.array([[DTYPE_BYTES[shape.dtype]]]),
            np.array([[SUBLANE[shape.dtype]]]),
            np.array([[peak_rate(shape.dtype)]]),
            bm, bn, bk, inner, accumulate=shape.accumulate)
        for ci, t in enumerate(tiles):
            c = estimate(shape, t)
            assert batch.hbm_bytes[0, ci] == c.hbm_bytes, (shape, t)
            assert batch.vmem_bytes[0, ci] == c.vmem_bytes
            assert batch.vmem_peak[0, ci] == c.vmem_peak
            assert batch.t_compute[0, ci] == c.t_compute
            assert batch.mxu_efficiency[0, ci] == c.mxu_efficiency
            assert batch.total(True)[0, ci] == c.total(True)
            assert batch.total(False)[0, ci] == c.total(False)


def test_tune_batch_fallback_tiny_shape():
    """Shapes with no feasible lattice point get the scalar fallback tile."""
    shape = GemmShape(1, 1, 1, "bf16")
    sec, tile = _scalar_tune(shape)
    d = tune_batch([shape], cache=False)[0]
    assert d.tile == tile and d.seconds == sec


def test_tune_batch_dedupes_and_memoises():
    s = GemmShape(64, 96, 128, "bf16")
    a, b = tune_batch([s, s])
    assert a is b
    (c,) = tune_batch([s])        # memoised across calls
    assert c is a


def test_all_arch_selections_identical_to_scalar():
    """Acceptance: batched and scalar paths select identical tiles on every
    shape in model_gemm_shapes for all arch configs."""
    shapes = []
    for arch in ARCH_IDS:
        shapes += model_gemm_shapes(get_config(arch))
    unique = list(dict.fromkeys(shapes))
    decisions = tune_batch(unique, cache=False)
    for s, d in zip(unique, decisions):
        sec, tile = _scalar_tune(s)
        assert d.tile == tile, s
        assert d.seconds == sec, s


# ---------------------------------------------------------------------------
# GAP8 engine: simulate_batch / best_microkernel_batch == the scalar loop
# ---------------------------------------------------------------------------

gap_dims = st.integers(min_value=1, max_value=3000)
policies = st.sampled_from(["analytic", "padded"])


@settings(max_examples=25, deadline=None)
@given(m=gap_dims, n=gap_dims, k=gap_dims, policy=policies)
def test_gap8_batch_matches_scalar_loop(m, n, k, policy):
    p = Problem(m, n, k)
    for v in Variant:
        s = _scalar_best_mk(GAP8_FC, v, p, policy)
        b = best_microkernel_batch(GAP8_FC, v, [p], policy=policy)[0]
        assert b.micro_kernel == s.micro_kernel, (v, p)
        assert b.total == s.total
    sb = search_batch(GAP8_FC, [p], policy=policy)[0]
    ss = min((_scalar_best_mk(GAP8_FC, v, p, policy) for v in Variant),
             key=lambda c: c.total)
    assert (sb.variant, sb.micro_kernel) == (ss.variant, ss.micro_kernel)


def test_simulate_batch_totals_bit_identical():
    probs = [TABLE2[0].problem, TABLE2[9].problem, Problem(100, 60, 250),
             Problem(1, 1, 1), Problem(2048, 2048, 2048)]
    for policy in ("analytic", "padded"):
        for v in Variant:
            batch = simulate_batch(GAP8_FC, probs, v, policy=policy)
            for pi, p in enumerate(probs):
                for ci, mk in enumerate(batch.micro_kernels):
                    want = simulate(GAP8_FC, v, mk, p, policy=policy).total
                    assert batch.total[pi, ci] == want, (policy, v, p, mk)


def test_table2_regression_through_sweep():
    """Acceptance: the bulk sweep() reproduces the scalar Table-2 winners on
    every layer and keeps the documented paper-agreement levels."""
    probs = [row.problem for row in TABLE2]
    res = gemm.sweep(probs, backends=["analytic-gap8"],
                     variants=list(Variant), cache=False)
    assert len(res) == len(TABLE2) * 3
    agree = {v: 0 for v in Variant}
    for v in Variant:
        rows = res.filter(variant=v.value)
        assert len(rows) == len(TABLE2)
        for t2row, r in zip(TABLE2, rows):
            scalar = _scalar_best_mk(GAP8_FC, v, t2row.problem)
            assert r.plan.selection.micro_kernel == scalar.micro_kernel
            assert r.seconds == scalar.total
            paper = t2row.best[v.value]
            agree[v] += (scalar.micro_kernel.rows, scalar.micro_kernel.cols) \
                == (paper.rows, paper.cols)
    assert agree[Variant.B3A2C0] >= 13
    assert agree[Variant.B3C2A0] >= 16
    assert agree[Variant.C3B2A0] >= 7


# ---------------------------------------------------------------------------
# Bulk façade: plan_many / sweep / plan_model_gemms
# ---------------------------------------------------------------------------


def test_plan_many_dedupes_and_preserves_order():
    probs = [(64, 64, 64), (128, 64, 64), (64, 64, 64), (64, 64, 64)]
    plans = gemm.plan_many(probs, backend="analytic-tpu")
    assert [(p.problem.m, p.problem.n) for p in plans] == \
        [(64, 64), (128, 64), (64, 64), (64, 64)]
    assert plans[0] is plans[2] is plans[3]
    stats = gemm.plan_cache_stats()
    assert stats["deduped"] == 2 and stats["size"] == 2


def test_plan_many_matches_scalar_plan():
    probs = [(256, 128, 512), (64, 64, 64), (100, 70, 130)]
    many = gemm.plan_many(probs, backend="analytic-tpu")
    gemm.clear_plan_cache()
    singles = [gemm.plan(p, backend="analytic-tpu") for p in probs]
    for a, b in zip(many, singles):
        assert a.selection == b.selection
        assert a.predicted_seconds == b.predicted_seconds
        assert a.provenance == b.provenance


def test_plan_many_uses_cache_and_manifest(tmp_path):
    path = str(tmp_path / "tiles.json")
    first = gemm.plan_many([(512, 512, 512)], backend="analytic-tpu")
    assert first[0].provenance["source"] == "search"
    gemm.save_cache(path)
    gemm.clear_plan_cache()
    gemm.warm_cache(path)
    warmed = gemm.plan_many([(512, 512, 512), (512, 512, 512)],
                            backend="analytic-tpu")
    assert warmed[0] is warmed[1]
    assert warmed[0].provenance["source"] == "manifest"
    assert warmed[0].selection == first[0].selection


def test_plan_many_cache_false_still_dedupes_evaluation():
    probs = [(96, 96, 96)] * 3
    plans = gemm.plan_many(probs, backend="analytic-gap8", cache=False)
    assert plans[0] is plans[1] is plans[2]
    assert gemm.plan_cache_stats()["size"] == 0


def test_sweep_grid_and_best():
    res = gemm.sweep([(64, 64, 64), (256, 256, 256)],
                     backends=["analytic-tpu"],
                     policies=["analytic"],
                     overlap=True)
    assert len(res) == 2
    assert res.stats["grid_points"] == 2
    best = res.best((64, 64, 64))
    assert (best.problem.m, best.problem.n, best.problem.k) == (64, 64, 64)
    per = res.best_per_problem()
    assert len(per) == 2
    js = res.to_json()
    assert len(js["rows"]) == 2 and "seconds" in js["rows"][0]
    assert "backend@machine" in res.table().splitlines()[0] or res.table()


def test_sweep_gap8_variant_axis_matches_pinned_plans():
    prob = TABLE2[9].problem     # layer 10
    res = gemm.sweep([prob], backends=["analytic-gap8"],
                     variants=list(Variant))
    assert len(res) == 3
    for r in res:
        pinned = gemm.plan(prob, backend="analytic-gap8",
                           variant=Variant(r.variant))
        assert r.plan is pinned  # same cache entry: identical key
    win = res.best(prob)
    assert win.seconds == min(r.seconds for r in res)


def test_sweep_collapses_inapplicable_axes_per_backend():
    """Mixed-backend sweeps: GAP8-only axes (variants) must not stamp
    duplicate, mislabeled rows onto backends whose search ignores them."""
    res = gemm.sweep([(512, 512, 512)],
                     backends=["analytic-tpu", "analytic-gap8"],
                     variants=list(Variant))
    tpu_rows = res.filter(backend="analytic-tpu")
    gap_rows = res.filter(backend="analytic-gap8")
    assert len(tpu_rows) == 1 and tpu_rows[0].variant is None
    assert len(gap_rows) == 3
    assert sorted(r.variant for r in gap_rows) == \
        sorted(v.value for v in Variant)


def test_plan_model_gemms_identical_to_scalar_tune():
    """Acceptance: ServingEngine's frozen decode plans (plan_model_gemms via
    the bulk path) select the same tiles the scalar search would — so
    perf_report() output is unchanged for a fixed config."""
    for arch in ("qwen2-1.5b", "granite-moe-3b-a800m"):
        cfg = get_config(arch, smoke=True)
        for tokens in (4, 4096):
            plans = gemm.plan_model_gemms(cfg, tokens=tokens,
                                          backend="analytic-tpu")
            shapes = model_gemm_shapes(cfg, tokens=tokens)
            assert len(plans) == len(shapes)
            for p, s in zip(plans, shapes):
                sec, tile = _scalar_tune(s)
                assert p.selection == tile
                assert p.predicted_seconds == sec
