"""Tests for the TPU adaptation of the simulator (tpu_model + autotune)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.autotune import Manifest, candidate_tiles, tune
from repro.core.hardware import TPU_V5E, V5E_VMEM_BYTES
from repro.core.tpu_model import (
    GemmShape,
    GridOrder,
    TileConfig,
    estimate,
    mxu_efficiency,
    vmem_required,
)


def test_k_inner_beats_k_outer_on_c_traffic():
    """The paper's B3A2C0 conclusion (fewer stores of C) transfers to the
    Pallas grid order: k-innermost writes each C block once."""
    s = GemmShape(4096, 4096, 4096, "bf16")
    ti = TileConfig(512, 512, 512, GridOrder.K_INNER)
    to = TileConfig(512, 512, 512, GridOrder.K_OUTER)
    ci, co = estimate(s, ti), estimate(s, to)
    assert ci.hbm_bytes < co.hbm_bytes
    assert ci.total(overlap=True) < co.total(overlap=True)
    assert ci.total(overlap=False) < co.total(overlap=False)


def test_overlap_no_worse_than_paper_mode():
    """Double buffering (paper future work) can only help."""
    s = GemmShape(2048, 2048, 2048, "bf16")
    for t in candidate_tiles(s)[:50]:
        c = estimate(s, t)
        assert c.total_overlapped <= c.total_no_overlap + 1e-12


def test_vmem_budget_respected():
    s = GemmShape(8192, 8192, 8192, "bf16")
    for t in candidate_tiles(s):
        assert vmem_required(s, t) <= 0.75 * V5E_VMEM_BYTES


def test_mxu_efficiency_penalises_misalignment():
    s = GemmShape(4096, 4096, 4096, "bf16")
    aligned = mxu_efficiency(s, TileConfig(256, 256, 256))
    assert aligned == pytest.approx(1.0)
    # a 100-wide lane block pads to 128
    assert mxu_efficiency(s, TileConfig(256, 100, 256)) == pytest.approx(100 / 128)


def test_tune_square_gemm_near_roofline():
    d = tune(GemmShape(4096, 4096, 4096, "bf16"))
    assert d.cost.roofline_fraction() > 0.95
    assert d.tile.order is GridOrder.K_INNER


def test_tune_memory_bound_gemm_reports_low_fraction():
    # decode-style skinny GEMM: m=8 rows
    d = tune(GemmShape(8, 4096, 4096, "bf16"))
    assert d.cost.roofline_fraction() < 0.25
    assert d.cost.t_hbm > d.cost.t_compute


def test_manifest_roundtrip(tmp_path):
    p = str(tmp_path / "tiles.json")
    m = Manifest(p)
    d = tune(GemmShape(1024, 1024, 1024, "bf16"))
    m.record(d)
    m.save()
    m2 = Manifest(p)
    t = m2.lookup(GemmShape(1024, 1024, 1024, "bf16"))
    assert t == d.tile
    assert m2.lookup(GemmShape(3, 5, 7, "bf16")) is None


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(128, 8192), n=st.integers(128, 8192), k=st.integers(128, 8192),
    dt=st.sampled_from(["bf16", "int8", "f32"]),
)
def test_estimate_invariants(m, n, k, dt):
    s = GemmShape(m, n, k, dt)
    t = TileConfig(256, 256, 256)
    c = estimate(s, t)
    # compute time bounded below by peak
    assert c.t_compute >= s.flops / TPU_V5E.arith_rate["bf16" if dt == "f32" else dt] - 1e-12
    # HBM traffic at least compulsory
    nb = {"int8": 1, "bf16": 2, "f32": 4}[dt]
    assert c.hbm_bytes >= nb * (m * k + k * n + m * n) - 1e-6
    assert 0.0 < c.mxu_efficiency <= 1.0
    assert c.roofline_fraction() <= 1.0 + 1e-9
