"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
executed with interpret=True (CPU container; TPU is the deploy target)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.tpu_model import GridOrder, TileConfig
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.gemm import gemm_k_inner, gemm_k_outer
from repro.kernels.grouped_gemm import grouped_gemm_kernel
from repro.kernels.ops import matmul

RNG = np.random.default_rng(7)


def _rand(shape, dtype):
    if dtype == "int8":
        return jnp.array(RNG.integers(-100, 100, size=shape), jnp.int8)
    return jnp.array(RNG.normal(size=shape), dtype=dtype)


# ---------------------------------------------------------------------------
# GEMM: divisible-shape kernel sweeps
# ---------------------------------------------------------------------------

GEMM_CASES = [
    (128, 128, 128, "float32"), (256, 128, 512, "float32"),
    (128, 384, 256, "bfloat16"), (512, 256, 128, "bfloat16"),
    (128, 128, 256, "int8"), (256, 512, 128, "int8"),
]


@pytest.mark.parametrize("m,n,k,dt", GEMM_CASES)
def test_gemm_k_inner_matches_ref(m, n, k, dt):
    a, b = _rand((m, k), dt), _rand((k, n), dt)
    got = gemm_k_inner(a, b, tile=TileConfig(64, 128, 64), interpret=True)
    want = ref.gemm_ref(a, b)
    if dt == "int8":
        assert got.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    else:
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=2e-2 if dt == "bfloat16" else 1e-5,
                                   atol=2e-2 if dt == "bfloat16" else 1e-4)


@pytest.mark.parametrize("m,n,k,dt", GEMM_CASES[:4])
def test_gemm_k_outer_matches_streamed_ref(m, n, k, dt):
    """k_outer == the streamed oracle exactly (same per-pass rounding)."""
    a, b = _rand((m, k), dt), _rand((k, n), dt)
    c0 = _rand((m, n), dt)
    got = gemm_k_outer(a, b, c0, tile=TileConfig(64, 128, 64,
                                                 GridOrder.K_OUTER),
                       interpret=True)
    want = ref.gemm_ref_streamed(a, b, c0, bk=64)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_k_outer_step_kernel_constructed_once_and_reused():
    """The k-outer step kernel is built once per (shape, tile, dtype) config
    and reused across the k loop and across calls."""
    from repro.kernels import gemm as gemm_mod
    gemm_mod._k_step_call.cache_clear()
    m, n, k = 128, 128, 256
    a, b = _rand((m, k), "float32"), _rand((k, n), "float32")
    c0 = _rand((m, n), "float32")
    tile = TileConfig(64, 64, 64, GridOrder.K_OUTER)
    got = gemm_k_outer(a, b, c0, tile=tile, interpret=True)
    info = gemm_mod._k_step_call.cache_info()
    assert info.misses == 1 and info.hits == 0  # 4 k-steps, one constructor
    got2 = gemm_k_outer(a, b, c0, tile=tile, interpret=True)
    info = gemm_mod._k_step_call.cache_info()
    assert info.misses == 1 and info.hits == 1  # second call reuses it
    want = ref.gemm_ref_streamed(a, b, c0, bk=64)
    for out in (got, got2):
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=1e-5, atol=1e-5)


def test_k_outer_streaming_costs_precision_in_bf16():
    """Numerical finding: the C-streamed variant rounds C to bf16 every k
    pass; the output-stationary variant (f32 VMEM accumulator) does not —
    the numerical face of the paper's 'B3A2C0 reduces stores of C'."""
    m, n, k = 128, 256, 512
    a, b = _rand((m, k), "bfloat16"), _rand((k, n), "bfloat16")
    c0 = jnp.zeros((m, n), jnp.bfloat16)
    exact = np.asarray(ref.gemm_ref(a, b), np.float32)
    inner = np.asarray(gemm_k_inner(a, b, tile=TileConfig(64, 128, 64),
                                    interpret=True), np.float32)
    outer = np.asarray(gemm_k_outer(a, b, c0,
                                    tile=TileConfig(64, 128, 64,
                                                    GridOrder.K_OUTER),
                                    interpret=True), np.float32)
    err_inner = np.abs(inner - exact).max()
    err_outer = np.abs(outer - exact).max()
    assert err_outer > 2 * err_inner


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 300), n=st.integers(1, 300), k=st.integers(1, 300),
       order=st.sampled_from(list(GridOrder)))
def test_matmul_wrapper_pads_any_shape(m, n, k, order):
    a = jnp.array(np.arange(m * k).reshape(m, k) % 7, jnp.float32)
    b = jnp.array(np.arange(k * n).reshape(k, n) % 5, jnp.float32)
    got = matmul(a, b, tile=TileConfig(64, 128, 64, order), interpret=True)
    want = ref.gemm_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_k_inner_int8_exact_vs_k_outer():
    """int8 path: k_inner accumulates in int32 exactly."""
    a, b = _rand((128, 256), "int8"), _rand((256, 128), "int8")
    got = gemm_k_inner(a, b, tile=TileConfig(64, 64, 128), interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.gemm_ref(a, b)))


# ---------------------------------------------------------------------------
# Grouped GEMM (MoE)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("e,c,d,f,dt", [
    (4, 128, 256, 128, "float32"),
    (8, 256, 128, 256, "bfloat16"),
    (2, 128, 512, 384, "float32"),
])
def test_grouped_gemm_matches_ref(e, c, d, f, dt):
    x, w = _rand((e, c, d), dt), _rand((e, d, f), dt)
    got = grouped_gemm_kernel(x, w, block_c=128, block_f=128, block_k=128,
                              interpret=True)
    want = ref.grouped_gemm_ref(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dt == "bfloat16" else 1e-5,
                               atol=2e-2 if dt == "bfloat16" else 1e-4)


def test_grouped_gemm_expert_isolation():
    """Each expert's output depends only on its own weights."""
    e, c, d, f = 4, 128, 128, 128
    x = _rand((e, c, d), "float32")
    w = _rand((e, d, f), "float32")
    w2 = w.at[2].set(0.0)
    y1 = np.asarray(grouped_gemm_kernel(x, w, interpret=True))
    y2 = np.asarray(grouped_gemm_kernel(x, w2, interpret=True))
    assert np.allclose(y2[2], 0.0)
    np.testing.assert_allclose(y1[[0, 1, 3]], y2[[0, 1, 3]])


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,d,bq,bk,dt", [
    (2, 256, 3, 64, 64, 64, "float32"),
    (1, 512, 2, 128, 128, 128, "float32"),
    (2, 256, 4, 64, 128, 64, "bfloat16"),
])
def test_flash_attention_matches_ref(b, s, h, d, bq, bk, dt):
    q, k, v = (_rand((b, s, h, d), dt) for _ in range(3))
    got = flash_attention_fwd(q, k, v, causal=True, block_q=bq, block_k=bk,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2 if dt == "bfloat16" else 1e-5,
                               atol=3e-2 if dt == "bfloat16" else 1e-5)


def test_flash_attention_non_causal():
    q, k, v = (_rand((1, 128, 2, 64), "float32") for _ in range(3))
    got = flash_attention_fwd(q, k, v, causal=False, block_q=64, block_k=64,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_attention_matches_model_blockwise():
    """The Pallas kernel and the model's pure-jnp blockwise path agree —
    kernel-on-TPU and reference-on-dry-run compute the same function."""
    from repro.models.attention import blockwise_attention
    q, k, v = (_rand((2, 128, 2, 64), "float32") for _ in range(3))
    a = flash_attention_fwd(q, k, v, causal=True, block_q=64, block_k=64,
                            interpret=True)
    b = blockwise_attention(q, k, v, chunk=64, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


# ---------------------------------------------------------------------------
# Fused RMSNorm kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,dt,br", [
    ((4, 64, 128), "float32", 64),
    ((512, 256), "bfloat16", 128),
    ((2, 128, 512), "bfloat16", 32),
])
def test_rmsnorm_kernel_matches_ref(shape, dt, br):
    from repro.kernels.rmsnorm import rmsnorm
    x = _rand(shape, dt)
    s = jnp.array(RNG.normal(size=shape[-1]), jnp.float32)
    got = rmsnorm(x, s, block_rows=br, interpret=True)
    xf = x.astype(jnp.float32)
    want = (xf * jax.lax.rsqrt(jnp.mean(xf ** 2, -1, keepdims=True) + 1e-5)
            * s).astype(dt)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_rmsnorm_kernel_matches_model_norm():
    from repro.kernels.rmsnorm import rmsnorm
    from repro.models import layers
    from repro.configs import get_config
    cfg = get_config("qwen2-1.5b", smoke=True)
    x = _rand((4, 16, cfg.d_model), "float32")
    scale = jnp.array(RNG.normal(size=cfg.d_model), jnp.float32)
    got = rmsnorm(x, scale, block_rows=32, eps=cfg.norm_eps, interpret=True)
    want = layers.apply_norm({"scale": scale}, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
