"""Multi-device distribution tests (run with
XLA_FLAGS=--xla_force_host_platform_device_count=8)."""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, input_specs
from repro.configs.base import SHAPES
from repro.models.common import MeshInfo, split_params
from repro.models.moe import (
    apply_moe,
    apply_moe_ep,
    ep_applicable,
    init_moe,
    padded_experts,
)
from repro.runtime.sharding import batch_specs, mesh_info, use_mesh

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs >=8 host devices")


def _mesh24():
    return jax.make_mesh((2, 4), ("data", "model"))


def test_moe_ep_matches_baseline_exactly():
    """The shard_map EP path computes the same function as the pjit path
    (generous capacity so neither drops tokens)."""
    mesh = _mesh24()
    minfo = MeshInfo(data=2, model=4, data_axes=("data",))
    cfg = dataclasses.replace(get_config("kimi-k2-1t-a32b", smoke=True),
                              capacity_factor=64.0)
    values, _ = split_params(init_moe(jax.random.key(0), cfg, minfo,
                                      jnp.float32))
    x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model),
                          jnp.float32)
    assert ep_applicable(cfg, minfo, 16)
    with use_mesh(mesh):
        y1, _ = jax.jit(lambda v, x: apply_moe(v, x, cfg, minfo))(values, x)
        y2, _ = jax.jit(lambda v, x: apply_moe_ep(v, x, cfg, minfo))(values, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)


def test_moe_ep_grads_match_baseline():
    mesh = _mesh24()
    minfo = MeshInfo(data=2, model=4, data_axes=("data",))
    cfg = dataclasses.replace(get_config("kimi-k2-1t-a32b", smoke=True),
                              capacity_factor=64.0)
    values, _ = split_params(init_moe(jax.random.key(0), cfg, minfo,
                                      jnp.float32))
    x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model),
                          jnp.float32)

    def loss(fn, v):
        y, aux = fn(v, x, cfg, minfo)
        return jnp.sum(jnp.square(y.astype(jnp.float32)))

    with use_mesh(mesh):
        g1 = jax.jit(jax.grad(lambda v: loss(apply_moe, v)))(values)
        g2 = jax.jit(jax.grad(lambda v: loss(apply_moe_ep, v)))(values)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2), strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4)


def test_expert_padding_exact():
    """Padding 5 experts -> 8 on a 4-way axis must not change outputs
    (dead experts masked to -inf in the router)."""
    minfo_pad = MeshInfo(data=2, model=4, data_axes=("data",))
    minfo_host = MeshInfo(data=1, model=1)
    cfg = dataclasses.replace(get_config("granite-moe-3b-a800m", smoke=True),
                              capacity_factor=64.0)
    assert padded_experts(cfg, minfo_pad) == 8 and cfg.n_experts == 5
    v_pad, _ = split_params(init_moe(jax.random.key(7), cfg, minfo_pad,
                                     jnp.float32))
    v_host, _ = split_params(init_moe(jax.random.key(7), cfg, minfo_host,
                                      jnp.float32))
    # same logical weights: padded arrays extend the expert dim
    np.testing.assert_allclose(np.asarray(v_pad["w_up"][:5]),
                               np.asarray(v_host["w_up"]))
    x = jax.random.normal(jax.random.key(2), (2, 8, cfg.d_model), jnp.float32)
    y_host, _ = apply_moe(v_host, x, cfg, None)
    mesh = _mesh24()
    with use_mesh(mesh):
        y_pad, _ = jax.jit(lambda v, x: apply_moe(v, x, cfg, minfo_pad)
                           )(v_pad, x)
    np.testing.assert_allclose(np.asarray(y_pad), np.asarray(y_host),
                               rtol=1e-5, atol=1e-5)


def test_batch_specs_long_500k_replicates_batch():
    cfg = get_config("zamba2-1.2b")
    minfo = MeshInfo(data=16, model=16, data_axes=("data",))
    specs = batch_specs(cfg, SHAPES["long_500k"], minfo)
    assert specs["token"] == P(None, None)      # batch=1: no DP sharding
    specs4k = batch_specs(cfg, SHAPES["train_4k"], minfo)
    assert specs4k["tokens"] == P("data", None)


def test_mesh_info_from_mesh():
    mesh = _mesh24()
    mi = mesh_info(mesh, fsdp=True)
    assert mi.data == 2 and mi.model == 4 and mi.fsdp
    assert mi.data_axes == ("data",)


def test_sharded_train_step_runs():
    """A real sharded train step on the 2x4 mesh executes and improves."""
    from repro.configs.base import ParallelConfig, ShapeConfig, TrainConfig
    from repro.data import make_batch
    from repro.models.model import LM
    from repro.runtime.sharding import shardings_for
    from repro.runtime.train_lib import init_train_state, make_train_step

    mesh = _mesh24()
    minfo = mesh_info(mesh, fsdp=True)
    cfg = get_config("qwen2-7b", smoke=True)     # 6 heads -> padded to 8
    lm = LM(cfg, minfo)
    tcfg = TrainConfig(lr=3e-3, warmup_steps=2, total_steps=10)
    shape = ShapeConfig("t", "train", 32, 8)
    with use_mesh(mesh):
        params, pspecs, opt, ospecs = init_train_state(lm, tcfg,
                                                       jax.random.key(0))
        params = jax.device_put(params, shardings_for(mesh, pspecs))
        opt = jax.device_put(opt, shardings_for(mesh, ospecs))
        step = jax.jit(make_train_step(lm, tcfg, ParallelConfig(fsdp=True)))
        losses = []
        for i in range(8):
            batch = make_batch(cfg, shape, i, seed=4)
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()
