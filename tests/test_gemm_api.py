"""Unified ``repro.gemm`` plan/execute API: backends, round trips, cache."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro import gemm
from repro.core import GAP8_FC
from repro.core.mobilenet import LAYER10, TABLE2
from repro.core.simulator import CostBreakdown, best_microkernel
from repro.core.tpu_model import GridOrder, TileConfig, TpuCost
from repro.core.variants import MicroKernel, Problem, Variant
from repro.kernels import ref

RNG = np.random.default_rng(11)


@pytest.fixture(autouse=True)
def _fresh_cache():
    gemm.clear_plan_cache()
    yield
    gemm.clear_plan_cache()


def _ab(m, n, k, dtype=jnp.float32):
    a = jnp.array(RNG.normal(size=(m, k)), dtype)
    b = jnp.array(RNG.normal(size=(k, n)), dtype)
    return a, b


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_all_four_backends_registered():
    assert gemm.backends() == ["analytic-gap8", "analytic-tpu", "pallas",
                               "reference"]


def test_unknown_backend_raises():
    with pytest.raises(gemm.UnknownBackendError):
        gemm.plan((8, 8, 8), backend="cuda")


def test_plan_works_for_every_backend():
    for name in gemm.backends():
        p = gemm.plan((64, 96, 128), backend=name)
        assert p.backend == name
        assert p.problem.m == 64 and p.problem.n == 96 and p.problem.k == 128
        assert p.estimate() is not None and p.predicted_seconds > 0
        assert p.executable == gemm.get_backend(name).executable


# ---------------------------------------------------------------------------
# Problem coercion
# ---------------------------------------------------------------------------


def test_problem_coercion_and_dtype_defaults():
    assert gemm.plan((8, 8, 8), backend="analytic-gap8").problem.dtype == \
        "int8"
    assert gemm.plan((8, 8, 8), backend="analytic-tpu").problem.dtype == \
        "bf16"
    p = gemm.plan(Problem(16, 24, 32), backend="analytic-gap8")
    assert (p.problem.m, p.problem.n, p.problem.k) == (16, 24, 32)
    assert gemm.plan((8, 8, 8), backend="pallas",
                     dtype="f32").problem.dtype == "f32"
    with pytest.raises(TypeError):
        gemm.plan("512x512", backend="reference")


# ---------------------------------------------------------------------------
# Round trips: plan -> estimate -> execute
# ---------------------------------------------------------------------------


def test_reference_roundtrip():
    m, n, k = 96, 80, 64
    p = gemm.plan((m, n, k), backend="reference", dtype="f32")
    assert isinstance(p.estimate(), TpuCost)
    a, b = _ab(m, n, k)
    np.testing.assert_allclose(np.asarray(p.execute(a, b)),
                               np.asarray(ref.gemm_ref(a, b)),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("m,n,k", [(128, 128, 128), (100, 70, 130),
                                   (1, 300, 17)])
def test_pallas_interpret_roundtrip_matches_ref(m, n, k):
    """Acceptance: a cached plan's execute() == kernels.ref on CPU
    interpret mode (pad-and-slice handles non-divisible shapes)."""
    p1 = gemm.plan((m, n, k), backend="pallas", dtype="f32")
    p2 = gemm.plan((m, n, k), backend="pallas", dtype="f32")
    assert p2 is p1                       # the executed plan IS the cached one
    a, b = _ab(m, n, k)
    np.testing.assert_allclose(np.asarray(p2.execute(a, b, interpret=True)),
                               np.asarray(ref.gemm_ref(a, b)),
                               rtol=1e-5, atol=1e-4)


def test_pallas_k_outer_accumulate_matches_streamed_ref():
    m, n, k = 128, 128, 256
    a, b = _ab(m, n, k)
    c0 = jnp.array(RNG.normal(size=(m, n)), jnp.float32)
    p = gemm.plan((m, n, k), backend="pallas", dtype="f32",
                  tile=TileConfig(64, 64, 64, GridOrder.K_OUTER))
    got = p.execute(a, b, c0, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.gemm_ref_streamed(a, b, c0,
                                                                bk=64)),
                               rtol=1e-5, atol=1e-5)


def test_pallas_execute_validates_operand_shapes():
    p = gemm.plan((32, 32, 32), backend="pallas", dtype="f32")
    a, b = _ab(16, 32, 32)
    with pytest.raises(ValueError, match="do not match the planned"):
        p.execute(a, b, interpret=True)


def test_analytic_backends_raise_not_executable():
    for name in ("analytic-gap8", "analytic-tpu"):
        p = gemm.plan((64, 64, 64), backend=name)
        assert not p.executable
        with pytest.raises(gemm.NotExecutableError):
            p.execute(None, None)


# ---------------------------------------------------------------------------
# Plan cache semantics
# ---------------------------------------------------------------------------


def test_cache_hit_miss_semantics():
    s0 = gemm.plan_cache_stats()
    assert s0["size"] == 0
    p1 = gemm.plan((256, 256, 256), backend="analytic-tpu")
    s1 = gemm.plan_cache_stats()
    assert s1["misses"] == 1 and s1["hits"] == 0 and s1["size"] == 1
    p2 = gemm.plan((256, 256, 256), backend="analytic-tpu")
    s2 = gemm.plan_cache_stats()
    assert p2 is p1 and s2["hits"] == 1 and s2["size"] == 1
    # a different key dimension (backend / dtype / policy / options) misses
    gemm.plan((256, 256, 256), backend="pallas")
    gemm.plan((256, 256, 256), backend="analytic-tpu", dtype="int8")
    gemm.plan((256, 256, 256), backend="analytic-tpu", overlap=False)
    assert gemm.plan_cache_stats()["size"] == 4


def test_cache_false_bypasses():
    p1 = gemm.plan((128, 128, 128), backend="analytic-tpu", cache=False)
    p2 = gemm.plan((128, 128, 128), backend="analytic-tpu", cache=False)
    assert p1 is not p2 and p1.selection == p2.selection
    assert gemm.plan_cache_stats()["size"] == 0


def test_manifest_is_the_persistence_layer(tmp_path):
    path = str(tmp_path / "tiles.json")
    fresh = gemm.plan((1024, 512, 2048), backend="pallas")
    assert fresh.provenance["source"] == "search"
    assert gemm.save_cache(path) == 1
    gemm.clear_plan_cache()
    assert gemm.warm_cache(path) == 1
    warmed = gemm.plan((1024, 512, 2048), backend="pallas")
    assert warmed.provenance["source"] == "manifest"
    assert warmed.selection == fresh.selection
    assert isinstance(warmed.cost, TpuCost)
    # the manifest-restored plan still executes correctly
    a, b = _ab(64, 64, 64)
    p = gemm.plan((64, 64, 64), backend="pallas", dtype="f32")
    np.testing.assert_allclose(np.asarray(p.execute(a, b, interpret=True)),
                               np.asarray(ref.gemm_ref(a, b)),
                               rtol=1e-5, atol=1e-4)


def test_manifest_does_not_shadow_explicit_options(tmp_path):
    """A warmed manifest only answers option-free plans: a tile searched
    under the default overlap=True must not satisfy overlap=False, whose
    cost composition (and possibly optimal tile) differs."""
    path = str(tmp_path / "tiles.json")
    gemm.plan((512, 2048, 1024), backend="analytic-tpu")
    gemm.save_cache(path)
    gemm.clear_plan_cache()
    gemm.warm_cache(path)
    p = gemm.plan((512, 2048, 1024), backend="analytic-tpu", overlap=False)
    assert p.provenance["source"] == "search"
    assert p.provenance["overlap"] is False
    assert p.predicted_seconds == pytest.approx(p.cost.total_no_overlap)


# ---------------------------------------------------------------------------
# Regression: analytic-gap8 == the paper's Table-2 search
# ---------------------------------------------------------------------------


def test_gap8_reproduces_best_microkernel_layer10():
    for v in Variant:
        p = gemm.plan(LAYER10, backend="analytic-gap8", variant=v)
        cb = best_microkernel(GAP8_FC, v, LAYER10)
        assert isinstance(p.estimate(), CostBreakdown)
        assert p.selection.variant is v
        assert p.selection.micro_kernel == cb.micro_kernel
        assert p.predicted_seconds == pytest.approx(cb.total)


def test_gap8_reproduces_table2_winners_sample():
    for row in TABLE2[:4]:
        for v in Variant:
            p = gemm.plan(row.problem, backend="analytic-gap8", variant=v)
            cb = best_microkernel(GAP8_FC, v, row.problem)
            assert p.selection.micro_kernel == cb.micro_kernel, \
                (row.layer, v)


def test_gap8_variant_search_picks_global_best():
    p = gemm.plan(LAYER10, backend="analytic-gap8")
    per_variant = [best_microkernel(GAP8_FC, v, LAYER10).total
                   for v in Variant]
    assert p.predicted_seconds == pytest.approx(min(per_variant))
    assert set(p.provenance["variants"]) == {v.value for v in Variant}


def test_gap8_explicit_microkernel_override():
    mk = MicroKernel(4, 8)
    p = gemm.plan(LAYER10, backend="analytic-gap8",
                  variant=Variant.B3C2A0, micro_kernel=mk)
    assert p.selection.micro_kernel == mk
    assert p.provenance["source"] == "explicit"
    with pytest.raises(ValueError, match="requires an explicit variant"):
        gemm.plan(LAYER10, backend="analytic-gap8", micro_kernel=mk)


# ---------------------------------------------------------------------------
# Framework helpers
# ---------------------------------------------------------------------------


def test_matmul_helper_folds_leading_dims():
    x = jnp.array(RNG.normal(size=(2, 5, 48)), jnp.float32)
    w = jnp.array(RNG.normal(size=(48, 32)), jnp.float32)
    got = gemm.matmul(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                               rtol=1e-5, atol=1e-5)


def test_grouped_matmul_helper_matches_einsum():
    x = jnp.array(RNG.normal(size=(2, 3, 16, 24)), jnp.float32)
    w = jnp.array(RNG.normal(size=(3, 24, 8)), jnp.float32)
    got = gemm.grouped_matmul(x, w)
    want = jnp.einsum("becd,edf->becf", x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_plan_model_gemms_and_engine_report():
    from repro.configs import get_config
    cfg = get_config("qwen2-1.5b", smoke=True)
    plans = gemm.plan_model_gemms(cfg, tokens=8, backend="analytic-tpu")
    assert plans and all(p.backend == "analytic-tpu" for p in plans)
    assert all(p.problem.m == 8 for p in plans[:2])   # QKV / O proj rows
    assert sum(p.predicted_seconds for p in plans) > 0
