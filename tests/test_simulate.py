"""Discrete-event serving simulator: traffic determinism, event-queue
ordering, slot-server semantics, SLO-driven autoconfiguration, and the
closed loop against the real engine.

The acceptance properties:

* traffic generators are seeded-deterministic, prefix-stable, and hit
  their nominal rates; trace replay round-trips the request list
  bit-exactly;
* a single simulated request's latency matches the closed-form
  ``prefill + decode_len * step`` cost;
* replaying a real ``ServingEngine`` trace reproduces the completion
  order exactly and per-request latencies within the documented 2%;
* ``autoconfigure(slo=...)`` picks a *smaller* batch than the
  peak-throughput mode on a scenario where the tail demands it, with
  machine-readable ``slo_*`` rejections in the deployment report.
"""
import json
import math
import statistics

import pytest

from repro.configs import get_config
from repro.serving.buckets import PREFILL_BUCKETS, bucket_cover, bucket_len
from repro.simulate import (
    SLO,
    BurstyTraffic,
    LengthDist,
    Metrics,
    PoissonTraffic,
    ServiceModel,
    SimReport,
    Simulator,
    SlotServer,
    TraceTraffic,
    UniformTraffic,
    default_traffic,
    evaluate_deployment,
    make_traffic,
    percentile,
    replay,
    simulate_serving,
    trace_requests,
    trace_traffic,
)
from repro.simulate.autoconf import REJECT_SLO_P99, REJECT_SLO_UNFINISHED

QWEN = "qwen2-1.5b"


def _service(decode=0.01, prefill=None):
    return ServiceModel(decode_step_s=decode,
                        prefill_s=prefill or {b: 0.05 for b in
                                              PREFILL_BUCKETS})


# ---------------------------------------------------------------------------
# Prefill buckets (shared real-engine / simulator ladder)
# ---------------------------------------------------------------------------


def test_bucket_len_rounds_up_the_ladder():
    assert bucket_len(1) == 32
    assert bucket_len(32) == 32
    assert bucket_len(33) == 64
    assert bucket_len(1024) == 1024
    # beyond the ladder: next multiple of the last rung
    assert bucket_len(1025) == 2048
    assert bucket_len(2049) == 3072


def test_bucket_cover_prices_every_reachable_bucket():
    assert bucket_cover(128) == [32, 64, 128]
    assert bucket_cover(100) == [32, 64, 128]
    assert bucket_cover(2000) == [32, 64, 128, 256, 512, 1024, 2048]


# ---------------------------------------------------------------------------
# Traffic generators
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("make", [
    lambda seed: PoissonTraffic(rate=20, prompt_len=(8, 100),
                                decode_len=16, seed=seed),
    lambda seed: UniformTraffic(rate=20, prompt_len=32, decode_len=(4, 64),
                                seed=seed),
    lambda seed: BurstyTraffic(rate=40, burst=4, prompt_len=16,
                               decode_len=8, seed=seed),
])
def test_traffic_deterministic_and_prefix_stable(make):
    a, b = make(3).requests(200), make(3).requests(200)
    assert a == b                           # same seed -> same stream
    assert make(3).requests(50) == a[:50]   # longer stream extends shorter
    assert make(4).requests(200) != a       # seed matters
    assert all(r.arrival_s <= s.arrival_s for r, s in zip(a, a[1:]))
    assert all(r.prompt_len >= 1 and r.decode_len >= 1 for r in a)


def test_poisson_interarrival_mean_within_tolerance():
    reqs = PoissonTraffic(rate=50, seed=1).requests(4000)
    gaps = [b.arrival_s - a.arrival_s for a, b in zip(reqs, reqs[1:])]
    assert statistics.mean(gaps) == pytest.approx(1 / 50, rel=0.05)


def test_uniform_traffic_is_constant_gap():
    reqs = UniformTraffic(rate=8, seed=0).requests(100)
    gaps = {round(b.arrival_s - a.arrival_s, 12)
            for a, b in zip(reqs, reqs[1:])}
    assert gaps == {round(1 / 8, 12)}


def test_bursty_traffic_matches_long_run_rate():
    tr = BurstyTraffic(rate=40, burst=8, intra_gap=1e-3, seed=2)
    reqs = tr.requests(4000)
    span = reqs[-1].arrival_s - reqs[0].arrival_s
    assert len(reqs) / span == pytest.approx(40, rel=0.1)
    gaps = [b.arrival_s - a.arrival_s for a, b in zip(reqs, reqs[1:])]
    # 7 of every 8 gaps are the intra-burst spacing
    assert sum(1 for g in gaps if g == pytest.approx(1e-3)) \
        >= 0.8 * len(gaps) * 7 / 8


def test_trace_traffic_round_trips_bit_exactly():
    src = BurstyTraffic(rate=30, burst=4, prompt_len=(8, 64),
                        decode_len=(2, 32), seed=5).requests(64)
    assert TraceTraffic(src).requests() == src
    assert TraceTraffic(src).requests(10) == src[:10]


def test_length_dist_coercion_and_bounds():
    assert LengthDist.coerce(7) == LengthDist(kind="fixed", lo=7)
    assert LengthDist.coerce((3, 9)) == LengthDist(kind="uniform", lo=3,
                                                   hi=9)
    geo = LengthDist.coerce({"kind": "geometric", "lo": 4, "mean": 32.0})
    draws = [geo.sample(__import__("random").Random(i)) for i in range(200)]
    assert min(draws) >= 4
    assert LengthDist(kind="uniform", lo=8, hi=100).prefill_buckets(128) \
        == [32, 64, 128]
    with pytest.raises(ValueError):
        LengthDist(kind="uniform", lo=9, hi=3)
    with pytest.raises(ValueError):
        LengthDist(kind="nope")


def test_make_traffic_factory():
    tr = make_traffic("poisson", rate=10, seed=1)
    assert isinstance(tr, PoissonTraffic) and tr.rate == 10
    with pytest.raises(ValueError, match="unknown traffic kind"):
        make_traffic("fractal", rate=1)


# ---------------------------------------------------------------------------
# Event queue
# ---------------------------------------------------------------------------


def test_simulator_orders_events_and_breaks_ties_by_schedule_order():
    sim = Simulator(seed=0)
    fired = []
    sim.schedule(2.0, lambda: fired.append("late"))
    sim.schedule(1.0, lambda: fired.append("a"))
    sim.schedule(1.0, lambda: fired.append("b"))   # same time, queued after
    ev = sim.schedule(1.5, lambda: fired.append("cancelled"))
    ev.cancel()
    end = sim.run()
    assert fired == ["a", "b", "late"]
    assert end == 2.0 and sim.now == 2.0
    assert sim.events_processed == 3


def test_simulator_horizon_and_past_scheduling():
    sim = Simulator(seed=0, horizon=1.0)
    fired = []
    sim.schedule(0.5, lambda: fired.append(1))
    sim.schedule(2.0, lambda: fired.append(2))
    assert sim.run() == 1.0
    assert fired == [1] and sim.pending() == 1
    with pytest.raises(ValueError, match="before now"):
        sim.schedule_at(0.2, lambda: None)


def test_percentile_linear_interpolation():
    xs = list(range(1, 101))
    assert percentile(xs, 0) == 1.0
    assert percentile(xs, 100) == 100.0
    assert percentile(xs, 50) == pytest.approx(50.5)
    assert percentile([5.0], 99) == 5.0
    assert math.isnan(percentile([], 50))


# ---------------------------------------------------------------------------
# Slot server
# ---------------------------------------------------------------------------


def test_single_request_latency_is_closed_form():
    # prompt 10 -> prefix 9 -> bucket 32; decode_len 5 steps
    svc = _service(decode=0.01, prefill={32: 0.05})
    tr = TraceTraffic([__import__("repro.simulate.traffic",
                                  fromlist=["SimRequest"]).SimRequest(
        rid=0, arrival_s=0.0, prompt_len=10, decode_len=5)])
    rep = simulate_serving(svc, tr, max_batch=4, max_len=128)
    assert rep.requests == {"submitted": 1, "finished": 1,
                            "shed": 0, "unfinished": 0}
    # first step carries the prefill, every step decodes one token
    want = 0.05 + 5 * 0.01
    assert rep.latency["max"] == pytest.approx(want)
    assert rep.ttft["max"] == pytest.approx(0.05 + 0.01)
    assert rep.steps == 5


def test_decode_step_cost_is_occupancy_independent():
    # two same-time arrivals decode together: same span as one request
    from repro.simulate.traffic import SimRequest
    svc = _service(decode=0.01, prefill={32: 0.0})
    one = simulate_serving(svc, TraceTraffic(
        [SimRequest(0, 0.0, 4, 6)]), max_batch=4)
    two = simulate_serving(svc, TraceTraffic(
        [SimRequest(0, 0.0, 4, 6), SimRequest(1, 0.0, 4, 6)]), max_batch=4)
    assert two.span_s == pytest.approx(one.span_s)
    assert two.steps == one.steps


def test_admission_policies_order_tail_latency():
    svc = _service(decode=0.01, prefill={b: 0.02 for b in PREFILL_BUCKETS})
    tr = PoissonTraffic(rate=30, prompt_len=16, decode_len=16, seed=7)
    reports = {p: simulate_serving(svc, tr, max_batch=8, policy=p,
                                   requests=150)
               for p in ("greedy", "one-per-step", "drain-first")}
    for rep in reports.values():
        assert rep.finite
    # batch-synchronous draining stalls admissions: strictly worse tail
    assert reports["drain-first"].latency["p99"] \
        > reports["greedy"].latency["p99"]
    with pytest.raises(ValueError, match="unknown admission policy"):
        simulate_serving(svc, tr, max_batch=8, policy="psychic")


def test_overloaded_server_reports_unfinished_under_horizon():
    svc = _service(decode=0.1, prefill={32: 0.1})
    tr = PoissonTraffic(rate=100, prompt_len=8, decode_len=16, seed=0)
    rep = simulate_serving(svc, tr, max_batch=2, requests=200, horizon=5.0)
    assert rep.requests["unfinished"] > 0
    assert rep.queue["max_depth"] > 0
    slo = SLO(p99_latency_s=1e9)        # any latency OK, but must finish
    assert any(v["reason"] == REJECT_SLO_UNFINISHED
               for v in slo.check(rep))


def test_sim_report_json_round_trip(tmp_path):
    svc = _service()
    tr = PoissonTraffic(rate=20, seed=1)
    rep = simulate_serving(svc, tr, max_batch=4, requests=50,
                           config={"machine": "m", "dtype": "bf16"})
    path = rep.save(str(tmp_path / "sim.json"))
    back = SimReport.load(path)
    assert back.latency == rep.latency
    assert back.finish_order == rep.finish_order
    assert back.config["machine"] == "m"
    assert "sim" in rep.table()


def test_service_model_prices_from_planner():
    cfg = get_config(QWEN, smoke=True)
    svc = ServiceModel.from_plans(cfg, batch=4, machine="tpu-v5e",
                                  max_len=128)
    assert svc.decode_step_s > 0
    assert set(svc.prefill_s) == {32, 64, 128}
    assert all(v > 0 for v in svc.prefill_s.values())
    # longer prompts cost at least as much
    assert svc.prefill_s[128] >= svc.prefill_s[32]
    # beyond the priced ladder: pro-rata, monotone
    assert svc.prefill_seconds(4096) > svc.prefill_seconds(128)
    # empty ladder backstop (measured replay)
    assert ServiceModel(decode_step_s=1.0,
                        prefill_s={}).prefill_seconds(100) == 0.0


# ---------------------------------------------------------------------------
# SLO-driven autoconfiguration (config-only)
# ---------------------------------------------------------------------------


def _gap9_report():
    from repro.serving.report import plan_deployment
    cfg = get_config(QWEN, smoke=True)
    return cfg, plan_deployment(cfg, machines=("gap9-fc",),
                                batches=(1, 2, 4, 8, 16))


def test_slo_mode_rejects_the_throughput_pick():
    """The acceptance scenario: on a compute-bound edge cell the decode
    step slows down with the slot pool, so the biggest batch wins peak
    throughput but loses the simulated p99 tail — the SLO pick must be a
    smaller batch, with the oversized cell machine-readably rejected."""
    cfg, report = _gap9_report()
    base = report.select()
    traffic = PoissonTraffic(rate=5, prompt_len=16, decode_len=16, seed=0)
    sel = evaluate_deployment(cfg, report, slo=SLO(p99_latency_s=0.35),
                              traffic=traffic, requests=150)
    assert base.batch == 16
    assert sel.option.batch < base.batch
    # the peak-throughput cell is rejected with the SLO reason + evidence
    rej = [r for r in report.rejected
           if r.batch == base.batch and r.reason == REJECT_SLO_P99]
    assert rej, [r.as_dict() for r in report.rejected]
    detail = rej[0].as_dict()["detail"]
    assert detail["traffic"] == "poisson@5rps"
    assert detail["violations"][0]["observed"] > 0.35
    # the evaluation is attached to the report, options carry sim summaries
    assert report.slo["selected"]["batch"] == sel.option.batch
    assert all(o.sim is not None for o in report.options)
    assert json.dumps(report.to_json())    # JSON-serialisable end to end


def test_slo_infeasible_raises_with_per_cell_reasons():
    cfg, report = _gap9_report()
    traffic = PoissonTraffic(rate=5, prompt_len=16, decode_len=16, seed=0)
    with pytest.raises(ValueError, match="slo_p99_latency_exceeded"):
        evaluate_deployment(cfg, report, slo=SLO(p99_latency_s=1e-4),
                            traffic=traffic, requests=100)
    # ...and the rejections still land in the report for post-mortems
    assert any(r.reason == REJECT_SLO_P99 for r in report.rejected)


def test_slo_coercion_and_default_traffic():
    assert SLO.coerce(0.5).p99_latency_s == 0.5
    assert SLO.coerce({"p95_ttft_s": 0.1}).p95_ttft_s == 0.1
    with pytest.raises(TypeError):
        SLO.coerce("tight")
    _, report = _gap9_report()
    tr = default_traffic(report, utilization=0.5)
    peak = max(o.tokens_per_second for o in report.options)
    assert tr.rate == pytest.approx(0.5 * peak / 16)


# ---------------------------------------------------------------------------
# gemm.sweep scenarios axis
# ---------------------------------------------------------------------------


def test_sweep_scenarios_axis_tags_rows_and_defaults_to_none():
    from repro import gemm
    from repro.simulate import TrafficScenario

    plain = gemm.sweep([(64, 64, 64)], machines=("tpu-v5e",))
    assert plain.grid["scenarios"] == [None]
    assert all(r.scenario is None for r in plain.rows)
    assert "scenario" in plain.rows[0].as_dict()

    cfg = get_config(QWEN, smoke=True)
    scen = TrafficScenario(
        name="steady",
        traffic=PoissonTraffic(rate=5, prompt_len=(8, 100)))
    bound = scen.bind(cfg, max_len=128)
    res = gemm.sweep([(64, 64, 64)], machines=("tpu-v5e",),
                     scenarios=[bound])
    assert {r.scenario for r in res.rows} == {"steady"}
    # the scenario appended the prefill-bucket model GEMMs to the base list
    assert len(res.rows) > len(plain.rows)
    assert res.to_json()["grid"]["scenarios"] == ["steady"]
    assert res.filter(scenario="steady") == res.rows


# ---------------------------------------------------------------------------
# Closed loop against the real engine (jax)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_engine_trace():
    import jax
    from repro.models.common import HOST_MESH, split_params
    from repro.models.model import LM
    from repro.serving.engine import Request, ServingEngine

    cfg = get_config(QWEN, smoke=True)
    lm = LM(cfg, HOST_MESH)
    values, _ = split_params(lm.init(jax.random.key(0)))
    eng = ServingEngine(lm, values, max_batch=3, max_len=128)
    prompts = [[5, 6, 7, 8], [1, 2, 3], [9, 4, 2, 7, 5, 3], [11, 12],
               [4, 4, 4]]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=4 + i))
    done = eng.run_until_drained()
    return eng, done


def test_engine_stamps_request_timestamps(smoke_engine_trace):
    eng, done = smoke_engine_trace
    assert len(done) == 5
    for r in done:
        assert r.t_submit <= r.t_admit <= r.t_first_token <= r.t_finish
        assert r.wait_s >= 0 and r.service_s > 0
        assert r.latency_s == pytest.approx(r.wait_s + r.service_s)
        assert r.ttft_s <= r.latency_s
    perf = eng.perf_report()
    m = perf["measured_requests"]
    assert m["finished"] == 5
    for key in ("wait_s", "service_s", "latency_s", "ttft_s"):
        assert m[key]["mean"] > 0
        assert m[key]["max"] >= m[key]["mean"]


def test_trace_schema_and_event_consistency(smoke_engine_trace):
    eng, done = smoke_engine_trace
    trace = eng.trace_json()
    assert trace["schema"] == "repro.serving/trace-v1"
    kinds = {e["type"] for e in trace["events"]}
    assert kinds == {"submit", "admit", "first_token", "finish", "step"}
    # every request appears once per lifecycle kind
    for kind in ("submit", "admit", "first_token", "finish"):
        rids = [e["rid"] for e in trace["events"] if e["type"] == kind]
        assert sorted(rids) == [0, 1, 2, 3, 4]
    # each event kind is chronological (step events carry their *start*
    # time, so the flat list interleaves kinds but never reorders one)
    for kind in kinds:
        times = [e["t"] for e in trace["events"] if e["type"] == kind]
        assert times == sorted(times)
    assert all(e["dt"] > 0 for e in trace["events"] if e["type"] == "step")


def test_replay_closed_loop_matches_real_engine(smoke_engine_trace):
    """The tentpole validation: measured-service replay reproduces the
    real run's step count, completion order *exactly*, and per-request
    latency within the documented 2% tolerance."""
    eng, done = smoke_engine_trace
    trace = eng.trace_json()
    rep = replay(trace)
    assert rep.mode == "measured"
    assert rep.order_match and rep.steps_match
    assert len(rep.rows) == 5
    for row in rep.rows:
        assert row.ape < 0.02, row.as_dict()
    assert rep.mape < 2.0
    # the recorded arrival stream round-trips bit-exactly through the
    # traffic layer
    reqs = trace_requests(trace)
    assert trace_traffic(trace).requests() == reqs
    assert [r.decode_len for r in sorted(reqs, key=lambda r: r.rid)] \
        == [len(r.generated) for r in sorted(done, key=lambda r: r.rid)]


def test_replay_model_service_still_matches_order(smoke_engine_trace):
    eng, _ = smoke_engine_trace
    svc = ServiceModel(decode_step_s=0.05, prefill_s={32: 0.08})
    rep = replay(eng.trace_json(), svc)
    assert rep.mode == "model"
    assert rep.order_match and rep.steps_match
    assert math.isfinite(rep.mape)
    assert rep.to_json()["schema"] == "repro.simulate/replay-v1"


def test_run_until_drained_raises_on_truncation():
    import jax
    from repro.models.common import HOST_MESH, split_params
    from repro.models.model import LM
    from repro.serving.engine import (DrainTruncatedError, Request,
                                      ServingEngine)

    cfg = get_config(QWEN, smoke=True)
    lm = LM(cfg, HOST_MESH)
    values, _ = split_params(lm.init(jax.random.key(0)))
    eng = ServingEngine(lm, values, max_batch=2, max_len=128)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=[1, 2, 3], max_new_tokens=50))
    with pytest.raises(DrainTruncatedError, match="truncated after 5"):
        eng.run_until_drained(max_steps=5)


def test_autoconfigure_slo_picks_smaller_batch_than_throughput():
    """End-to-end acceptance: the engine's SLO mode configures a smaller
    max_batch than the peak-throughput mode on the same grid, and the
    deployment report records why."""
    import jax
    from repro.models.common import HOST_MESH, split_params
    from repro.models.model import LM
    from repro.serving.engine import Request, ServingEngine

    cfg = get_config(QWEN, smoke=True)
    lm = LM(cfg, HOST_MESH)
    values, _ = split_params(lm.init(jax.random.key(0)))
    kwargs = dict(machine="gap9-fc", batches=(1, 2, 4, 8, 16),
                  max_len=512)
    peak = ServingEngine.autoconfigure(lm, values, **kwargs)
    traffic = PoissonTraffic(rate=5, prompt_len=16, decode_len=16, seed=0)
    slo = ServingEngine.autoconfigure(
        lm, values, slo=SLO(p99_latency_s=0.35), traffic=traffic,
        sim_requests=150, **kwargs)
    assert slo.max_batch < peak.max_batch
    ac = slo.autoconfig["slo"]
    assert ac["slo"]["p99_latency_s"] == 0.35
    assert ac["policy"] == "greedy"
    assert any(r["reason"] == REJECT_SLO_P99 for r in ac["rejected"])
    assert any(r["batch"] == peak.max_batch for r in ac["rejected"])
    # the SLO-configured engine still serves correctly
    slo.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=4))
    out = slo.run_until_drained()
    assert len(out) == 1 and len(out[0].generated) == 4
