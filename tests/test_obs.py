"""Tests for ``repro.obs`` — tracing, metrics, drift, and explain().

Covers the observability contract end to end without jax: span nesting
and the disabled no-op fast path, the ``repro.obs/v1`` metrics snapshot
round-trip and its agreement with the legacy plan-cache/sweep counters,
DriftMonitor's ok → warn → stale transitions (including the simulator
integration where a throttle fault flips the verdict), ``explain()``'s
partition-of-total guarantee on every Table-2 cell, Chrome-trace export
validity, and the ``python -m repro.obs`` CLI.
"""
import json
import statistics

import pytest

from repro import obs
from repro.obs.trace import _NULL, Recorder, chrome_trace_from_serving
from repro.obs.drift import DriftMonitor
from repro.obs.metrics import MetricsRegistry


@pytest.fixture(autouse=True)
def _clean_obs():
    """Each test sees (and leaves behind) a pristine process recorder."""
    obs.disable()
    obs.clear()
    yield
    obs.disable()
    obs.clear()


# -- span channel -------------------------------------------------------------

class TestSpans:
    def test_disabled_span_is_shared_noop(self):
        assert not obs.enabled()
        s = obs.span("anything", attr=1)
        assert s is _NULL
        # the no-op supports the full call-site surface
        with s as inner:
            inner.set(more=2)
        assert obs.recorder.spans == []

    def test_disabled_add_span_records_nothing(self):
        assert obs.add_span("x", 0.0, 1.0) is None
        assert obs.recorder.spans == []

    def test_nesting_and_attrs(self):
        rec = Recorder(enabled=True)
        with rec.span("outer", a=1) as outer:
            with rec.span("inner"):
                pass
            outer.set(b=2)
        assert [s.name for s in rec.spans] == ["outer", "inner"]
        out, inn = rec.spans
        assert inn.parent == out.sid
        assert out.parent is None
        assert out.attrs == {"a": 1, "b": 2}
        assert out.t1 >= inn.t1 >= inn.t0 >= out.t0
        assert out.duration_s >= 0

    def test_exception_closes_span_and_tags_error(self):
        rec = Recorder(enabled=True)
        with pytest.raises(ValueError):
            with rec.span("boom"):
                raise ValueError("x")
        (s,) = rec.spans
        assert s.t1 is not None
        assert s.attrs["error"] == "ValueError"
        assert rec._stack == []

    def test_out_of_order_exit_tolerated(self):
        rec = Recorder(enabled=True)
        a = rec.span("a")
        b = rec.span("b")
        a.__exit__(None, None, None)  # closes a, unwinds b off the stack
        assert rec._stack == []
        b.__exit__(None, None, None)  # already unwound: harmless
        assert all(s.t1 is not None for s in rec.spans)

    def test_retroactive_add_span(self):
        rec = Recorder(enabled=True)
        s = rec.add_span("serve.step", 10.0, 10.5, track="sim", active=3)
        assert s.duration_s == pytest.approx(0.5)
        assert s.track == "sim"
        assert s.attrs == {"active": 3}

    def test_overhead_disabled_vs_stubbed(self):
        # the hard <2% assert lives in benchmarks/bench_planner.py on the
        # real Table-2 sweep; here just bound the per-call cost sanely
        import timeit
        n = 20000
        disabled = timeit.timeit(
            lambda: obs.span("hot", i=0), number=n) / n
        assert disabled < 5e-6  # single-digit microseconds at worst


# -- event channel + Chrome export --------------------------------------------

class TestChromeTrace:
    def test_events_always_on_and_tag_filtered(self):
        assert not obs.enabled()
        obs.recorder.add_event({"type": "submit", "rid": 0, "t": 1.0},
                               tag="engine-a")
        obs.recorder.add_event({"type": "submit", "rid": 1, "t": 2.0},
                               tag="engine-b")
        a = obs.recorder.events_for(tag="engine-a")
        assert [e["rid"] for e in a] == [0]
        # private routing keys never leak to consumers
        assert "_tag" not in a[0] and "_track" not in a[0]

    def test_chrome_trace_shape(self):
        obs.enable()
        with obs.span("outer", machine="gap9-fc"):
            with obs.span("inner"):
                pass
        obs.recorder.add_event({"type": "finish", "rid": 7, "t": 0.5})
        doc = obs.to_chrome_trace()
        assert doc["metadata"]["schema"] == "repro.obs/chrome-trace-v1"
        assert doc["metadata"]["spans"] == 2
        assert doc["metadata"]["events"] == 1
        evs = doc["traceEvents"]
        assert {e["ph"] for e in evs} == {"X", "i", "M"}
        slices = [e for e in evs if e["ph"] == "X"]
        assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in slices)
        assert {e["name"] for e in slices} == {"outer", "inner"}
        (inst,) = [e for e in evs if e["ph"] == "i"]
        assert inst["name"] == "event.finish"
        assert inst["args"] == {"rid": 7}  # type/t hoisted, privates dropped
        names = [e["args"]["name"] for e in evs if e["ph"] == "M"]
        assert names == ["wall"]
        json.dumps(doc)  # must be valid JSON end to end

    def test_save_chrome_trace_round_trip(self, tmp_path):
        obs.enable()
        with obs.span("s"):
            pass
        path = tmp_path / "trace.json"
        doc = obs.save_chrome_trace(path)
        assert json.loads(path.read_text()) == json.loads(json.dumps(doc))

    def test_nonjson_attrs_stringified(self):
        rec = Recorder(enabled=True)
        with rec.span("s", obj=object(), seq=(1, object())):
            pass
        args = rec.to_chrome_trace()["traceEvents"][0]["args"]
        assert isinstance(args["obj"], str)
        assert args["seq"][0] == 1 and isinstance(args["seq"][1], str)

    def test_chrome_trace_from_serving(self):
        trace = {"schema": "repro.serving/trace-v1", "events": [
            {"type": "submit", "rid": 0, "t": 0.0, "prompt_len": 4},
            {"type": "submit", "rid": 1, "t": 0.1, "prompt_len": 4},
            {"type": "step", "t": 0.2, "dt": 0.05, "active": 2,
             "admitted": [0, 1], "queue_depth": 0},
            {"type": "first_token", "rid": 0, "t": 0.25},
            {"type": "finish", "rid": 0, "t": 0.3},
            {"type": "shed", "rid": 1, "t": 0.35, "cause": "deadline"},
        ]}
        doc = chrome_trace_from_serving(trace)
        assert doc["metadata"]["source_schema"] == "repro.serving/trace-v1"
        by_name = {e["name"]: e for e in doc["traceEvents"]
                   if e["ph"] == "X"}
        assert set(by_name) == {"serve.step", "request.0", "request.1"}
        r0 = by_name["request.0"]
        assert r0["args"]["outcome"] == "finish"
        assert r0["args"]["ttft_s"] == pytest.approx(0.25)
        assert r0["dur"] == pytest.approx(0.3e6)
        assert by_name["request.1"]["args"] == {"outcome": "shed",
                                                "cause": "deadline"}

    def test_unfinished_requests_get_horizon_slices(self):
        trace = {"events": [
            {"type": "submit", "rid": 9, "t": 1.0},
            {"type": "step", "t": 2.0, "dt": 0.1, "active": 1,
             "admitted": [9], "queue_depth": 0},
        ]}
        doc = chrome_trace_from_serving(trace)
        (req,) = [e for e in doc["traceEvents"]
                  if e["ph"] == "X" and e["name"] == "request.9"]
        assert req["args"]["outcome"] == "unfinished"


# -- metrics registry ---------------------------------------------------------

class TestMetrics:
    def test_snapshot_schema_round_trip(self):
        m = MetricsRegistry()
        assert m.counter("a.hits") == 1
        assert m.counter("a.hits", 4) == 5
        m.gauge("a.depth", 3.5)
        for v in (0.1, 0.2, 0.3):
            m.observe("a.dt_s", v)
        snap = json.loads(json.dumps(m.snapshot()))
        assert snap["schema"] == "repro.obs/v1"
        assert snap["counters"] == {"a.hits": 5}
        assert snap["gauges"] == {"a.depth": 3.5}
        h = snap["histograms"]["a.dt_s"]
        assert h["count"] == 3
        assert h["sum"] == pytest.approx(0.6)
        assert h["min"] == 0.1 and h["max"] == 0.3
        assert h["p50"] == pytest.approx(0.2)

    def test_reset_and_delta(self):
        m = MetricsRegistry()
        m.counter("x", 2)
        before = m.snapshot()["counters"]
        m.counter("x", 3)
        m.counter("y")
        assert m.delta_since(before) == {"x": 3, "y": 1}
        m.reset()
        assert m.snapshot() == {"schema": "repro.obs/v1", "counters": {},
                                "gauges": {}, "histograms": {}}

    def test_plan_cache_counters_match_legacy_stats(self):
        from repro.gemm import plan, plan_cache_stats

        plan_cache_stats(reset=True)
        before = obs.metrics.snapshot()["counters"]
        plan((64, 64, 64), dtype="bf16", backend="analytic-tpu")
        plan((64, 64, 64), dtype="bf16", backend="analytic-tpu")  # hit
        legacy = plan_cache_stats()
        delta = obs.metrics.delta_since(before)
        assert delta.get("plan_cache.hits", 0) == legacy["hits"]
        assert delta.get("plan_cache.misses", 0) == legacy["misses"]
        assert legacy["hits"] >= 1 and legacy["misses"] >= 1

    def test_plan_cache_stats_reset_semantics(self):
        # satellite bugfix: back-to-back experiments need per-run numbers
        from repro.gemm import plan, plan_cache_stats

        plan((48, 48, 48), dtype="bf16", backend="analytic-tpu")
        first = plan_cache_stats(reset=True)
        assert first["misses"] >= 1
        zeroed = plan_cache_stats()
        assert zeroed["hits"] == zeroed["misses"] == 0
        assert zeroed["manifest_hits"] == zeroed["deduped"] == 0
        # the cache itself survives a stats reset: replanning hits
        plan((48, 48, 48), dtype="bf16", backend="analytic-tpu")
        assert plan_cache_stats()["hits"] == 1

    def test_sweep_stats_are_deltas_for_all_counters(self):
        # satellite bugfix: manifest_hits was cumulative, not a delta
        from repro.core.mobilenet import TABLE2
        from repro.gemm import plan_cache_stats, sweep

        probs = [row.problem for row in TABLE2[:4]]
        plan_cache_stats(reset=True)
        r1 = sweep(probs, backends=("analytic-gap8",), machines="gap8-fc")
        r2 = sweep(probs, backends=("analytic-gap8",), machines="gap8-fc")
        for key in ("cache_hits", "cache_misses", "manifest_hits",
                    "deduped", "pruned"):
            assert key in r1.stats and key in r2.stats
        # second sweep re-plans the same cells: all hits, no new misses —
        # and crucially its stats are its OWN deltas, not cumulative
        assert r1.stats["cache_misses"] > 0
        assert r2.stats["cache_misses"] == 0
        assert r2.stats["cache_hits"] > 0
        # cumulative == sum of per-sweep deltas, for EVERY counter —
        # manifest_hits used to leak the process-cumulative value
        cum = plan_cache_stats()
        for legacy, delta in (("hits", "cache_hits"),
                              ("misses", "cache_misses"),
                              ("manifest_hits", "manifest_hits"),
                              ("deduped", "deduped")):
            assert cum[legacy] == r1.stats[delta] + r2.stats[delta], legacy

    def test_sweep_metrics_counters(self):
        from repro.core.mobilenet import TABLE2
        from repro.gemm import sweep

        before = obs.metrics.snapshot()["counters"]
        res = sweep([row.problem for row in TABLE2[:3]],
                    backends=("analytic-gap8",), machines="gap8-fc")
        delta = obs.metrics.delta_since(before)
        assert delta["sweep.cells_scored"] == len(res.rows)


# -- drift monitor ------------------------------------------------------------

class TestDrift:
    def test_thresholds_validated(self):
        with pytest.raises(ValueError):
            DriftMonitor(warn_drift=0.3, max_drift=0.2)
        with pytest.raises(ValueError):
            DriftMonitor(warn_drift=0.0)

    def test_ok_warn_stale_transitions(self):
        mon = DriftMonitor(window=8, min_samples=4)
        # too few samples: verdict withheld
        for _ in range(3):
            mon.observe(1.0, 1.5)
        assert mon.status() == "ok"
        mon.observe(1.0, 1.05)
        # median of [1.5 1.5 1.5 1.05] -> warn territory? median=1.5 ->
        # stale; refill with mild drift instead
        mon.reset()
        for _ in range(8):
            mon.observe(1.0, 1.05)
        assert mon.status() == "ok"
        for _ in range(8):  # window=8: fully replaces the ok ratios
            mon.observe(1.0, 1.15)
        assert mon.status() == "warn"
        for _ in range(8):
            mon.observe(1.0, 1.5)
        assert mon.status() == "stale"
        # recovery: the window ages the fault out again
        for _ in range(8):
            mon.observe(1.0, 1.0)
        assert mon.status() == "ok"

    def test_slowdown_and_speedup_both_drift(self):
        mon = DriftMonitor(min_samples=2)
        for _ in range(4):
            mon.observe(1.0, 0.5)  # machine twice as fast as predicted
        assert mon.drift() == pytest.approx(0.5)
        assert mon.status() == "stale"

    def test_degenerate_inputs_ignored(self):
        mon = DriftMonitor()
        assert mon.observe(0.0, 1.0) is None
        assert mon.observe(1.0, -1.0) is None
        assert mon.keys() == []
        assert mon.median_ratio() is None
        assert mon.drift() is None
        assert mon.status() == "ok"

    def test_report_worst_of_keys(self):
        mon = DriftMonitor(min_samples=1)
        mon.observe(1.0, 1.0, key="a@f1")
        mon.observe(1.0, 1.15, key="b@f2")
        rep = mon.report()
        assert rep["schema"] == "repro.obs/drift-v1"
        assert rep["status"] == "warn"
        assert rep["keys"]["a@f1"]["status"] == "ok"
        assert rep["keys"]["b@f2"]["status"] == "warn"
        assert rep["keys"]["b@f2"]["median_ratio"] == pytest.approx(1.15)
        assert rep["warn_drift"] == 0.1 and rep["max_drift"] == 0.2
        json.dumps(rep)

    def test_window_median_matches_statistics(self):
        mon = DriftMonitor(window=4, min_samples=1)
        for m in (1.0, 2.0, 3.0, 4.0, 5.0):  # 1.0 ages out
            mon.observe(1.0, m)
        assert mon.median_ratio() == statistics.median([2.0, 3.0, 4.0, 5.0])

    def test_check_raises_offline_error_type(self):
        from repro.measure.campaign import CalibrationDriftError

        mon = DriftMonitor(min_samples=1)
        mon.observe(1.0, 1.0, key="fine")
        assert mon.check("fine") is None
        mon.observe(1.0, 2.0, key="bad")
        with pytest.raises(CalibrationDriftError) as ei:
            mon.check("bad")
        d = ei.value.as_dict()
        assert d["median_ratio"] == pytest.approx(2.0)
        assert d["max_drift"] == 0.2

    def test_simulator_throttle_flips_drift_stale(self):
        """Acceptance: an injected throttle flips the online verdict while
        the un-faulted control stays ok — the simulator's analytic costs
        make the control ratio exactly 1.0."""
        from repro.configs import get_config
        from repro.simulate import (
            PoissonTraffic,
            ServiceModel,
            simulate_serving,
        )

        cfg = get_config("qwen2-1.5b", smoke=True)
        service = ServiceModel.from_plans(cfg, batch=4, machine="gap9-fc",
                                          dtype="int8")
        kw = dict(max_batch=4, requests=60, deadline_s=5.0,
                  config={"machine": "gap9-fc", "dtype": "int8"})
        traffic = PoissonTraffic(rate=5, prompt_len=16, decode_len=8, seed=0)
        control = simulate_serving(service, traffic, **kw)
        assert control.drift["status"] == "ok"
        assert control.drift["keys"]["gap9-fc"]["median_ratio"] == 1.0
        # a throttle window covering the whole run scales every step, so
        # the median ratio sits at the factor wherever the run ends
        from repro.simulate.faults import FaultScenario, ThrottleWindow
        slow = FaultScenario(name="constant-throttle", throttles=(
            ThrottleWindow(start_s=0.0, duration_s=1e9, factor=1.5),))
        faulted = simulate_serving(service, traffic, faults=slow, **kw)
        assert faulted.drift["status"] == "stale"
        assert faulted.drift["keys"]["gap9-fc"]["median_ratio"] == \
            pytest.approx(1.5)
        # and the verdict round-trips with the report
        doc = json.loads(json.dumps(faulted.to_json()))
        assert doc["drift"]["status"] == "stale"


# -- explain() ----------------------------------------------------------------

class TestExplain:
    def test_table2_fractions_partition_estimate(self):
        """Acceptance: on every Table-2 cell the per-term seconds sum to
        estimate()'s total and the fractions sum to 1."""
        from repro.core.mobilenet import TABLE2
        from repro.gemm import plan

        for row in TABLE2:
            p = plan(row.problem, backend="analytic-gap8",
                     machine="gap8-fc")
            ex = p.explain()
            assert ex["schema"] == "repro.obs/explain-v1"
            assert ex["composition"] == "sum"
            assert sum(t["seconds"] for t in ex["terms"]) == pytest.approx(
                p.estimate().total, rel=1e-9)
            assert sum(t["fraction"] for t in ex["terms"]) == pytest.approx(
                1.0, rel=1e-9)
            assert ex["total_s"] == pytest.approx(ex["sum_s"], rel=1e-9)
            assert ex["terms"] == sorted(ex["terms"],
                                         key=lambda t: -t["seconds"])

    def test_tpu_overlapped_semantics(self):
        from repro.gemm import plan

        p = plan((512, 512, 512), dtype="bf16", backend="analytic-tpu")
        ex = p.explain()
        assert ex["composition"] == "overlapped"
        assert ex["total_s"] == pytest.approx(p.predicted_seconds)
        # fractions still partition the no-overlap sum
        assert sum(t["fraction"] for t in ex["terms"]) == pytest.approx(1.0)
        assert ex["sum_s"] >= ex["total_s"]
        levels = {t["name"]: t["level"] for t in ex["terms"]}
        assert levels == {"compute": "MXU", "stream_hbm": "HBM",
                          "stream_vmem": "VMEM"}
        traffic = [t for t in ex["terms"] if t["kind"] == "traffic"]
        assert all(t["bytes"] > 0 and t["rate"] > 0 for t in traffic)

    def test_tpu_no_overlap_sums_exactly(self):
        from repro.gemm import plan

        p = plan((256, 256, 256), dtype="bf16", backend="analytic-tpu",
                 overlap=False)
        ex = p.explain()
        assert ex["composition"] == "sum"
        assert ex["total_s"] == pytest.approx(ex["sum_s"], rel=1e-9)
        assert ex["total_s"] == pytest.approx(p.predicted_seconds, rel=1e-9)


# -- CLI ----------------------------------------------------------------------

class TestCli:
    def _trace_doc(self):
        return {"schema": "repro.serving/trace-v1", "max_batch": 2,
                "max_len": 64, "predicted_step_s": 0.05, "events": [
                    {"type": "submit", "rid": 0, "t": 0.0, "prompt_len": 4},
                    {"type": "step", "t": 0.1, "dt": 0.05, "active": 1,
                     "admitted": [0], "queue_depth": 0},
                    {"type": "step", "t": 0.2, "dt": 0.055, "active": 1,
                     "admitted": [], "queue_depth": 0},
                    {"type": "finish", "rid": 0, "t": 0.3},
                ]}

    def test_report(self, tmp_path, capsys):
        from repro.obs.__main__ import main

        path = tmp_path / "t.json"
        path.write_text(json.dumps(self._trace_doc()))
        assert main(["report", "--trace", str(path)]) == 0
        out = json.loads(capsys.readouterr().out)
        assert out["schema"] == "repro.obs/report-v1"
        assert out["events_by_type"] == {"submit": 1, "step": 2,
                                         "finish": 1}
        assert out["steps"]["count"] == 2
        assert out["drift"]["schema"] == "repro.obs/drift-v1"

    def test_export(self, tmp_path, capsys):
        from repro.obs.__main__ import main

        src = tmp_path / "t.json"
        out = tmp_path / "chrome.json"
        src.write_text(json.dumps(self._trace_doc()))
        assert main(["export", "--trace", str(src),
                     "--out", str(out)]) == 0
        doc = json.loads(out.read_text())
        assert doc["metadata"]["schema"] == "repro.obs/chrome-trace-v1"
        names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
        assert names == {"serve.step", "request.0"}

    def test_drift_strict_exit_code(self, tmp_path):
        from repro.obs.__main__ import main

        doc = self._trace_doc()
        # steps run 10x the predicted price: stale under any window
        doc["events"] = [
            {"type": "step", "t": 0.1 * i, "dt": 0.5, "active": 1,
             "admitted": [], "queue_depth": 0} for i in range(10)]
        path = tmp_path / "t.json"
        path.write_text(json.dumps(doc))
        assert main(["drift", "--trace", str(path)]) == 0
        assert main(["drift", "--trace", str(path), "--strict"]) == 3

    def test_rejects_non_trace_input(self, tmp_path):
        from repro.obs.__main__ import main

        path = tmp_path / "nope.json"
        path.write_text(json.dumps({"schema": "other"}))
        with pytest.raises(SystemExit):
            main(["report", "--trace", str(path)])
