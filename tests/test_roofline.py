"""Tests for the HLO collective-bytes parser and roofline report."""
import pytest

from repro.core.roofline import (
    RooflineReport,
    collective_bytes,
    cost_analysis_dict,
    from_compiled,
    shape_bytes,
)

HLO = """
HloModule jit_train_step, entry_computation_layout={...}

ENTRY %main (p0: bf16[256,4096,2048]) -> bf16[256,4096,2048] {
  %p0 = bf16[256,4096,2048]{2,1,0} parameter(0)
  %ar = bf16[256,4096,2048]{2,1,0} all-reduce(%p0), replica_groups={{0,1}}, to_apply=%add
  %ag = f32[1024,512]{1,0} all-gather(%x), replica_groups=[256,2]<=[512], dimensions={0}
  %rs = f32[256,512]{1,0} reduce-scatter(%y), replica_groups=[256,2]<=[512], dimensions={0}, to_apply=%add
  %cp = u32[16]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %a2a = bf16[64,64]{1,0} all-to-all(%w), replica_groups={{0,1,2,3}}, dimensions={0}
  %vt = (f32[40,1536]{1,0}, f32[40,1536,32]{2,1,0}) all-reduce(%a, %b), replica_groups=[16,16]<=[16,16]T(1,0), to_apply=%add
  %fusion.1 = bf16[8,8]{1,0} fusion(%q), kind=kLoop, calls=%fused
  ROOT %out = bf16[256,4096,2048]{2,1,0} copy(%ar)
}
"""


def test_shape_bytes():
    assert shape_bytes("bf16", "256,4096,2048") == 256 * 4096 * 2048 * 2
    assert shape_bytes("f32", "512,512") == 512 * 512 * 4
    assert shape_bytes("pred", "8") == 8


def test_collective_bytes_parses_all_ops():
    """Operand bytes derived from result shape x op semantics (XLA dumps
    print operands without types); group size from replica_groups."""
    c = collective_bytes(HLO)
    vt = (40 * 1536 + 40 * 1536 * 32) * 4           # variadic tuple result
    assert c["all-reduce"] == 256 * 4096 * 2048 * 2 + vt
    assert c["all-gather"] == 1024 * 512 * 4 / 2    # result / group(2)
    assert c["reduce-scatter"] == 256 * 512 * 4 * 2  # result * group(2)
    assert c["collective-permute"] == 16 * 4
    assert c["all-to-all"] == 64 * 64 * 2
    assert c["_count"] == 6
    assert c["_total"] == sum(
        c[k] for k in ("all-reduce", "all-gather", "reduce-scatter",
                       "collective-permute", "all-to-all"))


def test_collective_bytes_ignores_non_collectives():
    c = collective_bytes("%x = f32[8,8] dot(f32[8,8] %a, f32[8,8] %b)")
    assert c["_total"] == 0


def test_async_start_done_counted_once():
    hlo = """
  %ars = bf16[1024]{0} all-reduce-start(%p), to_apply=%add
  %ard = bf16[1024]{0} all-reduce-done(%ars)
"""
    c = collective_bytes(hlo)
    assert c["all-reduce"] == 1024 * 2


def test_roofline_report_terms():
    # hlo_* are PER-DEVICE values (cost_analysis on SPMD modules reports the
    # partitioned program; verified in test_cost_analysis_is_per_device).
    r = RooflineReport(
        arch="qwen2-7b", shape_name="train_4k", mesh="pod16x16", chips=256,
        hlo_flops=1e15, hlo_bytes=1e11, coll_bytes=1e10,
        model_flops=128e15, coll_detail={},
    )
    assert r.t_compute == pytest.approx(1e15 / 197e12)
    assert r.t_memory == pytest.approx(1e11 / 819e9)
    assert r.t_collective == pytest.approx(1e10 / 50e9)
    assert r.dominant == "compute"
    assert r.useful_flop_ratio == pytest.approx(128e15 / (1e15 * 256))
    assert 0 < r.roofline_fraction <= 1.0


def test_cost_analysis_is_per_device():
    """Pin the semantics the roofline relies on: XLA cost_analysis of an
    SPMD-partitioned module counts ONE device's program."""
    import jax
    import jax.numpy as jnp
    if len(jax.devices()) < 2:
        pytest.skip("single-device run")
    mesh = jax.make_mesh((len(jax.devices()),), ("d",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = NamedSharding(mesh, P("d", None))
    n = 256 * len(jax.devices())
    a = jax.ShapeDtypeStruct((n, 128), jnp.float32, sharding=sh)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(lambda a, w: a @ w, in_shardings=(sh, None)).lower(a, w).compile()
    flops = cost_analysis_dict(c)["flops"]
    per_dev = 2 * (n // len(jax.devices())) * 128 * 128
    assert flops == pytest.approx(per_dev, rel=0.05)


def test_from_compiled_smoke():
    r = from_compiled("a", "s", "m", 256, {"flops": 1e12, "bytes accessed": 1e9},
                      HLO, model_flops=5e11)
    assert r.coll_bytes > 0
    assert r.hlo_flops == 1e12
