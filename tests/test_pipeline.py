"""Pipeline-parallel correctness: fwd + grads == sequential stack."""
import os

# 8 placeholder devices BEFORE jax init (this file must run in its own
# process group when mixed with single-device tests; pytest-forked not
# available, so we guard on device count instead).
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.pipeline_parallel import pipeline_apply, split_stages

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 4, reason="needs >=4 host devices")


def _setup(n_layers=8, d=16, n_micro=4, mb=2, seed=0):
    rng = np.random.default_rng(seed)
    params = {
        "w": jnp.array(rng.normal(size=(n_layers, d, d)) * 0.2, jnp.float32),
        "b": jnp.array(rng.normal(size=(n_layers, d)) * 0.1, jnp.float32),
    }
    x = jnp.array(rng.normal(size=(n_micro, mb, d)), jnp.float32)
    return params, x


def _block(params, x):
    # one stage = a chunk of layers applied sequentially
    def layer(x, wl):
        return jnp.tanh(x @ wl[0] + wl[1]), None
    y, _ = jax.lax.scan(layer, x, (params["w"], params["b"]))
    return y


def _sequential(params, x_micro):
    def one(x):
        def layer(x, wl):
            return jnp.tanh(x @ wl[0] + wl[1]), None
        y, _ = jax.lax.scan(layer, x, (params["w"], params["b"]))
        return y
    return jax.vmap(one)(x_micro)


def test_pipeline_forward_matches_sequential():
    n_stages = 4
    mesh = jax.make_mesh((n_stages,), ("pod",))
    params, x = _setup()
    staged = split_stages(params, n_stages)
    got = pipeline_apply(_block, staged, x, mesh=mesh, axis="pod")
    want = _sequential(params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_pipeline_grads_match_sequential():
    n_stages = 4
    mesh = jax.make_mesh((n_stages,), ("pod",))
    params, x = _setup()

    def loss_pipe(p):
        staged = split_stages(p, n_stages)
        y = pipeline_apply(_block, staged, x, mesh=mesh, axis="pod")
        return jnp.sum(jnp.square(y))

    def loss_seq(p):
        return jnp.sum(jnp.square(_sequential(p, x)))

    g1 = jax.grad(loss_pipe)(params)
    g2 = jax.grad(loss_seq)(params)
    for k in params:
        np.testing.assert_allclose(np.asarray(g1[k]), np.asarray(g2[k]),
                                   rtol=1e-4, atol=1e-5), k


def test_pipeline_two_stages():
    mesh = jax.make_mesh((2,), ("pod",))
    params, x = _setup(n_layers=6, n_micro=3)
    staged = split_stages(params, 2)
    got = pipeline_apply(_block, staged, x, mesh=mesh, axis="pod")
    want = _sequential(params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
