"""The measurement & model-validation subsystem (ISSUE 4).

Acceptance: simulator-generated times pushed through the ``measure``
store→fit→validate loop recover every exercised rate to <1% and report
≈0 MAPE; the host-numpy harness replays plans as blocked loop nests and a
real smoke campaign fits and validates end to end; the per-micro-kernel
arithmetic table (paper §4) round-trips the manifest schema, refines the
batched GAP8 engine, and is recoverable by the closed loop.
"""
import dataclasses
import json
import math

import numpy as np
import pytest

from repro import gemm, machines, measure
from repro.core.mobilenet import TABLE2
from repro.core.simulator import (
    best_microkernel_batch,
    search_batch,
    simulate,
)
from repro.core.variants import MicroKernel, Variant
from repro.machines import MachineSpec, SpecValidationError


@pytest.fixture(autouse=True)
def _clean_registry():
    before = set(machines.list_machines())
    yield
    for name in set(machines.list_machines()) - before:
        machines.unregister(name)
    machines.load_zoo()


def _store(tmp_path, name="samples.jsonl") -> measure.SampleStore:
    return measure.SampleStore(str(tmp_path / name))


# ---------------------------------------------------------------------------
# Timing harness
# ---------------------------------------------------------------------------


def test_time_callable_warms_up_and_aggregates():
    calls = []
    res = measure.time_callable(lambda: calls.append(1), warmup=2, rounds=3)
    assert res.rounds >= 3
    assert res.calls == res.rounds                   # 1 call per round
    assert len(calls) == res.calls + 2               # + the 2 warmup calls
    assert res.seconds > 0
    assert res.seconds == pytest.approx(
        sorted(res.round_minima)[len(res.round_minima) // 2], rel=0.5)
    assert res.rounds <= 10                          # bounded even if noisy


def test_time_callable_repeats_until_stable():
    # zero tolerance: noop timings never agree exactly, so the stability
    # loop must add rounds and stop at the max_rounds bound
    res = measure.time_callable(lambda: None, rounds=2, max_rounds=4,
                                stable_rel=0.0)
    assert 2 <= res.rounds <= 4


def test_core_calibrate_time_delegates_to_harness():
    from repro.core.calibrate import _time
    calls = []
    t = _time(lambda: calls.append(1), reps=3)
    assert t > 0
    assert len(calls) >= 4        # 3 rounds + at least the 1 warmup call


def test_blocked_loop_nest_matches_reference():
    rng = np.random.default_rng(0)
    a = rng.standard_normal((37, 23)).astype(np.float32)
    b = rng.standard_normal((23, 41)).astype(np.float32)
    for order in ("jpi", "jip", "pji"):
        c = np.zeros((37, 41), np.float32)
        out = measure.blocked_loop_nest(a, b, c, 16, 12, 8, order)
        np.testing.assert_allclose(out, a @ b, rtol=1e-3, atol=1e-4)
    with pytest.raises(ValueError, match="permute"):
        measure.blocked_loop_nest(a, b, np.zeros((37, 41), np.float32),
                                  16, 12, 8, "jjj")


def test_plan_loop_order_follows_selection():
    """The host replay nests its loops the way the plan's selection says:
    C3B2A0 iterates p innermost, the B3 variants iterate i innermost, tile
    plans follow the grid order."""
    assert measure.plan_loop_order(
        gemm.plan((64, 96, 48), backend="analytic-gap8",
                  variant="B3A2C0", cache=False)) == "jpi"
    assert measure.plan_loop_order(
        gemm.plan((64, 96, 48), backend="analytic-gap8",
                  variant="C3B2A0", cache=False)) == "jip"
    assert measure.plan_loop_order(
        gemm.plan((64, 96, 48), backend="analytic-gap8",
                  variant="B3C2A0", cache=False)) == "jpi"
    tile_plan = gemm.plan((256, 512, 128), backend="analytic-tpu",
                          cache=False)
    want = "jip" if tile_plan.selection.order.value == "k_inner" else "pji"
    assert measure.plan_loop_order(tile_plan) == want
    assert measure.plan_loop_order(
        gemm.plan((64, 96, 48), backend="reference", cache=False)) == "jpi"


def test_host_numpy_harness_measures_plan():
    plan = gemm.plan((64, 96, 48), backend="analytic-gap8",
                     machine="host-cpu", dtype="f32", cache=False)
    h = measure.get_harness("host-numpy")
    res = h.measure(plan, timing={"warmup": 0, "rounds": 1})
    assert res.seconds > 0 and res.rounds >= 1


def test_plan_blocking_dims_views():
    gp = gemm.plan((64, 96, 48), backend="analytic-gap8", cache=False)
    bd = gp.blocking_dims()
    blk = gp.selection.blocking
    assert bd == (blk.m_c, blk.n_c, blk.k_c)
    tp = gemm.plan((256, 512, 128), backend="analytic-tpu", cache=False)
    t = tp.selection
    assert tp.blocking_dims() == (t.bm, t.bn, t.bk)
    rp = gemm.plan((64, 96, 48), backend="reference", cache=False)
    assert rp.blocking_dims() == (64, 96, 48)


def test_get_harness_unknown_and_simulated_requires_truth(tmp_path):
    with pytest.raises(KeyError, match="unknown timing harness"):
        measure.get_harness("cuda")
    with pytest.raises(ValueError, match="truth"):
        measure.run_campaign("smoke", harness="simulated",
                             store=_store(tmp_path))


def test_campaign_rejects_unsupported_dtype_early():
    """smoke defaults to f32; an int8-only machine must fail with a clear
    pointer to dtype=, not a KeyError deep inside planning."""
    with pytest.raises(ValueError, match="no arith_rate entry.*dtype"):
        measure.run_campaign("smoke", machine="gap8-fc",
                             harness="simulated", truth="gap8-fc")


def test_campaign_rejects_dtype_the_harness_cannot_materialise():
    """A harness declares which dtypes it can build operands for; the
    campaign must refuse up front, not KeyError mid-measurement."""

    class Int8Only(measure.Harness):
        name = "int8-only"
        supported_dtypes = frozenset({"int8"})

    with pytest.raises(ValueError, match="int8-only harness cannot"):
        measure.run_campaign("smoke", machine="host-cpu",
                             harness=Int8Only())   # smoke defaults to f32
    assert measure.get_harness("host-numpy").supported_dtypes == \
        {"int8", "bf16", "f32"}


def test_campaign_problem_override_is_not_stamped_with_grid(tmp_path):
    store = _store(tmp_path)
    res = measure.run_campaign("table2", machine="gap8-fc",
                               harness="simulated", truth="gap8-fc",
                               dtype="int8", store=store,
                               problems=[(100, 100, 100)])
    assert res.grid == "custom"
    assert all(s.meta["grid"] == "custom" for s in res.samples)


# ---------------------------------------------------------------------------
# Sample store
# ---------------------------------------------------------------------------


def _mk_sample(spec, seconds=1.0, **over):
    d = dict(m=64, n=96, k=48, dtype="int8", seconds=seconds,
             harness="simulated", machine=spec.name,
             machine_fingerprint=spec.geometry_fingerprint(),
             variant="B3A2C0", micro_kernel="4x24")
    d.update(over)
    return measure.Sample(**d)


def test_sample_store_roundtrip(tmp_path):
    spec = machines.get("gap8-fc")
    store = _store(tmp_path)
    wrote = [_mk_sample(spec, seconds=float(i + 1),
                        micro_kernel=f"{4 * (i + 1)}x4",
                        meta={"grid": "smoke"}) for i in range(3)]
    assert store.extend(wrote) == 3
    got = list(store)
    assert got == wrote
    assert len(store) == 3
    assert store.samples(micro_kernel="4x4") == [wrote[0]]
    # appending is non-destructive
    store.append(_mk_sample(spec, seconds=9.0))
    assert list(store)[:3] == wrote


def test_sample_store_rejects_fingerprint_mismatch(tmp_path):
    spec = machines.get("gap8-fc")
    store = _store(tmp_path)
    store.append(_mk_sample(spec))
    # same name, different geometry: the spec changed since the campaign
    changed = spec.with_capacities(spec.name, L1=64 * 1024)
    assert changed.name == spec.name
    assert changed.geometry_fingerprint() != spec.geometry_fingerprint()
    with pytest.raises(measure.StaleSampleError, match="different geometry"):
        store.for_machine(changed)
    assert store.for_machine(changed, allow_stale=True) == []
    # unrelated machines are ignored, not stale
    assert store.for_machine(machines.get("gap9-fc")) == []
    # the matching spec still reads its samples (rates don't matter)
    refit = spec.scaled(arith=2.0, name=spec.name)
    assert len(store.for_machine(refit)) == 1


def test_sample_store_lineage_excludes_same_geometry_ablations(tmp_path):
    """A rates-only ablation shares its base's geometry; its samples must
    still be invisible to the base (and vice versa) — only the calibration
    lineage (own name, or the fit's template) may supply samples."""
    base = machines.get("tpu-v5e")
    half = machines.get("tpu-v5e-bw-half")
    assert base.geometry_fingerprint() == half.geometry_fingerprint()
    store = _store(tmp_path)
    store.append(_mk_sample(base, machine=base.name))
    assert store.for_machine(half) == []          # not half's lineage
    assert len(store.for_machine(base)) == 1
    # a spec *fitted from* the sampled template reads them via provenance
    gap8 = machines.get("gap8-fc")
    store2 = _store(tmp_path, "lineage.jsonl")
    store2.append(_mk_sample(gap8))
    fitted = dataclasses.replace(
        gap8, name="gap8-fit-lineage",
        provenance={"base": "gap8-fc", "fit": {"samples": 1}})
    assert len(store2.for_machine(fitted)) == 1
    # ...but a transform-derived spec does not inherit them
    derived = gap8.scaled(arith=2.0, name="gap8-derived-lineage")
    assert derived.provenance["base"] == "gap8-fc"
    assert store2.for_machine(derived) == []


def test_sample_store_rejects_bad_schema(tmp_path):
    store = _store(tmp_path)
    store.append(_mk_sample(machines.get("gap8-fc")))
    with open(store.path, "a") as f:
        f.write(json.dumps({"schema": "other/v9", "m": 1}) + "\n")
    with pytest.raises(ValueError, match="bad sample record"):
        list(store)


# ---------------------------------------------------------------------------
# Closed loop (acceptance): simulator times -> store -> fit -> validate
# ---------------------------------------------------------------------------


def _seed_template(truth, name):
    """Same geometry as truth, deliberately wrong rates."""
    t = truth.scaled(arith=3.0, bw=0.4, name=name)
    assert t.geometry_fingerprint() == truth.geometry_fingerprint()
    return t


def test_closed_loop_recovers_rates_and_zero_mape(tmp_path):
    truth = machines.get("gap8-fc")
    template = _seed_template(truth, "gap8-seed")
    store = _store(tmp_path)
    res = measure.run_campaign("table2", machine=template,
                               harness="simulated", truth=truth,
                               store=store)
    assert len(res.samples) == len(TABLE2) * len(measure.DEFAULT_FIT_MKS)
    assert res.harness == "simulated"

    spec, report = measure.fit_from_store(store, template,
                                          name="gap8-recovered", date=None)
    # every rate the campaign exercised comes back to <1% (in fact ~1e-12)
    assert not report.dropped
    for col in report.columns:
        if col.startswith("rate:"):
            o, _, d = col[len("rate:"):].partition("->")
            assert spec.transfer_rates[(o, d)] == pytest.approx(
                truth.transfer_rates[(o, d)], rel=1e-2)
        else:
            assert spec.arith_rate[col[len("arith:"):]] == pytest.approx(
                truth.arith_rate[col[len("arith:"):]], rel=1e-2)

    val = measure.validate_spec(spec, store)
    assert val.mape == pytest.approx(0.0, abs=1e-6)
    assert val.finite
    assert len(val.rows) == len(res.samples)
    # the wrong-rate template, validated against the same store, is way off
    bad = measure.validate_spec(template, store)
    assert bad.mape > 50.0


def test_closed_loop_recovers_per_mk_arith_table(tmp_path):
    """Paper §4's refinement round-trips: per-micro-kernel truth rates are
    recovered by the per-mk fit (padded policy — under the analytic policy
    the system is provably rank-deficient, see design_matrix)."""
    base = machines.get("gap8-fc")
    table = {"int8": {"4x24": 6.2e9, "8x12": 5.1e9,
                      "12x8": 4.4e9, "16x4": 3.3e9}}
    truth = dataclasses.replace(base, name="gap8-permk-truth",
                                arith_per_mk=table).validate()
    template = _seed_template(truth, "gap8-permk-seed")
    store = _store(tmp_path)
    measure.run_campaign("table2", machine=template, harness="simulated",
                         truth=truth, policy="padded", store=store)
    spec, report = measure.fit_from_store(
        store, template, name="gap8-permk-fit", date=None, per_mk_arith=True)
    for mk, want in table["int8"].items():
        assert spec.arith_per_mk["int8"][mk] == pytest.approx(want, rel=1e-2)
    val = measure.validate_spec(spec, store)
    assert val.mape == pytest.approx(0.0, abs=1e-6)
    # the analytic-policy per-mk system is rank-deficient and refuses
    store2 = _store(tmp_path, "analytic.jsonl")
    measure.run_campaign("smoke", machine=template, harness="simulated",
                         truth=truth, dtype="int8", store=store2)
    with pytest.raises(ValueError, match="rank-deficient"):
        measure.fit_from_store(store2, template, date=None,
                               per_mk_arith=True)


def test_fit_drop_nonpositive_keeps_template_rate(tmp_path):
    """Measured times inconsistent with one traffic term: the default fit
    refuses; on_nonpositive='drop' eliminates the column and keeps the
    template's rate for it, recording the drop in provenance."""
    truth = machines.get("gap8-fc")
    template = _seed_template(truth, "gap8-drop-seed")
    store = _store(tmp_path)

    probs = [r.problem for r in TABLE2[:8]]
    mks = [MicroKernel(*mk) for mk in measure.DEFAULT_FIT_MKS] * 2
    for p, mk in zip(probs, mks):
        cb = simulate(truth, Variant.B3A2C0, mk, p)
        # subtract pack_A twice: the implied M->L2 inverse rate is negative
        seconds = cb.total - 2.0 * cb.components["pack_A"]
        plan = gemm.plan(p, backend="analytic-gap8", machine=template,
                         variant=Variant.B3A2C0, micro_kernel=mk,
                         cache=False)
        t = measure.TimingResult(seconds=seconds, rounds=1, calls=1,
                                 spread=0.0, round_minima=(seconds,))
        store.append(measure.Sample.from_measurement(plan, t, "simulated",
                                                     template))
    with pytest.raises(ValueError, match="non-positive"):
        measure.fit_from_store(store, template, date=None,
                               weighting="absolute")
    spec, report = measure.fit_from_store(store, template, date=None,
                                          weighting="absolute",
                                          on_nonpositive="drop")
    assert "rate:M->L2" in report.dropped
    assert spec.transfer_rates[("M", "L2")] == \
        template.transfer_rates[("M", "L2")]
    # every emitted rate is positive and the spec still validates/simulates
    assert all(r > 0 for r in spec.transfer_rates.values())
    assert measure.validate_spec(spec, store).finite
    assert "dropped_columns" in spec.provenance["fit"]
    # 'free' marks the term costless instead of keeping the template rate
    from repro.machines.calibrate import FREE_RATE
    spec_f, rep_f = measure.fit_from_store(store, template, date=None,
                                           weighting="absolute",
                                           on_nonpositive="free")
    assert "rate:M->L2" in rep_f.dropped
    assert spec_f.transfer_rates[("M", "L2")] == FREE_RATE
    assert spec_f.provenance["fit"]["nonpositive_policy"] == "free"
    # the recorded residual describes the *emitted* spec: predicting the
    # samples with each fitted spec reproduces its report's RMS
    for s, r in ((spec, report), (spec_f, rep_f)):
        preds = measure.predict_samples(s, list(store))
        meas = [smp.seconds for smp in store]
        rms = float(np.sqrt(np.mean((np.array(preds) - np.array(meas)) ** 2)))
        assert rms == pytest.approx(r.residual_rms_s, rel=1e-6)


def test_measure_host_sheds_template_per_mk_table(monkeypatch):
    from repro.core import calibrate as cal_mod
    monkeypatch.setattr(cal_mod, "measure_packing_rate", lambda c: 2.0e9)
    monkeypatch.setattr(cal_mod, "measure_copy_rate", lambda: 8.0e9)
    monkeypatch.setattr(cal_mod, "measure_arith_rate", lambda: 5.0e10)
    stale = dataclasses.replace(
        machines.get("host-cpu"), name="host-cpu",
        arith_per_mk={"f32": {"4x24": 1.0e9}})
    machines.register(stale, overwrite=True)
    spec = machines.Calibrator.measure_host("host-shed-test")
    assert spec.arith_per_mk == {}
    assert spec.arith_rate_for("f32", MicroKernel(4, 24)) == 5.0e10


def test_fit_from_store_rejects_mixed_axes(tmp_path):
    spec = machines.get("gap8-fc")
    store = _store(tmp_path)
    store.append(_mk_sample(spec, variant="B3A2C0"))
    store.append(_mk_sample(spec, variant="C3B2A0"))
    with pytest.raises(ValueError, match="span variants"):
        measure.fit_from_store(store, spec, date=None)
    empty = _store(tmp_path, "empty.jsonl")
    with pytest.raises(ValueError, match="no BLIS-model samples"):
        measure.fit_from_store(empty, spec, date=None)


# ---------------------------------------------------------------------------
# Validation-report math
# ---------------------------------------------------------------------------


def test_validation_report_math(tmp_path):
    """Hand-built measurements at known offsets from the prediction: the
    per-cell errors, MAPE, worst cell and breakdowns are exact."""
    spec = machines.get("gap8-fc")
    prob = TABLE2[9].problem
    offsets = {"4x24": 1.25, "8x12": 1.0, "12x8": 0.8}
    samples = []
    for mk_s, factor in offsets.items():
        mk = MicroKernel(*map(int, mk_s.split("x")))
        pred = simulate(spec, Variant.B3A2C0, mk, prob).total
        samples.append(_mk_sample(spec, seconds=pred * factor,
                                  m=prob.m, n=prob.n, k=prob.k,
                                  micro_kernel=mk_s))
    val = measure.validate_spec(spec, samples)
    by_mk = {r.sample.micro_kernel: r for r in val.rows}
    assert by_mk["4x24"].rel_err == pytest.approx(1 / 1.25 - 1)
    assert by_mk["8x12"].ape == pytest.approx(0.0, abs=1e-12)
    assert by_mk["12x8"].rel_err == pytest.approx(0.25)
    assert val.mape == pytest.approx(100 * (0.2 + 0.0 + 0.25) / 3)
    assert val.worst.sample.micro_kernel == "12x8"
    assert val.median_ape == pytest.approx(20.0)
    bd = val.per_micro_kernel()
    assert set(bd) == set(offsets)
    assert bd["12x8"]["bias_pct"] == pytest.approx(25.0)
    assert val.per_dtype()["int8"]["cells"] == 3
    # persisted JSON round-trips to the same numbers
    path = str(tmp_path / "report.json")
    val.save(path)
    loaded = measure.ValidationReport.load(path)
    assert loaded.mape == pytest.approx(val.mape)
    assert loaded.worst.sample.cell == val.worst.sample.cell


def test_validation_respects_fingerprint_guard(tmp_path):
    spec = machines.get("gap8-fc")
    store = _store(tmp_path)
    store.append(_mk_sample(spec, seconds=1.0))
    changed = spec.with_capacities(spec.name, L2=1024)
    with pytest.raises(measure.StaleSampleError):
        measure.validate_spec(changed, store)


# ---------------------------------------------------------------------------
# arith_per_mk schema + engine consumption
# ---------------------------------------------------------------------------


def _with_table(spec, name="gap8-mk-table"):
    return dataclasses.replace(
        spec, name=name,
        arith_per_mk={"int8": {"8x12": 2.0 * spec.arith_rate["int8"]}})


def test_arith_per_mk_roundtrips_manifest(tmp_path):
    spec = _with_table(machines.get("gap8-fc")).validate()
    assert MachineSpec.from_json(spec.to_json()) == spec
    path = spec.to_manifest(str(tmp_path / "mk.json"))
    assert MachineSpec.from_manifest(path).arith_per_mk == spec.arith_per_mk
    # absent table stays absent in the manifest (bit-stable zoo files)
    assert "arith_per_mk" not in machines.get("gap8-fc").to_json()


def test_arith_per_mk_validation():
    base = machines.get("gap8-fc")
    bad_mk = dataclasses.replace(base, arith_per_mk={"int8": {"8by12": 1.0}})
    with pytest.raises(SpecValidationError, match="micro-kernel key"):
        bad_mk.validate()
    bad_dt = dataclasses.replace(base, arith_per_mk={"int4": {"8x12": 1e9}})
    with pytest.raises(SpecValidationError, match="fallback"):
        bad_dt.validate()
    bad_rate = dataclasses.replace(base,
                                   arith_per_mk={"int8": {"8x12": -1.0}})
    with pytest.raises(SpecValidationError, match="positive finite"):
        bad_rate.validate()
    empty = dataclasses.replace(base, arith_per_mk={"int8": {}})
    with pytest.raises(SpecValidationError, match="empty"):
        empty.validate()


def test_arith_per_mk_absent_table_is_bit_identical():
    base = machines.get("gap8-fc")
    probs = [r.problem for r in TABLE2]
    with_empty = dataclasses.replace(base, arith_per_mk={})
    a = search_batch(base, probs)
    b = search_batch(with_empty, probs)
    for x, y in zip(a, b):
        assert x.total == y.total and x.micro_kernel == y.micro_kernel


def test_arith_per_mk_refines_simulation_and_selection():
    base = machines.get("gap8-fc")
    spec = _with_table(base)
    prob = TABLE2[9].problem
    mk = MicroKernel(8, 12)
    got = simulate(spec, Variant.B3A2C0, mk, prob)
    want = simulate(base, Variant.B3A2C0, mk, prob)
    assert got.arith == pytest.approx(want.arith / 2.0)
    # untabled micro-kernels fall back to the shared rate
    other = simulate(spec, Variant.B3A2C0, MicroKernel(4, 24), prob)
    assert other.arith == simulate(base, Variant.B3A2C0,
                                   MicroKernel(4, 24), prob).arith
    # the batched engine consumes the table identically to the scalar path
    batch = best_microkernel_batch(spec, Variant.B3A2C0, [prob])
    scal = min((simulate(spec, Variant.B3A2C0, m, prob)
                for m in (MicroKernel(4, 24), MicroKernel(8, 12),
                          MicroKernel(12, 8))),
               key=lambda cb: cb.total)
    assert batch[0].total <= scal.total
    assert batch[0].arith == simulate(spec, Variant.B3A2C0,
                                      batch[0].micro_kernel, prob).arith
    # on an arithmetic-bound machine a per-mk advantage flips the selection
    fast = base.scaled(bw=1e6, name="gap8-arith-bound")
    boosted = dataclasses.replace(
        fast, name="gap8-mk-boost",
        arith_per_mk={"int8": {"8x12": 2.0 * base.arith_rate["int8"]}})
    assert best_microkernel_batch(
        fast, Variant.B3A2C0, [prob])[0].micro_kernel != MicroKernel(8, 12)
    assert best_microkernel_batch(
        boosted, Variant.B3A2C0, [prob])[0].micro_kernel == MicroKernel(8, 12)


def test_shared_arith_refit_sheds_stale_per_mk_table(tmp_path):
    """A shared-rate refit supersedes any per-mk table the template carried
    for that dtype — the fitted spec must not predict through stale per-mk
    rates the solve never saw."""
    base = machines.get("gap8-fc")
    template = dataclasses.replace(
        _seed_template(base, "gap8-stale-seed"),
        arith_per_mk={"int8": {"4x24": base.arith_rate["int8"]}})
    store = _store(tmp_path)
    measure.run_campaign("table2", machine=template, harness="simulated",
                         truth=base.scaled(arith=2.0, name="gap8-2x"),
                         store=store)
    spec, _ = measure.fit_from_store(store, template, name="gap8-shed",
                                     date=None)
    assert "int8" not in spec.arith_per_mk
    assert spec.arith_rate_for("int8", MicroKernel(4, 24)) == \
        spec.arith_rate["int8"]
    assert measure.validate_spec(spec, store).mape == \
        pytest.approx(0.0, abs=1e-6)


def test_with_dtype_rates_override_sheds_per_mk_entries():
    spec = _with_table(machines.get("gap8-fc"))
    over = spec.with_dtype_rates(int8=2.0 * spec.arith_rate["int8"],
                                 name="gap8-mk-override")
    assert "int8" not in over.arith_per_mk
    assert over.arith_rate_for("int8", MicroKernel(8, 12)) == \
        2.0 * spec.arith_rate["int8"]
    # untouched dtypes keep their refinement
    multi = dataclasses.replace(
        spec, arith_rate={**spec.arith_rate, "f32": 1e9},
        arith_per_mk={**spec.arith_per_mk, "f32": {"4x24": 2e9}})
    kept = multi.with_dtype_rates(int8=1e9, name="gap8-mk-keep")
    assert kept.arith_per_mk == {"f32": {"4x24": 2e9}}


def test_calibrator_per_mk_design_matrix_batch_equals_scalar():
    cal = machines.Calibrator("gap8-fc", policy="padded")
    rng = np.random.default_rng(7)
    probs = [(int(m), int(n), int(k)) for m, n, k in
             zip(rng.integers(16, 2048, 12), rng.integers(16, 2048, 12),
                 rng.integers(16, 4096, 12))]
    mks = [MicroKernel(*measure.DEFAULT_FIT_MKS[i % 4]) for i in range(12)]
    A, cols = cal.design_matrix(probs, mks, per_mk_arith=True)
    B, cols2 = cal.design_matrix_scalar(probs, mks, per_mk_arith=True)
    assert cols == cols2
    assert np.array_equal(A, B)
    assert sum(c.startswith("arith:int8@") for c in cols) == 4


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_cli_closed_loop(tmp_path, capsys):
    from repro.measure.__main__ import main

    store = str(tmp_path / "cli.jsonl")
    assert main(["run", "--grid", "smoke", "--backend", "simulated",
                 "--truth", "gap8-fc", "--machine", "gap8-fc",
                 "--dtype", "int8", "--store", store]) == 0
    assert "24 samples via simulated" in capsys.readouterr().out
    assert main(["fit", "--store", store, "--template", "gap8-fc",
                 "--name", "gap8-cli-fit", "--out", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "fitted gap8-cli-fit" in out and "rate:M->L2" in out
    manifest = str(tmp_path / "gap8-cli-fit.json")
    report_path = str(tmp_path / "report.json")
    assert main(["validate", "--store", store, "--machine", manifest,
                 "--json", report_path]) == 0
    out = capsys.readouterr().out
    assert "MAPE 0.00%" in out
    assert main(["report", "--json", report_path, "--limit", "2"]) == 0
    assert "mape_pct" in capsys.readouterr().out


def test_machines_calibrate_cli_runs_full_fit(tmp_path, capsys,
                                              monkeypatch):
    """`python -m repro.machines calibrate --grid ...` is the whole loop:
    micro-experiment seed -> host-numpy campaign -> rate fit -> report."""
    from repro.core import calibrate as cal_mod
    from repro.machines.__main__ import main

    monkeypatch.setattr(cal_mod, "measure_packing_rate", lambda c: 2.0e9)
    monkeypatch.setattr(cal_mod, "measure_copy_rate", lambda: 8.0e9)
    monkeypatch.setattr(cal_mod, "measure_arith_rate", lambda: 5.0e10)
    store = str(tmp_path / "calib.jsonl")
    assert main(["calibrate", "--name", "host-cli-fit", "--grid", "smoke",
                 "--store", store, "--out", str(tmp_path),
                 "--date", "2026-07-27"]) == 0
    out = capsys.readouterr().out
    assert "measured 24 samples" in out and "validation MAPE" in out
    fitted = machines.get("host-cli-fit")
    assert machines.source_of("host-cli-fit") == "calibrated"
    assert fitted.provenance["fit"]["samples"] == 24
    assert len(measure.SampleStore(store)) == 24
    # the persisted manifest is the fitted spec
    persisted = MachineSpec.from_manifest(str(tmp_path /
                                              "host-cli-fit.json"))
    assert persisted == fitted


def test_cli_host_smoke_run(tmp_path, capsys):
    from repro.measure.__main__ import main

    store = str(tmp_path / "host.jsonl")
    assert main(["run", "--grid", "smoke", "--backend", "host-numpy",
                 "--machine", "host-cpu", "--store", store,
                 "--rounds", "1", "--warmup", "0",
                 "--mks", "4x24,8x12"]) == 0
    samples = list(measure.SampleStore(store))
    assert len(samples) == 12                 # 6 smoke shapes x 2 mks
    assert all(s.seconds > 0 and s.harness == "host-numpy"
               for s in samples)
    assert {s.micro_kernel for s in samples} == {"4x24", "8x12"}
    # a validation of the template against real host samples is finite
    val = measure.validate_spec("host-cpu", store)
    assert val.finite and math.isfinite(val.worst.ape)
